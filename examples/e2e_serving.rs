//! END-TO-END DRIVER: the full three-layer system on a real workload.
//!
//! Proves all layers compose: the Pallas kernels (L1) lowered through the
//! JAX payloads (L2) into HLO-text artifacts, loaded and executed by the
//! PJRT runtime inside real worker threads, coordinated by the Hiku
//! pull-based scheduler (L3) under the k6-like closed-loop workload —
//! Python nowhere on the request path.
//!
//! Serves a batch of requests per scheduler and reports latency,
//! throughput, cold-start rate and per-worker load — the paper's metrics,
//! on real compute. Results recorded in EXPERIMENTS.md §E2E.
//!
//! Requires `make artifacts`. Run:
//!   cargo run --release --example e2e_serving [-- --requests 200]

use hiku::config::Config;
use hiku::server::serve_n_requests;
use hiku::util::cli::Cli;

fn main() {
    let cli = Cli::new("e2e_serving", "real PJRT serving, scheduler comparison")
        .opt("requests", Some("200"), "requests per scheduler")
        .opt("workers", Some("3"), "worker threads")
        .opt("vus", Some("8"), "virtual users")
        .opt("schedulers", Some("hiku,ch-bl,random,least-connections"), "schedulers");
    let args = cli.parse_env();
    let requests = args.parse_usize("requests").unwrap();
    let workers = args.parse_usize("workers").unwrap();
    let vus = args.parse_usize("vus").unwrap();

    println!(
        "# End-to-end serving: {requests} requests, {workers} PJRT workers, {vus} VUs (real compute)"
    );
    println!(
        "{:<20} {:>9} {:>9} {:>9} {:>7} {:>8} {:>8}",
        "scheduler", "mean(ms)", "p95(ms)", "p99(ms)", "cold%", "rps", "CV"
    );

    for sched in args.parse_list("schedulers") {
        let mut cfg = Config::default();
        cfg.scheduler.name = sched.clone();
        cfg.cluster.workers = workers;
        cfg.workload.vus = vus;
        // Wall-clock run: compress think times (scales the paper's
        // 0.1-1 s down by 20x; the closed-loop structure is unchanged).
        cfg.workload.think_min_s = 0.005;
        cfg.workload.think_max_s = 0.05;
        // Tight executable caches so eviction/cold-start dynamics appear
        // at demo scale: 4 of 8 payloads warm per worker.
        cfg.cluster.mem_mb = 1024;

        match serve_n_requests(&cfg, requests) {
            Ok(mut m) => {
                println!(
                    "{:<20} {:>9.1} {:>9.1} {:>9.1} {:>6.1}% {:>8.1} {:>8.3}",
                    sched,
                    m.mean_latency_ms(),
                    m.latency_percentile_ms(95.0),
                    m.latency_percentile_ms(99.0),
                    m.cold_rate() * 100.0,
                    m.rps(),
                    m.mean_cv(),
                );
            }
            Err(e) => {
                eprintln!("{sched}: {e}");
                std::process::exit(1);
            }
        }
    }
    println!("\n(cold start = real XLA compilation of the AOT artifact on the worker)");
}
