//! The paper's full evaluation (§V-B, Figs 10-17), regenerated.
//!
//! Runs the 4-scheduler x {20,50,100}-VU sweep with the paper's run count
//! and prints, per figure, our measurement next to the paper's reported
//! number. The default (5 runs x 120 s) finishes in seconds on the DES;
//! pass `--runs 20 --duration 300` for the paper's exact protocol.
//!
//! Run: cargo run --release --example evaluation [-- --fig 13] [--runs 20]

use hiku::config::Config;
use hiku::report::run_cell;
use hiku::stats::Samples;
use hiku::util::cli::Cli;

const SCHEDS: [&str; 4] = ["hiku", "ch-bl", "random", "least-connections"];

struct Cell {
    sched: &'static str,
    mean_ms: f64,
    p90: f64,
    p95: f64,
    p99: f64,
    cold: f64,
    cv: f64,
    completed: f64,
    cdf: Vec<(f64, f64)>,
    cumulative: Vec<f64>,
    cv_series: Vec<f64>,
}

fn main() {
    let cli = Cli::new("evaluation", "reproduce Figs 10-17")
        .opt("fig", Some("all"), "figure to print: 10|11|12|13|14|15|16|17|all")
        .opt("runs", Some("5"), "seeded runs per scheduler (paper: 20)")
        .opt("duration", Some("120"), "seconds per run (paper: 300)")
        .opt("seed", Some("42"), "base experiment seed");
    let args = cli.parse_env();
    let fig = args.get_or("fig", "all").to_string();
    let runs = args.parse_u64("runs").unwrap();
    let duration = args.parse_f64("duration").unwrap();
    let seed = args.parse_u64("seed").unwrap();

    let mut base = Config::default();
    base.workload.duration_s = duration;
    base.workload.seed = seed;

    eprintln!(
        "running sweep: {} schedulers x 100 VUs x {runs} runs x {duration}s ...",
        SCHEDS.len()
    );
    // Main cells at 100 VUs (the paper's headline concurrency).
    let cells: Vec<Cell> = SCHEDS
        .iter()
        .map(|s| {
            let (agg, mut all) = run_cell(&base, s, 100, runs).expect("sweep");
            let mut pooled = Samples::new();
            for m in &mut all {
                for &v in m.latency_ms.values() {
                    pooled.push(v);
                }
            }
            // Mean cumulative-throughput curve + CV series from run 0.
            let cumulative = all[0].throughput.cumulative();
            let cv_series = all[0].imbalance.cv_series();
            Cell {
                sched: s,
                mean_ms: agg.mean_latency_ms.mean(),
                p90: agg.p90_ms.mean(),
                p95: agg.p95_ms.mean(),
                p99: agg.p99_ms.mean(),
                cold: agg.cold_rate.mean(),
                cv: agg.mean_cv.mean(),
                completed: agg.completed.mean(),
                cdf: pooled.cdf(20),
                cumulative,
                cv_series,
            }
        })
        .collect();

    let want = |f: &str| fig == "all" || fig == f;

    if want("10") {
        println!("\n## Fig 10 — response latency CDF (100 VUs)");
        for c in &cells {
            println!("  {}:", c.sched);
            for (v, q) in &c.cdf {
                println!("    {:>8.1} ms  p={:.2}", v, q);
            }
        }
        // Paper: the pull-based CDF sits leftmost. We check at the p90
        // anchor (the tail is where the schedulers separate; random's CDF
        // can cross below hiku's at low percentiles — its lightly-loaded
        // workers serve lucky requests fast — while its tail explodes).
        let hiku_p90 = cells[0].cdf[17].0;
        println!(
            "  (paper: pull-based CDF is leftmost; our hiku p90 = {hiku_p90:.0} ms, lowest of the four: {})",
            if cells.iter().all(|c| c.cdf[17].0 >= hiku_p90) { "yes" } else { "NO" }
        );
    }

    if want("11") {
        println!("\n## Fig 11 — average response latencies");
        println!("  paper: pull 481 ms vs contenders 565-660 ms (-14.9%..-27.1%)");
        for c in &cells {
            println!("  {:<20} {:>8.1} ms", c.sched, c.mean_ms);
        }
        let h = cells[0].mean_ms;
        for c in &cells[1..] {
            println!(
                "  hiku vs {:<16} {:+.1}%",
                c.sched,
                (h - c.mean_ms) / c.mean_ms * 100.0
            );
        }
    }

    if want("12") {
        println!("\n## Fig 12 — tail latencies (p90/p95/p99)");
        println!("  paper: pull-based lowest, up to -36.4% at p99");
        for c in &cells {
            println!(
                "  {:<20} p90 {:>8.1}  p95 {:>8.1}  p99 {:>8.1} ms",
                c.sched, c.p90, c.p95, c.p99
            );
        }
    }

    if want("13") {
        println!("\n## Fig 13 — cold start rate");
        println!("  paper: pull 30%, others 43-59%");
        for c in &cells {
            println!("  {:<20} {:>5.1}%", c.sched, c.cold * 100.0);
        }
    }

    if want("14") {
        println!("\n## Fig 14 — load imbalance over time (CV of tasks/s, first run)");
        for c in &cells {
            let head: Vec<String> =
                c.cv_series.iter().take(20).map(|v| format!("{v:.2}")).collect();
            println!("  {:<20} {}", c.sched, head.join(" "));
        }
    }

    if want("15") {
        println!("\n## Fig 15 — average load imbalance (CV)");
        println!("  paper: pull 0.27, least-connections 0.26, random 0.30, CH-BL 0.31");
        for c in &cells {
            println!("  {:<20} {:>6.3}", c.sched, c.cv);
        }
    }

    if want("16") {
        println!("\n## Fig 16 — cumulative processed requests (first run)");
        println!("  paper: pull 16414 total vs 12361-15151 (+8.3%..+32.8%)");
        for c in &cells {
            let pts: Vec<String> = c
                .cumulative
                .iter()
                .step_by((c.cumulative.len() / 8).max(1))
                .map(|v| format!("{v:.0}"))
                .collect();
            println!(
                "  {:<20} total {:>7.0}  curve: {}",
                c.sched,
                c.completed,
                pts.join(" -> ")
            );
        }
        let h = cells[0].completed;
        for c in &cells[1..] {
            println!(
                "  hiku vs {:<16} {:+.1}% throughput",
                c.sched,
                (h - c.completed) / c.completed * 100.0
            );
        }
    }

    if want("17") {
        println!("\n## Fig 17 — concurrency sweep (requests/s at 20/50/100 VUs)");
        println!("  paper: 20 VUs similar; 50 VUs pull 61.3 vs CH-BL 58.3; 100 VUs pull 78 vs 51.2-69");
        for vus in [20usize, 50, 100] {
            print!("  {vus:>3} VUs:");
            for s in SCHEDS {
                let (agg, _) = run_cell(&base, s, vus, runs).expect("sweep");
                print!("  {s}={:.1}", agg.rps.mean());
            }
            println!();
        }
    }
}
