//! Quickstart: the smallest complete use of the library.
//!
//! Builds the paper's default cluster (5 workers, 40 FunctionBench
//! functions), runs a 60-second simulated experiment with Hiku pull-based
//! scheduling, and prints the metrics the paper reports.
//!
//! Run: `cargo run --release --example quickstart`

use hiku::config::Config;
use hiku::sim::run_once;

fn main() {
    // 1. Configure the experiment (defaults mirror the paper's §V-A setup).
    let mut cfg = Config::default();
    cfg.scheduler.name = "hiku".into(); // try: ch-bl, random, least-connections
    cfg.workload.vus = 50;
    cfg.workload.duration_s = 60.0;

    // 2. Run one seeded, fully deterministic experiment.
    let mut metrics = run_once(&cfg, 42).expect("simulation failed");

    // 3. Read out the paper's metrics.
    println!("scheduler          : {}", cfg.scheduler.name);
    println!("completed requests : {}", metrics.completed);
    println!("mean latency       : {:.1} ms", metrics.mean_latency_ms());
    println!("p99 latency        : {:.1} ms", metrics.latency_percentile_ms(99.0));
    println!("cold-start rate    : {:.1} %", metrics.cold_rate() * 100.0);
    println!("load imbalance CV  : {:.3}", metrics.mean_cv());
    println!("throughput         : {:.1} req/s", metrics.rps());

    // 4. Machine-readable summary (same fields, JSON).
    println!("\n{}", metrics.summary_json().to_string_pretty());
}
