//! Figs 4-6: Azure-like workload characterization.
//!
//! Synthesizes the production-trace-calibrated workload (DESIGN.md §2) and
//! prints the three characterization figures of the paper's §III-B:
//! skewed popularity (Fig 4), heterogeneous performance (Fig 5), bursty
//! invocations (Fig 6), each with the paper's reference numbers inline.
//!
//! Run: `cargo run --release --example trace_analysis [-- --minutes 30]`

use hiku::report::trace_report;
use hiku::util::cli::Cli;

fn main() {
    let cli = Cli::new("trace_analysis", "Azure-like trace characterization (Figs 4-6)")
        .opt("universe", Some("10000"), "functions in the universe")
        .opt("minutes", Some("30"), "trace duration in minutes")
        .opt("seed", Some("42"), "trace seed");
    let args = cli.parse_env();
    let universe = args.parse_usize("universe").unwrap();
    let minutes = args.parse_f64("minutes").unwrap();
    let seed = args.parse_u64("seed").unwrap();
    print!("{}", trace_report(universe, minutes * 60.0, seed));
}
