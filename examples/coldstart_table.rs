//! Table I: cold vs warm response latencies per FunctionBench application,
//! measured on the REAL runtime — each cold start is an actual XLA
//! compilation of the AOT artifact on the PJRT CPU client, each warm start
//! a cache-hit execution. 20 runs each, like the paper.
//!
//! Requires `make artifacts`. Run:
//!   cargo run --release --example coldstart_table [-- --runs 20]

use hiku::runtime::{Engine, Manifest};
use hiku::stats::OnlineStats;
use hiku::util::cli::Cli;
use hiku::workload::BASE_APPS;

fn main() {
    let cli = Cli::new("coldstart_table", "Table I on the real PJRT runtime")
        .opt("runs", Some("20"), "measurement runs per application");
    let args = cli.parse_env();
    let runs = args.parse_usize("runs").unwrap();

    let manifest = Manifest::load("artifacts").unwrap_or_else(|e| {
        eprintln!("{e}");
        std::process::exit(1);
    });

    println!("# Table I — average response latencies over {runs} runs (real PJRT)");
    println!(
        "{:<18} {:>12} {:>12} {:>9}   paper(ms): cold/warm",
        "Application", "Cold (ms)", "Warm (ms)", "ratio"
    );

    let mut cold_sum = 0.0;
    let mut warm_sum = 0.0;
    for app in BASE_APPS.iter() {
        let mut cold = OnlineStats::new();
        let mut warm = OnlineStats::new();
        for r in 0..runs {
            // Fresh engine per run => a genuine cold start (XLA compile).
            let mut e = Engine::new(manifest.clone(), 8).expect("engine");
            let rc = e.execute(app.name, r as u32).expect("cold exec");
            assert!(rc.cold);
            cold.push(rc.total_s * 1000.0);
            let rw = e.execute(app.name, r as u32 + 1000).expect("warm exec");
            assert!(!rw.cold);
            warm.push(rw.total_s * 1000.0);
        }
        cold_sum += cold.mean();
        warm_sum += warm.mean();
        println!(
            "{:<18} {:>12.1} {:>12.1} {:>8.2}x   {:.0}/{:.0}",
            app.name,
            cold.mean(),
            warm.mean(),
            cold.mean() / warm.mean(),
            app.cold_ms,
            app.warm_ms
        );
    }
    println!("\nmean cold/warm slowdown: {:.2}x (paper: 1.79x)", cold_sum / warm_sum);
}
