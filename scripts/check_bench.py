#!/usr/bin/env python3
"""Bench-regression gate: compare quick-mode bench JSON against a baseline.

Usage:
    python3 scripts/check_bench.py [--baseline ci/bench_baseline.json]
                                   [--dir rust] [--update]

Reads the baseline's check list, extracts the measured value for each
check from the named bench output file (BENCH_sim_engine.json /
BENCH_dispatch.json, produced by `cargo bench --bench ... -- --quick`),
and fails (exit 1) on any regression.

Baseline schema (ci/bench_baseline.json):

    {
      "tolerance_pct": 20.0,          # default tolerance, +/- percent
      "checks": [
        {
          "file": "BENCH_dispatch.json",
          "key": "cold_rate_push",    # top-level key, or with "row":
          "row": {"workers": 1000},   # optional: match a rows[] entry by
                                      # these fields, then read "key"
          "value": 0.31,              # null => unseeded: record-only
          "op": "range",              # range | min | max  (default range)
          "tolerance_pct": 20.0       # optional per-check override
        },
        ...
      ]
    }

Semantics per op (tol = tolerance_pct / 100):
    range  fail if measured outside [value*(1-tol), value*(1+tol)]
    min    fail if measured <  value*(1-tol)   (throughput floors)
    max    fail if measured >  value*(1+tol)   (cold-rate ceilings)

A check whose baseline value is null is *unseeded*: it passes and only
prints the measured value. Run with --update to write every measured
value back into the baseline file (seeding nulls and refreshing stale
values) — commit the result to tighten the gate.
"""

import argparse
import json
import os
import sys


def load_json(path):
    with open(path, "r", encoding="utf-8") as fh:
        return json.load(fh)


def find_row(rows, spec):
    """First element of `rows` whose fields match every key in `spec`."""
    for row in rows:
        if all(row.get(k) == v for k, v in spec.items()):
            return row
    return None


def measured_value(bench, check):
    """Extract the measured value a check refers to, or (None, reason)."""
    if "row" in check:
        rows = bench.get("rows")
        if not isinstance(rows, list):
            return None, "bench file has no rows[] array"
        row = find_row(rows, check["row"])
        if row is None:
            return None, f"no row matches {check['row']}"
        if check["key"] not in row:
            return None, f"row lacks key '{check['key']}'"
        return row[check["key"]], None
    if check["key"] not in bench:
        return None, f"missing key '{check['key']}'"
    return bench[check["key"]], None


def check_one(check, measured, default_tol_pct):
    """Return (ok, message) for one seeded check."""
    value = check["value"]
    op = check.get("op", "range")
    tol = check.get("tolerance_pct", default_tol_pct) / 100.0
    lo, hi = value * (1.0 - tol), value * (1.0 + tol)
    if op == "min":
        ok = measured >= lo
        bound = f">= {lo:.6g}"
    elif op == "max":
        ok = measured <= hi
        bound = f"<= {hi:.6g}"
    elif op == "range":
        ok = lo <= measured <= hi
        bound = f"in [{lo:.6g}, {hi:.6g}]"
    else:
        return False, f"unknown op '{op}'"
    return ok, f"measured {measured:.6g}, want {bound} (baseline {value:.6g})"


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--baseline", default="ci/bench_baseline.json")
    ap.add_argument("--dir", default=".", help="directory holding the BENCH_*.json files")
    ap.add_argument(
        "--update",
        action="store_true",
        help="write measured values back into the baseline (seed/refresh), then exit 0",
    )
    args = ap.parse_args()

    baseline = load_json(args.baseline)
    default_tol = baseline.get("tolerance_pct", 20.0)
    checks = baseline.get("checks", [])
    if not checks:
        print("bench gate: baseline has no checks — nothing to do")
        return 0

    benches = {}  # file name -> parsed json (or None when unreadable)
    failures = 0
    unseeded = 0
    for check in checks:
        fname = check["file"]
        if fname not in benches:
            path = os.path.join(args.dir, fname)
            try:
                benches[fname] = load_json(path)
            except (OSError, ValueError) as err:
                benches[fname] = None
                print(f"FAIL {fname}: unreadable ({err})")
        bench = benches[fname]
        label = f"{fname}:{check['key']}"
        if "row" in check:
            sel = ",".join(f"{k}={v}" for k, v in sorted(check["row"].items()))
            label += f"[{sel}]"
        if bench is None:
            failures += 1
            continue
        measured, err = measured_value(bench, check)
        if err is not None:
            print(f"FAIL {label}: {err}")
            failures += 1
            continue
        if args.update:
            check["value"] = measured
            print(f"seed {label}: {measured:.6g}")
            continue  # unreachable-key/file failures still count above
        if check["value"] is None:
            unseeded += 1
            print(f"---- {label}: unseeded baseline, measured {measured:.6g} (record-only)")
            continue
        ok, msg = check_one(check, measured, default_tol)
        print(f"{'ok  ' if ok else 'FAIL'} {label}: {msg}")
        if not ok:
            failures += 1

    if args.update:
        with open(args.baseline, "w", encoding="utf-8") as fh:
            json.dump(baseline, fh, indent=2)
            fh.write("\n")
        if failures:
            # Values that could be measured were refreshed, but some
            # checks stayed unseeded/stale (missing file or key) — exit
            # nonzero so the operator doesn't commit a half-armed gate.
            print(
                f"bench gate: baseline updated ({args.baseline}) but {failures} "
                "check(s) could not be measured — rerun the quick benches first"
            )
            return 1
        print(f"bench gate: baseline updated ({args.baseline})")
        return 0
    if unseeded:
        print(
            f"bench gate: {unseeded} unseeded check(s) — run "
            "`python3 scripts/check_bench.py --update --dir rust` locally and "
            "commit ci/bench_baseline.json to arm them"
        )
    if failures:
        print(f"bench gate: {failures} check(s) failed")
        return 1
    print("bench gate: all checks passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
