//! Table I bench: cold (XLA compile + execute) vs warm (execute) latency
//! per FunctionBench payload on the real PJRT runtime.
//!
//! Requires `make artifacts` (skips gracefully otherwise, so `cargo bench`
//! stays green on a fresh checkout).

use hiku::bench::Reporter;
use hiku::runtime::{Engine, Manifest};
use hiku::stats::OnlineStats;
use hiku::workload::BASE_APPS;
use std::time::Instant;

const COLD_RUNS: usize = 5;
const WARM_RUNS: usize = 40;

fn main() {
    let Ok(manifest) = Manifest::load("artifacts") else {
        println!("table1_coldstart: artifacts/ not built, skipping (run `make artifacts`)");
        return;
    };
    println!("# Table I — cold vs warm latency, real PJRT ({COLD_RUNS} cold / {WARM_RUNS} warm runs)");
    let mut rep = Reporter::new(&["app", "cold(ms)", "warm(ms)", "ratio", "paper"]);
    let mut cold_sum = 0.0;
    let mut warm_sum = 0.0;
    for app in BASE_APPS.iter() {
        let mut cold = OnlineStats::new();
        for r in 0..COLD_RUNS {
            let mut e = Engine::new(manifest.clone(), 8).expect("engine");
            let t0 = Instant::now();
            let res = e.execute(app.name, r as u32).expect("exec");
            assert!(res.cold);
            cold.push(t0.elapsed().as_secs_f64() * 1000.0);
        }
        let mut e = Engine::new(manifest.clone(), 8).expect("engine");
        e.execute(app.name, 0).expect("prime");
        let mut warm = OnlineStats::new();
        for r in 0..WARM_RUNS {
            let t0 = Instant::now();
            let res = e.execute(app.name, r as u32).expect("exec");
            assert!(!res.cold);
            warm.push(t0.elapsed().as_secs_f64() * 1000.0);
        }
        cold_sum += cold.mean();
        warm_sum += warm.mean();
        rep.row(&[
            app.name.to_string(),
            format!("{:.1}", cold.mean()),
            format!("{:.2}", warm.mean()),
            format!("{:.1}x", cold.mean() / warm.mean()),
            format!("{:.0}/{:.0}", app.cold_ms, app.warm_ms),
        ]);
    }
    println!("\nmean cold/warm slowdown: {:.2}x (paper: 1.79x)", cold_sum / warm_sum);
}
