//! §V-B scheduling overhead: nanoseconds per scheduling decision.
//!
//! The paper reports 0.0023 ms (random) to 0.0149 ms (pull-based) per
//! decision on its testbed. This bench measures `Scheduler::select` for
//! every implemented algorithm against a loaded 5-worker cluster state
//! (Hiku with realistically populated idle queues: ~2 entries/function).

use hiku::bench::Bench;
use hiku::config::SchedulerConfig;
use hiku::scheduler::{make_scheduler, SchedCtx, ALL_SCHEDULERS};
use hiku::util::rng::Pcg64;

fn main() {
    const WORKERS: usize = 5;
    const FUNCTIONS: usize = 40;
    let bench = Bench::new();
    println!("# Scheduling decision overhead (paper: 2.3 us random .. 14.9 us pull-based)");

    for name in ALL_SCHEDULERS {
        let cfg = SchedulerConfig { name: name.into(), ..Default::default() };
        let mut sched = make_scheduler(&cfg, WORKERS).unwrap();
        let mut rng = Pcg64::new(42);
        let loads: Vec<u32> = (0..WORKERS).map(|w| (w as u32 * 3) % 7).collect();

        // Precondition Hiku/queue state: enqueue 2 idle workers per function.
        {
            let mut ctx = SchedCtx::new(&loads, &mut rng);
            for f in 0..FUNCTIONS {
                sched.on_complete(f % WORKERS, f, &mut ctx);
                sched.on_complete((f + 1) % WORKERS, f, &mut ctx);
            }
        }

        let mut f = 0usize;
        bench.report(&format!("select/{name}"), || {
            let mut ctx = SchedCtx::new(&loads, &mut rng);
            let w = sched.select(f, &mut ctx);
            std::hint::black_box(w);
            // Keep Hiku's queues topped up so we measure the pull path,
            // not an ever-draining fallback.
            sched.on_complete(w, f, &mut ctx);
            f = (f + 1) % FUNCTIONS;
        });
    }

    // The full router round-trip (select + on_complete + on_evict), the
    // number that bounds attainable cluster rps.
    let cfg = SchedulerConfig::default();
    let mut sched = make_scheduler(&cfg, WORKERS).unwrap();
    let mut rng = Pcg64::new(7);
    let loads = vec![1u32; WORKERS];
    let mut f = 0usize;
    bench.report("hiku full lifecycle (select+complete+evict)", || {
        let mut ctx = SchedCtx::new(&loads, &mut rng);
        let w = sched.select(f, &mut ctx);
        sched.on_complete(w, f, &mut ctx);
        sched.on_evict(w, f);
        std::hint::black_box(w);
        f = (f + 1) % 40;
    });
}
