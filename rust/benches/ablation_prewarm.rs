//! Extension ablation: predictive pre-warming (cf. Kim & Roh [24], §VI).
//!
//! The paper argues pre-warming techniques are complementary but can be
//! inaccurate and costly; Hiku's pull mechanism gets most of the benefit
//! without speculation. This bench quantifies that: cold-start rate and
//! latency with/without the pre-warm policy, per scheduler.

use hiku::config::Config;
use hiku::report::run_cell;

const SCHEDS: [&str; 3] = ["hiku", "ch-bl", "least-connections"];
const RUNS: u64 = 5;

fn regime(title: &str, vus: usize, keep_alive_s: f64, prewarm_cases: bool) {
    println!("\n## {title}");
    println!(
        "{:<20} {:>8} {:>10} {:>8} {:>8} {:>8}",
        "scheduler", "prewarm", "mean(ms)", "cold%", "rps", "CV"
    );
    for s in SCHEDS {
        for pw in if prewarm_cases { vec![false, true] } else { vec![false] } {
            let mut base = Config::default();
            base.workload.duration_s = 120.0;
            base.cluster.prewarm = pw;
            base.cluster.keep_alive_s = keep_alive_s;
            let (agg, _) = run_cell(&base, s, vus, RUNS).expect("run");
            println!(
                "{:<20} {:>8} {:>10.1} {:>7.1}% {:>8.1} {:>8.3}",
                s,
                if pw { "on" } else { "off" },
                agg.mean_latency_ms.mean(),
                agg.cold_rate.mean() * 100.0,
                agg.rps.mean(),
                agg.mean_cv.mean()
            );
        }
    }
}

fn main() {
    println!("# Extension — predictive pre-warming ({RUNS} runs)");
    regime("saturated: 100 VUs, keep-alive 20 s (no memory headroom -> prewarm inert)", 100, 20.0, true);
    regime("churny: 30 VUs, keep-alive 3 s (expiry-driven colds -> prewarm helps)", 30, 3.0, true);
    println!("\n(pre-warm policy: 1 Hz EWMA demand estimate, deficit-driven,");
    println!(" never evicts for speculation, <=2 speculative inits/s/function)");
}
