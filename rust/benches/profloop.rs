//! Profiling driver for the §Perf pass: 60 back-to-back 300 s x 100 VU
//! hiku runs — run under `perf record` to find simulator hot spots.
//! (Not a reporting bench; prints only the total request count.)
use hiku::config::Config;
use hiku::sim::run_once;
fn main() {
    let mut cfg = Config::default();
    cfg.workload.vus = 100;
    cfg.workload.duration_s = 300.0;
    cfg.scheduler.name = "hiku".into();
    let mut total = 0u64;
    for seed in 0..60 {
        total += run_once(&cfg, seed).unwrap().completed;
    }
    println!("{total}");
}
