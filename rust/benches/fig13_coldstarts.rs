//! Fig 13: cold-start rate per scheduler at 100 VUs.
//!
//! Paper: 30% of requests cold with pull-based scheduling vs 43-59% for
//! the other algorithms. Also reports the eviction breakdown (memory
//! pressure vs keep-alive) that drives the rate, via the sim's counters.

use hiku::config::Config;
use hiku::report::run_cell;

const SCHEDS: [&str; 4] = ["hiku", "ch-bl", "random", "least-connections"];
const RUNS: u64 = 5;

fn main() {
    let mut base = Config::default();
    base.workload.duration_s = 120.0;

    println!("# Fig 13 — cold starts at 100 VUs ({RUNS} runs)");
    println!("  paper: pull-based 30%, others 43-59%\n");
    println!(
        "{:<20} {:>8} {:>12} {:>12}",
        "scheduler", "cold%", "cold-starts", "warm-starts"
    );
    for s in SCHEDS {
        let (agg, all) = run_cell(&base, s, 100, RUNS).expect("sweep");
        let cold: u64 = all.iter().map(|m| m.cold_starts).sum();
        let warm: u64 = all.iter().map(|m| m.warm_starts).sum();
        println!(
            "{:<20} {:>7.1}% {:>12} {:>12}",
            s,
            agg.cold_rate.mean() * 100.0,
            cold,
            warm
        );
    }
}
