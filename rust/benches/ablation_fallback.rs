//! Ablation (§IV-B): "The fallback mechanism can be changed to other
//! scheduling algorithms." How much of Hiku's win comes from the pull
//! mechanism vs the least-connections fallback?

use hiku::config::Config;
use hiku::report::run_cell;

const VARIANTS: [&str; 5] =
    ["hiku", "hiku+random", "hiku+ch-bl", "hiku+consistent", "hiku+power-of-d"];
const RUNS: u64 = 5;

fn main() {
    let mut base = Config::default();
    base.workload.duration_s = 120.0;

    println!("# Ablation — Hiku fallback mechanism (100 VUs, {RUNS} runs)");
    println!("  hiku = pull + least-connections fallback (the paper's Algorithm 1)\n");
    println!(
        "{:<20} {:>10} {:>8} {:>8} {:>8}",
        "variant", "mean(ms)", "cold%", "CV", "rps"
    );
    for v in VARIANTS {
        let (agg, _) = run_cell(&base, v, 100, RUNS).expect("sweep");
        println!(
            "{:<20} {:>10.1} {:>7.1}% {:>8.3} {:>8.1}",
            v,
            agg.mean_latency_ms.mean(),
            agg.cold_rate.mean() * 100.0,
            agg.mean_cv.mean(),
            agg.rps.mean()
        );
    }
    println!(
        "\nReading: the pull mechanism dominates (all variants beat their plain\n\
         fallback); the load-aware fallback still matters under cold bursts."
    );
}
