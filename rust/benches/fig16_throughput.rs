//! Fig 16: cumulative requests processed over time per scheduler.
//!
//! Paper: pull-based processes 16414 requests on average vs 12361-15151
//! for the others (+8.3%..+32.8% throughput).

use hiku::config::Config;
use hiku::report::run_cell;

const SCHEDS: [&str; 4] = ["hiku", "ch-bl", "random", "least-connections"];
const RUNS: u64 = 5;

fn main() {
    let mut base = Config::default();
    base.workload.duration_s = 120.0;

    println!("# Fig 16 — cumulative throughput at 100 VUs ({RUNS} runs x 120 s)");
    println!("  paper: pull 16414 vs 12361-15151 total (+8.3%..+32.8%)\n");
    println!("{:<20} {:>10}   cumulative curve (every 15 s)", "scheduler", "total");
    let mut hiku_total = 0.0;
    let mut worst = f64::MAX;
    let mut best_other: f64 = 0.0;
    for s in SCHEDS {
        let (agg, all) = run_cell(&base, s, 100, RUNS).expect("sweep");
        let cum = all[0].throughput.cumulative();
        let pts: Vec<String> =
            cum.iter().step_by(15).map(|v| format!("{v:.0}")).collect();
        let total = agg.completed.mean();
        if s == "hiku" {
            hiku_total = total;
        } else {
            worst = worst.min(total);
            best_other = best_other.max(total);
        }
        println!("{:<20} {:>10.0}   {}", s, total, pts.join(" "));
    }
    println!(
        "\nhiku throughput gain: +{:.1}% vs best contender, +{:.1}% vs worst (paper: +8.3% .. +32.8%)",
        (hiku_total - best_other) / best_other * 100.0,
        (hiku_total - worst) / worst * 100.0
    );
}
