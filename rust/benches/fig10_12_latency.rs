//! Figs 10-12: response latency distribution per scheduler (100 VUs).
//!
//! Prints the paper's latency rows (mean + tails + CDF anchor points) and
//! times the simulator itself (events/s) as the engine-perf metric.

use hiku::config::Config;
use hiku::report::run_cell;
use hiku::stats::Samples;
use std::time::Instant;

const SCHEDS: [&str; 4] = ["hiku", "ch-bl", "random", "least-connections"];
const RUNS: u64 = 5;

fn main() {
    let mut base = Config::default();
    base.workload.duration_s = 120.0;

    println!("# Figs 10-12 — response latencies at 100 VUs ({RUNS} runs x {}s)", 120);
    println!("  paper Fig 11: pull 481 ms, contenders 565-660 ms (-14.9%..-27.1%)");
    println!("  paper Fig 12: pull lowest tails, up to -36.4% at p99\n");
    println!(
        "{:<20} {:>9} {:>9} {:>9} {:>9} {:>9} {:>9}",
        "scheduler", "mean(ms)", "p50", "p90", "p95", "p99", "sim-time"
    );

    let mut hiku_mean = 0.0;
    for s in SCHEDS {
        let t0 = Instant::now();
        let (agg, mut all) = run_cell(&base, s, 100, RUNS).expect("sweep");
        let wall = t0.elapsed().as_secs_f64();
        let mut pooled = Samples::new();
        for m in &mut all {
            let samples = m.latency_ms.as_samples_mut().expect("bench runs in exact mode");
            for &v in samples.values() {
                pooled.push(v);
            }
        }
        if s == "hiku" {
            hiku_mean = agg.mean_latency_ms.mean();
        }
        println!(
            "{:<20} {:>9.1} {:>9.1} {:>9.1} {:>9.1} {:>9.1} {:>8.2}s",
            s,
            agg.mean_latency_ms.mean(),
            pooled.percentile(50.0),
            agg.p90_ms.mean(),
            agg.p95_ms.mean(),
            agg.p99_ms.mean(),
            wall,
        );
    }
    println!();
    for s in &SCHEDS[1..] {
        let (agg, _) = run_cell(&base, s, 100, RUNS).expect("sweep");
        println!(
            "hiku vs {:<18} {:+.1}% mean latency",
            s,
            (hiku_mean - agg.mean_latency_ms.mean()) / agg.mean_latency_ms.mean() * 100.0
        );
    }
}
