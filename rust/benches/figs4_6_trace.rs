//! Figs 4-6: trace characterization + generator performance.
//!
//! Validates the synthetic Azure-like workload against the paper's
//! reported statistics and benches the generator (invocations/s) — the
//! workload layer must never bottleneck the simulator.

use hiku::bench::Bench;
use hiku::workload::azure::SyntheticTrace;
use std::time::Instant;

fn main() {
    println!("# Figs 4-6 — Azure-like trace characterization");

    let t0 = Instant::now();
    let tr = SyntheticTrace::generate(10_000, 1800.0, 42);
    let gen_s = t0.elapsed().as_secs_f64();
    println!(
        "generated {} invocations over 30 min in {:.3} s ({:.1}M inv/s)\n",
        tr.invocations.len(),
        gen_s,
        tr.invocations.len() as f64 / gen_s / 1e6
    );

    println!("Fig 4: top  1% -> {:>5.1}% of invocations (paper 51.3%)", tr.top_share(0.01) * 100.0);
    println!("Fig 4: top 10% -> {:>5.1}% of invocations (paper 92.3%)", tr.top_share(0.10) * 100.0);

    let het = tr.exec_heterogeneity(10, 42);
    let means: Vec<f64> = het.iter().map(|&(_, m, _)| m * 1000.0).collect();
    let min = means.iter().cloned().fold(f64::MAX, f64::min);
    let max = means.iter().cloned().fold(f64::MIN, f64::max);
    println!("Fig 5: exec-time means span {:.0}..{:.0} ms across first 10 functions", min, max);

    let (_, max_ratio) = tr.interarrival_per_minute();
    println!("Fig 6: max minute-over-minute interarrival swing {:.1}x (paper: up to 13.5x)", max_ratio);

    // Micro: per-component generation costs.
    println!();
    let bench = Bench::new();
    bench.report("SyntheticTrace::generate(2000 fns, 5 min)", || {
        std::hint::black_box(SyntheticTrace::generate(2000, 300.0, 7));
    });
    let tr2 = SyntheticTrace::generate(2000, 300.0, 7);
    bench.report("top_share(0.01) over 2000 fns", || {
        std::hint::black_box(tr2.top_share(0.01));
    });
}
