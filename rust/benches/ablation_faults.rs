//! Robustness ablation: fault injection & recovery — pull-mode hiku vs
//! push-mode baselines under a deterministic kill-and-recover schedule.
//!
//! Section 1 kills two workers mid-run (`faults.crashes`, explicit
//! schedule, recovering after `mttr_s`) and compares three arms on the
//! same closed-loop workload:
//!
//!   hiku / pull    — parked work re-routes around the dead workers
//!                    (liveness-aware late binding), in-flight work
//!                    re-enqueues into the pending queue on crash
//!   lc / push      — least-connections steers via the avoid mask but
//!                    binds immediately; in-flight losses burn retries
//!   hash-mod / push — address-based placement cannot observe liveness:
//!                    every arrival hashed to a dead worker bounces off
//!                    it until the retry budget fails the request
//!
//! The headline is the `failed` column: requests whose bounded retry
//! budget (`faults.max_retries`) ran out. The pull router should fail
//! strictly fewer than push-mode hash-mod — that delta is what
//! liveness-aware pull dispatch buys during partial outages.
//!
//! Section 2 is a chaos run (random crash/recover churn + stragglers +
//! cold-init failures) on pull-mode hiku, reporting the recovery
//! machinery: crash/recovery counts, mean recovery latency, straggler
//! hedges, warm-state migrations, and the conservation identity
//! `arrivals == completed + rejected + failed`.
//!
//! Emits machine-readable **`BENCH_faults.json`** — the committed
//! experiment recipe is in EXPERIMENTS.md §Faults; determinism and
//! conservation are enforced by `tests/faults.rs`.
//!
//! Usage:
//!   cargo bench --bench ablation_faults            # full table
//!   cargo bench --bench ablation_faults -- --quick # CI smoke

use hiku::config::Config;
use hiku::sim::run_once;
use hiku::util::json::{obj, Json};

fn base_cfg(dur: f64) -> Config {
    let mut cfg = Config::default();
    cfg.workload.vus = 40;
    cfg.workload.duration_s = dur;
    cfg
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let dur = if quick { 30.0 } else { 90.0 };
    let seeds: &[u64] = if quick { &[1] } else { &[1, 2, 3] };

    // Two explicit mid-run kills, each down for 20% of the run.
    let kill_a = 0.3 * dur;
    let kill_b = 0.5 * dur;
    let crashes = format!("{kill_a}:1;{kill_b}:2");
    let mttr = 0.2 * dur;

    println!(
        "# fault ablation: kill workers 1,2 at t={kill_a:.0}s,{kill_b:.0}s (mttr {mttr:.0}s), \
         {} workers, {} VUs, {dur:.0} s",
        Config::default().cluster.workers,
        base_cfg(dur).workload.vus,
    );
    println!(
        "{:<10} {:<5} {:>5} {:>9} {:>7} {:>8} {:>8} {:>7} {:>8} {:>9}",
        "sched", "mode", "seed", "completed", "failed", "retried", "rerouted", "hedged",
        "migrated", "p95(ms)"
    );

    let mut rows: Vec<Json> = Vec::new();
    let mut failed_by_arm = [0u64; 3]; // [hiku/pull, lc/push, hash/push]
    let arms: [(&str, &str); 3] =
        [("hiku", "pull"), ("least-connections", "push"), ("hash-mod", "push")];
    for (i, &(sched, mode)) in arms.iter().enumerate() {
        for &seed in seeds {
            let mut cfg = base_cfg(dur);
            cfg.scheduler.name = sched.into();
            cfg.dispatch.mode = mode.into();
            cfg.faults.enabled = true;
            cfg.faults.crashes = crashes.clone();
            cfg.faults.mttr_s = mttr;
            let mut m = run_once(&cfg, seed).expect("fault ablation run");
            assert_eq!(
                m.arrivals,
                m.completed + m.rejected + m.failed,
                "conservation violated: {sched}/{mode} seed {seed}"
            );
            failed_by_arm[i] += m.failed;
            let p95 = m.latency_percentile_ms(95.0);
            println!(
                "{:<10} {:<5} {:>5} {:>9} {:>7} {:>8} {:>8} {:>7} {:>8} {:>9.1}",
                sched, mode, seed, m.completed, m.failed, m.retried, m.re_routed, m.hedged,
                m.migrated, p95
            );
            rows.push(obj(vec![
                ("scheduler", sched.into()),
                ("mode", mode.into()),
                ("seed", seed.into()),
                ("arrivals", m.arrivals.into()),
                ("completed", m.completed.into()),
                ("rejected", m.rejected.into()),
                ("failed", m.failed.into()),
                ("retried", m.retried.into()),
                ("re_routed", m.re_routed.into()),
                ("hedged", m.hedged.into()),
                ("migrated", m.migrated.into()),
                ("worker_crashes", m.worker_crashes.into()),
                ("worker_recoveries", m.worker_recoveries.into()),
                ("p95_ms", p95.into()),
            ]));
        }
    }

    // ---- chaos run: random churn + stragglers + init failures ----
    println!("# chaos: pull-mode hiku, random crash/recover + stragglers + init failures");
    let mut chaos_rows: Vec<Json> = Vec::new();
    for &seed in seeds {
        let mut cfg = base_cfg(dur);
        cfg.scheduler.name = "hiku".into();
        cfg.dispatch.mode = "pull".into();
        cfg.faults.enabled = true;
        cfg.faults.crash_rate = 0.5; // per worker per minute
        cfg.faults.mttr_s = 0.1 * dur;
        cfg.faults.straggler_frac = 0.25;
        cfg.faults.straggler_slowdown = 4.0;
        cfg.faults.init_fail_prob = 0.02;
        let mut m = run_once(&cfg, seed).expect("chaos run");
        assert_eq!(m.arrivals, m.completed + m.rejected + m.failed, "chaos conservation");
        let mean_recovery = if m.recovery_latency_ms.is_empty() {
            0.0
        } else {
            m.recovery_latency_ms.mean()
        };
        println!(
            "seed {seed}: crashes {} recoveries {} (mean down {:>6.0} ms), hedged {}, \
             migrated {}, init_fail {}, failed {}/{}",
            m.worker_crashes,
            m.worker_recoveries,
            mean_recovery,
            m.hedged,
            m.migrated,
            m.init_failures,
            m.failed,
            m.arrivals
        );
        chaos_rows.push(obj(vec![
            ("seed", seed.into()),
            ("worker_crashes", m.worker_crashes.into()),
            ("worker_recoveries", m.worker_recoveries.into()),
            ("mean_recovery_ms", mean_recovery.into()),
            ("hedged", m.hedged.into()),
            ("migrated", m.migrated.into()),
            ("init_failures", m.init_failures.into()),
            ("failed", m.failed.into()),
            ("completed", m.completed.into()),
            ("arrivals", m.arrivals.into()),
        ]));
    }

    let [f_pull, f_lc, f_hash] = failed_by_arm;
    println!(
        "failed (sum over seeds): hiku/pull {f_pull}  lc/push {f_lc}  hash-mod/push {f_hash}  \
         (pull beats hash: {})",
        f_pull < f_hash
    );
    let out = obj(vec![
        ("bench", "faults".into()),
        ("quick", quick.into()),
        ("failed_pull_hiku", f_pull.into()),
        ("failed_push_lc", f_lc.into()),
        ("failed_push_hash", f_hash.into()),
        ("pull_beats_push_hash", (f_pull < f_hash).into()),
        ("rows", Json::Arr(rows)),
        ("chaos_rows", Json::Arr(chaos_rows)),
    ]);
    let path = "BENCH_faults.json";
    std::fs::write(path, out.to_string_pretty()).expect("write bench json");
    println!("wrote {path}");
}
