//! Ablation (§I): distributed scheduling. The paper argues pull-based
//! scheduling "reduces the need for synchronization" when multiple
//! schedulers coexist. We shard the VUs across S independent scheduler
//! instances — each with a local (unsynchronized) load view — and measure
//! how each algorithm degrades as S grows.

use hiku::config::Config;
use hiku::report::run_cell;

const SCHEDS: [&str; 3] = ["hiku", "ch-bl", "least-connections"];
const INSTANCES: [usize; 3] = [1, 2, 4];
const RUNS: u64 = 5;

fn main() {
    let mut base = Config::default();
    base.workload.duration_s = 120.0;

    println!("# Ablation — S independent scheduler instances (100 VUs, {RUNS} runs)");
    println!("  local load views, no synchronization; idle advertisements go to");
    println!("  the instance that routed the completed request (distributed JIQ [21])\n");
    println!(
        "{:<20} {:>4} {:>10} {:>8} {:>8} {:>8}",
        "scheduler", "S", "mean(ms)", "cold%", "CV", "rps"
    );
    for s in SCHEDS {
        let mut s1_rps = 0.0;
        for &inst in &INSTANCES {
            let mut cfg = base.clone();
            cfg.scheduler.instances = inst;
            let (agg, _) = run_cell(&cfg, s, 100, RUNS).expect("run");
            if inst == 1 {
                s1_rps = agg.rps.mean();
            }
            println!(
                "{:<20} {:>4} {:>10.1} {:>7.1}% {:>8.3} {:>8.1}  ({:+.1}% vs S=1)",
                s,
                inst,
                agg.mean_latency_ms.mean(),
                agg.cold_rate.mean() * 100.0,
                agg.mean_cv.mean(),
                agg.rps.mean(),
                (agg.rps.mean() - s1_rps) / s1_rps * 100.0
            );
        }
        println!();
    }
}
