//! Bench — autoscale policies x schedulers under the Azure bursty trace.
//!
//! The cluster starts at 2 workers with bounds [2, 10] and replays a
//! 4-minute open-loop bursty trace (regime-switching arrival rate, §III-B
//! Fig 6). For every policy x scheduler cell the table reports the
//! cost/quality trade-off:
//!
//! - cold-start rate and latency (quality),
//! - worker-seconds, i.e. the integral of active workers over the run
//!   (the cost proxy a real deployment pays for),
//! - scaling actions and pre-warm speculation accuracy.
//!
//! Expected qualitative result: `reactive` buys latency with extra
//! workers but still serves bursts cold (capacity arrives only after load
//! is visible); `predictive` converts forecasts into pre-warmed pools and
//! earlier scale-ups, cutting the cold-start rate at comparable
//! worker-seconds. The run ends with a determinism check: with a fixed
//! seed, repeated autoscaled runs must be bit-identical.

use hiku::config::Config;
use hiku::report::{autoscale_report, bursty_trace};
use hiku::sim::run_trace;

const POLICIES: [&str; 4] = ["none", "scheduled", "reactive", "predictive"];
const SCHEDS: [&str; 2] = ["hiku", "least-connections"];
const SEED: u64 = 4242;

fn main() {
    let mut base = Config::default();
    base.workload.duration_s = 240.0;
    base.cluster.workers = 2;
    base.autoscale.min_workers = 2;
    base.autoscale.max_workers = 10;
    base.autoscale.events = "60;120".into(); // scheduled policy's script

    let policies: Vec<String> = POLICIES.iter().map(|s| s.to_string()).collect();
    let scheds: Vec<String> = SCHEDS.iter().map(|s| s.to_string()).collect();
    let report = autoscale_report(&base, &policies, &scheds, SEED).expect("autoscale sweep");
    println!("{report}");

    // Determinism under seed with the closed-loop autoscaler active: the
    // whole run must be bit-identical across repetitions.
    let trace = bursty_trace(base.num_functions(), base.workload.duration_s, SEED);
    for policy in ["reactive", "predictive"] {
        let mut cfg = base.clone();
        cfg.scheduler.name = "hiku".into();
        cfg.autoscale.policy = policy.into();
        let mut a = run_trace(&cfg, &trace, SEED).expect("run a");
        let mut b = run_trace(&cfg, &trace, SEED).expect("run b");
        assert_eq!(a.completed, b.completed, "{policy}: completed diverged");
        assert_eq!(a.cold_starts, b.cold_starts, "{policy}: cold starts diverged");
        assert_eq!(a.scaling_timeline, b.scaling_timeline, "{policy}: timeline diverged");
        assert!(
            a.mean_latency_ms() == b.mean_latency_ms(),
            "{policy}: latency diverged bit-wise"
        );
    }
    println!("determinism check: OK (repeated autoscaled runs are bit-identical under seed)");
}
