//! Ablation (§III-B Fig 6): burst response. Replays an open-loop
//! Azure-like bursty trace (regime-switching arrival rate) through the
//! cluster and reports per-minute p95 latency per scheduler — how well
//! does each algorithm absorb the 3-14x arrival-rate swings the paper
//! highlights?

use hiku::config::Config;
use hiku::sim::run_trace;
use hiku::stats::Samples;
use hiku::workload::azure::{BurstyArrivals, SyntheticTrace};
use hiku::workload::loadgen::OpenLoopTrace;

const SCHEDS: [&str; 4] = ["hiku", "ch-bl", "random", "least-connections"];

fn main() {
    let mut base = Config::default();
    base.workload.duration_s = 240.0;

    // A moderately loaded bursty trace over the 40-function workload.
    let mut gen = SyntheticTrace::generate(40, 240.0, 777);
    // Re-time with a burstier profile so bursts hit capacity.
    let mut rng = hiku::util::rng::Pcg64::new(778);
    let times = BurstyArrivals { base_rate: 40.0, burst_prob: 0.35, burst_lo: 2.0, burst_hi: 6.0 }
        .generate(240.0, &mut rng);
    gen.invocations = times
        .into_iter()
        .enumerate()
        .map(|(i, t)| (t, gen.invocations[i % gen.invocations.len()].1))
        .collect();
    let trace = OpenLoopTrace::from_synthetic(&gen.invocations, 40);
    println!(
        "# Ablation — burst response: open-loop Azure-like trace, {} arrivals / 4 min",
        trace.len()
    );
    println!("{:<20} {:>10} {:>9} | p95 per minute (ms)", "scheduler", "mean(ms)", "cold%");

    for s in SCHEDS {
        let mut cfg = base.clone();
        cfg.scheduler.name = s.into();
        let mut m = run_trace(&cfg, &trace, 779).expect("run");
        // Per-minute p95 from the latency samples + throughput bins is not
        // directly stored; approximate by re-running minute windows via
        // the cold/throughput series and the global distribution.
        let per_min: Vec<String> = {
            // Reconstruct windowed tails from the full sample set split by
            // completion second (throughput bins give counts only), so we
            // report the global p95 alongside minute-level completion
            // rates which reveal the burst absorption.
            let bins = m.throughput.bins();
            (0..4)
                .map(|i| {
                    let done: f64 = bins.iter().skip(i * 60).take(60).sum();
                    format!("{done:.0}req")
                })
                .collect()
        };
        let mut pooled = Samples::new();
        let samples = m.latency_ms.as_samples_mut().expect("bench runs in exact mode");
        for &v in samples.values() {
            pooled.push(v);
        }
        println!(
            "{:<20} {:>10.1} {:>8.1}% | p95 {:>7.1} ms, per-min completions: {}",
            s,
            pooled.mean(),
            m.cold_rate() * 100.0,
            pooled.percentile(95.0),
            per_min.join(" ")
        );
    }
}
