//! Figs 14-15: load imbalance — coefficient of variation of requests
//! assigned per worker per second.
//!
//! Paper Fig 15: pull-based 0.27, least-connections 0.26, random 0.30,
//! CH-BL 0.31 (pull balances 12.9% more evenly than CH-BL).

use hiku::config::Config;
use hiku::report::run_cell;

const SCHEDS: [&str; 4] = ["hiku", "least-connections", "random", "ch-bl"];
const RUNS: u64 = 5;

fn main() {
    let mut base = Config::default();
    base.workload.duration_s = 120.0;

    println!("# Figs 14-15 — load imbalance at 100 VUs ({RUNS} runs)");
    println!("  paper: pull 0.27 ~ LC 0.26 < random 0.30 < CH-BL 0.31\n");
    println!("{:<20} {:>8} {:>30}", "scheduler", "mean CV", "CV series (first 12 s, run 0)");
    let mut hiku_cv = 0.0;
    let mut chbl_cv = 0.0;
    for s in SCHEDS {
        let (agg, all) = run_cell(&base, s, 100, RUNS).expect("sweep");
        let series: Vec<String> = all[0]
            .imbalance
            .cv_series()
            .iter()
            .take(12)
            .map(|v| format!("{v:.2}"))
            .collect();
        if s == "hiku" {
            hiku_cv = agg.mean_cv.mean();
        }
        if s == "ch-bl" {
            chbl_cv = agg.mean_cv.mean();
        }
        println!("{:<20} {:>8.3}   {}", s, agg.mean_cv.mean(), series.join(" "));
    }
    println!(
        "\nhiku balances {:.1}% more evenly than CH-BL (paper: 12.9%)",
        (chbl_cv - hiku_cv) / chbl_cv * 100.0
    );
}
