//! Core-granularity ablation: worker-granular vs core-granular pull
//! dispatch (and push with a bounded rebind window) on a mixed
//! short/long trace.
//!
//! The scenario is head-of-line blocking by construction: every 2 s a
//! burst of 24 long `chameleon` calls (~392 ms warm) saturates the
//! cluster's 16 execution slots, then 6 short `linpack` calls (~58 ms
//! warm) trail in 50-110 ms later. Under worker-granular accounting
//! (`cores_per_worker = 1`, `concurrency = 4`) least-connections must
//! bind each short immediately, so it lands in some worker's FIFO
//! behind queued longs and waits multiple long service times. Under
//! core-granular accounting (`cores_per_worker = 4`) the scheduler sees
//! zero free slots and the engine parks the short centrally instead —
//! late binding — so the first slot to free anywhere in the cluster
//! claims it. The push row keeps eager binding but re-routes queued
//! requests to idle slots within `dispatch.rebind_window_s`.
//!
//! The money metric is the **p99 arrival-to-start wait of the short
//! class** (`slots.hol_short_p99_ms` in the summary): core-granular
//! pull must beat worker-granular, which `tests/dispatch.rs::
//! core_granular_pull_beats_worker_granular_on_short_p99` enforces on
//! the same trace.
//!
//! Emits machine-readable **`BENCH_cores.json`** (one row per run +
//! headline scalars) — the committed experiment recipe is in
//! EXPERIMENTS.md §Core granularity.
//!
//! Usage:
//!   cargo bench --bench ablation_cores            # full table
//!   cargo bench --bench ablation_cores -- --quick # CI smoke

use hiku::config::Config;
use hiku::report::mixed_class_trace;
use hiku::sim::run_trace;
use hiku::util::json::{obj, Json};

/// Shared base: least-connections (the baselines' default `decide`
/// always binds, so the worker-vs-core contrast is purely the slot
/// model, not hiku's own parking policy), 4 workers, hard admission
/// (`elastic = false`, required by the slot model) with 4 execution
/// slots per worker either way — capacity is identical across arms,
/// only the granularity the scheduler sees differs.
fn base_cfg(dur: f64) -> Config {
    let mut cfg = Config::default();
    cfg.scheduler.name = "least-connections".into();
    cfg.workload.vus = 1; // open loop ignores the VU scripts
    cfg.workload.duration_s = dur;
    cfg.cluster.workers = 4;
    cfg.cluster.concurrency = 4;
    cfg.cluster.elastic = false;
    cfg
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let dur = if quick { 20.0 } else { 60.0 };
    let seeds: &[u64] = if quick { &[1] } else { &[1, 2, 3] };
    let trace = mixed_class_trace(dur);
    println!(
        "# cores ablation: worker-granular vs core-granular, mixed trace ({} arrivals / {:.0} s), \
         4 workers x 4 slots",
        trace.len(),
        dur
    );
    println!(
        "{:<12} {:>5} {:>9} {:>12} {:>11} {:>9} {:>9} {:>8} {:>8}",
        "arm", "seed", "completed", "p99short(ms)", "p99long(ms)", "mean(ms)", "p95(ms)",
        "enqueued", "rebound"
    );

    let mut rows: Vec<Json> = Vec::new();
    // Seed-averaged p99 short wait per arm: [worker, cores, rebind].
    let mut p99_short = [0.0f64; 3];
    let mut rebound_push = 0u64;
    let arms: [(&str, usize, &str, f64); 3] = [
        ("pull/worker", 1, "pull", 0.0),
        ("pull/cores", 4, "pull", 0.0),
        ("push/rebind", 4, "push", 0.25),
    ];
    for (i, &(arm, cores, mode, rebind)) in arms.iter().enumerate() {
        for &seed in seeds {
            let mut cfg = base_cfg(dur);
            cfg.sim.cores_per_worker = cores;
            cfg.dispatch.mode = mode.into();
            cfg.dispatch.rebind_window_s = rebind;
            let mut m = run_trace(&cfg, &trace, seed).expect("cores ablation run");
            let short = m.hol_wait_p99_ms(true);
            let long = m.hol_wait_p99_ms(false);
            let mean = m.mean_latency_ms();
            let p95 = m.latency_percentile_ms(95.0);
            println!(
                "{:<12} {:>5} {:>9} {:>12.1} {:>11.1} {:>9.1} {:>9.1} {:>8} {:>8}",
                arm, seed, m.completed, short, long, mean, p95, m.enqueued, m.rebound
            );
            p99_short[i] += short / seeds.len() as f64;
            if mode == "push" {
                rebound_push += m.rebound;
            }
            rows.push(obj(vec![
                ("arm", arm.into()),
                ("cores_per_worker", cores.into()),
                ("mode", mode.into()),
                ("rebind_window_s", rebind.into()),
                ("seed", seed.into()),
                ("completed", m.completed.into()),
                ("p99_short_wait_ms", short.into()),
                ("p99_long_wait_ms", long.into()),
                ("mean_ms", mean.into()),
                ("p95_ms", p95.into()),
                ("enqueued", m.enqueued.into()),
                ("rebound", m.rebound.into()),
                ("cold_rate", m.cold_rate().into()),
            ]));
        }
    }

    let speedup =
        if p99_short[1] > 0.0 { p99_short[0] / p99_short[1] } else { f64::INFINITY };
    println!(
        "p99 short wait: worker-granular {:.1} ms -> core-granular {:.1} ms ({speedup:.2}x), \
         push+rebind {:.1} ms ({rebound_push} rebinds)",
        p99_short[0], p99_short[1], p99_short[2]
    );
    let out = obj(vec![
        ("bench", "cores".into()),
        ("quick", quick.into()),
        ("p99_short_wait_ms_worker", p99_short[0].into()),
        ("p99_short_wait_ms_cores", p99_short[1].into()),
        ("p99_short_wait_ms_rebind", p99_short[2].into()),
        ("short_wait_speedup", speedup.into()),
        ("rebound_push", rebound_push.into()),
        ("rows", Json::Arr(rows)),
    ]);
    let path = "BENCH_cores.json";
    std::fs::write(path, out.to_string_pretty()).expect("write bench json");
    println!("wrote {path}");
}
