//! L2 ablation: the dispatch protocol — hiku under `dispatch.mode =
//! "push"` vs `"pull"` on the bursty open-loop workload.
//!
//! The pull rows sweep the wait deadline (`dispatch.max_wait_s`): how
//! long a request with a warm prospect may park in the router's pending
//! queue before it is force-placed. The push row is the pre-redesign
//! behavior (immediate fallback placement when `PQ_f` is empty). The
//! headline number is the cold-start fraction: parked requests that get
//! pulled are warm by construction, so pull should trade a bounded queue
//! wait for a lower cold rate on bursts.
//!
//! A second section prices scale-to-zero: the same trace with a 60 s
//! idle tail, reactive autoscaling with `min_workers` 1 vs 0 — the
//! worker-seconds delta is the cost of holding the floor, and the cold
//! rate shows what the queue-triggered wake pays for it.
//!
//! Emits machine-readable **`BENCH_dispatch.json`** (one row per run +
//! aggregate cold-rate/cost keys) — the committed experiment recipe is
//! in EXPERIMENTS.md §Dispatch. The equivalence/reduction contracts are
//! enforced separately by `tests/determinism.rs` (push bit-identity) and
//! `tests/dispatch.rs` (pull never cold-starts more than push on this
//! workload).
//!
//! Usage:
//!   cargo bench --bench ablation_dispatch            # full table
//!   cargo bench --bench ablation_dispatch -- --quick # CI smoke

use hiku::config::Config;
use hiku::report::bursty_trace;
use hiku::sim::run_trace;
use hiku::util::json::{obj, Json};

fn base_cfg(dur: f64) -> Config {
    let mut cfg = Config::default();
    cfg.scheduler.name = "hiku".into();
    cfg.workload.vus = 1; // open loop ignores the VU scripts
    cfg.workload.duration_s = dur;
    cfg
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let dur = if quick { 30.0 } else { 120.0 };
    let seeds: &[u64] = if quick { &[1] } else { &[1, 2, 3] };
    let waits: &[f64] = if quick { &[0.5] } else { &[0.25, 0.5, 1.0] };
    let trace = bursty_trace(40, dur, 42);
    println!(
        "# dispatch ablation: hiku push vs pull, bursty trace ({} arrivals / {:.0} s), {} workers",
        trace.len(),
        dur,
        Config::default().cluster.workers
    );
    println!(
        "{:<6} {:>6} {:>5} {:>9} {:>7} {:>9} {:>9} {:>9} {:>9} {:>7}",
        "mode", "wait_s", "seed", "completed", "cold%", "mean(ms)", "p95(ms)", "wait(ms)",
        "enqueued", "reject"
    );

    let mut rows: Vec<Json> = Vec::new();
    let mut cold_push = 0.0f64;
    let mut cold_pull = 0.0f64; // at the default 0.5 s deadline
    let mut run_cell = |mode: &str, wait: f64, seed: u64, rows: &mut Vec<Json>| -> (f64, f64) {
        let mut cfg = base_cfg(dur);
        cfg.dispatch.mode = mode.into();
        if wait > 0.0 {
            cfg.dispatch.max_wait_s = wait;
        }
        let mut m = run_trace(&cfg, &trace, seed).expect("dispatch ablation run");
        let cold = m.cold_rate();
        let mean = m.mean_latency_ms();
        let p95 = m.latency_percentile_ms(95.0);
        println!(
            "{:<6} {:>6.2} {:>5} {:>9} {:>6.1}% {:>9.1} {:>9.1} {:>9.1} {:>9} {:>7}",
            mode,
            wait,
            seed,
            m.completed,
            cold * 100.0,
            mean,
            p95,
            m.mean_pending_wait_ms(),
            m.enqueued,
            m.rejected
        );
        rows.push(obj(vec![
            ("mode", mode.into()),
            ("max_wait_s", wait.into()),
            ("seed", seed.into()),
            ("completed", m.completed.into()),
            ("cold_rate", cold.into()),
            ("mean_ms", mean.into()),
            ("p95_ms", p95.into()),
            ("mean_pending_wait_ms", m.mean_pending_wait_ms().into()),
            ("enqueued", m.enqueued.into()),
            ("rejected", m.rejected.into()),
            ("worker_seconds", m.worker_seconds.into()),
        ]));
        (cold, m.worker_seconds)
    };

    for &seed in seeds {
        let (c, _) = run_cell("push", 0.0, seed, &mut rows);
        cold_push += c / seeds.len() as f64;
    }
    for &wait in waits {
        for &seed in seeds {
            let (c, _) = run_cell("pull", wait, seed, &mut rows);
            if (wait - 0.5).abs() < 1e-9 {
                cold_pull += c / seeds.len() as f64;
            }
        }
    }

    // ---- scale-to-zero pricing: the trace plus a 60 s idle tail ----
    println!("# scale-to-zero: reactive autoscale, min_workers 1 vs 0, 60 s idle tail");
    let tail = 60.0;
    let mut z_rows: Vec<Json> = Vec::new();
    let mut ws = [0.0f64; 2];
    for (i, &floor) in [1usize, 0].iter().enumerate() {
        let mut cfg = base_cfg(dur + tail);
        cfg.dispatch.mode = "pull".into();
        cfg.cluster.workers = 2;
        cfg.autoscale.policy = "reactive".into();
        cfg.autoscale.min_workers = floor;
        cfg.autoscale.max_workers = 10;
        let mut m = run_trace(&cfg, &trace, 1).expect("scale-to-zero run");
        println!(
            "min_workers={} -> worker-seconds {:>8.0}, cold {:>5.1}%, p95 {:>8.1} ms",
            floor,
            m.worker_seconds,
            m.cold_rate() * 100.0,
            m.latency_percentile_ms(95.0)
        );
        ws[i] = m.worker_seconds;
        z_rows.push(obj(vec![
            ("min_workers", floor.into()),
            ("worker_seconds", m.worker_seconds.into()),
            ("cold_rate", m.cold_rate().into()),
            ("p95_ms", m.latency_percentile_ms(95.0).into()),
            ("completed", m.completed.into()),
        ]));
    }

    let reduction =
        if cold_push > 0.0 { (cold_push - cold_pull) / cold_push * 100.0 } else { 0.0 };
    println!(
        "cold-start fraction: push {:.2}% -> pull(0.5s) {:.2}%  ({reduction:.1}% reduction)",
        cold_push * 100.0,
        cold_pull * 100.0
    );
    let out = obj(vec![
        ("bench", "dispatch".into()),
        ("quick", quick.into()),
        ("cold_rate_push", cold_push.into()),
        ("cold_rate_pull_wait0_5", cold_pull.into()),
        ("cold_reduction_pct", reduction.into()),
        ("scale_to_zero_worker_seconds_floor1", ws[0].into()),
        ("scale_to_zero_worker_seconds_floor0", ws[1].into()),
        ("rows", Json::Arr(rows)),
        ("scale_to_zero_rows", Json::Arr(z_rows)),
    ]);
    let path = "BENCH_dispatch.json";
    std::fs::write(path, out.to_string_pretty()).expect("write bench json");
    println!("wrote {path}");
}
