//! L2 ablation: the dispatch protocol — hiku under `dispatch.mode =
//! "push"` vs `"pull"` on the bursty open-loop workload.
//!
//! The pull rows sweep the wait deadline (`dispatch.max_wait_s`, with
//! `adaptive_wait` pinned off so the sweep actually varies the
//! deadline): how long a request with a warm prospect may park in the
//! router's pending queue before it is force-placed. The push row is
//! the pre-redesign behavior (immediate fallback placement when `PQ_f`
//! is empty), and the `pull+a` row is cost-aware waiting — per-function
//! `min(max_wait_s, ewma cold penalty)` deadlines. The headline number
//! is the cold-start fraction: parked requests that get pulled are warm
//! by construction, so pull should trade a bounded queue wait for a
//! lower cold rate on bursts.
//!
//! A second section prices scale-to-zero: the same trace with a 60 s
//! idle tail, reactive autoscaling with `min_workers` 1 vs 0 — the
//! worker-seconds delta is the cost of holding the floor, and the cold
//! rate shows what the queue-triggered wake pays for it.
//!
//! A third section is the **fairness ablation** (`dispatch.fair` DRR vs
//! the PR 4 arrival-order FIFO): a hot function monopolizes a donor
//! shard's pending queue while a background function parks alongside it;
//! cross-shard steal donation in DRR order gives the background its
//! share of every handoff, while FIFO donation lets the hot backlog
//! crowd it out until its wait deadline. Reported per function: p99
//! pending wait and the admission-reject split under per-function caps
//! (the background function must never be the one rejecting).
//!
//! Emits machine-readable **`BENCH_dispatch.json`** (one row per run +
//! aggregate cold-rate/cost keys) — the committed experiment recipe is
//! in EXPERIMENTS.md §Dispatch. The equivalence/reduction contracts are
//! enforced separately by `tests/determinism.rs` (push bit-identity) and
//! `tests/dispatch.rs` (pull never cold-starts more than push on this
//! workload).
//!
//! Usage:
//!   cargo bench --bench ablation_dispatch            # full table
//!   cargo bench --bench ablation_dispatch -- --quick # CI smoke

use hiku::config::Config;
use hiku::report::{bursty_trace, monopoly_trace};
use hiku::sim::run_trace;
use hiku::util::json::{obj, Json};

fn base_cfg(dur: f64) -> Config {
    let mut cfg = Config::default();
    cfg.scheduler.name = "hiku".into();
    cfg.workload.vus = 1; // open loop ignores the VU scripts
    cfg.workload.duration_s = dur;
    cfg
}

/// The fairness-ablation config: 3 workers over 2 shards (donor shard 1
/// owns a single worker), short epochs, per-function admission caps and
/// a small steal batch so drain order decides who a handoff serves.
/// `adaptive_wait` is pinned off so the fair-vs-FIFO axis is the only
/// difference between the two rows.
fn fairness_cfg(dur: f64, fair: bool) -> Config {
    let mut cfg = base_cfg(dur);
    cfg.cluster.workers = 3;
    cfg.sim.shards = 2;
    cfg.sim.barrier_s = 0.25;
    cfg.dispatch.mode = "pull".into();
    cfg.dispatch.max_wait_s = 1.0;
    cfg.dispatch.adaptive_wait = false;
    cfg.dispatch.queue_cap = 10;
    cfg.dispatch.steal_batch = 2;
    cfg.dispatch.fair = fair;
    cfg
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let dur = if quick { 30.0 } else { 120.0 };
    let seeds: &[u64] = if quick { &[1] } else { &[1, 2, 3] };
    let waits: &[f64] = if quick { &[0.5] } else { &[0.25, 0.5, 1.0] };
    let trace = bursty_trace(40, dur, 42);
    println!(
        "# dispatch ablation: hiku push vs pull, bursty trace ({} arrivals / {:.0} s), {} workers",
        trace.len(),
        dur,
        Config::default().cluster.workers
    );
    println!(
        "{:<6} {:>6} {:>5} {:>9} {:>7} {:>9} {:>9} {:>9} {:>9} {:>7}",
        "mode", "wait_s", "seed", "completed", "cold%", "mean(ms)", "p95(ms)", "wait(ms)",
        "enqueued", "reject"
    );

    let mut rows: Vec<Json> = Vec::new();
    let mut cold_push = 0.0f64;
    let mut cold_pull = 0.0f64; // at the fixed 0.5 s deadline
    let mut cold_adaptive = 0.0f64; // cost-aware deadlines
    // Fixed-wait rows pin `adaptive_wait = false` so the sweep actually
    // varies the deadline; the `pull+a` row is the cost-aware variant
    // (per-function `min(max_wait_s, ewma cold penalty)` deadlines).
    let mut run_cell =
        |mode: &str, wait: f64, adaptive: bool, seed: u64, rows: &mut Vec<Json>| -> (f64, f64) {
            let mut cfg = base_cfg(dur);
            cfg.dispatch.mode = mode.trim_end_matches("+a").into();
            cfg.dispatch.adaptive_wait = adaptive;
            if wait > 0.0 {
                cfg.dispatch.max_wait_s = wait;
            }
            let mut m = run_trace(&cfg, &trace, seed).expect("dispatch ablation run");
            let cold = m.cold_rate();
            let mean = m.mean_latency_ms();
            let p95 = m.latency_percentile_ms(95.0);
            println!(
                "{:<6} {:>6.2} {:>5} {:>9} {:>6.1}% {:>9.1} {:>9.1} {:>9.1} {:>9} {:>7}",
                mode,
                wait,
                seed,
                m.completed,
                cold * 100.0,
                mean,
                p95,
                m.mean_pending_wait_ms(),
                m.enqueued,
                m.rejected
            );
            rows.push(obj(vec![
                ("mode", mode.into()),
                ("max_wait_s", wait.into()),
                ("adaptive_wait", adaptive.into()),
                ("seed", seed.into()),
                ("completed", m.completed.into()),
                ("cold_rate", cold.into()),
                ("mean_ms", mean.into()),
                ("p95_ms", p95.into()),
                ("mean_pending_wait_ms", m.mean_pending_wait_ms().into()),
                ("enqueued", m.enqueued.into()),
                ("rejected", m.rejected.into()),
                ("worker_seconds", m.worker_seconds.into()),
            ]));
            (cold, m.worker_seconds)
        };

    for &seed in seeds {
        let (c, _) = run_cell("push", 0.0, false, seed, &mut rows);
        cold_push += c / seeds.len() as f64;
    }
    for &wait in waits {
        for &seed in seeds {
            let (c, _) = run_cell("pull", wait, false, seed, &mut rows);
            if (wait - 0.5).abs() < 1e-9 {
                cold_pull += c / seeds.len() as f64;
            }
        }
    }
    for &seed in seeds {
        let (c, _) = run_cell("pull+a", 0.5, true, seed, &mut rows);
        cold_adaptive += c / seeds.len() as f64;
    }

    // ---- scale-to-zero pricing: the trace plus a 60 s idle tail ----
    println!("# scale-to-zero: reactive autoscale, min_workers 1 vs 0, 60 s idle tail");
    let tail = 60.0;
    let mut z_rows: Vec<Json> = Vec::new();
    let mut ws = [0.0f64; 2];
    for (i, &floor) in [1usize, 0].iter().enumerate() {
        let mut cfg = base_cfg(dur + tail);
        cfg.dispatch.mode = "pull".into();
        cfg.cluster.workers = 2;
        cfg.autoscale.policy = "reactive".into();
        cfg.autoscale.min_workers = floor;
        cfg.autoscale.max_workers = 10;
        let mut m = run_trace(&cfg, &trace, 1).expect("scale-to-zero run");
        println!(
            "min_workers={} -> worker-seconds {:>8.0}, cold {:>5.1}%, p95 {:>8.1} ms",
            floor,
            m.worker_seconds,
            m.cold_rate() * 100.0,
            m.latency_percentile_ms(95.0)
        );
        ws[i] = m.worker_seconds;
        z_rows.push(obj(vec![
            ("min_workers", floor.into()),
            ("worker_seconds", m.worker_seconds.into()),
            ("cold_rate", m.cold_rate().into()),
            ("p95_ms", m.latency_percentile_ms(95.0).into()),
            ("completed", m.completed.into()),
        ]));
    }

    // ---- fairness ablation: DRR vs arrival-order FIFO draining ----
    println!(
        "# fairness: hot-function monopoly vs background, DRR (fair) vs FIFO steal donation"
    );
    let fdur = if quick { 15.0 } else { 40.0 };
    // The shared hot-monopoly scenario — exactly what
    // tests/dispatch.rs::fair_drr_bounds_starved_function_wait_vs_fifo
    // proves, so the CI gate and the test cannot drift apart.
    let ftrace = monopoly_trace(24.0, fdur, true);
    let mut f_rows: Vec<Json> = Vec::new();
    // [fair, fifo] × (hot p99 wait, bg p99 wait, hot rejects, bg rejects)
    let mut fairness = [(0.0f64, 0.0f64, 0u64, 0u64); 2];
    for (i, &fair) in [true, false].iter().enumerate() {
        let cfg = fairness_cfg(fdur, fair);
        let mut m = run_trace(&cfg, &ftrace, 1).expect("fairness ablation run");
        let hot_p99 = m.pending_wait_p99_fn_ms(0);
        let bg_p99 = m.pending_wait_p99_fn_ms(1);
        let hot_rej = m.reject_count_fn(0);
        let bg_rej = m.reject_count_fn(1);
        fairness[i] = (hot_p99, bg_p99, hot_rej, bg_rej);
        println!(
            "{:<5} -> hot p99 wait {:>8.1} ms, bg p99 wait {:>8.1} ms, rejects hot/bg {}/{}, \
             stolen {}",
            if fair { "fair" } else { "fifo" },
            hot_p99,
            bg_p99,
            hot_rej,
            bg_rej,
            m.stolen
        );
        f_rows.push(obj(vec![
            ("fair", fair.into()),
            ("hot_p99_wait_ms", hot_p99.into()),
            ("bg_p99_wait_ms", bg_p99.into()),
            ("hot_rejects", hot_rej.into()),
            ("bg_rejects", bg_rej.into()),
            ("stolen", m.stolen.into()),
            ("enqueued", m.enqueued.into()),
            ("completed", m.completed.into()),
        ]));
    }

    let reduction =
        if cold_push > 0.0 { (cold_push - cold_pull) / cold_push * 100.0 } else { 0.0 };
    println!(
        "cold-start fraction: push {:.2}% -> pull(0.5s) {:.2}%  ({reduction:.1}% reduction)",
        cold_push * 100.0,
        cold_pull * 100.0
    );
    let out = obj(vec![
        ("bench", "dispatch".into()),
        ("quick", quick.into()),
        ("cold_rate_push", cold_push.into()),
        ("cold_rate_pull_wait0_5", cold_pull.into()),
        ("cold_rate_pull_adaptive", cold_adaptive.into()),
        ("cold_reduction_pct", reduction.into()),
        ("scale_to_zero_worker_seconds_floor1", ws[0].into()),
        ("scale_to_zero_worker_seconds_floor0", ws[1].into()),
        ("fairness_hot_p99_wait_ms_fair", fairness[0].0.into()),
        ("fairness_bg_p99_wait_ms_fair", fairness[0].1.into()),
        ("fairness_hot_p99_wait_ms_fifo", fairness[1].0.into()),
        ("fairness_bg_p99_wait_ms_fifo", fairness[1].1.into()),
        ("fairness_hot_rejects_fair", fairness[0].2.into()),
        ("fairness_bg_rejects_fair", fairness[0].3.into()),
        ("rows", Json::Arr(rows)),
        ("scale_to_zero_rows", Json::Arr(z_rows)),
        ("fairness_rows", Json::Arr(f_rows)),
    ]);
    let path = "BENCH_dispatch.json";
    std::fs::write(path, out.to_string_pretty()).expect("write bench json");
    println!("wrote {path}");
}
