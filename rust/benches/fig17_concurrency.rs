//! Fig 17: throughput (requests/s) under increasing concurrency.
//!
//! Paper: at 20 VUs all algorithms are similar; at 50 VUs pull-based
//! processes 61.3 rps vs CH-BL 58.3; at 100 VUs pull-based reaches 78 rps
//! vs 51.2-69 for the others — the gap widens with concurrency.

use hiku::config::Config;
use hiku::report::run_cell;

const SCHEDS: [&str; 4] = ["hiku", "ch-bl", "random", "least-connections"];
const RUNS: u64 = 5;

fn main() {
    let mut base = Config::default();
    base.workload.duration_s = 120.0;

    println!("# Fig 17 — concurrency sweep ({RUNS} runs x 120 s)");
    println!("  paper rps: 20 VUs ~equal | 50 VUs pull 61.3, CH-BL 58.3 | 100 VUs pull 78, others 51.2-69\n");
    println!(
        "{:<20} {:>10} {:>10} {:>10}",
        "scheduler", "20 VUs", "50 VUs", "100 VUs"
    );
    let mut rows = Vec::new();
    for s in SCHEDS {
        let mut row = Vec::new();
        for vus in [20usize, 50, 100] {
            let (agg, _) = run_cell(&base, s, vus, RUNS).expect("sweep");
            row.push(agg.rps.mean());
        }
        println!("{:<20} {:>10.1} {:>10.1} {:>10.1}", s, row[0], row[1], row[2]);
        rows.push((s, row));
    }
    let hiku = &rows[0].1;
    let chbl = &rows[1].1;
    println!(
        "\nhiku/CH-BL rps ratio: {:.2} @20 -> {:.2} @50 -> {:.2} @100 (advantage must widen)",
        hiku[0] / chbl[0],
        hiku[1] / chbl[1],
        hiku[2] / chbl[2]
    );
}
