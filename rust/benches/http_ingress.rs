//! End-to-end HTTP front-door bench: the in-tree ingress
//! (`server::http::HttpIngress`) under the open-loop loadgen
//! (`workload::loadgen::run_http_loadgen`) over real sockets on
//! localhost — the full client → parser → router → stub-worker →
//! response path, DESIGN.md §13.
//!
//! Runs on the stub runtime backend (`runtime.backend = "stub"`), so no
//! AOT artifacts are needed: workers replay Table-I cold/warm latencies
//! scaled down by `runtime.stub_speedup`. The headline numbers are
//! sustained throughput and end-to-end latency percentiles, plus the
//! conservation identity on both sides of the socket: every issued
//! request is accounted for by the loadgen (completed + rejected +
//! failed + transport errors) AND by the server (arrivals == completed
//! + rejected + failed once drained).
//!
//! Emits machine-readable **`BENCH_http.json`** — the committed
//! experiment recipe is in EXPERIMENTS.md §HTTP.
//!
//! Usage:
//!   cargo bench --bench http_ingress            # 10k requests @ 1000 rps
//!   cargo bench --bench http_ingress -- --quick # CI smoke: 1k @ 500 rps

use hiku::config::Config;
use hiku::server::http::HttpIngress;
use hiku::util::json::{obj, Json};
use hiku::workload::loadgen::{run_http_loadgen, LoadgenOpts};

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let (requests, rate_rps) = if quick { (1_000usize, 500.0) } else { (10_000usize, 1_000.0) };

    let mut cfg = Config::default();
    cfg.runtime.backend = "stub".into();
    cfg.scheduler.name = "hiku".into();
    cfg.dispatch.mode = "pull".into();
    cfg.cluster.workers = 4;
    cfg.http.io_threads = 16;
    cfg.validate().expect("bench config");

    let ingress = HttpIngress::start(&cfg, "127.0.0.1:0").expect("start ingress");
    let addr = ingress.local_addr().to_string();
    println!(
        "# http ingress bench: {requests} requests @ {rate_rps:.0} rps open-loop on {addr} \
         ({} stub workers, pull dispatch)",
        cfg.cluster.workers
    );

    let opts = LoadgenOpts {
        addr,
        requests,
        rate_rps,
        connections: 8,
        num_functions: cfg.num_functions(),
        seed: 42,
        ..Default::default()
    };
    let report = run_http_loadgen(&opts).expect("loadgen run");
    let mut m = ingress.stop().expect("ingress stop");

    // Conservation, client side: every scheduled request is accounted.
    assert!(report.accounted(), "loadgen accounting must balance");
    assert_eq!(report.sent, requests, "loadgen must issue the whole schedule");
    assert_eq!(report.transport_errors, 0, "no dropped connections expected on localhost");
    // Conservation, server side: after drain, every admitted arrival
    // resolved (completed, rejected at admission, or failed).
    assert_eq!(
        m.arrivals,
        m.completed + m.rejected + m.failed,
        "server-side conservation identity must hold after drain"
    );
    assert_eq!(m.completed, report.completed, "both sides must agree on completions");

    println!(
        "loadgen : {} sent, {} completed, {} rejected, {} failed in {:.2} s -> {:.0} rps",
        report.sent,
        report.completed,
        report.rejected,
        report.failed,
        report.duration_s,
        report.throughput_rps()
    );
    println!(
        "latency : mean {:.2} ms, p50 {:.2} ms, p95 {:.2} ms, p99 {:.2} ms",
        report.mean_ms(),
        report.percentile_ms(50.0),
        report.percentile_ms(95.0),
        report.percentile_ms(99.0)
    );
    println!(
        "server  : {} arrivals, cold rate {:.1}%, prewarm spawned/hit {}/{}",
        m.arrivals,
        m.cold_rate() * 100.0,
        m.prewarm_spawned,
        m.prewarm_hits
    );

    let out = obj(vec![
        ("bench", "http".into()),
        ("quick", quick.into()),
        ("requests", requests.into()),
        ("rate_rps", rate_rps.into()),
        ("connections", opts.connections.into()),
        ("workers", cfg.cluster.workers.into()),
        ("io_threads", cfg.http.io_threads.into()),
        ("throughput_rps", report.throughput_rps().into()),
        ("duration_s", report.duration_s.into()),
        ("mean_ms", report.mean_ms().into()),
        ("p50_ms", report.percentile_ms(50.0).into()),
        ("p95_ms", report.percentile_ms(95.0).into()),
        ("p99_ms", report.percentile_ms(99.0).into()),
        ("completed", report.completed.into()),
        ("rejected", report.rejected.into()),
        ("failed", report.failed.into()),
        ("transport_errors", report.transport_errors.into()),
        ("server_arrivals", m.arrivals.into()),
        ("server_cold_rate", m.cold_rate().into()),
        ("loadgen", report.to_json()),
    ]);
    let path = "BENCH_http.json";
    std::fs::write(path, out.to_string_pretty()).expect("write bench json");
    println!("wrote {path}");
}
