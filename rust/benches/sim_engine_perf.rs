//! L3 engine performance: simulated-events/s and per-layer cost breakdown.
//! This is the §Perf before/after bench for the optimization pass.

use hiku::config::Config;
use hiku::sim::run_once;
use hiku::workload::loadgen::Workload;
use std::time::Instant;

fn main() {
    let mut cfg = Config::default();
    cfg.workload.vus = 100;
    cfg.workload.duration_s = 300.0;

    // Layer: workload generation.
    let t0 = Instant::now();
    let w = Workload::generate(&cfg.workload, 40, 42);
    let gen_s = t0.elapsed().as_secs_f64();
    println!(
        "workload generation: {:.1} ms ({} scripted steps)",
        gen_s * 1000.0,
        w.total_steps()
    );

    // Layer: one full 300 s x 100 VU run per scheduler.
    for sched in ["hiku", "ch-bl", "random", "least-connections"] {
        cfg.scheduler.name = sched.into();
        let t0 = Instant::now();
        let m = run_once(&cfg, 42).expect("run");
        let wall = t0.elapsed().as_secs_f64();
        // Events per completed request: arrival + completion + keepalive
        // (~1 per idle period) — report requests/s and a >=3x event bound.
        let reqs = m.completed as f64;
        println!(
            "{:<20} {:>7.0} requests in {:>6.1} ms  ({:>5.2} M req/s, >= {:>5.2} M events/s)",
            sched,
            reqs,
            wall * 1000.0,
            reqs / wall / 1e6,
            3.0 * reqs / wall / 1e6
        );
    }

    // Layer: metrics summarization.
    cfg.scheduler.name = "hiku".into();
    let mut m = run_once(&cfg, 43).expect("run");
    let t0 = Instant::now();
    let _ = m.summary_json();
    println!("metrics summarization: {:.2} ms", t0.elapsed().as_secs_f64() * 1000.0);
}
