//! L3 engine performance: the event-core scaling sweep.
//!
//! Runs closed- and open-loop simulations at 1k / 10k / 100k workers on
//! the optimized engine (calendar queue + incremental load accounting)
//! and, where affordable, on the seed reference engine (`BinaryHeap` +
//! full-cluster scans, behind the `ref-heap` feature) — same binary, same
//! (config, seed), bit-identical results (see tests/determinism.rs), so
//! the events/s ratio is a pure engine-cost comparison.
//!
//! Emits machine-readable `BENCH_sim_engine.json` (events/s, wall time,
//! peak queue length per scale point, plus per-scale speedups) so future
//! PRs have a perf trajectory to regress against.
//!
//! Two sweeps:
//! - **core sweep**: calendar engine vs the seed `ref-heap` engine,
//!   serial, per scheduler/mode/scale (`speedup_1k`/`speedup_10k`);
//! - **shard sweep**: the calendar engine at `--shards {1,2,4}` (hiku,
//!   closed loop) — workload generation outside the timer, so the
//!   `shard_speedup_*` keys are pure engine-parallelism ratios. The
//!   sampled tie-break row (`calendar-sampled`) shows least-connections
//!   running at 100k workers with `scheduler.tie_sample_d = 2`.
//!
//! Usage:
//!   cargo bench --bench sim_engine_perf            # full sweep
//!   cargo bench --bench sim_engine_perf -- --quick # CI smoke (~seconds)
//!                                                  # (includes --shards 2)
//!
//! Notes on the sweep shape:
//! - closed loop uses 24 VUs/worker at 1k/10k (the paper's
//!   high-concurrency regime: the event set is hundreds of thousands of
//!   pending events and every worker holds ~a dozen outstanding
//!   requests) and 1 VU/worker at 100k (bounded warm-up cost);
//! - the reference engine is only run at 1k/10k — at 100k the seed's
//!   O(workers) per-decision scans would run for many minutes, which is
//!   exactly the point of the overhaul;
//! - least-connections keeps the seed's *exact* uniform-random
//!   tie-breaking (one RNG draw per tied worker, bit-identical streams),
//!   so its per-decision cost is inherently Θ(tie set) in *both* engines
//!   and the tie set under load-equalizing schedulers is Θ(workers). It
//!   is measured at the 1k point for the trajectory but excluded from the
//!   headline speedup aggregate and from the larger scale points; hiku's
//!   *fallback* uses the same rule but fires only when PQ_f is empty.

use hiku::config::Config;
use hiku::metrics::RunMetrics;
use hiku::scheduler::make_scheduler;
use hiku::sim::shard::run_sharded_with;
use hiku::sim::Simulation;
use hiku::util::json::{obj, Json};
use hiku::util::rng::Pcg64;
use hiku::workload::azure::BurstyArrivals;
use hiku::workload::loadgen::{OpenLoopTrace, Workload};
use hiku::workload::spec::FunctionRegistry;
use std::time::Instant;

const SEED: u64 = 42;

struct Row {
    workers: usize,
    mode: &'static str,
    scheduler: &'static str,
    core: &'static str,
    shards: usize,
    completed: u64,
    events: u64,
    wall_s: f64,
    events_per_s: f64,
    peak_queue: usize,
}

impl Row {
    fn json(&self) -> Json {
        obj(vec![
            ("workers", self.workers.into()),
            ("mode", self.mode.into()),
            ("scheduler", self.scheduler.into()),
            ("core", self.core.into()),
            ("shards", self.shards.into()),
            ("completed", self.completed.into()),
            ("events", self.events.into()),
            ("wall_s", self.wall_s.into()),
            ("events_per_s", self.events_per_s.into()),
            ("peak_queue_len", self.peak_queue.into()),
        ])
    }
}

fn scale_cfg(workers: usize, sched: &'static str, duration_s: f64, vus_mult: usize) -> Config {
    let mut cfg = Config::default();
    cfg.cluster.workers = workers;
    cfg.scheduler.name = sched.into();
    cfg.workload.vus = vus_mult * workers;
    cfg.workload.duration_s = duration_s;
    // Exercise the control-tick paths the overhaul made incremental.
    cfg.cluster.prewarm = true;
    cfg
}

fn build_sim<'a>(
    cfg: &'a Config,
    registry: &'a FunctionRegistry,
    workload: &'a Workload,
    reference: bool,
) -> Simulation<'a> {
    let sched = make_scheduler(&cfg.scheduler, cfg.cluster.workers).expect("scheduler");
    let sim = Simulation::new(cfg, registry, workload, sched, SEED);
    if reference {
        sim.with_reference_core()
    } else {
        sim
    }
}

fn run_closed(cfg: &Config, reference: bool) -> (RunMetrics, f64) {
    let registry = FunctionRegistry::functionbench(cfg.workload.copies);
    let workload = Workload::generate(&cfg.workload, registry.len(), SEED);
    let sim = build_sim(cfg, &registry, &workload, reference);
    let t0 = Instant::now();
    let m = sim.run();
    (m, t0.elapsed().as_secs_f64())
}

fn run_open(cfg: &Config, trace: &OpenLoopTrace, reference: bool) -> (RunMetrics, f64) {
    let registry = FunctionRegistry::functionbench(cfg.workload.copies);
    let mut wcfg = cfg.workload.clone();
    wcfg.vus = 1; // placeholder scripts; open loop ignores them
    let workload = Workload::generate(&wcfg, registry.len(), SEED);
    let sim = build_sim(cfg, &registry, &workload, reference);
    let t0 = Instant::now();
    let m = sim.run_open_loop(trace);
    (m, t0.elapsed().as_secs_f64())
}

/// Open-loop trace with arrival rate proportional to the cluster size
/// (`rate` req/s/worker), uniform over the 40 function types.
fn make_trace(workers: usize, duration_s: f64, rate: f64) -> OpenLoopTrace {
    let mut rng = Pcg64::new(SEED ^ 0x7ACE);
    let gen = BurstyArrivals { base_rate: rate * workers as f64, ..Default::default() };
    let times = gen.generate(duration_s, &mut rng);
    let invocations: Vec<(f64, usize)> = times.into_iter().map(|t| (t, rng.index(40))).collect();
    OpenLoopTrace::from_synthetic(&invocations, 40)
}

fn record(
    rows: &mut Vec<Row>,
    workers: usize,
    mode: &'static str,
    scheduler: &'static str,
    core: &'static str,
    m: &RunMetrics,
    wall: f64,
) {
    record_sharded(rows, workers, mode, scheduler, core, 1, m, wall);
}

#[allow(clippy::too_many_arguments)]
fn record_sharded(
    rows: &mut Vec<Row>,
    workers: usize,
    mode: &'static str,
    scheduler: &'static str,
    core: &'static str,
    shards: usize,
    m: &RunMetrics,
    wall: f64,
) {
    let events_per_s = m.events_processed as f64 / wall.max(1e-9);
    println!(
        "{workers:>7} workers  {mode:<6} {scheduler:<18} {core:<9} x{shards} \
         {:>9} reqs  {:>10} events  {:>8.1} ms  {:>7.2} M events/s  peak queue {}",
        m.completed,
        m.events_processed,
        wall * 1000.0,
        events_per_s / 1e6,
        m.peak_event_queue,
    );
    rows.push(Row {
        workers,
        mode,
        scheduler,
        core,
        shards,
        completed: m.completed,
        events: m.events_processed,
        wall_s: wall,
        events_per_s,
        peak_queue: m.peak_event_queue,
    });
}

/// Aggregate events/s speedup (calendar vs reference) over all rows at one
/// scale point and mode. Least-connections is excluded: its exact
/// uniform-random tie-breaking is Θ(tie set) in both engines by
/// construction (see module docs), so it measures tie-set size, not
/// engine cost; its rows stay in the JSON for transparency.
fn speedup(rows: &[Row], workers: usize, mode: &str) -> Option<f64> {
    let sum = |core: &str| {
        let (ev, wall) = rows
            .iter()
            .filter(|r| {
                r.workers == workers
                    && r.mode == mode
                    && r.core == core
                    && r.shards == 1 // shard-sweep rows have their own aggregate
                    && r.scheduler != "least-connections"
            })
            .fold((0u64, 0f64), |(e, w), r| (e + r.events, w + r.wall_s));
        if wall > 0.0 {
            Some(ev as f64 / wall)
        } else {
            None
        }
    };
    Some(sum("calendar")? / sum("ref-heap")?)
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let mut rows: Vec<Row> = Vec::new();

    // (workers, closed-loop duration_s, VUs per worker, schedulers,
    //  run the reference engine too)
    type ScalePoint = (usize, f64, usize, Vec<&'static str>, bool);
    let scale_points: Vec<ScalePoint> = if quick {
        vec![(1_000, 4.0, 8, vec!["hiku"], true)]
    } else {
        vec![
            (1_000, 30.0, 24, vec!["hiku", "least-connections", "ch-bl", "jsq", "random"], true),
            (10_000, 12.0, 24, vec!["hiku", "ch-bl", "jsq", "random"], true),
            // The reference engine is deliberately skipped at 100k (the
            // seed scans would run for minutes); least-connections is
            // skipped beyond 1k since its exact tie-breaking semantics
            // are inherently tie-set-bound (see module docs).
            (100_000, 6.0, 1, vec!["hiku", "random"], false),
        ]
    };

    println!("# sim_engine scaling sweep (calendar queue + incremental accounting vs seed)");
    for (workers, dur, vus_mult, scheds, with_ref) in &scale_points {
        for &sched in scheds {
            let cfg = scale_cfg(*workers, sched, *dur, *vus_mult);
            let (m, wall) = run_closed(&cfg, false);
            record(&mut rows, *workers, "closed", sched, "calendar", &m, wall);
            if *with_ref {
                let (m, wall) = run_closed(&cfg, true);
                record(&mut rows, *workers, "closed", sched, "ref-heap", &m, wall);
            }
        }
        // Open loop: hiku against a rate-scaled bursty trace.
        let open_dur = (*dur).min(10.0);
        let rate = if *workers >= 100_000 { 1.0 } else { 2.0 };
        let trace = make_trace(*workers, open_dur, rate);
        let cfg = scale_cfg(*workers, "hiku", open_dur, *vus_mult);
        let (m, wall) = run_open(&cfg, &trace, false);
        record(&mut rows, *workers, "open", "hiku", "calendar", &m, wall);
        if *with_ref {
            let (m, wall) = run_open(&cfg, &trace, true);
            record(&mut rows, *workers, "open", "hiku", "ref-heap", &m, wall);
        }
    }

    // ---- shard-scaling sweep: the same calendar engine partitioned ----
    // across N OS threads behind the event-time barrier. Workload
    // generation stays outside the timer so the ratio is pure engine
    // cost; shards=1 is the serial engine (the `--shards 1` path).
    // (workers, duration_s, VUs/worker, shard counts)
    let shard_points: Vec<(usize, f64, usize, Vec<usize>)> = if quick {
        vec![(1_000, 4.0, 8, vec![1, 2])]
    } else {
        vec![(10_000, 12.0, 24, vec![1, 2, 4]), (100_000, 6.0, 1, vec![1, 2, 4])]
    };
    println!("# shard scaling (hiku closed loop, calendar core, N OS threads)");
    let mut shard_eps: Vec<(usize, usize, f64)> = Vec::new(); // (workers, shards, events/s)
    for (workers, dur, vus_mult, counts) in &shard_points {
        let cfg0 = scale_cfg(*workers, "hiku", *dur, *vus_mult);
        let registry = FunctionRegistry::functionbench(cfg0.workload.copies);
        let workload = Workload::generate(&cfg0.workload, registry.len(), SEED);
        for &sh in counts {
            let mut cfg = cfg0.clone();
            cfg.sim.shards = sh;
            let (m, wall) = if sh <= 1 {
                let sim = build_sim(&cfg, &registry, &workload, false);
                let t0 = Instant::now();
                let m = sim.run();
                (m, t0.elapsed().as_secs_f64())
            } else {
                let t0 = Instant::now();
                let m = run_sharded_with(&cfg, &registry, &workload, None, SEED)
                    .expect("sharded run");
                (m, t0.elapsed().as_secs_f64())
            };
            record_sharded(&mut rows, *workers, "closed", "hiku", "calendar", sh, &m, wall);
            shard_eps.push((*workers, sh, m.events_processed as f64 / wall.max(1e-9)));
        }
    }

    // Sampled tie-break: least-connections is now feasible at 100k
    // workers with the O(d) power-of-d variant (scheduler.tie_sample_d);
    // the exact-semantics rule stays excluded above (Θ(tie set)).
    if !quick {
        let mut cfg = scale_cfg(100_000, "least-connections", 6.0, 1);
        cfg.scheduler.tie_sample_d = 2;
        let (m, wall) = run_closed(&cfg, false);
        record(&mut rows, 100_000, "closed", "least-connections", "calendar-sampled", &m, wall);
    }

    // Dispatch-protocol overhead: hiku under `dispatch.mode = "pull"` at
    // the 10k closed-loop point (1k in quick mode) — pending-queue,
    // deadline-event and pull-bind machinery measured against the plain
    // push rows at the same scale. Like `calendar-sampled`, the distinct
    // core tag keeps the row out of the push-vs-reference speedup
    // aggregates (pull changes the event stream by design).
    {
        let (workers, dur, vus_mult) =
            if quick { (1_000, 4.0, 8) } else { (10_000, 12.0, 24) };
        let mut cfg = scale_cfg(workers, "hiku", dur, vus_mult);
        cfg.dispatch.mode = "pull".into();
        let (m, wall) = run_closed(&cfg, false);
        record(&mut rows, workers, "closed", "hiku", "calendar-pull", &m, wall);
    }

    // Engine phase profile: one profiled sharded pull run so the bench
    // JSON carries the phase breakdown (`phase_*_frac` — event pop,
    // decide, barrier merge, handoff, autoscale tick as fractions of the
    // profiled wall time) plus process peak RSS. The distinct core tag
    // keeps the row out of every speedup aggregate: the phase timers add
    // measurement overhead by design.
    let profile: Option<(hiku::metrics::PhaseProfile, f64)> = {
        let (workers, dur, vus_mult) =
            if quick { (1_000, 4.0, 8) } else { (10_000, 12.0, 24) };
        let mut cfg = scale_cfg(workers, "hiku", dur, vus_mult);
        cfg.dispatch.mode = "pull".into();
        cfg.sim.shards = 2;
        cfg.telemetry.phase_profile = true;
        let registry = FunctionRegistry::functionbench(cfg.workload.copies);
        let workload = Workload::generate(&cfg.workload, registry.len(), SEED);
        let t0 = Instant::now();
        let m =
            run_sharded_with(&cfg, &registry, &workload, None, SEED).expect("profiled run");
        let wall = t0.elapsed().as_secs_f64();
        record_sharded(&mut rows, workers, "closed", "hiku", "calendar-profiled", 2, &m, wall);
        println!(
            "phase profile @ {workers} workers x2 shards: pop {:.1}% decide {:.1}% \
             barrier {:.1}% handoff {:.1}% autoscale {:.1}% of {:.2} s profiled wall",
            m.phases.frac(m.phases.pop_s) * 100.0,
            m.phases.frac(m.phases.decide_s) * 100.0,
            m.phases.frac(m.phases.barrier_s) * 100.0,
            m.phases.frac(m.phases.handoff_s) * 100.0,
            m.phases.frac(m.phases.autoscale_s) * 100.0,
            m.phases.wall_s,
        );
        let eps = m.events_processed as f64 / wall.max(1e-9);
        Some((m.phases.clone(), eps))
    };

    // Per-scale aggregate speedups (the acceptance gate reads speedup_10k).
    let mut summary: Vec<(&'static str, Json)> = vec![
        ("bench", "sim_engine".into()),
        ("quick", quick.into()),
        (
            "speedup_note",
            "aggregate events/s per scale point, calendar engine vs seed ref-heap engine \
             (same binary, bit-identical runs); least-connections rows excluded from the \
             aggregate (tie-set-bound by its exact-semantics requirement)"
                .into(),
        ),
    ];
    for (workers, _, _, _, with_ref) in &scale_points {
        if !*with_ref {
            continue;
        }
        if let Some(s) = speedup(&rows, *workers, "closed") {
            println!("closed-loop speedup @ {workers} workers: {s:.2}x");
            let key: &'static str = match *workers {
                1_000 => "speedup_1k",
                10_000 => "speedup_10k",
                _ => "speedup_other",
            };
            summary.push((key, s.into()));
        }
        if let Some(s) = speedup(&rows, *workers, "open") {
            println!("open-loop   speedup @ {workers} workers: {s:.2}x");
        }
    }
    // Shard speedups: events/s at the highest shard count vs shards=1 at
    // the same scale (the acceptance gate reads shard_speedup_100k).
    for (workers, key) in [
        (1_000usize, "shard_speedup_1k"),
        (10_000, "shard_speedup_10k"),
        (100_000, "shard_speedup_100k"),
    ] {
        let base = shard_eps.iter().find(|&&(w, sh, _)| w == workers && sh == 1);
        let best = shard_eps
            .iter()
            .filter(|&&(w, _, _)| w == workers)
            .max_by_key(|&&(_, sh, _)| sh);
        if let (Some(&(_, _, e1)), Some(&(_, shn, en))) = (base, best) {
            if shn > 1 && e1 > 0.0 {
                let s = en / e1;
                println!("shard speedup @ {workers} workers: {s:.2}x ({shn} shards vs 1)");
                summary.push((key, s.into()));
            }
        }
    }
    if let Some((p, eps)) = profile {
        summary.push(("phase_pop_frac", p.frac(p.pop_s).into()));
        summary.push(("phase_decide_frac", p.frac(p.decide_s).into()));
        summary.push(("phase_barrier_frac", p.frac(p.barrier_s).into()));
        summary.push(("phase_handoff_frac", p.frac(p.handoff_s).into()));
        summary.push(("phase_autoscale_frac", p.frac(p.autoscale_s).into()));
        summary.push(("profiled_events_per_s", eps.into()));
        summary.push((
            "peak_rss_mb",
            match hiku::util::sysinfo::peak_rss_mb() {
                Some(v) => v.into(),
                None => Json::Null,
            },
        ));
    }
    summary.push(("rows", Json::Arr(rows.iter().map(Row::json).collect())));

    let out = obj(summary);
    let path = "BENCH_sim_engine.json";
    std::fs::write(path, out.to_string_pretty()).expect("write bench json");
    println!("wrote {path} ({} rows)", rows.len());
}
