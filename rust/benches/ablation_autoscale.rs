//! Ablation (§II-C): auto-scaling disruption. Two workers join a 4-worker
//! cluster mid-run (t=60 s, t=120 s) under moderate 60-VU load; how do the
//! schedulers absorb the scale events?
//!
//! The consistent-hashing motivation says: the ring remaps only the keys
//! the new worker steals, hash-mod remaps nearly all keys (cold storm),
//! and pull-based scheduling needs no remapping at all — the new worker
//! begins pulling as soon as it finishes fallback-routed requests.

use hiku::config::Config;
use hiku::sim::run_once;

const SCHEDS: [&str; 5] = ["hiku", "ch-bl", "consistent", "hash-mod", "least-connections"];
const SEEDS: [u64; 3] = [1, 2, 3];
/// Scale times, expressed as the `scheduled` autoscale policy's event list
/// (the policy-driven home of the old `run_scaled(cfg, seed, &[60, 120])`).
const SCALE_EVENTS: &str = "60;120";

fn window_cold_rate(cold: &[f64], total: &[f64], from: usize, to: usize) -> f64 {
    let c: f64 = cold.iter().skip(from).take(to - from).sum();
    let t: f64 = total.iter().skip(from).take(to - from).sum();
    if t == 0.0 {
        0.0
    } else {
        c / t
    }
}

fn main() {
    let mut base = Config::default();
    base.cluster.workers = 4;
    base.workload.duration_s = 180.0;
    base.workload.vus = 60;
    base.autoscale.policy = "scheduled".into();
    base.autoscale.events = SCALE_EVENTS.into();

    println!("# Ablation — auto-scaling: 4 workers -> +1 @60s -> +1 @120s, 60 VUs");
    println!("  cold-start rate per 30 s window (average of {} seeds)\n", SEEDS.len());
    println!(
        "{:<20} {:>9} {:>9} {:>9} {:>9} {:>9} {:>9} {:>8}",
        "scheduler", "0-30", "30-60", "60-90*", "90-120", "120-150*", "150-180", "mean ms"
    );
    for s in SCHEDS {
        let mut cfg = base.clone();
        cfg.scheduler.name = s.into();
        let mut windows = [0.0f64; 6];
        let mut mean_ms = 0.0;
        for &seed in &SEEDS {
            let mut m = run_once(&cfg, seed).expect("run");
            let cold = m.cold_series.bins().to_vec();
            let total = m.throughput.bins().to_vec();
            for (i, w) in windows.iter_mut().enumerate() {
                *w += window_cold_rate(&cold, &total, i * 30, (i + 1) * 30);
            }
            mean_ms += m.mean_latency_ms();
        }
        let n = SEEDS.len() as f64;
        println!(
            "{:<20} {:>8.1}% {:>8.1}% {:>8.1}% {:>8.1}% {:>8.1}% {:>8.1}% {:>8.0}",
            s,
            windows[0] / n * 100.0,
            windows[1] / n * 100.0,
            windows[2] / n * 100.0,
            windows[3] / n * 100.0,
            windows[4] / n * 100.0,
            windows[5] / n * 100.0,
            mean_ms / n
        );
    }
    println!("\n  (* = window containing a scale event. Findings: hiku absorbs scale");
    println!("   events invisibly — new capacity is used as soon as the new worker's");
    println!("   first fallback-routed executions finish. The hash-based schedulers'");
    println!("   load-oblivious churn dwarfs the remapping spike itself; hash-mod");
    println!("   additionally shows the §II-C remap bump in the * windows.)");
}
