//! Function specifications: the paper's workload of 40 functions
//! (8 FunctionBench applications × 5 identical copies, Table II), with
//! cold/warm latency calibration from Table I and a per-function service
//! time model used by the discrete-event simulator.

use crate::util::rng::Pcg64;

/// One FunctionBench application (Table I / Table II of the paper).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct BaseApp {
    /// FunctionBench application name.
    pub name: &'static str,
    /// Resource category (cpu / disk / network).
    pub category: &'static str,
    /// Mean cold-start response latency in ms (Table I).
    pub cold_ms: f64,
    /// Mean warm-start response latency in ms (Table I).
    pub warm_ms: f64,
    /// Sandbox memory footprint in MB (drives eviction pressure).
    pub mem_mb: u64,
}

/// Table I of the paper, verbatim.
pub const BASE_APPS: [BaseApp; 8] = [
    BaseApp { name: "chameleon", category: "cpu", cold_ms: 536.0, warm_ms: 392.0, mem_mb: 256 },
    BaseApp { name: "dd", category: "disk", cold_ms: 706.0, warm_ms: 549.0, mem_mb: 256 },
    BaseApp { name: "float_operation", category: "cpu", cold_ms: 263.0, warm_ms: 94.0, mem_mb: 128 },
    BaseApp { name: "gzip_compression", category: "disk", cold_ms: 510.0, warm_ms: 303.0, mem_mb: 256 },
    BaseApp { name: "json_dumps_loads", category: "network", cold_ms: 269.0, warm_ms: 105.0, mem_mb: 128 },
    BaseApp { name: "linpack", category: "cpu", cold_ms: 282.0, warm_ms: 58.0, mem_mb: 128 },
    BaseApp { name: "matmul", category: "cpu", cold_ms: 284.0, warm_ms: 125.0, mem_mb: 256 },
    BaseApp { name: "pyaes", category: "cpu", cold_ms: 329.0, warm_ms: 149.0, mem_mb: 128 },
];

/// Average cold/warm slowdown across Table I: ratio of mean cold latency to
/// mean warm latency (the paper reports "on average 1.79x slower").
pub fn mean_cold_slowdown() -> f64 {
    let cold: f64 = BASE_APPS.iter().map(|a| a.cold_ms).sum();
    let warm: f64 = BASE_APPS.iter().map(|a| a.warm_ms).sum();
    cold / warm
}

/// A concrete function type in the experiment (one of the 40).
#[derive(Clone, Debug)]
pub struct FunctionSpec {
    /// Unique name, e.g. "matmul_3".
    pub name: String,
    /// Index into BASE_APPS.
    pub app: usize,
    /// Stable id (index into the registry).
    pub id: FunctionId,
}

/// Dense function-type index into the experiment's registry.
pub type FunctionId = usize;

/// The registry of all function types for an experiment.
#[derive(Clone, Debug)]
pub struct FunctionRegistry {
    /// Every function type, indexed by [`FunctionId`].
    pub functions: Vec<FunctionSpec>,
    /// Lognormal sigma of warm execution time (Fig 5 heterogeneity: repeated
    /// executions of the same function vary significantly).
    pub exec_sigma: f64,
    /// Lognormal sigma of the cold-start initialization overhead.
    pub init_sigma: f64,
}

impl FunctionRegistry {
    /// Build the paper's registry: `copies` copies of each base app.
    pub fn functionbench(copies: usize) -> Self {
        let mut functions = Vec::with_capacity(BASE_APPS.len() * copies);
        for c in 0..copies {
            for (ai, app) in BASE_APPS.iter().enumerate() {
                let id = functions.len();
                functions.push(FunctionSpec { name: format!("{}_{c}", app.name), app: ai, id });
            }
        }
        Self { functions, exec_sigma: 0.25, init_sigma: 0.20 }
    }

    /// Subset of base apps (used by unit tests and small experiments).
    pub fn subset(apps: &[usize], copies: usize) -> Self {
        let mut functions = Vec::new();
        for c in 0..copies {
            for &ai in apps {
                let id = functions.len();
                functions.push(FunctionSpec {
                    name: format!("{}_{c}", BASE_APPS[ai].name),
                    app: ai,
                    id,
                });
            }
        }
        Self { functions, exec_sigma: 0.25, init_sigma: 0.20 }
    }

    /// Number of function types.
    pub fn len(&self) -> usize {
        self.functions.len()
    }

    /// True when the registry holds no functions.
    pub fn is_empty(&self) -> bool {
        self.functions.is_empty()
    }

    /// The function spec for `id`.
    pub fn get(&self, id: FunctionId) -> &FunctionSpec {
        &self.functions[id]
    }

    /// The base application behind function `id`.
    pub fn app(&self, id: FunctionId) -> &'static BaseApp {
        &BASE_APPS[self.functions[id].app]
    }

    /// Sandbox memory footprint of function `id`, in MB.
    pub fn mem_mb(&self, id: FunctionId) -> u64 {
        self.app(id).mem_mb
    }

    /// Reverse lookup by unique function name.
    pub fn by_name(&self, name: &str) -> Option<FunctionId> {
        self.functions.iter().position(|f| f.name == name)
    }

    /// Sample a warm execution time in seconds. Lognormal around the
    /// Table I warm latency, matching Fig 5's within-function variance.
    pub fn sample_exec_s(&self, id: FunctionId, rng: &mut Pcg64) -> f64 {
        let app = self.app(id);
        lognormal_with_mean(rng, app.warm_ms / 1000.0, self.exec_sigma)
    }

    /// Sample the *additional* cold-start initialization time in seconds
    /// (cold response = init + exec, calibrated so the means match Table I).
    pub fn sample_init_s(&self, id: FunctionId, rng: &mut Pcg64) -> f64 {
        let app = self.app(id);
        let init_mean = (app.cold_ms - app.warm_ms).max(1.0) / 1000.0;
        lognormal_with_mean(rng, init_mean, self.init_sigma)
    }
}

/// Lognormal sample with a target *mean* (not median): mu is corrected by
/// -sigma^2/2 so E[X] = mean exactly.
fn lognormal_with_mean(rng: &mut Pcg64, mean: f64, sigma: f64) -> f64 {
    debug_assert!(mean > 0.0);
    let mu = mean.ln() - sigma * sigma / 2.0;
    rng.lognormal(mu, sigma)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_slowdown_matches_paper() {
        // Paper §II-B: "cold start executions are 1.79x slower".
        let s = mean_cold_slowdown();
        assert!((s - 1.79).abs() < 0.01, "slowdown {s} drifted from Table I");
    }

    #[test]
    fn registry_has_40_functions() {
        let reg = FunctionRegistry::functionbench(5);
        assert_eq!(reg.len(), 40);
        // Unique names.
        let mut names: Vec<&str> = reg.functions.iter().map(|f| f.name.as_str()).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), 40);
    }

    #[test]
    fn name_lookup_roundtrip() {
        let reg = FunctionRegistry::functionbench(5);
        for f in &reg.functions {
            assert_eq!(reg.by_name(&f.name), Some(f.id));
        }
        assert_eq!(reg.by_name("nope"), None);
    }

    #[test]
    fn exec_time_mean_calibrated() {
        let reg = FunctionRegistry::functionbench(1);
        let mut rng = Pcg64::new(1);
        let id = reg.by_name("matmul_0").unwrap();
        let n = 20_000;
        let mean_s: f64 = (0..n).map(|_| reg.sample_exec_s(id, &mut rng)).sum::<f64>() / n as f64;
        let expect = BASE_APPS[6].warm_ms / 1000.0;
        assert!((mean_s - expect).abs() / expect < 0.03, "mean {mean_s} vs {expect}");
    }

    #[test]
    fn cold_init_positive_and_calibrated() {
        let reg = FunctionRegistry::functionbench(1);
        let mut rng = Pcg64::new(2);
        for id in 0..reg.len() {
            let app = reg.app(id);
            let n = 5_000;
            let mean_s: f64 =
                (0..n).map(|_| reg.sample_init_s(id, &mut rng)).sum::<f64>() / n as f64;
            let expect = (app.cold_ms - app.warm_ms) / 1000.0;
            assert!(mean_s > 0.0);
            assert!((mean_s - expect).abs() / expect < 0.10, "{}: {mean_s} vs {expect}", app.name);
        }
    }

    #[test]
    fn subset_registry() {
        let reg = FunctionRegistry::subset(&[0, 6], 2);
        assert_eq!(reg.len(), 4);
        assert_eq!(reg.app(1).name, "matmul");
    }
}
