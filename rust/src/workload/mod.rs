//! Workload substrate: FunctionBench registry (Tables I/II), Azure-like
//! trace synthesis (Figs 4-6), and the k6-like closed-loop load generator.

pub mod azure;
pub mod loadgen;
pub mod spec;
pub mod trace_io;

pub use loadgen::{VuScript, VuStep, Workload};
pub use spec::{FunctionId, FunctionRegistry, BASE_APPS};
