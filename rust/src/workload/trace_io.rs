//! Trace file I/O: persist and load invocation traces as CSV.
//!
//! Lets users replay a *real* Azure Functions trace (or any invocation
//! log) through the cluster instead of the synthetic generator: convert
//! the log to `timestamp_s,function` rows, load it with
//! [`load_trace_csv`], and feed it to `sim::run_trace`. The synthetic
//! generator's traces round-trip through the same format, which the tests
//! rely on.

use super::loadgen::OpenLoopTrace;
use super::spec::FunctionId;

/// Serialize a trace as `timestamp_s,function` CSV (with header).
pub fn trace_to_csv(trace: &OpenLoopTrace) -> String {
    let mut out = String::with_capacity(trace.len() * 16 + 24);
    out.push_str("timestamp_s,function\n");
    for &(t, f) in &trace.arrivals {
        out.push_str(&format!("{t:.6},{f}\n"));
    }
    out
}

/// Parse a `timestamp_s,function` CSV into a trace. Rows must be
/// time-ordered; `num_functions` bounds the function ids (rows outside the
/// range are folded by modulo, mirroring `OpenLoopTrace::from_synthetic`).
pub fn trace_from_csv(text: &str, num_functions: usize) -> Result<OpenLoopTrace, String> {
    assert!(num_functions > 0);
    let mut arrivals: Vec<(f64, FunctionId)> = Vec::new();
    let mut lines = text.lines().enumerate();
    // Header (required, keeps files self-describing).
    match lines.next() {
        Some((_, h)) if h.trim() == "timestamp_s,function" => {}
        Some((_, h)) => return Err(format!("bad header '{h}'")),
        None => return Err("empty trace file".into()),
    }
    let mut prev_t = f64::NEG_INFINITY;
    for (lineno, line) in lines {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let (ts, fs) = line
            .split_once(',')
            .ok_or_else(|| format!("line {}: expected 'timestamp,function'", lineno + 1))?;
        let t: f64 = ts
            .trim()
            .parse()
            .map_err(|_| format!("line {}: bad timestamp '{ts}'", lineno + 1))?;
        let f: usize = fs
            .trim()
            .parse()
            .map_err(|_| format!("line {}: bad function id '{fs}'", lineno + 1))?;
        if !t.is_finite() || t < 0.0 {
            return Err(format!("line {}: invalid timestamp {t}", lineno + 1));
        }
        if t < prev_t {
            return Err(format!("line {}: timestamps not ordered ({t} < {prev_t})", lineno + 1));
        }
        prev_t = t;
        arrivals.push((t, f % num_functions));
    }
    Ok(OpenLoopTrace { arrivals })
}

/// Write a trace to a file.
pub fn save_trace(trace: &OpenLoopTrace, path: &str) -> Result<(), String> {
    std::fs::write(path, trace_to_csv(trace)).map_err(|e| format!("writing {path}: {e}"))
}

/// Load a trace from a file.
pub fn load_trace(path: &str, num_functions: usize) -> Result<OpenLoopTrace, String> {
    let text = std::fs::read_to_string(path).map_err(|e| format!("reading {path}: {e}"))?;
    trace_from_csv(&text, num_functions)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::azure::SyntheticTrace;

    #[test]
    fn roundtrip_synthetic_trace() {
        let gen = SyntheticTrace::generate(100, 60.0, 5);
        let tr = OpenLoopTrace::from_synthetic(&gen.invocations, 40);
        let csv = trace_to_csv(&tr);
        let back = trace_from_csv(&csv, 40).unwrap();
        assert_eq!(back.len(), tr.len());
        for (a, b) in tr.arrivals.iter().zip(&back.arrivals) {
            assert!((a.0 - b.0).abs() < 1e-5);
            assert_eq!(a.1, b.1);
        }
    }

    #[test]
    fn rejects_malformed_input() {
        assert!(trace_from_csv("", 40).is_err());
        assert!(trace_from_csv("nope\n", 40).is_err());
        assert!(trace_from_csv("timestamp_s,function\nx,1\n", 40).is_err());
        assert!(trace_from_csv("timestamp_s,function\n1.0\n", 40).is_err());
        assert!(trace_from_csv("timestamp_s,function\n-1.0,3\n", 40).is_err());
        // Out-of-order timestamps.
        assert!(trace_from_csv("timestamp_s,function\n2.0,1\n1.0,2\n", 40).is_err());
    }

    #[test]
    fn folds_function_ids() {
        let tr = trace_from_csv("timestamp_s,function\n0.5,123\n", 40).unwrap();
        assert_eq!(tr.arrivals, vec![(0.5, 3)]);
    }

    #[test]
    fn file_roundtrip_and_replay() {
        let gen = SyntheticTrace::generate(50, 20.0, 6);
        let tr = OpenLoopTrace::from_synthetic(&gen.invocations, 40);
        let path = std::env::temp_dir().join("hiku_trace_io_test.csv");
        let path = path.to_str().unwrap();
        save_trace(&tr, path).unwrap();
        let back = load_trace(path, 40).unwrap();
        assert_eq!(back.len(), tr.len());
        // The loaded trace replays through the simulator.
        let cfg = crate::config::Config::default();
        let m = crate::sim::run_trace(&cfg, &back, 6).unwrap();
        assert_eq!(m.issued, m.completed);
        let _ = std::fs::remove_file(path);
    }
}
