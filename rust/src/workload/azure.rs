//! Synthetic Azure-Functions-like trace generator.
//!
//! The paper (§III-B) characterizes the production trace of a commercial
//! FaaS platform [12] by three marginal statistics and builds its workload
//! from them; we synthesize a trace calibrated to the same statistics:
//!
//! - **Skewed popularity** (Fig 4): the top 1% of functions receive 51.3% of
//!   invocations and the top 10% receive 92.3%. A Zipf law with exponent
//!   ~1.5 over a 10k-function universe lands on those shares.
//! - **Heterogeneous performance** (Fig 5): per-function execution times are
//!   lognormal across functions (means spanning ms..s) and noisy within a
//!   function.
//! - **Bursty invocations** (Fig 6): per-minute mean interarrival times
//!   swing by up to 13.5x minute-over-minute. We modulate a base Poisson
//!   process with a regime-switching burst multiplier.
//!
//! The generator also backs the load generator's "weighted random selection"
//! (§V-A): each experiment run samples 40 invocation probabilities from this
//! popularity law, exactly as the paper samples 40 functions from the Azure
//! dataset.

use crate::stats::OnlineStats;
use crate::util::rng::{Pcg64, Zipf};

/// Popularity law over a universe of functions (Zipf-Mandelbrot).
#[derive(Clone, Debug)]
pub struct Popularity {
    /// Functions in the universe the law ranges over.
    pub universe: usize,
    /// The calibrated Zipf-Mandelbrot distribution.
    pub zipf: Zipf,
}

/// Calibrated Zipf-Mandelbrot parameters: pmf(k) ∝ 1/(k+100)^2.05 over a
/// 10k universe yields top-1% = 52.0% and top-10% = 92.6% of invocations —
/// the paper reports 51.3% / 92.3% for the Azure dataset (Fig 4).
pub const AZURE_ZIPF_S: f64 = 2.05;
/// Zipf-Mandelbrot head-flattening shift calibrated to Fig 4.
pub const AZURE_ZIPF_Q: f64 = 100.0;
/// Function-universe size of the Azure characterization (Fig 4).
pub const AZURE_UNIVERSE: usize = 10_000;

impl Popularity {
    /// A popularity law over `universe` functions with exponent `s`.
    pub fn new(universe: usize, s: f64) -> Self {
        Self { universe, zipf: Zipf::with_shift(universe, s, AZURE_ZIPF_Q) }
    }

    /// Azure-calibrated default (matches Fig 4's 51.3% / 92.3% shares).
    pub fn azure_like() -> Self {
        Self::new(AZURE_UNIVERSE, AZURE_ZIPF_S)
    }

    /// Share of invocations going to the top `frac` of functions.
    pub fn top_share(&self, frac: f64) -> f64 {
        let k = ((self.universe as f64 * frac).ceil() as usize).max(1);
        (0..k).map(|r| self.zipf.pmf(r)).sum()
    }

    /// Sample per-function invocation probabilities for an experiment:
    /// pick `n` distinct functions uniformly from the universe and
    /// normalize their popularity masses (paper §V-A "randomly selected 40
    /// functions from this dataset, calculated and normalized invocation
    /// probabilities").
    pub fn sample_weights(&self, n: usize, rng: &mut Pcg64) -> Vec<f64> {
        assert!(n <= self.universe);
        // Uniform sample of distinct ranks via partial Fisher-Yates on a
        // sparse map (universe can be large).
        let mut picked = std::collections::BTreeSet::new();
        while picked.len() < n {
            picked.insert(rng.index(self.universe));
        }
        let mut w: Vec<f64> = picked.iter().map(|&r| self.zipf.pmf(r)).collect();
        let total: f64 = w.iter().sum();
        for x in &mut w {
            *x /= total;
        }
        // Shuffle so function ids are not rank-ordered.
        rng.shuffle(&mut w);
        w
    }
}

/// Per-function performance profile in the synthetic universe (Fig 5).
#[derive(Clone, Debug)]
pub struct PerfProfile {
    /// Mean execution time per function (seconds).
    pub mean_s: Vec<f64>,
    /// Within-function lognormal sigma.
    pub sigma: f64,
}

impl PerfProfile {
    /// Means lognormal across functions: median ~120 ms, heavy right tail
    /// (seconds), matching the spread visible in Fig 5.
    pub fn synthesize(n: usize, rng: &mut Pcg64) -> Self {
        let mean_s = (0..n).map(|_| rng.lognormal(-2.1, 1.1).clamp(0.001, 60.0)).collect();
        Self { mean_s, sigma: 0.4 }
    }

    /// Sample one execution time for function `f`, in seconds.
    pub fn sample_exec_s(&self, f: usize, rng: &mut Pcg64) -> f64 {
        let mean = self.mean_s[f];
        let mu = mean.ln() - self.sigma * self.sigma / 2.0;
        rng.lognormal(mu, self.sigma)
    }
}

/// Regime-switching arrival-rate process (Fig 6 burstiness): each minute the
/// base rate is multiplied by a burst factor that occasionally jumps.
#[derive(Clone, Debug)]
pub struct BurstyArrivals {
    /// Base arrival rate (requests/second).
    pub base_rate: f64,
    /// Probability per minute of switching into a burst regime.
    pub burst_prob: f64,
    /// Lower bound of the burst intensity multiplier.
    pub burst_lo: f64,
    /// Upper bound of the burst intensity multiplier.
    pub burst_hi: f64,
}

impl Default for BurstyArrivals {
    fn default() -> Self {
        Self { base_rate: 50.0, burst_prob: 0.25, burst_lo: 3.0, burst_hi: 14.0 }
    }
}

impl BurstyArrivals {
    /// Generate arrival timestamps over `duration_s` seconds.
    pub fn generate(&self, duration_s: f64, rng: &mut Pcg64) -> Vec<f64> {
        let mut out = Vec::new();
        let mut t = 0.0;
        let mut minute_end = 60.0;
        let mut rate = self.base_rate;
        loop {
            t += rng.exponential(rate);
            if t >= duration_s {
                break;
            }
            if t >= minute_end {
                // Re-draw the regime at each minute boundary crossed.
                while t >= minute_end {
                    minute_end += 60.0;
                }
                rate = if rng.next_f64() < self.burst_prob {
                    self.base_rate * rng.uniform(self.burst_lo, self.burst_hi)
                } else {
                    self.base_rate * rng.uniform(0.6, 1.6)
                };
            }
            out.push(t);
        }
        out
    }
}

/// A complete synthetic trace plus the summary statistics the paper plots.
#[derive(Clone, Debug)]
pub struct SyntheticTrace {
    /// (arrival time s, function index) pairs, time-ordered.
    pub invocations: Vec<(f64, usize)>,
    /// Size of the function universe the trace draws from.
    pub universe: usize,
    /// Per-function performance profile (Fig 5).
    pub perf: PerfProfile,
}

impl SyntheticTrace {
    /// Synthesize a trace over `universe` functions for `duration_s`
    /// seconds, fully determined by `seed`.
    pub fn generate(universe: usize, duration_s: f64, seed: u64) -> Self {
        let mut rng = Pcg64::new(seed);
        let pop = Popularity::new(universe, AZURE_ZIPF_S);
        let perf = PerfProfile::synthesize(universe, &mut rng);
        let arrivals = BurstyArrivals::default().generate(duration_s, &mut rng);
        let invocations =
            arrivals.into_iter().map(|t| (t, pop.zipf.sample(&mut rng))).collect();
        Self { invocations, universe, perf }
    }

    /// Fig 4: cumulative invocation share of the top q-fraction of functions.
    /// Returns (fraction_of_functions, share_of_invocations) points.
    pub fn popularity_curve(&self, points: usize) -> Vec<(f64, f64)> {
        let mut counts = vec![0u64; self.universe];
        for &(_, f) in &self.invocations {
            counts[f] += 1;
        }
        counts.sort_unstable_by(|a, b| b.cmp(a));
        let total: u64 = counts.iter().sum();
        if total == 0 {
            return Vec::new();
        }
        let mut out = Vec::with_capacity(points);
        let mut acc = 0u64;
        let mut next_point = 1;
        for (i, &c) in counts.iter().enumerate() {
            acc += c;
            let frac = (i + 1) as f64 / self.universe as f64;
            if frac >= next_point as f64 / points as f64 {
                out.push((frac, acc as f64 / total as f64));
                next_point += 1;
            }
        }
        out
    }

    /// Share of invocations received by the top `frac` fraction of functions
    /// (Fig 4's headline: top 1% -> 51.3%, top 10% -> 92.3%).
    pub fn top_share(&self, frac: f64) -> f64 {
        let mut counts = vec![0u64; self.universe];
        for &(_, f) in &self.invocations {
            counts[f] += 1;
        }
        counts.sort_unstable_by(|a, b| b.cmp(a));
        let total: u64 = counts.iter().sum();
        let k = ((self.universe as f64 * frac).ceil() as usize).max(1);
        let top: u64 = counts.iter().take(k).sum();
        top as f64 / total.max(1) as f64
    }

    /// Fig 5: per-function execution mean/std for the `n` most invoked
    /// functions, ordered by first appearance in the trace (as the paper
    /// orders them).
    pub fn exec_heterogeneity(&self, n: usize, seed: u64) -> Vec<(usize, f64, f64)> {
        let mut rng = Pcg64::new(seed ^ 0xFEED);
        let mut seen = Vec::new();
        let mut seen_set = std::collections::BTreeSet::new();
        for &(_, f) in &self.invocations {
            if seen_set.insert(f) {
                seen.push(f);
                if seen.len() == n {
                    break;
                }
            }
        }
        seen.iter()
            .map(|&f| {
                let mut st = OnlineStats::new();
                for _ in 0..200 {
                    st.push(self.perf.sample_exec_s(f, &mut rng));
                }
                (f, st.mean(), st.std())
            })
            .collect()
    }

    /// Fig 6: mean interarrival time per minute (ms), plus the maximum
    /// minute-over-minute ratio (paper: up to 13.5x within a minute).
    pub fn interarrival_per_minute(&self) -> (Vec<f64>, f64) {
        if self.invocations.len() < 2 {
            return (Vec::new(), 1.0);
        }
        let horizon = self.invocations.last().unwrap().0;
        let minutes = (horizon / 60.0).ceil() as usize;
        let mut sums = vec![0.0f64; minutes];
        let mut counts = vec![0u64; minutes];
        let mut prev_t = self.invocations[0].0;
        for &(t, _) in self.invocations.iter().skip(1) {
            let m = ((t / 60.0) as usize).min(minutes - 1);
            sums[m] += t - prev_t;
            counts[m] += 1;
            prev_t = t;
        }
        let series: Vec<f64> = sums
            .iter()
            .zip(&counts)
            .map(|(&s, &c)| if c > 0 { s / c as f64 * 1000.0 } else { f64::NAN })
            .collect();
        let mut max_ratio = 1.0f64;
        for w in series.windows(2) {
            if w[0].is_finite() && w[1].is_finite() && w[0] > 0.0 && w[1] > 0.0 {
                let r = (w[0] / w[1]).max(w[1] / w[0]);
                max_ratio = max_ratio.max(r);
            }
        }
        (series, max_ratio)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn popularity_matches_azure_shares() {
        // Fig 4 calibration targets: top 1% -> ~51.3%, top 10% -> ~92.3%.
        let pop = Popularity::azure_like();
        let s1 = pop.top_share(0.01);
        let s10 = pop.top_share(0.10);
        assert!((s1 - 0.513).abs() < 0.03, "top-1% share {s1}");
        assert!((s10 - 0.923).abs() < 0.03, "top-10% share {s10}");
    }

    #[test]
    fn sampled_weights_normalized_and_skewed() {
        let pop = Popularity::azure_like();
        let mut rng = Pcg64::new(3);
        let w = pop.sample_weights(40, &mut rng);
        assert_eq!(w.len(), 40);
        assert!((w.iter().sum::<f64>() - 1.0).abs() < 1e-9);
        let mut sorted = w.clone();
        sorted.sort_by(|a, b| b.partial_cmp(a).unwrap());
        // Heavy skew: the top function dominates the median one.
        assert!(sorted[0] > 5.0 * sorted[20], "weights not skewed: {sorted:?}");
    }

    #[test]
    fn trace_top_shares() {
        // Empirical shares on the full calibrated universe (the Fig 4
        // claim is stated for the 10k-function universe).
        let tr = SyntheticTrace::generate(AZURE_UNIVERSE, 1200.0, 7);
        let s10 = tr.top_share(0.10);
        assert!(s10 > 0.85, "empirical top-10% share {s10}");
        assert!(tr.top_share(0.01) > 0.40);
    }

    #[test]
    fn popularity_curve_monotone() {
        let tr = SyntheticTrace::generate(1000, 600.0, 8);
        let curve = tr.popularity_curve(20);
        assert!(!curve.is_empty());
        for w in curve.windows(2) {
            assert!(w[0].0 <= w[1].0 && w[0].1 <= w[1].1 + 1e-12);
        }
        assert!((curve.last().unwrap().1 - 1.0).abs() < 1e-9);
    }

    #[test]
    fn bursty_interarrival_swings() {
        // Fig 6: the per-minute interarrival mean must swing by several x
        // minute-over-minute (paper: up to 13.5x).
        let tr = SyntheticTrace::generate(500, 1800.0, 9);
        let (series, max_ratio) = tr.interarrival_per_minute();
        assert!(series.len() >= 25);
        assert!(max_ratio > 3.0, "trace not bursty: max ratio {max_ratio}");
        assert!(max_ratio < 50.0, "implausibly bursty: {max_ratio}");
    }

    #[test]
    fn heterogeneity_varies_across_functions() {
        let tr = SyntheticTrace::generate(500, 600.0, 10);
        let het = tr.exec_heterogeneity(20, 10);
        assert_eq!(het.len(), 20);
        let means: Vec<f64> = het.iter().map(|&(_, m, _)| m).collect();
        let max = means.iter().cloned().fold(f64::MIN, f64::max);
        let min = means.iter().cloned().fold(f64::MAX, f64::min);
        assert!(max / min > 3.0, "means not heterogeneous: {min}..{max}");
        // Within-function std is nonzero.
        assert!(het.iter().all(|&(_, _, s)| s > 0.0));
    }

    #[test]
    fn trace_deterministic_under_seed() {
        let a = SyntheticTrace::generate(300, 120.0, 11);
        let b = SyntheticTrace::generate(300, 120.0, 11);
        assert_eq!(a.invocations.len(), b.invocations.len());
        assert_eq!(a.invocations.first(), b.invocations.first());
        assert_eq!(a.invocations.last(), b.invocations.last());
    }
}
