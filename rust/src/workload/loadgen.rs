//! k6-like closed-loop load generator (§V-A "Execution").
//!
//! Each virtual user (VU) loops: invoke a function chosen by weighted random
//! selection -> wait for the response -> sleep U(0.1 s, 1 s) -> repeat. The
//! paper seeds the RNG with the experiment start date so that *the order of
//! function invocations and the sleep durations are identical for every
//! scheduling algorithm*; we reproduce that by pre-generating each VU's
//! script (function choices + think times) from the run seed, independent of
//! scheduler behaviour.

use super::azure::Popularity;
use super::spec::FunctionId;
use crate::config::WorkloadConfig;
use crate::util::rng::{AliasTable, Pcg64};

/// One scripted VU step.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct VuStep {
    /// The function this step invokes.
    pub function: FunctionId,
    /// Think time *after* this invocation completes, seconds.
    pub think_s: f64,
}

/// A scripted virtual user: a deterministic sequence of steps.
#[derive(Clone, Debug)]
pub struct VuScript {
    /// The VU's invocation sequence, consumed in order.
    pub steps: Vec<VuStep>,
    /// Initial stagger before the first invocation (spreads VU ramp-up).
    pub start_delay_s: f64,
}

/// The full scripted workload for one run.
#[derive(Clone, Debug)]
pub struct Workload {
    /// One pre-generated script per virtual user.
    pub vus: Vec<VuScript>,
    /// Invocation probability per function (the run's weighted selection).
    pub weights: Vec<f64>,
    /// Run duration in virtual seconds.
    pub duration_s: f64,
}

impl Workload {
    /// Generate the scripted workload for a run. `seed` plays the role of
    /// the paper's "start date of the experiment" seed; two calls with the
    /// same config+seed yield identical scripts.
    pub fn generate(cfg: &WorkloadConfig, num_functions: usize, seed: u64) -> Self {
        let mut rng = Pcg64::new(seed);
        let pop = Popularity::new(10_000.max(num_functions), cfg.zipf_s);
        let weights = pop.sample_weights(num_functions, &mut rng);
        let table = AliasTable::new(&weights);

        // Upper bound on steps a VU can need: duration / min cycle time.
        // Cycle = think time + response; the fastest FunctionBench payload
        // (linpack, 58 ms mean warm) rarely samples below ~20 ms, so bound
        // the cycle at think_min + 20 ms. The simulator stops consuming
        // steps at duration_s anyway and tolerates exhausted scripts.
        let min_cycle_s = cfg.think_min_s.max(0.01) + 0.02;
        let max_steps = ((cfg.duration_s / min_cycle_s).ceil() as usize + 8).min(100_000);

        let vus = (0..cfg.vus)
            .map(|_| {
                // Each VU gets its own derived stream, but all streams are
                // fixed by `seed` — scheduler-independent by construction.
                let mut vrng = rng.split();
                let start_delay_s = vrng.uniform(0.0, cfg.think_max_s);
                let steps = (0..max_steps)
                    .map(|_| VuStep {
                        function: table.sample(&mut vrng),
                        think_s: vrng.uniform(cfg.think_min_s, cfg.think_max_s),
                    })
                    .collect();
                VuScript { steps, start_delay_s }
            })
            .collect();

        Self { vus, weights, duration_s: cfg.duration_s }
    }

    /// Number of virtual users.
    pub fn num_vus(&self) -> usize {
        self.vus.len()
    }

    /// Total scripted invocations (upper bound; closed loop consumes fewer).
    pub fn total_steps(&self) -> usize {
        self.vus.iter().map(|v| v.steps.len()).sum()
    }
}

/// Open-loop replayer: turns a (time, function) trace into the same VuStep
/// interface, for replaying synthetic Azure traces through the cluster
/// (used by ablation benches; the paper's main experiments are closed-loop).
#[derive(Clone, Debug)]
pub struct OpenLoopTrace {
    /// (arrival time, function) pairs, ascending in time.
    pub arrivals: Vec<(f64, FunctionId)>,
}

impl OpenLoopTrace {
    /// Fold a synthetic trace's function universe onto the experiment's
    /// `num_functions` types (modulo mapping).
    pub fn from_synthetic(
        invocations: &[(f64, usize)],
        num_functions: usize,
    ) -> Self {
        // Fold the trace's universe onto the experiment's function set.
        let arrivals = invocations
            .iter()
            .map(|&(t, f)| (t, f % num_functions))
            .collect();
        Self { arrivals }
    }

    /// Number of arrivals.
    pub fn len(&self) -> usize {
        self.arrivals.len()
    }

    /// True when the trace has no arrivals.
    pub fn is_empty(&self) -> bool {
        self.arrivals.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::WorkloadConfig;

    fn cfg() -> WorkloadConfig {
        WorkloadConfig { vus: 10, duration_s: 30.0, ..Default::default() }
    }

    #[test]
    fn scripts_identical_for_same_seed() {
        let a = Workload::generate(&cfg(), 40, 99);
        let b = Workload::generate(&cfg(), 40, 99);
        assert_eq!(a.num_vus(), b.num_vus());
        for (va, vb) in a.vus.iter().zip(&b.vus) {
            assert_eq!(va.start_delay_s, vb.start_delay_s);
            assert_eq!(va.steps, vb.steps);
        }
    }

    #[test]
    fn scripts_differ_across_seeds() {
        let a = Workload::generate(&cfg(), 40, 1);
        let b = Workload::generate(&cfg(), 40, 2);
        assert_ne!(a.vus[0].steps, b.vus[0].steps);
    }

    #[test]
    fn think_times_in_range() {
        let w = Workload::generate(&cfg(), 40, 3);
        for vu in &w.vus {
            for s in &vu.steps {
                assert!((0.1..=1.0).contains(&s.think_s), "think {}", s.think_s);
                assert!(s.function < 40);
            }
        }
    }

    #[test]
    fn weights_skewed_and_functions_covered() {
        let w = Workload::generate(&cfg(), 40, 4);
        assert_eq!(w.weights.len(), 40);
        assert!((w.weights.iter().sum::<f64>() - 1.0).abs() < 1e-9);
        // Empirical selection follows the weights: most-popular function is
        // picked far more often than the least-popular one.
        let mut counts = vec![0u64; 40];
        for vu in &w.vus {
            for s in &vu.steps {
                counts[s.function] += 1;
            }
        }
        let top_w = w.weights.iter().cloned().fold(f64::MIN, f64::max);
        let top_i = w.weights.iter().position(|&x| x == top_w).unwrap();
        let max_c = *counts.iter().max().unwrap();
        assert_eq!(counts[top_i], max_c, "most-weighted function not most-selected");
    }

    #[test]
    fn enough_steps_for_duration() {
        let w = Workload::generate(&cfg(), 40, 5);
        // With think >= 0.1 s and response >= 20 ms, a 30 s run consumes at
        // most 250 steps/VU.
        for vu in &w.vus {
            assert!(vu.steps.len() >= 250, "script too short: {}", vu.steps.len());
        }
    }

    #[test]
    fn open_loop_folding() {
        let tr = vec![(0.5, 123usize), (1.0, 41), (2.0, 39)];
        let ol = OpenLoopTrace::from_synthetic(&tr, 40);
        assert_eq!(ol.arrivals, vec![(0.5, 3), (1.0, 1), (2.0, 39)]);
    }
}
