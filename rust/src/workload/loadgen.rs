//! k6-like load generators (§V-A "Execution").
//!
//! Closed loop ([`Workload`]): each virtual user (VU) loops: invoke a
//! function chosen by weighted random selection -> wait for the response ->
//! sleep U(0.1 s, 1 s) -> repeat. The paper seeds the RNG with the
//! experiment start date so that *the order of function invocations and the
//! sleep durations are identical for every scheduling algorithm*; we
//! reproduce that by pre-generating each VU's script (function choices +
//! think times) from the run seed, independent of scheduler behaviour.
//!
//! Open loop over HTTP ([`run_http_loadgen`]): a self-contained socket
//! client driving the in-tree HTTP front door
//! (`hiku serve --http` / [`crate::server::http`]) from a pre-generated
//! arrival schedule — Poisson arrivals over the same Zipf popularity mix,
//! or the bursty Azure-like synthetic trace. Wall-clock by nature; every
//! clock read carries a detlint R2 waiver.

use super::azure::{Popularity, SyntheticTrace};
use super::spec::FunctionId;
use crate::config::WorkloadConfig;
use crate::util::json::{obj, Json};
use crate::util::rng::{AliasTable, Pcg64};
use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// One scripted VU step.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct VuStep {
    /// The function this step invokes.
    pub function: FunctionId,
    /// Think time *after* this invocation completes, seconds.
    pub think_s: f64,
}

/// A scripted virtual user: a deterministic sequence of steps.
#[derive(Clone, Debug)]
pub struct VuScript {
    /// The VU's invocation sequence, consumed in order.
    pub steps: Vec<VuStep>,
    /// Initial stagger before the first invocation (spreads VU ramp-up).
    pub start_delay_s: f64,
}

/// The full scripted workload for one run.
#[derive(Clone, Debug)]
pub struct Workload {
    /// One pre-generated script per virtual user.
    pub vus: Vec<VuScript>,
    /// Invocation probability per function (the run's weighted selection).
    pub weights: Vec<f64>,
    /// Run duration in virtual seconds.
    pub duration_s: f64,
}

impl Workload {
    /// Generate the scripted workload for a run. `seed` plays the role of
    /// the paper's "start date of the experiment" seed; two calls with the
    /// same config+seed yield identical scripts.
    pub fn generate(cfg: &WorkloadConfig, num_functions: usize, seed: u64) -> Self {
        let mut rng = Pcg64::new(seed);
        let pop = Popularity::new(10_000.max(num_functions), cfg.zipf_s);
        let weights = pop.sample_weights(num_functions, &mut rng);
        let table = AliasTable::new(&weights);

        // Upper bound on steps a VU can need: duration / min cycle time.
        // Cycle = think time + response; the fastest FunctionBench payload
        // (linpack, 58 ms mean warm) rarely samples below ~20 ms, so bound
        // the cycle at think_min + 20 ms. The simulator stops consuming
        // steps at duration_s anyway and tolerates exhausted scripts.
        let min_cycle_s = cfg.think_min_s.max(0.01) + 0.02;
        let max_steps = ((cfg.duration_s / min_cycle_s).ceil() as usize + 8).min(100_000);

        let vus = (0..cfg.vus)
            .map(|_| {
                // Each VU gets its own derived stream, but all streams are
                // fixed by `seed` — scheduler-independent by construction.
                let mut vrng = rng.split();
                let start_delay_s = vrng.uniform(0.0, cfg.think_max_s);
                let steps = (0..max_steps)
                    .map(|_| VuStep {
                        function: table.sample(&mut vrng),
                        think_s: vrng.uniform(cfg.think_min_s, cfg.think_max_s),
                    })
                    .collect();
                VuScript { steps, start_delay_s }
            })
            .collect();

        Self { vus, weights, duration_s: cfg.duration_s }
    }

    /// Number of virtual users.
    pub fn num_vus(&self) -> usize {
        self.vus.len()
    }

    /// Total scripted invocations (upper bound; closed loop consumes fewer).
    pub fn total_steps(&self) -> usize {
        self.vus.iter().map(|v| v.steps.len()).sum()
    }
}

/// Open-loop replayer: turns a (time, function) trace into the same VuStep
/// interface, for replaying synthetic Azure traces through the cluster
/// (used by ablation benches; the paper's main experiments are closed-loop).
#[derive(Clone, Debug)]
pub struct OpenLoopTrace {
    /// (arrival time, function) pairs, ascending in time.
    pub arrivals: Vec<(f64, FunctionId)>,
}

impl OpenLoopTrace {
    /// Fold a synthetic trace's function universe onto the experiment's
    /// `num_functions` types (modulo mapping).
    pub fn from_synthetic(
        invocations: &[(f64, usize)],
        num_functions: usize,
    ) -> Self {
        // Fold the trace's universe onto the experiment's function set.
        let arrivals = invocations
            .iter()
            .map(|&(t, f)| (t, f % num_functions))
            .collect();
        Self { arrivals }
    }

    /// Number of arrivals.
    pub fn len(&self) -> usize {
        self.arrivals.len()
    }

    /// True when the trace has no arrivals.
    pub fn is_empty(&self) -> bool {
        self.arrivals.is_empty()
    }
}

// ---------------------------------------------------------------------------
// Open-loop HTTP load generator (`hiku loadgen`)
// ---------------------------------------------------------------------------

/// Options for the open-loop HTTP load generator (`hiku loadgen`): an
/// in-tree k6 substitute that drives the HTTP front door over real
/// sockets. The arrival schedule is pre-generated from `seed` (so two
/// runs against the same server are identical traffic), then replayed
/// open-loop: arrivals do not wait for earlier responses, `connections`
/// bounds concurrency, and a generator running behind schedule bursts to
/// catch up (k6 "constant-arrival-rate" semantics).
#[derive(Clone, Debug)]
pub struct LoadgenOpts {
    /// Server address, e.g. `127.0.0.1:8080`.
    pub addr: String,
    /// Total requests to send.
    pub requests: usize,
    /// Mean arrival rate in requests/second.
    pub rate_rps: f64,
    /// Concurrent keep-alive connections (one OS thread each).
    pub connections: usize,
    /// Function-id universe: requests target `0..num_functions`.
    pub num_functions: usize,
    /// Zipf exponent of the popularity mix (Poisson mode).
    pub zipf_s: f64,
    /// Schedule seed (arrival times + function choices).
    pub seed: u64,
    /// Draw arrivals from the bursty Azure-like synthetic trace
    /// ([`SyntheticTrace`]) instead of a Poisson process; times are
    /// rescaled so the mean rate still matches `rate_rps`.
    pub use_trace: bool,
}

impl Default for LoadgenOpts {
    fn default() -> Self {
        Self {
            addr: "127.0.0.1:8080".into(),
            requests: 1000,
            rate_rps: 200.0,
            connections: 8,
            num_functions: 40,
            zipf_s: 2.05,
            seed: 42,
            use_trace: false,
        }
    }
}

/// Aggregated results of one [`run_http_loadgen`] run. Latency
/// percentiles cover every HTTP-answered request (completed, rejected,
/// failed); transport errors have no latency sample.
#[derive(Clone, Debug, Default)]
pub struct LoadgenReport {
    /// Requests attempted (schedule entries claimed by a connection).
    pub sent: usize,
    /// 2xx responses (request executed).
    pub completed: usize,
    /// 429 responses (admission refused).
    pub rejected: usize,
    /// Other HTTP statuses (e.g. 500 after retry-budget exhaustion).
    pub failed: usize,
    /// Connect/read/write failures — the request got no HTTP answer.
    pub transport_errors: usize,
    /// Wall-clock span of the run, seconds.
    pub duration_s: f64,
    /// Per-request end-to-end latencies in ms, ascending.
    latencies_ms: Vec<f64>,
}

impl LoadgenReport {
    /// Completed requests per wall-clock second.
    pub fn throughput_rps(&self) -> f64 {
        self.completed as f64 / self.duration_s.max(1e-9)
    }

    /// Mean end-to-end latency over HTTP-answered requests, ms.
    pub fn mean_ms(&self) -> f64 {
        if self.latencies_ms.is_empty() {
            return 0.0;
        }
        self.latencies_ms.iter().sum::<f64>() / self.latencies_ms.len() as f64
    }

    /// Latency percentile (`p` in 0..=100) over HTTP-answered requests, ms.
    pub fn percentile_ms(&self, p: f64) -> f64 {
        if self.latencies_ms.is_empty() {
            return 0.0;
        }
        let last = self.latencies_ms.len() - 1;
        let idx = ((p / 100.0) * last as f64).round() as usize;
        self.latencies_ms[idx.min(last)]
    }

    /// Number of requests with an HTTP answer (latency samples).
    pub fn responses(&self) -> usize {
        self.latencies_ms.len()
    }

    /// Conservation identity: every attempted request is accounted for
    /// exactly once across the four outcome counters.
    pub fn accounted(&self) -> bool {
        self.sent == self.completed + self.rejected + self.failed + self.transport_errors
    }

    /// The report as a JSON object (the `BENCH_http.json` row shape).
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("sent", self.sent.into()),
            ("completed", self.completed.into()),
            ("rejected", self.rejected.into()),
            ("failed", self.failed.into()),
            ("transport_errors", self.transport_errors.into()),
            ("duration_s", self.duration_s.into()),
            ("throughput_rps", self.throughput_rps().into()),
            ("mean_ms", self.mean_ms().into()),
            ("p50_ms", self.percentile_ms(50.0).into()),
            ("p95_ms", self.percentile_ms(95.0).into()),
            ("p99_ms", self.percentile_ms(99.0).into()),
        ])
    }
}

/// Pre-generate the open-loop arrival schedule for `opts`:
/// time-ascending `(arrival_s, function)` pairs, fully determined by
/// `opts.seed`. Poisson mode yields exactly `opts.requests` arrivals
/// with exponential inter-arrivals at `rate_rps` and Zipf-weighted
/// function choices (the same popularity construction as
/// [`Workload::generate`]); trace mode replays the bursty synthetic
/// trace rescaled to the requested mean rate (and may yield fewer
/// arrivals if the trace runs short).
pub fn loadgen_schedule(opts: &LoadgenOpts) -> Vec<(f64, FunctionId)> {
    let n = opts.requests;
    let funcs = opts.num_functions.max(1);
    let mut rng = Pcg64::new(opts.seed);
    if opts.use_trace {
        // Double the trace duration until it covers n arrivals, then
        // rescale times so the mean rate matches rate_rps.
        let mut dur = 60.0;
        for _ in 0..16 {
            let tr = SyntheticTrace::generate(10_000.max(funcs), dur, opts.seed);
            if tr.invocations.len() >= n || dur > 1e6 {
                let folded = OpenLoopTrace::from_synthetic(&tr.invocations, funcs);
                let mut arr: Vec<(f64, FunctionId)> =
                    folded.arrivals.into_iter().take(n).collect();
                let span = arr.last().map(|&(t, _)| t).unwrap_or(0.0).max(1e-9);
                let target_span = arr.len() as f64 / opts.rate_rps.max(1e-9);
                let k = target_span / span;
                for a in &mut arr {
                    a.0 *= k;
                }
                return arr;
            }
            dur *= 2.0;
        }
        return Vec::new();
    }
    let pop = Popularity::new(10_000.max(funcs), opts.zipf_s);
    let weights = pop.sample_weights(funcs, &mut rng);
    let table = AliasTable::new(&weights);
    let mut t = 0.0;
    (0..n)
        .map(|_| {
            t += rng.exponential(opts.rate_rps.max(1e-9));
            (t, table.sample(&mut rng))
        })
        .collect()
}

/// Per-connection tallies, merged into the final [`LoadgenReport`].
#[derive(Default)]
struct ConnStats {
    completed: usize,
    rejected: usize,
    failed: usize,
    transport_errors: usize,
    latencies_ms: Vec<f64>,
}

/// Run the open-loop HTTP load generator against a live server and
/// block until the schedule is spent. `connections` OS threads share
/// one atomic schedule cursor: each claims the next arrival, sleeps
/// until its time, and issues `POST /invoke/{fn}` on its keep-alive
/// connection (reconnecting after transport errors).
pub fn run_http_loadgen(opts: &LoadgenOpts) -> Result<LoadgenReport, String> {
    let schedule = Arc::new(loadgen_schedule(opts));
    if schedule.is_empty() {
        return Err("loadgen: empty arrival schedule".to_string());
    }
    let next = Arc::new(AtomicUsize::new(0));
    // detlint:allow(R2) -- the loadgen's product is wall-clock pacing and latency measurement
    let start = Instant::now();
    let mut threads = Vec::new();
    for _ in 0..opts.connections.max(1) {
        let schedule = Arc::clone(&schedule);
        let next = Arc::clone(&next);
        let addr = opts.addr.clone();
        threads.push(std::thread::spawn(move || {
            drive_connection(&addr, &schedule, &next, start)
        }));
    }
    let mut report = LoadgenReport { sent: schedule.len(), ..Default::default() };
    for t in threads {
        let s = t.join().map_err(|_| "loadgen connection thread panicked".to_string())?;
        report.completed += s.completed;
        report.rejected += s.rejected;
        report.failed += s.failed;
        report.transport_errors += s.transport_errors;
        report.latencies_ms.extend(s.latencies_ms);
    }
    report.duration_s = start.elapsed().as_secs_f64();
    report.latencies_ms.sort_unstable_by(f64::total_cmp);
    Ok(report)
}

/// One connection thread: claim-schedule-send-read until the cursor
/// passes the end of the schedule.
fn drive_connection(
    addr: &str,
    schedule: &[(f64, FunctionId)],
    next: &AtomicUsize,
    start: Instant,
) -> ConnStats {
    let mut stats = ConnStats::default();
    let mut conn: Option<(BufReader<TcpStream>, TcpStream)> = None;
    loop {
        let i = next.fetch_add(1, Ordering::Relaxed);
        if i >= schedule.len() {
            return stats;
        }
        let (due, f) = schedule[i];
        let now_s = start.elapsed().as_secs_f64();
        if due > now_s {
            std::thread::sleep(Duration::from_secs_f64(due - now_s));
        }
        if conn.is_none() {
            conn = match TcpStream::connect(addr) {
                Ok(stream) => {
                    let _ = stream.set_nodelay(true);
                    match stream.try_clone() {
                        Ok(rd) => Some((BufReader::new(rd), stream)),
                        Err(_) => None,
                    }
                }
                Err(_) => None,
            };
            if conn.is_none() {
                stats.transport_errors += 1;
                continue;
            }
        }
        let Some((reader, writer)) = conn.as_mut() else { unreachable!() };
        // detlint:allow(R2) -- per-request end-to-end latency is the measurement itself
        let t0 = Instant::now();
        let req =
            format!("POST /invoke/{f} HTTP/1.1\r\nHost: {addr}\r\nContent-Length: 0\r\n\r\n");
        if writer.write_all(req.as_bytes()).and_then(|_| writer.flush()).is_err() {
            stats.transport_errors += 1;
            conn = None;
            continue;
        }
        match read_response(reader) {
            Ok((code, keep)) => {
                stats.latencies_ms.push(t0.elapsed().as_secs_f64() * 1000.0);
                match code {
                    200..=299 => stats.completed += 1,
                    429 => stats.rejected += 1,
                    _ => stats.failed += 1,
                }
                if !keep {
                    conn = None;
                }
            }
            Err(()) => {
                stats.transport_errors += 1;
                conn = None;
            }
        }
    }
}

/// Read one HTTP response off the connection; returns (status,
/// keep-alive). Any socket or framing error is `Err(())` — the caller
/// reconnects.
fn read_response(reader: &mut BufReader<TcpStream>) -> Result<(u16, bool), ()> {
    let mut line = String::new();
    match reader.read_line(&mut line) {
        Ok(0) | Err(_) => return Err(()),
        Ok(_) => {}
    }
    let code: u16 = line.split_whitespace().nth(1).and_then(|c| c.parse().ok()).ok_or(())?;
    let mut content_length = 0usize;
    let mut keep = true;
    for _ in 0..128 {
        let mut h = String::new();
        match reader.read_line(&mut h) {
            Ok(0) | Err(_) => return Err(()),
            Ok(_) => {}
        }
        let h = h.trim_end();
        if h.is_empty() {
            let mut body = vec![0u8; content_length];
            reader.read_exact(&mut body).map_err(|_| ())?;
            return Ok((code, keep));
        }
        if let Some((name, value)) = h.split_once(':') {
            let value = value.trim();
            if name.eq_ignore_ascii_case("content-length") {
                content_length = value.parse().map_err(|_| ())?;
            } else if name.eq_ignore_ascii_case("connection")
                && value.eq_ignore_ascii_case("close")
            {
                keep = false;
            }
        }
    }
    Err(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::WorkloadConfig;

    fn cfg() -> WorkloadConfig {
        WorkloadConfig { vus: 10, duration_s: 30.0, ..Default::default() }
    }

    #[test]
    fn scripts_identical_for_same_seed() {
        let a = Workload::generate(&cfg(), 40, 99);
        let b = Workload::generate(&cfg(), 40, 99);
        assert_eq!(a.num_vus(), b.num_vus());
        for (va, vb) in a.vus.iter().zip(&b.vus) {
            assert_eq!(va.start_delay_s, vb.start_delay_s);
            assert_eq!(va.steps, vb.steps);
        }
    }

    #[test]
    fn scripts_differ_across_seeds() {
        let a = Workload::generate(&cfg(), 40, 1);
        let b = Workload::generate(&cfg(), 40, 2);
        assert_ne!(a.vus[0].steps, b.vus[0].steps);
    }

    #[test]
    fn think_times_in_range() {
        let w = Workload::generate(&cfg(), 40, 3);
        for vu in &w.vus {
            for s in &vu.steps {
                assert!((0.1..=1.0).contains(&s.think_s), "think {}", s.think_s);
                assert!(s.function < 40);
            }
        }
    }

    #[test]
    fn weights_skewed_and_functions_covered() {
        let w = Workload::generate(&cfg(), 40, 4);
        assert_eq!(w.weights.len(), 40);
        assert!((w.weights.iter().sum::<f64>() - 1.0).abs() < 1e-9);
        // Empirical selection follows the weights: most-popular function is
        // picked far more often than the least-popular one.
        let mut counts = vec![0u64; 40];
        for vu in &w.vus {
            for s in &vu.steps {
                counts[s.function] += 1;
            }
        }
        let top_w = w.weights.iter().cloned().fold(f64::MIN, f64::max);
        let top_i = w.weights.iter().position(|&x| x == top_w).unwrap();
        let max_c = *counts.iter().max().unwrap();
        assert_eq!(counts[top_i], max_c, "most-weighted function not most-selected");
    }

    #[test]
    fn enough_steps_for_duration() {
        let w = Workload::generate(&cfg(), 40, 5);
        // With think >= 0.1 s and response >= 20 ms, a 30 s run consumes at
        // most 250 steps/VU.
        for vu in &w.vus {
            assert!(vu.steps.len() >= 250, "script too short: {}", vu.steps.len());
        }
    }

    #[test]
    fn open_loop_folding() {
        let tr = vec![(0.5, 123usize), (1.0, 41), (2.0, 39)];
        let ol = OpenLoopTrace::from_synthetic(&tr, 40);
        assert_eq!(ol.arrivals, vec![(0.5, 3), (1.0, 1), (2.0, 39)]);
    }

    #[test]
    fn loadgen_schedule_deterministic_sorted_in_range() {
        let opts = LoadgenOpts { requests: 500, num_functions: 40, ..Default::default() };
        let a = loadgen_schedule(&opts);
        let b = loadgen_schedule(&opts);
        assert_eq!(a, b, "schedule must be seed-deterministic");
        assert_eq!(a.len(), 500);
        assert!(a.windows(2).all(|w| w[0].0 <= w[1].0), "times must ascend");
        assert!(a.iter().all(|&(t, f)| t >= 0.0 && f < 40));
        // Mean rate tracks rate_rps (Poisson: span ~ n/rate, loose 2x band).
        let span = a.last().unwrap().0;
        let expect = 500.0 / opts.rate_rps;
        assert!(span > expect * 0.5 && span < expect * 2.0, "span {span} vs {expect}");
    }

    #[test]
    fn loadgen_trace_schedule_rescales_to_rate() {
        let opts = LoadgenOpts {
            requests: 400,
            rate_rps: 100.0,
            use_trace: true,
            ..Default::default()
        };
        let a = loadgen_schedule(&opts);
        assert!(!a.is_empty());
        assert!(a.windows(2).all(|w| w[0].0 <= w[1].0), "times must ascend");
        assert!(a.iter().all(|&(_, f)| f < 40));
        let span = a.last().unwrap().0;
        let expect = a.len() as f64 / opts.rate_rps;
        assert!((span - expect).abs() < 1e-6, "trace rescaled span {span} vs {expect}");
    }

    #[test]
    fn loadgen_report_percentiles_and_accounting() {
        let mut r = LoadgenReport {
            sent: 5,
            completed: 3,
            rejected: 1,
            failed: 0,
            transport_errors: 1,
            duration_s: 2.0,
            ..Default::default()
        };
        r.latencies_ms = vec![1.0, 2.0, 3.0, 4.0];
        assert!(r.accounted());
        assert_eq!(r.responses(), 4);
        assert_eq!(r.percentile_ms(0.0), 1.0);
        assert_eq!(r.percentile_ms(100.0), 4.0);
        assert!((r.mean_ms() - 2.5).abs() < 1e-12);
        assert!((r.throughput_rps() - 1.5).abs() < 1e-12);
        let j = r.to_json();
        for key in ["sent", "completed", "rejected", "failed", "transport_errors",
            "duration_s", "throughput_rps", "mean_ms", "p50_ms", "p95_ms", "p99_ms"]
        {
            assert!(j.get(key).is_some(), "missing loadgen JSON key {key}");
        }
        let bad = LoadgenReport { sent: 2, completed: 1, ..Default::default() };
        assert!(!bad.accounted());
    }
}
