//! `hiku` — the launcher binary.
//!
//! Subcommands:
//!   sim        run one simulated experiment (one scheduler, one seed)
//!   sweep      run the paper's evaluation sweep (schedulers x seeds x VUs)
//!   trace      synthesize + analyze an Azure-like trace (Figs 4-6)
//!   autoscale  compare autoscale policies x schedulers on the bursty trace
//!   serve      real-time serving demo (PJRT or stub runtime; --http for ingress)
//!   loadgen    open-loop HTTP load generator against a running ingress
//!   config     print the default config as JSON
//!
//! Examples:
//!   hiku sim --scheduler hiku --vus 100 --duration 300 --seed 42
//!   hiku sim --scheduler hiku --autoscale reactive --workers 2
//!   hiku sim --scheduler hiku --dispatch pull --vus 100
//!   hiku sim --dispatch pull --faults crash:0.1 --shards 2
//!   hiku sim --workers 100000 --vus 100000 --shards 4 --duration 10
//!   hiku sim --sketch --trace-sample 100 --profile --trace-out traces
//!   hiku sweep --runs 5 --vu-levels 20,50,100
//!   hiku trace --universe 10000 --minutes 30
//!   hiku autoscale --policies none,reactive,predictive --schedulers hiku,lc
//!   hiku serve --scheduler hiku --requests 200
//!   hiku serve --http 127.0.0.1:8080 --set runtime.backend=stub --dispatch pull
//!   hiku loadgen --addr 127.0.0.1:8080 --requests 10000 --rate 1000

use hiku::config::Config;
use hiku::logging;
use hiku::util::cli::Cli;

fn main() {
    logging::init();
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let cmd = argv.first().map(|s| s.as_str()).unwrap_or("");
    let rest = if argv.is_empty() { &[][..] } else { &argv[1..] };
    let code = match cmd {
        "sim" => cmd_sim(rest),
        "sweep" => cmd_sweep(rest),
        "trace" => cmd_trace(rest),
        "autoscale" => cmd_autoscale(rest),
        "serve" => cmd_serve(rest),
        "loadgen" => cmd_loadgen(rest),
        "config" => cmd_config(rest),
        "export" => cmd_export(rest),
        "" | "--help" | "-h" | "help" => {
            eprintln!(
                "hiku — pull-based scheduling for serverless computing (CCGRID'25 reproduction)\n\n\
                 USAGE:\n  hiku <sim|sweep|trace|autoscale|serve|loadgen|config|export> [OPTIONS]\n\n\
                 Run `hiku <subcommand> --help` for options."
            );
            0
        }
        other => {
            eprintln!("unknown subcommand '{other}' (try --help)");
            2
        }
    };
    std::process::exit(code);
}

/// Shared config-building options.
fn config_cli(cli: Cli) -> Cli {
    cli.opt("config", None, "JSON config file")
        .opt("set", None, "comma-separated path=value overrides")
        .opt("scheduler", None, "scheduler name (overrides config)")
        .opt("vus", None, "virtual users")
        .opt("duration", None, "run duration in seconds")
        .opt("workers", None, "number of workers")
        .opt("autoscale", None, "autoscale policy (none|scheduled|reactive|predictive)")
        .opt("scale-events", None, "scheduled-policy events, e.g. '60;120;-150'")
        .opt("shards", None, "event-core shards (OS threads; 1 = serial engine)")
        .opt("dispatch", None, "dispatch protocol mode (push|pull)")
        .opt("queue-cap", None, "per-function pending-queue admission cap (0 = unbounded)")
        .opt("queue-caps", None, "per-function cap overrides, e.g. '0:4;7:64'")
        .opt("max-wait", None, "pull wait-deadline upper bound in seconds")
        .opt("faults", None, "enable fault injection, e.g. 'crash:0.1;straggle:0.25;slow:4'")
        .opt("seed", None, "experiment seed")
        .flag("sketch", "bounded-memory quantile sketches instead of exact sample vectors")
        .opt("trace-sample", None, "lifecycle tracing: record every Nth request (0 = off)")
        .flag("profile", "engine phase profiling (pop/decide/barrier/handoff/autoscale)")
}

fn build_config(args: &hiku::util::cli::Args) -> Result<Config, String> {
    let mut cfg = match args.get("config") {
        Some(path) => Config::from_file(path).map_err(|e| e.to_string())?,
        None => Config::default(),
    };
    for kv in args.parse_list("set") {
        cfg.apply_override(&kv).map_err(|e| e.to_string())?;
    }
    if let Some(s) = args.get("scheduler") {
        cfg.scheduler.name = s.to_string();
    }
    if let Some(v) = args.get("vus") {
        cfg.workload.vus = v.parse().map_err(|_| "--vus: integer expected".to_string())?;
    }
    if let Some(v) = args.get("duration") {
        cfg.workload.duration_s =
            v.parse().map_err(|_| "--duration: number expected".to_string())?;
    }
    if let Some(v) = args.get("workers") {
        cfg.cluster.workers =
            v.parse().map_err(|_| "--workers: integer expected".to_string())?;
    }
    if let Some(p) = args.get("autoscale") {
        cfg.autoscale.policy = p.to_string();
    }
    if let Some(e) = args.get("scale-events") {
        cfg.autoscale.events = e.to_string();
    }
    if let Some(v) = args.get("shards") {
        cfg.sim.shards = v.parse().map_err(|_| "--shards: integer expected".to_string())?;
    }
    if let Some(m) = args.get("dispatch") {
        cfg.dispatch.mode = m.to_string();
    }
    if let Some(v) = args.get("queue-cap") {
        cfg.dispatch.queue_cap =
            v.parse().map_err(|_| "--queue-cap: integer expected".to_string())?;
    }
    if let Some(v) = args.get("queue-caps") {
        cfg.dispatch.queue_caps = v.to_string();
    }
    if let Some(v) = args.get("max-wait") {
        cfg.dispatch.max_wait_s =
            v.parse().map_err(|_| "--max-wait: number expected".to_string())?;
    }
    if let Some(spec) = args.get("faults") {
        cfg.faults.apply_spec(spec).map_err(|e| format!("--faults: {e}"))?;
    }
    if let Some(v) = args.get("seed") {
        cfg.workload.seed = v.parse().map_err(|_| "--seed: integer expected".to_string())?;
    }
    if args.has_flag("sketch") {
        cfg.telemetry.sketch = true;
    }
    if let Some(v) = args.get("trace-sample") {
        cfg.telemetry.trace_sample =
            v.parse().map_err(|_| "--trace-sample: integer expected".to_string())?;
    }
    if args.has_flag("profile") {
        cfg.telemetry.phase_profile = true;
    }
    cfg.validate().map_err(|e| e.to_string())?;
    Ok(cfg)
}

/// Write the lifecycle-trace artifacts — `trace.csv` plus the Chrome-trace
/// document `trace.chrome.json` (load it in `chrome://tracing` or
/// Perfetto) — into `dir`.
fn write_trace(dir: &str, cfg: &Config, m: &hiku::metrics::RunMetrics) -> Result<(), String> {
    if cfg.telemetry.trace_sample == 0 {
        eprintln!("note: --trace-out without --trace-sample N records no spans");
    }
    std::fs::create_dir_all(dir).map_err(|e| format!("creating {dir}: {e}"))?;
    let csv_path = format!("{dir}/trace.csv");
    std::fs::write(&csv_path, hiku::report::export::trace_csv(m))
        .map_err(|e| format!("writing {csv_path}: {e}"))?;
    let json_path = format!("{dir}/trace.chrome.json");
    std::fs::write(&json_path, hiku::report::export::chrome_trace_json(m).to_string_compact())
        .map_err(|e| format!("writing {json_path}: {e}"))?;
    eprintln!("wrote {csv_path} and {json_path} ({} spans)", m.trace.len());
    Ok(())
}

fn cmd_sim(argv: &[String]) -> i32 {
    let cli = config_cli(Cli::new("hiku sim", "run one simulated experiment"))
        .opt("trace-out", None, "directory for trace.csv + trace.chrome.json");
    let args = match cli.parse(argv) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("{e}");
            return if e.0.contains("USAGE") { 0 } else { 2 };
        }
    };
    let cfg = match build_config(&args) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("error: {e}");
            return 2;
        }
    };
    match hiku::sim::run_once(&cfg, cfg.workload.seed) {
        Ok(mut m) => {
            println!("{}", m.summary_json().to_string_pretty());
            if let Some(dir) = args.get("trace-out") {
                if let Err(e) = write_trace(dir, &cfg, &m) {
                    eprintln!("error: {e}");
                    return 1;
                }
            }
            0
        }
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    }
}

fn cmd_sweep(argv: &[String]) -> i32 {
    let cli = config_cli(Cli::new("hiku sweep", "paper evaluation sweep"))
        .opt("runs", Some("5"), "seeded runs per scheduler")
        .opt("vu-levels", Some("20,50,100"), "VU levels (comma-separated)")
        .opt("schedulers", Some("hiku,ch-bl,random,least-connections"), "schedulers to sweep");
    let args = match cli.parse(argv) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("{e}");
            return if e.0.contains("USAGE") { 0 } else { 2 };
        }
    };
    let base = match build_config(&args) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("error: {e}");
            return 2;
        }
    };
    let runs = args.parse_u64("runs").unwrap_or(5);
    let vu_levels: Vec<usize> =
        args.parse_list("vu-levels").iter().filter_map(|v| v.parse().ok()).collect();
    let schedulers = args.parse_list("schedulers");
    match hiku::report::evaluation_report(&base, &schedulers, &vu_levels, runs) {
        Ok(text) => {
            println!("{text}");
            0
        }
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    }
}

fn cmd_trace(argv: &[String]) -> i32 {
    let cli = Cli::new("hiku trace", "synthesize + analyze an Azure-like trace (Figs 4-6)")
        .opt("universe", Some("10000"), "functions in the universe")
        .opt("minutes", Some("30"), "trace duration in minutes")
        .opt("seed", Some("42"), "trace seed");
    let args = match cli.parse(argv) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("{e}");
            return if e.0.contains("USAGE") { 0 } else { 2 };
        }
    };
    let universe = args.parse_usize("universe").unwrap_or(10_000);
    let minutes = args.parse_f64("minutes").unwrap_or(30.0);
    let seed = args.parse_u64("seed").unwrap_or(42);
    println!("{}", hiku::report::trace_report(universe, minutes * 60.0, seed));
    0
}

fn cmd_autoscale(argv: &[String]) -> i32 {
    let cli = config_cli(Cli::new(
        "hiku autoscale",
        "compare autoscale policies x schedulers on the bursty trace",
    ))
    .opt("policies", Some("none,scheduled,reactive,predictive"), "policies to sweep")
    .opt("schedulers", Some("hiku,least-connections"), "schedulers to sweep");
    let args = match cli.parse(argv) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("{e}");
            return if e.0.contains("USAGE") { 0 } else { 2 };
        }
    };
    let mut base = match build_config(&args) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("error: {e}");
            return 2;
        }
    };
    // Autoscale-friendly defaults when the caller sticks to the paper
    // setup: start small so scaling has room to act.
    if args.get("workers").is_none() && args.get("config").is_none() {
        base.cluster.workers = 2;
        base.autoscale.min_workers = 2;
        base.autoscale.max_workers = 10;
    }
    if args.get("duration").is_none() {
        base.workload.duration_s = 240.0;
    }
    let policies = args.parse_list("policies");
    let schedulers = args.parse_list("schedulers");
    match hiku::report::autoscale_report(&base, &policies, &schedulers, base.workload.seed) {
        Ok(text) => {
            println!("{text}");
            0
        }
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    }
}

fn cmd_serve(argv: &[String]) -> i32 {
    let cli = config_cli(Cli::new("hiku serve", "real-time serving demo (add --http for ingress)"))
        .opt("requests", Some("100"), "requests to issue (closed-loop mode)")
        .opt("http", None, "bind the HTTP front door on ADDR and serve until killed")
        .opt("trace-out", None, "directory for trace.csv + trace.chrome.json");
    let args = match cli.parse(argv) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("{e}");
            return if e.0.contains("USAGE") { 0 } else { 2 };
        }
    };
    let cfg = match build_config(&args) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("error: {e}");
            return 2;
        }
    };
    if let Some(addr) = args.get("http") {
        // Ingress mode: bind the front door and serve until the process
        // is killed. `[http]` keys (io_threads, keep-alive, body cap,
        // read timeout) come from the config / --set overrides.
        let ingress = match hiku::server::http::HttpIngress::start(&cfg, addr) {
            Ok(i) => i,
            Err(e) => {
                eprintln!("error: {e}");
                return 1;
            }
        };
        println!("listening on http://{}", ingress.local_addr());
        println!("routes: POST /invoke/{{id}}  POST /prewarm/{{id}}  GET /summary  GET /healthz");
        loop {
            std::thread::sleep(std::time::Duration::from_secs(3600));
        }
    }
    let requests = args.parse_u64("requests").unwrap_or(100) as usize;
    match hiku::server::serve_n_requests(&cfg, requests) {
        Ok(mut m) => {
            println!("{}", m.summary_json().to_string_pretty());
            if let Some(dir) = args.get("trace-out") {
                if let Err(e) = write_trace(dir, &cfg, &m) {
                    eprintln!("error: {e}");
                    return 1;
                }
            }
            0
        }
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    }
}

fn cmd_loadgen(argv: &[String]) -> i32 {
    let cli = Cli::new("hiku loadgen", "open-loop HTTP load generator (k6-style)")
        .opt("addr", Some("127.0.0.1:8080"), "ingress address to hammer")
        .opt("requests", Some("1000"), "total requests to issue")
        .opt("rate", Some("200"), "mean arrival rate in requests/second")
        .opt("connections", Some("8"), "concurrent keep-alive connections")
        .opt("functions", Some("40"), "function-id universe (must match the server)")
        .opt("zipf", Some("2.05"), "Zipf skew for function popularity")
        .opt("seed", Some("42"), "schedule seed (same seed = same schedule)")
        .flag("trace", "pace arrivals from the bursty Azure-like trace instead of Poisson")
        .opt("json", None, "also write the report JSON to this file");
    let args = match cli.parse(argv) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("{e}");
            return if e.0.contains("USAGE") { 0 } else { 2 };
        }
    };
    let opts = hiku::workload::loadgen::LoadgenOpts {
        addr: args.get_or("addr", "127.0.0.1:8080").to_string(),
        requests: args.parse_usize("requests").unwrap_or(1000),
        rate_rps: args.parse_f64("rate").unwrap_or(200.0),
        connections: args.parse_usize("connections").unwrap_or(8),
        num_functions: args.parse_usize("functions").unwrap_or(40),
        zipf_s: args.parse_f64("zipf").unwrap_or(2.05),
        seed: args.parse_u64("seed").unwrap_or(42),
        use_trace: args.has_flag("trace"),
    };
    match hiku::workload::loadgen::run_http_loadgen(&opts) {
        Ok(report) => {
            let json = report.to_json();
            println!("{}", json.to_string_pretty());
            if let Some(path) = args.get("json") {
                if let Err(e) = std::fs::write(path, json.to_string_pretty()) {
                    eprintln!("error: writing {path}: {e}");
                    return 1;
                }
            }
            if !report.accounted() {
                eprintln!("error: request accounting does not balance");
                return 1;
            }
            if report.transport_errors > 0 {
                eprintln!("error: {} transport errors", report.transport_errors);
                return 1;
            }
            0
        }
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    }
}

fn cmd_export(argv: &[String]) -> i32 {
    let cli = config_cli(Cli::new("hiku export", "dump figure series as CSV for plotting"))
        .opt("runs", Some("5"), "seeded runs per scheduler")
        .opt("out-dir", Some("figures"), "output directory")
        .opt("schedulers", Some("hiku,ch-bl,random,least-connections"), "schedulers");
    let args = match cli.parse(argv) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("{e}");
            return if e.0.contains("USAGE") { 0 } else { 2 };
        }
    };
    let cfg = match build_config(&args) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("error: {e}");
            return 2;
        }
    };
    let runs = args.parse_u64("runs").unwrap_or(5);
    let out_dir = args.get_or("out-dir", "figures").to_string();
    if let Err(e) = std::fs::create_dir_all(&out_dir) {
        eprintln!("error: creating {out_dir}: {e}");
        return 1;
    }
    let mut all: Vec<(String, Vec<hiku::metrics::RunMetrics>)> = Vec::new();
    for s in args.parse_list("schedulers") {
        match hiku::report::run_cell(&cfg, &s, cfg.workload.vus, runs) {
            Ok((_, rs)) => all.push((s, rs)),
            Err(e) => {
                eprintln!("error: {e}");
                return 1;
            }
        }
    }
    use hiku::report::export;
    let files = [
        ("fig10_latency_cdf.csv", export::latency_cdf_csv(&mut all, 100)),
        ("fig14_cv_series.csv", export::cv_series_csv(&all)),
        ("fig16_cumulative.csv", export::cumulative_csv(&all)),
        ("autoscale_timeline.csv", export::scaling_timeline_csv(&all)),
        ("pending_depth.csv", export::pending_depth_csv(&all)),
        ("dispatch_fairness.csv", export::per_function_csv(&mut all)),
        ("summary.csv", export::summary_csv(&mut all)),
    ];
    for (name, content) in files {
        let path = format!("{out_dir}/{name}");
        if let Err(e) = std::fs::write(&path, content) {
            eprintln!("error: writing {path}: {e}");
            return 1;
        }
        println!("wrote {path}");
    }
    0
}

fn cmd_config(argv: &[String]) -> i32 {
    let cli = config_cli(Cli::new("hiku config", "print effective config as JSON"));
    let args = match cli.parse(argv) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("{e}");
            return if e.0.contains("USAGE") { 0 } else { 2 };
        }
    };
    match build_config(&args) {
        Ok(c) => {
            println!("{}", c.to_json().to_string_pretty());
            0
        }
        Err(e) => {
            eprintln!("error: {e}");
            2
        }
    }
}
