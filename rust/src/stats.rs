//! Statistics substrate: streaming moments, percentiles, CDFs, time series.
//!
//! Everything the paper's evaluation reports is computed here:
//! - response-latency CDFs (Fig 10) and percentiles (Fig 12),
//! - means (Fig 11), cold-start rates (Fig 13),
//! - the coefficient of variation of per-worker assignment rates
//!   (Figs 14/15 — the paper's load-imbalance metric),
//! - throughput time series (Fig 16) and requests/s (Fig 17).

/// Streaming mean/variance via Welford's algorithm, plus min/max.
#[derive(Clone, Debug, Default)]
pub struct OnlineStats {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl OnlineStats {
    /// An empty stream (mean/variance are NaN until the first push).
    pub fn new() -> Self {
        Self { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    /// Fold one observation into the stream.
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        if x < self.min {
            self.min = x;
        }
        if x > self.max {
            self.max = x;
        }
    }

    /// Observations folded in so far.
    pub fn count(&self) -> u64 {
        self.n
    }

    /// Arithmetic mean (NaN for the empty stream).
    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            f64::NAN
        } else {
            self.mean
        }
    }

    /// Population variance.
    pub fn variance(&self) -> f64 {
        if self.n == 0 {
            f64::NAN
        } else {
            self.m2 / self.n as f64
        }
    }

    /// Population standard deviation.
    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }

    /// Coefficient of variation (std/mean) — the paper's load-imbalance
    /// metric (Figs 14/15).
    pub fn cv(&self) -> f64 {
        let m = self.mean();
        if m == 0.0 {
            0.0
        } else {
            self.std() / m
        }
    }

    /// Smallest observation (`+inf` for the empty stream).
    pub fn min(&self) -> f64 {
        self.min
    }

    /// Largest observation (`-inf` for the empty stream).
    pub fn max(&self) -> f64 {
        self.max
    }

    /// Merge two streams (parallel reduction).
    pub fn merge(&mut self, other: &OnlineStats) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n = self.n + other.n;
        let d = other.mean - self.mean;
        let mean = self.mean + d * other.n as f64 / n as f64;
        let m2 = self.m2 + other.m2 + d * d * (self.n as f64 * other.n as f64) / n as f64;
        self.n = n;
        self.mean = mean;
        self.m2 = m2;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

/// Exact sample reservoir for percentiles/CDFs. The paper's runs are
/// ~16k requests × 20 runs — small enough that exact quantiles are cheap,
/// so we keep all samples rather than approximating (a capped variant is
/// available via `with_capacity_cap` for very long runs).
#[derive(Clone, Debug, Default)]
pub struct Samples {
    xs: Vec<f64>,
    sorted: bool,
    cap: Option<usize>,
    seen: u64,
}

impl Samples {
    /// An uncapped (exact) sample set.
    pub fn new() -> Self {
        Self::default()
    }

    /// Reservoir-capped variant (uniform reservoir sampling beyond `cap`).
    pub fn with_capacity_cap(cap: usize) -> Self {
        Self { cap: Some(cap), ..Default::default() }
    }

    /// Record one sample (reservoir-replacing beyond the cap, if any).
    pub fn push(&mut self, x: f64) {
        self.seen += 1;
        match self.cap {
            Some(cap) if self.xs.len() >= cap => {
                // Deterministic reservoir: replace slot h(seen) % cap with
                // probability cap/seen using a cheap hash of the counter.
                let h = crate::util::hashing::mix64(self.seen);
                if (h % self.seen) < cap as u64 {
                    let slot = (h >> 32) as usize % cap;
                    self.xs[slot] = x;
                }
            }
            _ => self.xs.push(x),
        }
        self.sorted = false;
    }

    /// Samples currently retained (≤ seen when capped).
    pub fn len(&self) -> usize {
        self.xs.len()
    }

    /// True when nothing has been retained.
    pub fn is_empty(&self) -> bool {
        self.xs.is_empty()
    }

    /// Samples ever pushed (including reservoir-dropped ones).
    pub fn seen(&self) -> u64 {
        self.seen
    }

    fn ensure_sorted(&mut self) {
        if !self.sorted {
            self.xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
            self.sorted = true;
        }
    }

    /// Exact percentile in [0, 100] by linear interpolation.
    pub fn percentile(&mut self, p: f64) -> f64 {
        assert!((0.0..=100.0).contains(&p));
        self.ensure_sorted();
        if self.xs.is_empty() {
            return f64::NAN;
        }
        let n = self.xs.len();
        if n == 1 {
            return self.xs[0];
        }
        let rank = p / 100.0 * (n - 1) as f64;
        let lo = rank.floor() as usize;
        let hi = rank.ceil() as usize;
        let frac = rank - lo as f64;
        self.xs[lo] * (1.0 - frac) + self.xs[hi] * frac
    }

    /// Mean of the retained samples (NaN when empty).
    pub fn mean(&self) -> f64 {
        if self.xs.is_empty() {
            f64::NAN
        } else {
            self.xs.iter().sum::<f64>() / self.xs.len() as f64
        }
    }

    /// CDF sampled at `points` evenly spaced quantiles: Vec<(value, prob)>.
    pub fn cdf(&mut self, points: usize) -> Vec<(f64, f64)> {
        self.ensure_sorted();
        if self.xs.is_empty() {
            return Vec::new();
        }
        let n = self.xs.len();
        (0..points)
            .map(|i| {
                let q = (i + 1) as f64 / points as f64;
                let idx = ((q * n as f64).ceil() as usize).clamp(1, n) - 1;
                (self.xs[idx], q)
            })
            .collect()
    }

    /// The retained samples, sorted ascending.
    pub fn values(&mut self) -> &[f64] {
        self.ensure_sorted();
        &self.xs
    }

    /// Append another sample set (the shard-merge reduction). Percentiles
    /// and CDFs over the merged set are exact when neither side is
    /// reservoir-capped — the simulator's per-run samples never are; a
    /// capped reservoir merges its *retained* samples only.
    pub fn merge_from(&mut self, other: &Samples) {
        self.xs.extend_from_slice(&other.xs);
        self.seen += other.seen;
        self.sorted = false;
    }
}

/// Mergeable streaming quantile sketch (DDSketch-style, Masson et al.,
/// VLDB 2019): logarithmic buckets with relative accuracy `alpha`, so any
/// reported quantile `v̂` satisfies `|v̂ - v| <= alpha * v` for the true
/// quantile value `v`. Memory is bounded by the *value range*, not the
/// stream length — `O(log(max/min) / alpha)` buckets — which is what lets
/// `RunMetrics` drop its per-request sample vectors on huge runs
/// (`[telemetry] sketch = true`).
///
/// Determinism: buckets live in a `BTreeMap` keyed by integer bucket
/// index, inserts/merges are pure integer-count arithmetic, and two
/// sketches with the same `alpha` have the same bucket geometry — so a
/// merge (the shard-barrier reduction) is an exact count addition and the
/// merged sketch is *bit-identical* to one sketch fed the pooled stream,
/// in any merge order.
#[derive(Clone, Debug)]
pub struct QuantileSketch {
    alpha: f64,
    /// ln(gamma) with gamma = (1 + alpha) / (1 - alpha).
    ln_gamma: f64,
    /// Counts per logarithmic bucket: index `i` covers `(γ^(i-1), γ^i]`.
    bins: std::collections::BTreeMap<i32, u64>,
    /// Observations at or below [`QuantileSketch::MIN_VALUE`] (zeros).
    zeros: u64,
    count: u64,
    sum: f64,
    min: f64,
    max: f64,
}

impl QuantileSketch {
    /// Values at or below this threshold land in the exact zeros bucket
    /// (latencies are non-negative; 1 ns in the engine's ms unit).
    pub const MIN_VALUE: f64 = 1e-9;
    /// Hard cap on live buckets; beyond it the lowest-index buckets
    /// collapse together (DDSketch's bound — it only coarsens the extreme
    /// low tail, which no reported percentile reads).
    pub const MAX_BINS: usize = 4096;

    /// An empty sketch with relative accuracy `alpha` (e.g. 0.005 = 0.5%).
    pub fn new(alpha: f64) -> Self {
        assert!(alpha > 0.0 && alpha < 0.5, "alpha out of range: {alpha}");
        let gamma = (1.0 + alpha) / (1.0 - alpha);
        Self {
            alpha,
            ln_gamma: gamma.ln(),
            bins: std::collections::BTreeMap::new(),
            zeros: 0,
            count: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    /// The configured relative accuracy.
    pub fn alpha(&self) -> f64 {
        self.alpha
    }

    /// Bucket index for a value above `MIN_VALUE`.
    #[inline]
    fn index_of(&self, x: f64) -> i32 {
        (x.ln() / self.ln_gamma).ceil() as i32
    }

    /// Representative value of bucket `i`: the midpoint `2γ^i/(γ+1)`,
    /// whose relative distance to every value in the bucket is ≤ alpha.
    #[inline]
    fn value_of(&self, i: i32) -> f64 {
        let gamma_i = (self.ln_gamma * i as f64).exp();
        2.0 * gamma_i / ((self.ln_gamma.exp()) + 1.0)
    }

    /// Fold one observation into the sketch.
    pub fn push(&mut self, x: f64) {
        assert!(x.is_finite() && x >= 0.0, "sketch values must be finite and >= 0: {x}");
        self.count += 1;
        self.sum += x;
        if x < self.min {
            self.min = x;
        }
        if x > self.max {
            self.max = x;
        }
        if x <= Self::MIN_VALUE {
            self.zeros += 1;
            return;
        }
        *self.bins.entry(self.index_of(x)).or_insert(0) += 1;
        if self.bins.len() > Self::MAX_BINS {
            self.collapse_lowest();
        }
    }

    /// Merge the two lowest buckets (bounds memory; coarsens only the
    /// extreme low tail).
    fn collapse_lowest(&mut self) {
        let mut it = self.bins.keys().copied();
        if let (Some(lo), Some(next)) = (it.next(), it.next()) {
            let c = self.bins.remove(&lo).unwrap_or(0);
            *self.bins.entry(next).or_insert(0) += c;
        }
    }

    /// Observations folded in so far.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// True when nothing was pushed yet.
    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// Exact arithmetic mean of the stream (NaN when empty).
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            f64::NAN
        } else {
            self.sum / self.count as f64
        }
    }

    /// Exact minimum (`+inf` when empty).
    pub fn min(&self) -> f64 {
        self.min
    }

    /// Exact maximum (`-inf` when empty).
    pub fn max(&self) -> f64 {
        self.max
    }

    /// Live bucket count (memory diagnostic; bounded by `MAX_BINS`).
    pub fn bin_count(&self) -> usize {
        self.bins.len()
    }

    /// Quantile estimate for `p` in [0, 100] (NaN when empty). The exact
    /// min/max are returned at the extremes; interior quantiles carry the
    /// `alpha` relative-error guarantee.
    pub fn percentile(&self, p: f64) -> f64 {
        assert!((0.0..=100.0).contains(&p));
        if self.count == 0 {
            return f64::NAN;
        }
        if p == 0.0 {
            return self.min;
        }
        if p == 100.0 {
            return self.max;
        }
        let rank = (p / 100.0 * (self.count - 1) as f64).floor() as u64;
        let mut cum = self.zeros;
        if rank < cum {
            return 0.0;
        }
        for (&i, &c) in &self.bins {
            cum += c;
            if rank < cum {
                return self.value_of(i).clamp(self.min, self.max);
            }
        }
        self.max
    }

    /// CDF sampled at `points` evenly spaced quantiles: Vec<(value, prob)>
    /// — the sketch-mode backing of the latency-CDF exports.
    pub fn cdf(&self, points: usize) -> Vec<(f64, f64)> {
        if self.count == 0 {
            return Vec::new();
        }
        (0..points)
            .map(|i| {
                let q = (i + 1) as f64 / points as f64;
                (self.percentile(q * 100.0), q)
            })
            .collect()
    }

    /// Merge another sketch (the shard barrier reduction). Requires the
    /// same `alpha` (identical bucket geometry); the result is identical
    /// to a single sketch fed both streams, in any merge order.
    pub fn merge_from(&mut self, other: &QuantileSketch) {
        assert!(
            (self.alpha - other.alpha).abs() < 1e-12,
            "merging sketches with different accuracies ({} vs {})",
            self.alpha,
            other.alpha
        );
        for (&i, &c) in &other.bins {
            *self.bins.entry(i).or_insert(0) += c;
        }
        self.zeros += other.zeros;
        self.count += other.count;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
        while self.bins.len() > Self::MAX_BINS {
            self.collapse_lowest();
        }
    }
}

/// A latency/wait distribution in one of two storage modes: exact sample
/// vectors (the determinism/ablation baseline — every figure-grade run)
/// or a bounded-memory [`QuantileSketch`] (`[telemetry] sketch = true`,
/// the million-worker tier). The engine pushes through one API and the
/// summary/export layers query percentiles without caring which backing
/// is live; exact mode is bit-identical to the pre-telemetry layout.
#[derive(Clone, Debug)]
pub enum Dist {
    /// Exact per-sample storage ([`Samples`]).
    Exact(Samples),
    /// Bounded-memory streaming sketch ([`QuantileSketch`]).
    Sketch(QuantileSketch),
}

impl Dist {
    /// An exact (uncapped) sample store.
    pub fn exact() -> Self {
        Dist::Exact(Samples::new())
    }

    /// A streaming sketch with relative accuracy `alpha`.
    pub fn sketch(alpha: f64) -> Self {
        Dist::Sketch(QuantileSketch::new(alpha))
    }

    /// Build the mode the telemetry config asks for.
    pub fn for_mode(sketch: bool, alpha: f64) -> Self {
        if sketch {
            Self::sketch(alpha)
        } else {
            Self::exact()
        }
    }

    /// True when the sketch backing is live.
    pub fn is_sketch(&self) -> bool {
        matches!(self, Dist::Sketch(_))
    }

    /// Record one observation.
    pub fn push(&mut self, x: f64) {
        match self {
            Dist::Exact(s) => s.push(x),
            Dist::Sketch(k) => k.push(x),
        }
    }

    /// Observations ever pushed.
    pub fn seen(&self) -> u64 {
        match self {
            Dist::Exact(s) => s.seen(),
            Dist::Sketch(k) => k.count(),
        }
    }

    /// True when nothing was pushed yet.
    pub fn is_empty(&self) -> bool {
        self.seen() == 0
    }

    /// Percentile in [0, 100]: exact (linear interpolation) or within the
    /// sketch's `alpha` relative error. NaN when empty.
    pub fn percentile(&mut self, p: f64) -> f64 {
        match self {
            Dist::Exact(s) => s.percentile(p),
            Dist::Sketch(k) => k.percentile(p),
        }
    }

    /// Mean of the stream (exact in both modes; NaN when empty).
    pub fn mean(&self) -> f64 {
        match self {
            Dist::Exact(s) => s.mean(),
            Dist::Sketch(k) => k.mean(),
        }
    }

    /// CDF sampled at `points` evenly spaced quantiles.
    pub fn cdf(&mut self, points: usize) -> Vec<(f64, f64)> {
        match self {
            Dist::Exact(s) => s.cdf(points),
            Dist::Sketch(k) => k.cdf(points),
        }
    }

    /// The exact sample store, when that mode is live (the raw-value CSV
    /// export paths are exact-only).
    pub fn as_samples_mut(&mut self) -> Option<&mut Samples> {
        match self {
            Dist::Exact(s) => Some(s),
            Dist::Sketch(_) => None,
        }
    }

    /// Merge another distribution of the same mode (the shard reduction).
    pub fn merge_from(&mut self, other: &Dist) {
        match (self, other) {
            (Dist::Exact(a), Dist::Exact(b)) => a.merge_from(b),
            (Dist::Sketch(a), Dist::Sketch(b)) => a.merge_from(b),
            _ => panic!("merging Dist values with different storage modes"),
        }
    }
}

/// Fixed-width time binning: accumulate per-bin counts/sums over virtual
/// time. Backs the tasks-per-second series (Fig 14), the cumulative
/// throughput curve (Fig 16) and requests/s (Fig 17).
#[derive(Clone, Debug)]
pub struct TimeSeries {
    bin_width: f64,
    bins: Vec<f64>,
}

impl TimeSeries {
    /// An empty series with `bin_width`-second bins.
    pub fn new(bin_width: f64) -> Self {
        assert!(bin_width > 0.0);
        Self { bin_width, bins: Vec::new() }
    }

    /// Accumulate `value` into the bin containing time `t`.
    pub fn add(&mut self, t: f64, value: f64) {
        assert!(t >= 0.0, "negative time {t}");
        let idx = (t / self.bin_width) as usize;
        if idx >= self.bins.len() {
            self.bins.resize(idx + 1, 0.0);
        }
        self.bins[idx] += value;
    }

    /// Count one event at time `t`.
    pub fn increment(&mut self, t: f64) {
        self.add(t, 1.0);
    }

    /// The per-bin accumulated values (index = bin number).
    pub fn bins(&self) -> &[f64] {
        &self.bins
    }

    /// Bin width in seconds.
    pub fn bin_width(&self) -> f64 {
        self.bin_width
    }

    /// Cumulative sum series (Fig 16's "cumulative requests over time").
    pub fn cumulative(&self) -> Vec<f64> {
        let mut acc = 0.0;
        self.bins
            .iter()
            .map(|&x| {
                acc += x;
                acc
            })
            .collect()
    }

    /// Sum over all bins.
    pub fn total(&self) -> f64 {
        self.bins.iter().sum()
    }

    /// Mean rate per bin over the observed window.
    pub fn mean_rate(&self) -> f64 {
        if self.bins.is_empty() {
            0.0
        } else {
            self.total() / (self.bins.len() as f64 * self.bin_width)
        }
    }

    /// Elementwise-add another series with the same bin width (disjoint
    /// event streams over the same virtual clock — the shard merge).
    pub fn merge_add(&mut self, other: &TimeSeries) {
        assert!(
            (self.bin_width - other.bin_width).abs() < 1e-12,
            "merging series with different bin widths ({} vs {})",
            self.bin_width,
            other.bin_width
        );
        if other.bins.len() > self.bins.len() {
            self.bins.resize(other.bins.len(), 0.0);
        }
        for (b, &v) in self.bins.iter_mut().zip(&other.bins) {
            *b += v;
        }
    }
}

/// The paper's load-imbalance metric: per second, the coefficient of
/// variation of requests assigned across workers; reported as a time series
/// (Fig 14) and as its average (Fig 15).
#[derive(Clone, Debug)]
pub struct LoadImbalance {
    per_worker: Vec<TimeSeries>,
}

impl LoadImbalance {
    /// Start tracking `workers` workers with `bin_width`-second bins.
    pub fn new(workers: usize, bin_width: f64) -> Self {
        Self { per_worker: (0..workers).map(|_| TimeSeries::new(bin_width)).collect() }
    }

    /// One request was assigned to `worker` at time `t`.
    pub fn record_assignment(&mut self, worker: usize, t: f64) {
        self.per_worker[worker].increment(t);
    }

    /// Auto-scaling: start tracking an additional worker. Its bins before
    /// the join time are implicitly zero (it received nothing). Note that
    /// `cv_series` treats those zeros as real, so pre-join bins show a
    /// higher CV in scaled runs — the auto-scale ablation reports windowed
    /// cold rates/latency instead.
    pub fn add_worker(&mut self) {
        let bw = self.per_worker[0].bin_width();
        self.per_worker.push(TimeSeries::new(bw));
    }

    /// CV across workers for each time bin.
    pub fn cv_series(&self) -> Vec<f64> {
        let n_bins = self.per_worker.iter().map(|ts| ts.bins().len()).max().unwrap_or(0);
        (0..n_bins)
            .map(|b| {
                let mut st = OnlineStats::new();
                for ts in &self.per_worker {
                    st.push(ts.bins().get(b).copied().unwrap_or(0.0));
                }
                st.cv()
            })
            .collect()
    }

    /// Average CV over bins that saw any traffic (Fig 15's headline number).
    pub fn mean_cv(&self) -> f64 {
        let series = self.cv_series();
        let active: Vec<f64> = series
            .iter()
            .enumerate()
            .filter(|(b, _)| {
                self.per_worker
                    .iter()
                    .any(|ts| ts.bins().get(*b).copied().unwrap_or(0.0) > 0.0)
            })
            .map(|(_, &cv)| cv)
            .collect();
        if active.is_empty() {
            0.0
        } else {
            active.iter().sum::<f64>() / active.len() as f64
        }
    }

    /// Total requests assigned per worker (sanity/reporting).
    pub fn totals(&self) -> Vec<f64> {
        self.per_worker.iter().map(|ts| ts.total()).collect()
    }

    /// Append another *disjoint* worker set's assignment series: merged
    /// worker ids are `self`'s workers followed by `other`'s, in order —
    /// the shard-merge reduction (the CV is then computed over the global
    /// worker set, exactly as a single run over all workers would).
    pub fn merge_append(&mut self, other: &LoadImbalance) {
        self.per_worker.extend(other.per_worker.iter().cloned());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn online_stats_basic() {
        let mut s = OnlineStats::new();
        for x in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            s.push(x);
        }
        assert_eq!(s.count(), 8);
        assert!((s.mean() - 5.0).abs() < 1e-12);
        assert!((s.std() - 2.0).abs() < 1e-12);
        assert!((s.cv() - 0.4).abs() < 1e-12);
        assert_eq!(s.min(), 2.0);
        assert_eq!(s.max(), 9.0);
    }

    #[test]
    fn online_stats_merge_equals_combined() {
        let xs: Vec<f64> = (0..100).map(|i| (i as f64).sin() * 10.0).collect();
        let mut all = OnlineStats::new();
        let mut a = OnlineStats::new();
        let mut b = OnlineStats::new();
        for (i, &x) in xs.iter().enumerate() {
            all.push(x);
            if i % 2 == 0 {
                a.push(x)
            } else {
                b.push(x)
            }
        }
        a.merge(&b);
        assert!((a.mean() - all.mean()).abs() < 1e-9);
        assert!((a.variance() - all.variance()).abs() < 1e-9);
    }

    #[test]
    fn percentiles_exact() {
        let mut s = Samples::new();
        for i in 1..=100 {
            s.push(i as f64);
        }
        assert!((s.percentile(0.0) - 1.0).abs() < 1e-12);
        assert!((s.percentile(100.0) - 100.0).abs() < 1e-12);
        assert!((s.percentile(50.0) - 50.5).abs() < 1e-9);
        assert!((s.percentile(99.0) - 99.01).abs() < 0.02);
    }

    #[test]
    fn cdf_monotone() {
        let mut s = Samples::new();
        let mut rng = crate::util::rng::Pcg64::new(11);
        for _ in 0..1000 {
            s.push(rng.next_f64() * 100.0);
        }
        let cdf = s.cdf(50);
        assert_eq!(cdf.len(), 50);
        for w in cdf.windows(2) {
            assert!(w[0].0 <= w[1].0, "values not monotone");
            assert!(w[0].1 < w[1].1, "probs not monotone");
        }
        assert!((cdf.last().unwrap().1 - 1.0).abs() < 1e-12);
    }

    #[test]
    fn reservoir_cap_respected() {
        let mut s = Samples::with_capacity_cap(100);
        for i in 0..10_000 {
            s.push(i as f64);
        }
        assert_eq!(s.len(), 100);
        assert_eq!(s.seen(), 10_000);
        // Reservoir should span the range, not just the head.
        assert!(s.percentile(90.0) > 2_000.0);
    }

    #[test]
    fn time_series_binning() {
        let mut ts = TimeSeries::new(1.0);
        ts.increment(0.1);
        ts.increment(0.9);
        ts.increment(1.5);
        ts.increment(5.0);
        assert_eq!(ts.bins(), &[2.0, 1.0, 0.0, 0.0, 0.0, 1.0]);
        assert_eq!(ts.cumulative(), vec![2.0, 3.0, 3.0, 3.0, 3.0, 4.0]);
        assert_eq!(ts.total(), 4.0);
    }

    #[test]
    fn load_imbalance_uniform_is_zero() {
        let mut li = LoadImbalance::new(4, 1.0);
        for t in 0..10 {
            for w in 0..4 {
                li.record_assignment(w, t as f64 + 0.5);
            }
        }
        assert!(li.mean_cv() < 1e-12);
    }

    #[test]
    fn load_imbalance_skewed_is_positive() {
        let mut li = LoadImbalance::new(4, 1.0);
        for t in 0..10 {
            // all load on worker 0
            for _ in 0..4 {
                li.record_assignment(0, t as f64 + 0.5);
            }
        }
        // CV of (4,0,0,0) = std/mean = sqrt(3)/1 ≈ 1.732
        assert!((li.mean_cv() - 3.0f64.sqrt()).abs() < 1e-9);
    }

    #[test]
    fn samples_merge_is_exact_union() {
        let mut a = Samples::new();
        let mut b = Samples::new();
        let mut all = Samples::new();
        for i in 0..50 {
            let x = ((i * 37) % 50) as f64;
            all.push(x);
            if i % 2 == 0 {
                a.push(x)
            } else {
                b.push(x)
            }
        }
        a.merge_from(&b);
        assert_eq!(a.len(), all.len());
        assert_eq!(a.seen(), all.seen());
        for p in [0.0, 25.0, 50.0, 90.0, 100.0] {
            assert_eq!(a.percentile(p), all.percentile(p), "p{p} diverged");
        }
    }

    #[test]
    fn time_series_merge_adds_elementwise() {
        let mut a = TimeSeries::new(1.0);
        let mut b = TimeSeries::new(1.0);
        a.increment(0.5);
        a.increment(2.5);
        b.increment(0.7);
        b.increment(4.1); // longer than a
        a.merge_add(&b);
        assert_eq!(a.bins(), &[2.0, 0.0, 1.0, 0.0, 1.0]);
        // Shorter other leaves the tail untouched.
        let c = TimeSeries::new(1.0);
        a.merge_add(&c);
        assert_eq!(a.bins(), &[2.0, 0.0, 1.0, 0.0, 1.0]);
    }

    #[test]
    #[should_panic(expected = "different bin widths")]
    fn time_series_merge_rejects_width_mismatch() {
        let mut a = TimeSeries::new(1.0);
        a.merge_add(&TimeSeries::new(0.5));
    }

    #[test]
    fn load_imbalance_merge_appends_worker_sets() {
        // Two disjoint shards, each perfectly balanced internally but at
        // different rates: the merged CV must equal a single tracker over
        // the union (order: shard 0's workers then shard 1's).
        let mut a = LoadImbalance::new(2, 1.0);
        let mut b = LoadImbalance::new(2, 1.0);
        let mut whole = LoadImbalance::new(4, 1.0);
        for t in 0..5 {
            let tt = t as f64 + 0.5;
            for w in 0..2 {
                a.record_assignment(w, tt);
                whole.record_assignment(w, tt);
            }
            for w in 0..2 {
                b.record_assignment(w, tt);
                b.record_assignment(w, tt);
                whole.record_assignment(2 + w, tt);
                whole.record_assignment(2 + w, tt);
            }
        }
        a.merge_append(&b);
        assert_eq!(a.totals(), whole.totals());
        assert!((a.mean_cv() - whole.mean_cv()).abs() < 1e-12);
    }

    #[test]
    fn empty_stats_are_nan_or_zero() {
        let s = OnlineStats::new();
        assert!(s.mean().is_nan());
        let mut e = Samples::new();
        assert!(e.percentile(50.0).is_nan());
        assert!(TimeSeries::new(1.0).mean_rate() == 0.0);
        let k = QuantileSketch::new(0.01);
        assert!(k.percentile(50.0).is_nan());
        assert!(k.mean().is_nan());
        assert!(k.min().is_infinite() && k.max().is_infinite());
        let mut d = Dist::sketch(0.01);
        assert!(d.is_empty());
        assert!(d.percentile(99.0).is_nan());
    }

    /// A lognormal-ish heavy-tailed stream (the latency shape): every
    /// interior percentile must sit within the advertised relative error
    /// of the exact value.
    #[test]
    fn sketch_relative_error_bound() {
        let alpha = 0.005;
        let mut exact = Samples::new();
        let mut sk = QuantileSketch::new(alpha);
        let mut rng = crate::util::rng::Pcg64::new(42);
        for _ in 0..100_000 {
            // exp(N(0,1)-ish via sum of uniforms) scaled into ms.
            let z = (0..4).map(|_| rng.next_f64()).sum::<f64>() - 2.0;
            let x = 40.0 * (z * 1.2).exp();
            exact.push(x);
            sk.push(x);
        }
        for p in [1.0, 10.0, 50.0, 90.0, 95.0, 99.0, 99.9] {
            let e = exact.percentile(p);
            let s = sk.percentile(p);
            let rel = (s - e).abs() / e;
            assert!(rel <= 2.0 * alpha, "p{p}: exact {e}, sketch {s}, rel err {rel}");
        }
        assert!((sk.mean() - exact.mean()).abs() / exact.mean() < 1e-9, "mean is exact");
        assert_eq!(sk.percentile(0.0), exact.percentile(0.0), "min is exact");
        assert_eq!(sk.percentile(100.0), exact.percentile(100.0), "max is exact");
        assert!(sk.bin_count() <= QuantileSketch::MAX_BINS);
    }

    /// Shard-merge contract: merging K sub-sketches is *identical* to one
    /// sketch over the pooled stream (pure integer count addition), in
    /// any merge order.
    #[test]
    fn sketch_merge_equals_pooled() {
        let mut pooled = QuantileSketch::new(0.005);
        let mut parts: Vec<QuantileSketch> = (0..4).map(|_| QuantileSketch::new(0.005)).collect();
        let mut rng = crate::util::rng::Pcg64::new(7);
        for i in 0..20_000 {
            let x = rng.next_f64() * 500.0;
            pooled.push(x);
            parts[i % 4].push(x);
        }
        let mut merged = parts[0].clone();
        for p in &parts[1..] {
            merged.merge_from(p);
        }
        assert_eq!(merged.count(), pooled.count());
        for p in [0.0, 25.0, 50.0, 75.0, 99.0, 100.0] {
            assert_eq!(merged.percentile(p), pooled.percentile(p), "p{p} diverged");
        }
        assert_eq!(merged.min(), pooled.min());
        assert_eq!(merged.max(), pooled.max());
    }

    /// Memory bound: a huge stream over a wide value range keeps the live
    /// bucket count under the cap (no per-request growth).
    #[test]
    fn sketch_memory_bounded() {
        let mut sk = QuantileSketch::new(0.005);
        let mut rng = crate::util::rng::Pcg64::new(3);
        for _ in 0..200_000 {
            sk.push(rng.next_f64().powi(6) * 1e7 + 1e-6);
        }
        assert_eq!(sk.count(), 200_000);
        assert!(sk.bin_count() <= QuantileSketch::MAX_BINS, "bins: {}", sk.bin_count());
    }

    #[test]
    fn sketch_zeros_bucket() {
        let mut sk = QuantileSketch::new(0.01);
        for _ in 0..90 {
            sk.push(0.0);
        }
        for _ in 0..10 {
            sk.push(100.0);
        }
        assert_eq!(sk.percentile(50.0), 0.0);
        assert!((sk.percentile(95.0) - 100.0).abs() / 100.0 < 0.01);
    }

    #[test]
    fn dist_exact_mode_matches_samples() {
        let mut d = Dist::exact();
        let mut s = Samples::new();
        for i in 0..1000 {
            let x = ((i * 131) % 997) as f64;
            d.push(x);
            s.push(x);
        }
        for p in [0.0, 50.0, 99.0, 100.0] {
            assert_eq!(d.percentile(p), s.percentile(p));
        }
        assert_eq!(d.seen(), s.seen());
        assert!(d.as_samples_mut().is_some());
        assert!(Dist::sketch(0.01).as_samples_mut().is_none());
    }

    #[test]
    #[should_panic(expected = "different storage modes")]
    fn dist_merge_rejects_mode_mismatch() {
        let mut a = Dist::exact();
        a.merge_from(&Dist::sketch(0.01));
    }
}
