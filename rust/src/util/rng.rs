//! Deterministic pseudo-random number generation and distributions.
//!
//! The image has no `rand` crate vendored, and the paper's methodology
//! *requires* seeded determinism anyway ("we seeded the random number
//! generator in each run ... so that the order of function invocations as
//! well as sleep durations ... were identical for each scheduling
//! algorithm"). We implement SplitMix64 (seeding / stream splitting) and
//! PCG64 (xsl-rr variant) from the published algorithms, plus the
//! distributions the workload model needs: uniform, exponential, lognormal,
//! Zipf (via rejection inversion), and an O(1) weighted alias table.

/// SplitMix64: tiny, high-quality 64-bit mixer. Used to seed Pcg64 and to
/// split independent streams from a single experiment seed.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// Start a stream at `seed`.
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    /// Next 64 random bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// PCG XSL-RR 128/64: the de-facto default PRNG ("pcg64" in the pcg paper's
/// nomenclature). 128-bit LCG state, 64-bit xorshift-low + random-rotate
/// output.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360ED051FC65DA44385DF649FCCF645;

impl Pcg64 {
    /// Seed from a single u64 via SplitMix64 stream expansion.
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let s = ((sm.next_u64() as u128) << 64) | sm.next_u64() as u128;
        let i = ((sm.next_u64() as u128) << 64) | sm.next_u64() as u128;
        Self::from_state(s, i)
    }

    /// Derive an independent stream (distinct odd increment selects a
    /// distinct PCG sequence).
    pub fn split(&mut self) -> Self {
        let s = ((self.next_u64() as u128) << 64) | self.next_u64() as u128;
        let i = ((self.next_u64() as u128) << 64) | self.next_u64() as u128;
        Self::from_state(s, i)
    }

    fn from_state(state: u128, inc: u128) -> Self {
        let mut rng = Self { state: 0, inc: (inc << 1) | 1 };
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng.state = rng.state.wrapping_add(state);
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng
    }

    /// Next 64 random bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xsl = ((self.state >> 64) as u64) ^ (self.state as u64);
        xsl.rotate_right(rot)
    }

    /// Uniform f64 in [0, 1) with 53 bits of precision.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, bound) without modulo bias (Lemire's method).
    #[inline]
    pub fn next_bounded(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128) * (bound as u128);
        let mut lo = m as u64;
        if lo < bound {
            let t = bound.wrapping_neg() % bound;
            while lo < t {
                x = self.next_u64();
                m = (x as u128) * (bound as u128);
                lo = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize index in [0, len).
    #[inline]
    pub fn index(&mut self, len: usize) -> usize {
        self.next_bounded(len as u64) as usize
    }

    /// Uniform f64 in [lo, hi).
    #[inline]
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Exponential with rate lambda (mean 1/lambda).
    #[inline]
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        debug_assert!(lambda > 0.0);
        // 1 - U in (0, 1] avoids ln(0).
        -(1.0 - self.next_f64()).ln() / lambda
    }

    /// Standard normal via Box-Muller (polar-free, fine for workload gen).
    pub fn normal(&mut self, mean: f64, std: f64) -> f64 {
        let u1 = 1.0 - self.next_f64();
        let u2 = self.next_f64();
        let z = (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
        mean + std * z
    }

    /// Lognormal parameterized by the *underlying* normal's mu/sigma.
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        self.normal(mu, sigma).exp()
    }

    /// Shuffle a slice in place (Fisher-Yates).
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample one element uniformly.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.index(xs.len())]
    }
}

/// Zipf-Mandelbrot distribution over ranks 1..=n: pmf(k) ∝ 1/(k+q)^s, by
/// inversion on the precomputed CDF. O(n) setup, O(log n) sampling; n is at
/// most ~100k for the Azure-like trace so this is plenty. The shift q
/// flattens the head — needed to match Azure's empirical (top-1%, top-10%)
/// invocation shares simultaneously (see workload::azure).
#[derive(Clone, Debug)]
pub struct Zipf {
    cdf: Vec<f64>,
}

impl Zipf {
    /// Plain Zipf over ranks 1..=n with exponent `s`.
    pub fn new(n: usize, s: f64) -> Self {
        Self::with_shift(n, s, 0.0)
    }

    /// Zipf-Mandelbrot with head-flattening shift `q`.
    pub fn with_shift(n: usize, s: f64, q: f64) -> Self {
        assert!(n > 0);
        assert!(q >= 0.0);
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0;
        for k in 1..=n {
            acc += 1.0 / (k as f64 + q).powf(s);
            cdf.push(acc);
        }
        let total = acc;
        for c in &mut cdf {
            *c /= total;
        }
        Self { cdf }
    }

    /// Sample a rank in [0, n) (0 = most popular).
    pub fn sample(&self, rng: &mut Pcg64) -> usize {
        let u = rng.next_f64();
        match self.cdf.binary_search_by(|c| c.partial_cmp(&u).unwrap()) {
            Ok(i) => i,
            Err(i) => i.min(self.cdf.len() - 1),
        }
    }

    /// Probability mass of rank k (0-based).
    pub fn pmf(&self, k: usize) -> f64 {
        let prev = if k == 0 { 0.0 } else { self.cdf[k - 1] };
        self.cdf[k] - prev
    }
}

/// Walker's alias method: O(1) sampling from an arbitrary discrete
/// distribution. Used for weighted function selection in the load generator
/// (the paper's "weighted random selection" of Azure invocation
/// probabilities).
#[derive(Clone, Debug)]
pub struct AliasTable {
    prob: Vec<f64>,
    alias: Vec<usize>,
}

impl AliasTable {
    /// Build the alias table for the given (unnormalized) weights.
    pub fn new(weights: &[f64]) -> Self {
        let n = weights.len();
        assert!(n > 0, "alias table needs at least one weight");
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "weights must sum to > 0");
        let mut prob = vec![0.0; n];
        let mut alias = vec![0usize; n];
        let mut scaled: Vec<f64> = weights.iter().map(|w| w * n as f64 / total).collect();
        let mut small: Vec<usize> = (0..n).filter(|&i| scaled[i] < 1.0).collect();
        let mut large: Vec<usize> = (0..n).filter(|&i| scaled[i] >= 1.0).collect();
        while let (Some(&s), Some(&l)) = (small.last(), large.last()) {
            small.pop();
            prob[s] = scaled[s];
            alias[s] = l;
            scaled[l] = (scaled[l] + scaled[s]) - 1.0;
            if scaled[l] < 1.0 {
                large.pop();
                small.push(l);
            }
        }
        for &l in &large {
            prob[l] = 1.0;
        }
        for &s in &small {
            prob[s] = 1.0; // numerical leftovers
        }
        Self { prob, alias }
    }

    /// Sample an index with probability proportional to its weight, O(1).
    #[inline]
    pub fn sample(&self, rng: &mut Pcg64) -> usize {
        let i = rng.index(self.prob.len());
        if rng.next_f64() < self.prob[i] {
            i
        } else {
            self.alias[i]
        }
    }

    /// Number of weights.
    pub fn len(&self) -> usize {
        self.prob.len()
    }

    /// True when the table has no weights (cannot happen via `new`).
    pub fn is_empty(&self) -> bool {
        self.prob.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_known_values() {
        // First outputs for seed 0 (reference values from the published
        // splitmix64 implementation).
        let mut sm = SplitMix64::new(0);
        assert_eq!(sm.next_u64(), 0xE220A8397B1DCDAF);
        assert_eq!(sm.next_u64(), 0x6E789E6AA1B965F4);
    }

    #[test]
    fn pcg_deterministic_and_seed_sensitive() {
        let mut a = Pcg64::new(42);
        let mut b = Pcg64::new(42);
        let mut c = Pcg64::new(43);
        let va: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let vb: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        let vc: Vec<u64> = (0..8).map(|_| c.next_u64()).collect();
        assert_eq!(va, vb);
        assert_ne!(va, vc);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut rng = Pcg64::new(1);
        for _ in 0..10_000 {
            let x = rng.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn bounded_unbiased_small_range() {
        let mut rng = Pcg64::new(2);
        let mut counts = [0usize; 5];
        for _ in 0..50_000 {
            counts[rng.next_bounded(5) as usize] += 1;
        }
        for &c in &counts {
            assert!((9000..11000).contains(&c), "counts skewed: {counts:?}");
        }
    }

    #[test]
    fn exponential_mean() {
        let mut rng = Pcg64::new(3);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| rng.exponential(2.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg64::new(4);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.normal(3.0, 2.0)).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!((mean - 3.0).abs() < 0.05, "mean {mean}");
        assert!((var - 4.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn zipf_is_skewed_and_normalized() {
        let z = Zipf::new(1000, 1.1);
        let mut rng = Pcg64::new(5);
        let mut counts = vec![0usize; 1000];
        let n = 200_000;
        for _ in 0..n {
            counts[z.sample(&mut rng)] += 1;
        }
        // Rank 0 must dominate and pmf must sum to 1.
        assert!(counts[0] > counts[10] && counts[10] > counts[500]);
        let total_pmf: f64 = (0..1000).map(|k| z.pmf(k)).sum();
        assert!((total_pmf - 1.0).abs() < 1e-9);
    }

    #[test]
    fn alias_table_matches_weights() {
        let weights = [0.5, 0.25, 0.125, 0.125];
        let at = AliasTable::new(&weights);
        let mut rng = Pcg64::new(6);
        let mut counts = [0usize; 4];
        let n = 200_000;
        for _ in 0..n {
            counts[at.sample(&mut rng)] += 1;
        }
        for (i, &w) in weights.iter().enumerate() {
            let freq = counts[i] as f64 / n as f64;
            assert!((freq - w).abs() < 0.01, "bin {i}: {freq} vs {w}");
        }
    }

    #[test]
    fn alias_table_single_weight() {
        let at = AliasTable::new(&[3.0]);
        let mut rng = Pcg64::new(7);
        for _ in 0..100 {
            assert_eq!(at.sample(&mut rng), 0);
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg64::new(8);
        let mut xs: Vec<u32> = (0..100).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>(), "shuffle did nothing");
    }

    #[test]
    fn split_streams_are_independent() {
        let mut a = Pcg64::new(9);
        let mut b = a.split();
        let va: Vec<u64> = (0..4).map(|_| a.next_u64()).collect();
        let vb: Vec<u64> = (0..4).map(|_| b.next_u64()).collect();
        assert_ne!(va, vb);
    }
}
