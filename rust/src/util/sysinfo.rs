//! Process self-inspection without external crates: peak RSS from
//! `/proc/self/status` (Linux only; `None` elsewhere).

/// Peak resident set size of this process in MiB, read from the
/// kernel's `VmHWM` high-water mark. Returns `None` off Linux or if
/// `/proc` is unavailable — callers should report `null`, not 0, so a
/// missing measurement is never mistaken for a tiny one.
pub fn peak_rss_mb() -> Option<f64> {
    #[cfg(target_os = "linux")]
    {
        let status = std::fs::read_to_string("/proc/self/status").ok()?;
        parse_vm_hwm_kb(&status).map(|kb| kb / 1024.0)
    }
    #[cfg(not(target_os = "linux"))]
    {
        None
    }
}

/// Extract the `VmHWM` value in kB from `/proc/self/status` text.
///
/// Returns `None` — never a garbage number — when the field is absent
/// (kernels built without `CONFIG_MEMCG`-style accounting, restricted
/// `/proc` mounts), has no value, or carries a non-positive/non-finite
/// one.
#[cfg_attr(not(target_os = "linux"), allow(dead_code))]
fn parse_vm_hwm_kb(status: &str) -> Option<f64> {
    for line in status.lines() {
        if let Some(rest) = line.strip_prefix("VmHWM:") {
            let num = rest.trim().split_whitespace().next()?;
            return num.parse::<f64>().ok().filter(|v| v.is_finite() && *v > 0.0);
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_vm_hwm_line() {
        let status = "Name:\thiku\nVmPeak:\t  999 kB\nVmHWM:\t   2048 kB\nThreads:\t1\n";
        assert_eq!(parse_vm_hwm_kb(status), Some(2048.0));
        assert_eq!(parse_vm_hwm_kb("Name:\thiku\n"), None);
    }

    #[test]
    fn missing_or_malformed_vm_hwm_is_none() {
        // A status file with no VmHWM line at all (restricted kernels).
        assert_eq!(parse_vm_hwm_kb("Name:\thiku\nVmPeak:\t 999 kB\n"), None);
        // Key present but valueless or malformed — still None, never 0.
        assert_eq!(parse_vm_hwm_kb("VmHWM:\n"), None);
        assert_eq!(parse_vm_hwm_kb("VmHWM:\t kB\n"), None);
        assert_eq!(parse_vm_hwm_kb("VmHWM:\t 0 kB\n"), None);
        assert_eq!(parse_vm_hwm_kb("VmHWM:\t -5 kB\n"), None);
        assert_eq!(parse_vm_hwm_kb(""), None);
    }

    #[test]
    #[cfg(target_os = "linux")]
    fn peak_rss_on_linux_is_none_or_positive() {
        // Containers and hardened kernels may omit VmHWM entirely — the
        // contract is "None cleanly", not a panic or a zero.
        if let Some(mb) = peak_rss_mb() {
            assert!(mb > 0.0);
        }
    }
}
