//! Declarative command-line argument parser (no clap in this image).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positional arguments and
//! subcommands. Generates `--help` text from the declarations. Used by the
//! `hiku` binary, the examples and the bench harness.

use std::collections::BTreeMap;
use std::fmt;

/// An argument-parsing failure (or the `--help` text).
#[derive(Debug)]
pub struct CliError(
    /// The error message, or the full help text on `--help`.
    pub String,
);

impl fmt::Display for CliError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for CliError {}

#[derive(Clone, Debug)]
struct OptSpec {
    name: String,
    help: String,
    default: Option<String>,
    is_flag: bool,
}

/// Declarative CLI: register options, then parse.
#[derive(Clone, Debug, Default)]
pub struct Cli {
    program: String,
    about: String,
    opts: Vec<OptSpec>,
    positionals: Vec<(String, String)>, // (name, help)
}

/// Parse result with typed accessors.
#[derive(Clone, Debug, Default)]
pub struct Args {
    values: BTreeMap<String, String>,
    flags: Vec<String>,
    positionals: Vec<String>,
}

impl Cli {
    /// A CLI named `program` with a one-line description.
    pub fn new(program: &str, about: &str) -> Self {
        Self { program: program.into(), about: about.into(), ..Default::default() }
    }

    /// `--name <value>` option with an optional default.
    pub fn opt(mut self, name: &str, default: Option<&str>, help: &str) -> Self {
        self.opts.push(OptSpec {
            name: name.into(),
            help: help.into(),
            default: default.map(String::from),
            is_flag: false,
        });
        self
    }

    /// Boolean `--name` flag.
    pub fn flag(mut self, name: &str, help: &str) -> Self {
        self.opts.push(OptSpec {
            name: name.into(),
            help: help.into(),
            default: None,
            is_flag: true,
        });
        self
    }

    /// Positional argument (order of declaration = expected order).
    pub fn positional(mut self, name: &str, help: &str) -> Self {
        self.positionals.push((name.into(), help.into()));
        self
    }

    /// Render the generated `--help` text.
    pub fn help_text(&self) -> String {
        let mut s = format!("{} — {}\n\nUSAGE:\n  {}", self.program, self.about, self.program);
        for (name, _) in &self.positionals {
            s.push_str(&format!(" <{name}>"));
        }
        s.push_str(" [OPTIONS]\n");
        if !self.positionals.is_empty() {
            s.push_str("\nARGS:\n");
            for (name, help) in &self.positionals {
                s.push_str(&format!("  <{name:<18}> {help}\n"));
            }
        }
        s.push_str("\nOPTIONS:\n");
        for o in &self.opts {
            let left = if o.is_flag {
                format!("--{}", o.name)
            } else {
                format!("--{} <v>", o.name)
            };
            let def = o
                .default
                .as_ref()
                .map(|d| format!(" [default: {d}]"))
                .unwrap_or_default();
            s.push_str(&format!("  {left:<22} {}{def}\n", o.help));
        }
        s.push_str("  --help                 print this help\n");
        s
    }

    /// Parse a raw argv slice (excluding argv[0]). On `--help`, returns
    /// Err with the help text so callers can print and exit.
    pub fn parse(&self, argv: &[String]) -> Result<Args, CliError> {
        let mut args = Args::default();
        for o in &self.opts {
            if let Some(d) = &o.default {
                args.values.insert(o.name.clone(), d.clone());
            }
        }
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if a == "--help" || a == "-h" {
                return Err(CliError(self.help_text()));
            }
            if let Some(stripped) = a.strip_prefix("--") {
                let (key, inline_val) = match stripped.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                let spec = self
                    .opts
                    .iter()
                    .find(|o| o.name == key)
                    .ok_or_else(|| CliError(format!("unknown option --{key}")))?;
                if spec.is_flag {
                    if inline_val.is_some() {
                        return Err(CliError(format!("--{key} takes no value")));
                    }
                    args.flags.push(key);
                } else {
                    let v = match inline_val {
                        Some(v) => v,
                        None => {
                            i += 1;
                            argv.get(i)
                                .cloned()
                                .ok_or_else(|| CliError(format!("--{key} needs a value")))?
                        }
                    };
                    args.values.insert(key, v);
                }
            } else {
                args.positionals.push(a.clone());
            }
            i += 1;
        }
        if args.positionals.len() > self.positionals.len() {
            return Err(CliError(format!(
                "too many positional arguments (expected {})",
                self.positionals.len()
            )));
        }
        Ok(args)
    }

    /// Parse std::env::args(), printing help/errors and exiting as needed.
    pub fn parse_env(&self) -> Args {
        let argv: Vec<String> = std::env::args().skip(1).collect();
        match self.parse(&argv) {
            Ok(a) => a,
            Err(e) => {
                eprintln!("{e}");
                std::process::exit(if e.0.contains("USAGE:") { 0 } else { 2 });
            }
        }
    }
}

impl Args {
    /// The value of `--name`, if given or defaulted.
    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str())
    }

    /// The value of `--name`, or `default` when absent.
    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    /// Whether the boolean `--name` flag was passed.
    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    /// The i-th positional argument.
    pub fn positional(&self, i: usize) -> Option<&str> {
        self.positionals.get(i).map(|s| s.as_str())
    }

    /// Parse `--name` as f64 (error when missing or malformed).
    pub fn parse_f64(&self, name: &str) -> Result<f64, CliError> {
        self.get(name)
            .ok_or_else(|| CliError(format!("missing --{name}")))?
            .parse()
            .map_err(|_| CliError(format!("--{name}: expected a number")))
    }

    /// Parse `--name` as u64 (error when missing or malformed).
    pub fn parse_u64(&self, name: &str) -> Result<u64, CliError> {
        self.get(name)
            .ok_or_else(|| CliError(format!("missing --{name}")))?
            .parse()
            .map_err(|_| CliError(format!("--{name}: expected an integer")))
    }

    /// Parse `--name` as usize (error when missing or malformed).
    pub fn parse_usize(&self, name: &str) -> Result<usize, CliError> {
        Ok(self.parse_u64(name)? as usize)
    }

    /// Comma-separated list.
    pub fn parse_list(&self, name: &str) -> Vec<String> {
        self.get(name)
            .map(|s| {
                s.split(',')
                    .map(|x| x.trim().to_string())
                    .filter(|x| !x.is_empty())
                    .collect()
            })
            .unwrap_or_default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    fn cli() -> Cli {
        Cli::new("test", "test tool")
            .opt("workers", Some("5"), "number of workers")
            .opt("scheduler", None, "scheduler name")
            .flag("verbose", "chatty output")
            .positional("input", "input file")
    }

    #[test]
    fn defaults_and_overrides() {
        let a = cli().parse(&argv(&[])).unwrap();
        assert_eq!(a.get("workers"), Some("5"));
        assert_eq!(a.get("scheduler"), None);
        let a = cli().parse(&argv(&["--workers", "9"])).unwrap();
        assert_eq!(a.parse_u64("workers").unwrap(), 9);
    }

    #[test]
    fn equals_syntax() {
        let a = cli().parse(&argv(&["--scheduler=hiku"])).unwrap();
        assert_eq!(a.get("scheduler"), Some("hiku"));
    }

    #[test]
    fn flags_and_positionals() {
        let a = cli().parse(&argv(&["--verbose", "file.json"])).unwrap();
        assert!(a.has_flag("verbose"));
        assert!(!a.has_flag("quiet"));
        assert_eq!(a.positional(0), Some("file.json"));
    }

    #[test]
    fn errors() {
        assert!(cli().parse(&argv(&["--nope"])).is_err());
        assert!(cli().parse(&argv(&["--scheduler"])).is_err());
        assert!(cli().parse(&argv(&["--verbose=yes"])).is_err());
        assert!(cli().parse(&argv(&["a", "b"])).is_err());
    }

    #[test]
    fn help_contains_options() {
        let err = cli().parse(&argv(&["--help"])).unwrap_err();
        assert!(err.0.contains("--workers"));
        assert!(err.0.contains("USAGE"));
    }

    #[test]
    fn parse_list_splits() {
        let c = Cli::new("t", "t").opt("algos", Some("a, b,c"), "x");
        let a = c.parse(&argv(&[])).unwrap();
        assert_eq!(a.parse_list("algos"), vec!["a", "b", "c"]);
    }
}
