//! Incremental min-load index: a bucket queue over per-worker load values.
//!
//! The seed implementation answered "which active worker has the fewest
//! active connections?" with an O(workers) scan per scheduling decision —
//! the cost Kaffes et al. identify as the scheduling-core limiter at
//! cluster scale. This index maintains, incrementally under ±1 load
//! updates, a bucket per load value holding the *active* workers at that
//! load (a `BTreeSet`, so members iterate in ascending worker id). Queries
//! then touch only the tie set at the minimum load instead of the whole
//! cluster, while reproducing the seed's selection bit-for-bit:
//!
//! - [`MinLoadIndex::least_loaded_random_tie`] replays the seed's
//!   reservoir sampling over the tie set in ascending worker order, so it
//!   consumes the *identical* RNG stream and returns the identical worker
//!   as a full-vector scan (`scheduler::least_loaded_random_tie`).
//! - [`MinLoadIndex::least_loaded_lowest_id`] is JSQ's deterministic
//!   lowest-id-among-minima rule.
//! - [`MinLoadIndex::least_loaded_where`] walks buckets upward and returns
//!   the lowest-id worker passing a fitness predicate in the lowest load
//!   bucket that has one — exactly `filter(fit).min_by_key(load)` over
//!   ascending worker ids.
//!
//! Workers are split into an *active* prefix `0..active` (eligible for
//! selection, present in buckets) and a drained suffix (load still
//! tracked in `load_of`, absent from buckets) — mirroring the engine's
//! LIFO scale-down. `set_active` moves boundary workers in or out with
//! their current load, so re-activation restores in-flight load exactly.

use std::cell::Cell;
use std::collections::BTreeSet;

use super::rng::Pcg64;

/// A mergeable O(1) digest of a [`MinLoadIndex`]: just enough to compare
/// and combine the load state of *disjoint* worker sets without touching
/// per-worker data. This is the unit the sharded simulation exchanges at
/// its event-time barriers (DESIGN.md §6): each shard publishes the
/// summary of its local index, the coordinator merges them, and
/// cross-shard placement decisions (power-of-d sampling) read only these
/// four fields — O(shards), never O(workers).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct LoadSummary {
    /// Active (selectable) workers in the summarized set.
    pub active: usize,
    /// Lowest load among active workers (`u32::MAX` for the empty set, so
    /// merging with the identity never wins a minimum).
    pub min_load: u32,
    /// Active workers at `min_load` — the tie-set size.
    pub min_count: usize,
    /// Sum of loads over the active workers.
    pub total_load: u64,
    /// Free execution slots over the active workers (core-granular
    /// scheduling, DESIGN.md §11). The index itself tracks loads, not
    /// slots, so [`MinLoadIndex::summary`] reports 0 and the cluster
    /// overwrites the field from its incremental slot aggregate before
    /// the summary crosses a shard barrier. Deliberately *not* part of
    /// [`LoadSummary::less_loaded_than`]: placement comparisons must stay
    /// bit-identical to the pre-slot engine at `cores_per_worker = 1`.
    pub free_slots: u64,
}

impl Default for LoadSummary {
    fn default() -> Self {
        Self::empty()
    }
}

impl LoadSummary {
    /// Summary of the empty worker set: the identity of [`LoadSummary::merge`].
    pub fn empty() -> Self {
        Self { active: 0, min_load: u32::MAX, min_count: 0, total_load: 0, free_slots: 0 }
    }

    /// Combine the summaries of two disjoint worker sets. Associative and
    /// commutative with [`LoadSummary::empty`] as identity, so shard
    /// summaries can be folded in any grouping.
    pub fn merge(&self, other: &LoadSummary) -> LoadSummary {
        use std::cmp::Ordering;
        let (min_load, min_count) = match self.min_load.cmp(&other.min_load) {
            Ordering::Less => (self.min_load, self.min_count),
            Ordering::Greater => (other.min_load, other.min_count),
            Ordering::Equal => (self.min_load, self.min_count + other.min_count),
        };
        LoadSummary {
            active: self.active + other.active,
            min_load,
            min_count,
            total_load: self.total_load + other.total_load,
            free_slots: self.free_slots + other.free_slots,
        }
    }

    /// Mean load per active worker; the empty set reports `f64::INFINITY`
    /// so it always loses a "less loaded" comparison.
    pub fn mean_load(&self) -> f64 {
        if self.active == 0 {
            f64::INFINITY
        } else {
            self.total_load as f64 / self.active as f64
        }
    }

    /// "Less loaded" order for placement decisions: by mean load, then by
    /// `min_load` (a set with an idler minimum wins a mean tie). Total,
    /// deterministic and allocation-free — the comparison the sharded
    /// coordinator's power-of-d sampling uses.
    pub fn less_loaded_than(&self, other: &LoadSummary) -> bool {
        let (a, b) = (self.mean_load(), other.mean_load());
        if a != b {
            return a < b;
        }
        self.min_load < other.min_load
    }
}

/// Bucket queue over worker loads with an active-prefix restriction.
#[derive(Clone, Debug)]
pub struct MinLoadIndex {
    /// Current load per worker (tracked for drained workers too).
    load_of: Vec<u32>,
    /// `buckets[l]` = active workers whose load is exactly `l`.
    buckets: Vec<BTreeSet<usize>>,
    /// Workers `0..active` are selectable; `active..len` are drained.
    active: usize,
    /// Sum of loads over the active prefix (CH-BL's total-inflight input).
    active_total: u64,
    /// Lower bound on the lowest non-empty bucket; advanced lazily during
    /// queries (interior mutability keeps queries `&self`).
    min_hint: Cell<usize>,
}

impl MinLoadIndex {
    /// A fresh index: `n` active workers, all at load 0.
    pub fn new(n: usize) -> Self {
        let mut zero = BTreeSet::new();
        zero.extend(0..n);
        Self {
            load_of: vec![0; n],
            buckets: vec![zero],
            active: n,
            active_total: 0,
            min_hint: Cell::new(0),
        }
    }

    /// Total tracked workers (active + drained).
    pub fn len(&self) -> usize {
        self.load_of.len()
    }

    /// True when the index tracks no workers at all.
    pub fn is_empty(&self) -> bool {
        self.load_of.is_empty()
    }

    /// Size of the active (selectable) prefix.
    pub fn active(&self) -> usize {
        self.active
    }

    /// The full per-worker load vector (slice `[..active]` for the view
    /// schedulers see).
    pub fn loads(&self) -> &[u32] {
        &self.load_of
    }

    /// Current load of worker `w` (tracked whether or not it is active).
    pub fn load(&self, w: usize) -> u32 {
        self.load_of[w]
    }

    /// Sum of loads over the active prefix.
    pub fn total_active_load(&self) -> u64 {
        self.active_total
    }

    /// Append a new worker slot at load 0. The worker joins *inactive*;
    /// activate it with [`MinLoadIndex::set_active`] (the engine's scale-up
    /// order: create, then activate).
    pub fn add_worker(&mut self) {
        self.load_of.push(0);
    }

    /// Grow or shrink the active prefix to `n` workers, moving boundary
    /// workers into/out of the buckets with their current load.
    pub fn set_active(&mut self, n: usize) {
        assert!(n <= self.load_of.len(), "active {n} > {} workers", self.load_of.len());
        while self.active < n {
            let w = self.active;
            let l = self.load_of[w] as usize;
            if l >= self.buckets.len() {
                self.buckets.resize_with(l + 1, BTreeSet::new);
            }
            self.buckets[l].insert(w);
            self.active_total += self.load_of[w] as u64;
            if l < self.min_hint.get() {
                self.min_hint.set(l);
            }
            self.active += 1;
        }
        while self.active > n {
            let w = self.active - 1;
            let l = self.load_of[w] as usize;
            let removed = self.buckets[l].remove(&w);
            debug_assert!(removed, "active worker {w} missing from bucket {l}");
            self.active_total -= self.load_of[w] as u64;
            self.active -= 1;
        }
    }

    /// Set worker `w`'s load to `new`, relocating it between buckets if it
    /// is active.
    pub fn set_load(&mut self, w: usize, new: u32) {
        let old = self.load_of[w];
        if old == new {
            return;
        }
        self.load_of[w] = new;
        if w < self.active {
            let newl = new as usize;
            if newl >= self.buckets.len() {
                self.buckets.resize_with(newl + 1, BTreeSet::new);
            }
            let removed = self.buckets[old as usize].remove(&w);
            debug_assert!(removed, "active worker {w} missing from bucket {old}");
            self.buckets[newl].insert(w);
            self.active_total = self.active_total + new as u64 - old as u64;
            if newl < self.min_hint.get() {
                self.min_hint.set(newl);
            }
        }
    }

    /// Increment worker `w`'s load by one (request routed to it).
    pub fn inc(&mut self, w: usize) {
        let l = self.load_of[w];
        self.set_load(w, l + 1);
    }

    /// Decrement worker `w`'s load by one (response returned).
    pub fn dec(&mut self, w: usize) {
        let l = self.load_of[w];
        debug_assert!(l > 0, "decrementing idle worker {w}");
        self.set_load(w, l - 1);
    }

    /// Lowest load value held by an active worker (advances the lazy hint).
    fn min_nonempty(&self) -> Option<usize> {
        if self.active == 0 {
            return None;
        }
        let mut l = self.min_hint.get();
        while l < self.buckets.len() {
            if !self.buckets[l].is_empty() {
                self.min_hint.set(l);
                return Some(l);
            }
            l += 1;
        }
        unreachable!("active workers exist but every bucket is empty");
    }

    /// Minimum load among active workers.
    pub fn min_load(&self) -> Option<u32> {
        self.min_nonempty().map(|l| l as u32)
    }

    /// Least-loaded active worker with uniform random tie-breaking.
    ///
    /// Bit-identical to `scheduler::least_loaded_random_tie` over
    /// `loads()[..active]`: the tie set is visited in ascending worker id
    /// and one `next_bounded(seen)` is drawn per tie, so both the RNG
    /// stream and the selected worker match the seed scan exactly.
    pub fn least_loaded_random_tie(&self, rng: &mut Pcg64) -> usize {
        let l = self.min_nonempty().expect("no active workers");
        let mut chosen = 0usize;
        let mut seen = 0u64;
        for &w in self.buckets[l].iter() {
            seen += 1;
            if rng.next_bounded(seen) == 0 {
                chosen = w;
            }
        }
        chosen
    }

    /// Least-loaded active worker, lowest id among ties (JSQ's rule).
    pub fn least_loaded_lowest_id(&self) -> usize {
        let l = self.min_nonempty().expect("no active workers");
        *self.buckets[l].iter().next().expect("non-empty min bucket")
    }

    /// O(1) digest of the active prefix for cross-index comparison and
    /// merging (the sharded engine's barrier payload).
    pub fn summary(&self) -> LoadSummary {
        match self.min_nonempty() {
            None => LoadSummary::empty(),
            Some(l) => LoadSummary {
                active: self.active,
                min_load: l as u32,
                min_count: self.buckets[l].len(),
                total_load: self.active_total,
                free_slots: 0,
            },
        }
    }

    /// Lowest-id worker passing `fit` in the lowest load bucket that has
    /// one — identical to `(0..active).filter(fit).min_by_key(load)`
    /// (`min_by_key` keeps the first minimum, i.e. the lowest id).
    pub fn least_loaded_where<F: FnMut(usize) -> bool>(&self, mut fit: F) -> Option<usize> {
        let mut l = self.min_nonempty()?;
        while l < self.buckets.len() {
            for &w in self.buckets[l].iter() {
                if fit(w) {
                    return Some(w);
                }
            }
            l += 1;
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::prop::{check, PropConfig};

    #[test]
    fn starts_all_zero() {
        let idx = MinLoadIndex::new(4);
        assert_eq!(idx.len(), 4);
        assert_eq!(idx.active(), 4);
        assert_eq!(idx.min_load(), Some(0));
        assert_eq!(idx.total_active_load(), 0);
        assert_eq!(idx.least_loaded_lowest_id(), 0);
    }

    #[test]
    fn inc_dec_moves_buckets() {
        let mut idx = MinLoadIndex::new(3);
        idx.inc(0);
        idx.inc(0);
        idx.inc(1);
        assert_eq!(idx.loads(), &[2, 1, 0]);
        assert_eq!(idx.min_load(), Some(0));
        assert_eq!(idx.least_loaded_lowest_id(), 2);
        idx.inc(2);
        assert_eq!(idx.min_load(), Some(1));
        assert_eq!(idx.least_loaded_lowest_id(), 1);
        idx.dec(0);
        idx.dec(0);
        assert_eq!(idx.least_loaded_lowest_id(), 0);
        assert_eq!(idx.total_active_load(), 2);
    }

    #[test]
    fn drained_workers_are_unselectable_but_tracked() {
        let mut idx = MinLoadIndex::new(3);
        idx.inc(0);
        idx.inc(2); // worker 2 has in-flight load 1
        idx.set_active(2); // drain worker 2
        assert_eq!(idx.active(), 2);
        assert_eq!(idx.total_active_load(), 1);
        // Worker 2 never selected even though its load would win later.
        idx.inc(0);
        idx.inc(1);
        idx.inc(1);
        assert_eq!(idx.least_loaded_lowest_id(), 0);
        // Its load keeps changing while drained...
        idx.dec(2);
        assert_eq!(idx.load(2), 0);
        // ...and re-activation restores it at the current value.
        idx.set_active(3);
        assert_eq!(idx.least_loaded_lowest_id(), 2);
        assert_eq!(idx.total_active_load(), 5);
    }

    #[test]
    fn add_worker_joins_inactive() {
        let mut idx = MinLoadIndex::new(2);
        idx.inc(0);
        idx.inc(1);
        idx.add_worker();
        assert_eq!(idx.len(), 3);
        assert_eq!(idx.active(), 2);
        assert_eq!(idx.min_load(), Some(1), "inactive worker must not appear in buckets");
        idx.set_active(3);
        assert_eq!(idx.least_loaded_lowest_id(), 2);
    }

    #[test]
    fn summary_digest_and_merge() {
        let mut a = MinLoadIndex::new(3);
        a.inc(0);
        a.inc(0);
        a.inc(1); // loads [2, 1, 0]
        let sa = a.summary();
        assert_eq!(sa, LoadSummary { active: 3, min_load: 0, min_count: 1, total_load: 3, free_slots: 0 });
        let mut b = MinLoadIndex::new(2);
        b.inc(0);
        b.inc(1); // loads [1, 1]
        let sb = b.summary();
        assert_eq!(sb, LoadSummary { active: 2, min_load: 1, min_count: 2, total_load: 2, free_slots: 0 });
        // Merge over disjoint sets: global min/tie-set/total, any grouping.
        let m = sa.merge(&sb);
        assert_eq!(m, LoadSummary { active: 5, min_load: 0, min_count: 1, total_load: 5, free_slots: 0 });
        assert_eq!(m, sb.merge(&sa), "merge must be commutative");
        assert_eq!(m, sa.merge(&LoadSummary::empty()).merge(&sb), "empty is the identity");
        assert_eq!(LoadSummary::empty().mean_load(), f64::INFINITY);
        assert!(sb.mean_load() > sa.mean_load());
        assert!(sa.less_loaded_than(&sb));
        // Mean tie resolved by min_load: [0, 2] beats [1, 1].
        let mut c = MinLoadIndex::new(2);
        c.inc(0);
        c.inc(0); // loads [2, 0], mean 1.0 == sb's mean
        assert!(c.summary().less_loaded_than(&sb));
        assert!(!sb.less_loaded_than(&c.summary()));
    }

    #[test]
    fn least_loaded_where_skips_unfit() {
        let mut idx = MinLoadIndex::new(4);
        idx.inc(0); // loads [1, 0, 0, 0]
        // Min bucket {1,2,3}; 1 and 2 unfit -> 3.
        assert_eq!(idx.least_loaded_where(|w| w == 3 || w == 0), Some(3));
        // Nobody in the min bucket fits -> next bucket up.
        assert_eq!(idx.least_loaded_where(|w| w == 0), Some(0));
        assert_eq!(idx.least_loaded_where(|_| false), None);
    }

    /// Property: against a naive model, every query matches the seed scan
    /// bit-for-bit — including the RNG stream consumed by tie-breaking.
    #[test]
    fn prop_matches_linear_scan() {
        check("loadidx-vs-scan", PropConfig { cases: 150, ..Default::default() }, |rng, size| {
            let n = 1 + rng.index(12);
            let mut idx = MinLoadIndex::new(n);
            let mut model: Vec<u32> = vec![0; n];
            let mut active = n;
            for _ in 0..size * 4 {
                match rng.index(5) {
                    0 | 1 => {
                        let w = rng.index(n);
                        idx.inc(w);
                        model[w] += 1;
                    }
                    2 => {
                        let w = rng.index(n);
                        if model[w] > 0 {
                            idx.dec(w);
                            model[w] -= 1;
                        }
                    }
                    3 => {
                        active = 1 + rng.index(n);
                        idx.set_active(active);
                    }
                    _ => {}
                }
                let view = &model[..active];
                // Total and minimum agree with the slice.
                let total: u64 = view.iter().map(|&l| l as u64).sum();
                prop_assert!(
                    idx.total_active_load() == total,
                    "total {} != {}",
                    idx.total_active_load(),
                    total
                );
                let min = *view.iter().min().unwrap();
                prop_assert!(
                    idx.min_load() == Some(min),
                    "min {:?} != {}",
                    idx.min_load(),
                    min
                );
                // The O(1) digest agrees with the slice scan.
                let s = idx.summary();
                let ties = view.iter().filter(|&&l| l == min).count();
                prop_assert!(
                    s == LoadSummary { active, min_load: min, min_count: ties, total_load: total, free_slots: 0 },
                    "summary {:?} != scan (active {}, min {}, ties {}, total {})",
                    s,
                    active,
                    min,
                    ties,
                    total
                );
                // Random-tie selection: identical worker AND identical RNG
                // consumption vs the seed reservoir scan.
                let mut rng_a = rng.split();
                let mut rng_b = rng_a.clone();
                let fast = idx.least_loaded_random_tie(&mut rng_a);
                let slow = crate::scheduler::least_loaded_random_tie(view, &mut rng_b);
                prop_assert!(fast == slow, "tie-break {} != {}", fast, slow);
                prop_assert!(
                    rng_a.next_u64() == rng_b.next_u64(),
                    "RNG streams diverged after tie-break"
                );
                // Lowest-id rule matches a JSQ scan.
                let jsq = view
                    .iter()
                    .enumerate()
                    .min_by_key(|&(_, &l)| l)
                    .map(|(w, _)| w)
                    .unwrap();
                prop_assert!(
                    idx.least_loaded_lowest_id() == jsq,
                    "jsq {} != {}",
                    idx.least_loaded_lowest_id(),
                    jsq
                );
                // Predicate walk matches filter + min_by_key.
                let fit = |w: usize| w % 2 == 0;
                let want = (0..active).filter(|&w| fit(w)).min_by_key(|&w| view[w]);
                prop_assert!(
                    idx.least_loaded_where(fit) == want,
                    "where {:?} != {:?}",
                    idx.least_loaded_where(fit),
                    want
                );
            }
            Ok(())
        });
    }
}
