//! Minimal JSON parser and writer.
//!
//! No serde is vendored in this image, so the config system, the AOT
//! manifest reader and the metrics dumps use this hand-rolled implementation.
//! Supports the full JSON grammar (objects, arrays, strings with escapes,
//! numbers, booleans, null); numbers are represented as f64 (adequate for
//! config values and metrics; the manifest contains nothing above 2^53).

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Objects use BTreeMap so serialization is deterministic.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any JSON number (f64 representation).
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object (sorted keys ⇒ deterministic output).
    Obj(BTreeMap<String, Json>),
}

/// A parse failure with its byte offset.
#[derive(Debug)]
pub struct JsonError {
    /// What went wrong.
    pub msg: String,
    /// Byte offset into the input where parsing failed.
    pub offset: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    /// Parse a complete JSON document (trailing garbage is an error).
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // ---- typed accessors -------------------------------------------------

    /// The number value, if this is a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    /// The value as a non-negative integer ≤ 2^53, if exactly one.
    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().and_then(|x| {
            if x >= 0.0 && x.fract() == 0.0 && x <= (1u64 << 53) as f64 {
                Some(x as u64)
            } else {
                None
            }
        })
    }

    /// The string value, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// The boolean value, if this is a boolean.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// The elements, if this is an array.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// The key/value map, if this is an object.
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// `obj["a"]["b"]` style access; returns None on any miss.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|m| m.get(key))
    }

    /// Path access: `j.at(&["payloads", "0", "name"])` (array indices as
    /// decimal strings).
    pub fn at(&self, path: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for p in path {
            cur = match cur {
                Json::Obj(m) => m.get(*p)?,
                Json::Arr(v) => v.get(p.parse::<usize>().ok()?)?,
                _ => return None,
            };
        }
        Some(cur)
    }

    // ---- writer ----------------------------------------------------------

    /// Serialize with 2-space indentation.
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(0));
        s
    }

    /// Serialize with no whitespace (the determinism-test comparison form).
    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < (1u64 << 53) as f64 {
                    out.push_str(&format!("{}", *x as i64));
                } else {
                    out.push_str(&format!("{x}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                if v.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if let Some(d) = indent {
                        out.push('\n');
                        out.push_str(&"  ".repeat(d + 1));
                        item.write(out, Some(d + 1));
                    } else {
                        item.write(out, None);
                    }
                }
                if let Some(d) = indent {
                    out.push('\n');
                    out.push_str(&"  ".repeat(d));
                }
                out.push(']');
            }
            Json::Obj(m) => {
                if m.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if let Some(d) = indent {
                        out.push('\n');
                        out.push_str(&"  ".repeat(d + 1));
                        write_escaped(out, k);
                        out.push_str(": ");
                        v.write(out, Some(d + 1));
                    } else {
                        write_escaped(out, k);
                        out.push(':');
                        v.write(out, None);
                    }
                }
                if let Some(d) = indent {
                    out.push('\n');
                    out.push_str(&"  ".repeat(d));
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

// Convenience constructors.
impl From<&str> for Json {
    fn from(s: &str) -> Self {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Self {
        Json::Str(s)
    }
}
impl From<f64> for Json {
    fn from(x: f64) -> Self {
        Json::Num(x)
    }
}
impl From<u64> for Json {
    fn from(x: u64) -> Self {
        Json::Num(x as f64)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Self {
        Json::Num(x as f64)
    }
}
impl From<bool> for Json {
    fn from(b: bool) -> Self {
        Json::Bool(b)
    }
}
impl From<Vec<Json>> for Json {
    fn from(v: Vec<Json>) -> Self {
        Json::Arr(v)
    }
}

/// Build an object from (key, value) pairs.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), offset: self.pos }
    }

    fn skip_ws(&mut self) {
        while let Some(&b) = self.bytes.get(self.pos) {
            if b == b' ' || b == b'\t' || b == b'\n' || b == b'\r' {
                self.pos += 1;
            } else {
                break;
            }
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(b'-') | Some(b'0'..=b'9') => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            m.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.bytes.len() {
                                return Err(self.err("truncated \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.bytes[self.pos + 1..self.pos + 5])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            // Surrogate pairs unsupported (not needed for
                            // config/manifest content); map to replacement.
                            s.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9')) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("3.5").unwrap(), Json::Num(3.5));
        assert_eq!(Json::parse("-17").unwrap(), Json::Num(-17.0));
        assert_eq!(Json::parse("1e3").unwrap(), Json::Num(1000.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(j.at(&["a", "2", "b"]).unwrap().as_str(), Some("c"));
        assert_eq!(j.get("d"), Some(&Json::Null));
    }

    #[test]
    fn parse_escapes() {
        let j = Json::parse(r#""a\nb\t\"q\"A""#).unwrap();
        assert_eq!(j.as_str(), Some("a\nb\t\"q\"A"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("tru").is_err());
        assert!(Json::parse("{} x").is_err());
        assert!(Json::parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn roundtrip_pretty_and_compact() {
        let src = r#"{"name":"hiku","n":5,"xs":[1,2.5,true,null],"nested":{"k":"v"}}"#;
        let j = Json::parse(src).unwrap();
        let pretty = j.to_string_pretty();
        let compact = j.to_string_compact();
        assert_eq!(Json::parse(&pretty).unwrap(), j);
        assert_eq!(Json::parse(&compact).unwrap(), j);
    }

    #[test]
    fn u64_accessor_bounds() {
        assert_eq!(Json::parse("5").unwrap().as_u64(), Some(5));
        assert_eq!(Json::parse("-5").unwrap().as_u64(), None);
        assert_eq!(Json::parse("5.5").unwrap().as_u64(), None);
    }

    #[test]
    fn manifest_shape_parses() {
        // Shape of artifacts/manifest.json emitted by aot.py.
        let src = r#"{
          "format": "hlo-text",
          "payloads": [
            {"name": "matmul", "artifact": "matmul.hlo.txt",
             "input": {"dtype": "u32", "shape": []},
             "output": {"dtype": "f32", "shape": [2], "tuple": true},
             "goldens": [{"seed": 42, "digest": [0.25, 64.0]}],
             "hlo_bytes": 13124}
          ]
        }"#;
        let j = Json::parse(src).unwrap();
        let p = &j.get("payloads").unwrap().as_arr().unwrap()[0];
        assert_eq!(p.get("name").unwrap().as_str(), Some("matmul"));
        assert_eq!(
            p.at(&["goldens", "0", "seed"]).unwrap().as_u64(),
            Some(42)
        );
    }
}
