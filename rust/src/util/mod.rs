//! Hand-rolled substrates (no external crates vendored beyond `xla`):
//! PRNG + distributions, stable hashing, JSON, CLI parsing, property tests.

pub mod cli;
pub mod hashing;
pub mod json;
pub mod loadidx;
pub mod prop;
pub mod rng;
pub mod sysinfo;
