//! Non-cryptographic hashes for the consistent-hashing ring and hash-mod
//! schedulers. FNV-1a for strings (function names) and a SplitMix-style
//! avalanche finalizer for integer keys (virtual node ids).

/// FNV-1a, 64-bit. Stable across runs and platforms (unlike `DefaultHasher`,
/// whose seed is randomized per process — useless for a reproducible ring).
#[inline]
pub fn fnv1a_64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xCBF29CE484222325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001B3);
    }
    h
}

/// Hash a string key.
#[inline]
pub fn hash_str(s: &str) -> u64 {
    fnv1a_64(s.as_bytes())
}

/// Finalizing mixer for integer keys (SplitMix64 finalizer); combines a base
/// hash with a counter, e.g. `mix64(worker_hash ^ vnode_index)`.
#[inline]
pub fn mix64(x: u64) -> u64 {
    let mut z = x.wrapping_add(0x9E3779B97F4A7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// Combine two hashes (for (name, index) composite keys).
#[inline]
pub fn combine(a: u64, b: u64) -> u64 {
    mix64(a ^ b.wrapping_mul(0x9E3779B97F4A7C15))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fnv_known_vectors() {
        // Published FNV-1a test vectors.
        assert_eq!(fnv1a_64(b""), 0xCBF29CE484222325);
        assert_eq!(fnv1a_64(b"a"), 0xAF63DC4C8601EC8C);
        assert_eq!(fnv1a_64(b"foobar"), 0x85944171F73967E8);
    }

    #[test]
    fn hash_str_stable() {
        assert_eq!(hash_str("matmul_0"), hash_str("matmul_0"));
        assert_ne!(hash_str("matmul_0"), hash_str("matmul_1"));
    }

    #[test]
    fn mix64_avalanche() {
        // Single-bit input flips should flip ~half of the output bits.
        let mut total = 0u32;
        let samples = 64;
        for i in 0..samples {
            let a = mix64(i);
            let b = mix64(i ^ 1);
            total += (a ^ b).count_ones();
        }
        let avg = total as f64 / samples as f64;
        assert!((24.0..40.0).contains(&avg), "weak avalanche: {avg}");
    }

    #[test]
    fn combine_order_sensitive() {
        assert_ne!(combine(1, 2), combine(2, 1));
    }
}
