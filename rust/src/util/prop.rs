//! Miniature property-testing framework (no proptest vendored).
//!
//! `check` runs a property over N seeded random cases; on failure it reports
//! the failing case seed so the exact case can be replayed with
//! `replay(seed, ...)`. Generators are plain closures over `Pcg64`, which
//! keeps shrinking simple: we re-generate with progressively "smaller" size
//! hints rather than structurally shrinking values.
//!
//! Used by the scheduler/platform/sim test suites to state invariants
//! (routing conservation, queue sortedness, ring monotonicity, determinism).

use super::rng::Pcg64;

/// Configuration for a property run.
#[derive(Clone, Copy, Debug)]
pub struct PropConfig {
    /// Random cases to run.
    pub cases: usize,
    /// Base seed; each case derives its own replayable seed from it.
    pub seed: u64,
    /// Maximum "size" hint passed to the generator; cases ramp from small
    /// to large sizes so failures tend to be found at small sizes first.
    pub max_size: usize,
}

impl Default for PropConfig {
    fn default() -> Self {
        Self { cases: 100, seed: 0xC0FFEE, max_size: 64 }
    }
}

/// Run `prop(rng, size)` for `cfg.cases` cases. The property returns
/// `Result<(), String>`; an Err fails the run with a replayable report.
pub fn check<F>(name: &str, cfg: PropConfig, mut prop: F)
where
    F: FnMut(&mut Pcg64, usize) -> Result<(), String>,
{
    for case in 0..cfg.cases {
        // Size ramps 1..=max_size across the run.
        let size = 1 + (case * cfg.max_size) / cfg.cases.max(1);
        let case_seed = cfg.seed ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Pcg64::new(case_seed);
        if let Err(msg) = prop(&mut rng, size) {
            // Try smaller sizes with the same seed to present a minimal-ish
            // counterexample.
            let mut min_fail = (size, msg.clone());
            let mut s = size / 2;
            while s >= 1 {
                let mut rng2 = Pcg64::new(case_seed);
                if let Err(m2) = prop(&mut rng2, s) {
                    min_fail = (s, m2);
                    if s == 1 {
                        break;
                    }
                    s /= 2;
                } else {
                    break;
                }
            }
            panic!(
                "property '{name}' failed at case {case} (seed {case_seed:#x}, size {}): {}\n\
                 replay with prop::replay({case_seed:#x}, {}, ...)",
                min_fail.0, min_fail.1, min_fail.0
            );
        }
    }
}

/// Replay a single failing case.
pub fn replay<F>(case_seed: u64, size: usize, mut prop: F) -> Result<(), String>
where
    F: FnMut(&mut Pcg64, usize) -> Result<(), String>,
{
    let mut rng = Pcg64::new(case_seed);
    prop(&mut rng, size)
}

/// Assert helper for property bodies.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !$cond {
            return Err(format!($($fmt)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        check("trivial", PropConfig { cases: 50, ..Default::default() }, |rng, size| {
            count += 1;
            let x = rng.index(size.max(1) * 10 + 1);
            prop_assert!(x <= size * 10, "x {} out of range", x);
            Ok(())
        });
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "property 'always-fails' failed")]
    fn failing_property_panics_with_seed() {
        check("always-fails", PropConfig { cases: 5, ..Default::default() }, |_, _| {
            Err("nope".into())
        });
    }

    #[test]
    fn replay_reproduces() {
        let res = replay(0x1234, 8, |rng, size| {
            let a = rng.next_u64();
            prop_assert!(size == 8, "size mismatch");
            let b = Pcg64::new(0x1234).next_u64();
            prop_assert!(a == b, "rng not reproducible");
            Ok(())
        });
        assert!(res.is_ok());
    }

    #[test]
    fn sizes_ramp_up() {
        let mut sizes = Vec::new();
        check("sizes", PropConfig { cases: 10, max_size: 100, ..Default::default() }, |_, s| {
            sizes.push(s);
            Ok(())
        });
        assert!(sizes[0] < sizes[9]);
        assert!(*sizes.last().unwrap() <= 100);
    }
}
