//! Typed configuration system: JSON file + `--set path.key=value` overrides.
//!
//! Mirrors the paper's experimental setup (§V-A) in its defaults: 5 workers,
//! 40 functions (8 FunctionBench types × 5 copies), 20/50/100 virtual users,
//! 5-minute runs, CH-BL load threshold 1.25, think time U(0.1 s, 1 s).

use crate::util::json::{obj, Json};
use std::fmt;

/// A configuration parse/validation failure.
#[derive(Debug)]
pub struct ConfigError(
    /// Human-readable description of what is wrong.
    pub String,
);

impl fmt::Display for ConfigError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "config error: {}", self.0)
    }
}

impl std::error::Error for ConfigError {}

/// Cluster topology and worker resources.
#[derive(Clone, Debug, PartialEq)]
pub struct ClusterConfig {
    /// Number of workers (paper: 5 OpenLambda workers).
    pub workers: usize,
    /// Per-worker sandbox memory pool in MB. Calibrated (see EXPERIMENTS.md
    /// §Calibration) so the cold-start regime matches the paper's Fig 13:
    /// busy sandboxes at 100 VUs occupy most of the pool and idle
    /// sandboxes churn under pressure, yielding ~25-30% cold starts for
    /// Hiku and 40-60% for the baselines.
    pub mem_mb: u64,
    /// Concurrent executions per worker (m5.xlarge: 4 vCPUs).
    pub concurrency: usize,
    /// Keep-alive: idle sandboxes are evicted after this many seconds.
    pub keep_alive_s: f64,
    /// Elastic workers (OpenLambda-like): requests start immediately and
    /// vCPUs are time-shared (congestion multiplier); false = hard FIFO
    /// admission queue at `concurrency` slots (ablation mode).
    pub elastic: bool,
    /// Predictive pre-warming (extension, cf. Kim & Roh [24]): each second
    /// the platform compares per-function demand estimates against warm
    /// supply and speculatively initializes sandboxes for the deficit.
    pub prewarm: bool,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        Self {
            workers: 5,
            mem_mb: 3584,
            concurrency: 4,
            keep_alive_s: 20.0,
            elastic: true,
            prewarm: false,
        }
    }
}

/// Workload shape (§V-A "Workload"/"Execution").
#[derive(Clone, Debug, PartialEq)]
pub struct WorkloadConfig {
    /// Distinct FunctionBench applications (Table II).
    pub base_functions: usize,
    /// Copies per application ("5 identical copies with unique names").
    pub copies: usize,
    /// Virtual users (paper sweeps 20/50/100).
    pub vus: usize,
    /// Run duration in (virtual) seconds.
    pub duration_s: f64,
    /// Lower think-time bound between invocations per VU, in seconds.
    pub think_min_s: f64,
    /// Upper think-time bound between invocations per VU, in seconds.
    pub think_max_s: f64,
    /// Zipf exponent for Azure-like popularity skew.
    pub zipf_s: f64,
    /// Experiment seed (identical across schedulers within a run).
    pub seed: u64,
}

impl Default for WorkloadConfig {
    fn default() -> Self {
        Self {
            base_functions: 8,
            copies: 5,
            vus: 100,
            duration_s: 300.0,
            think_min_s: 0.1,
            think_max_s: 1.0,
            zipf_s: 2.05,
            seed: 42,
        }
    }
}

/// Scheduler selection and algorithm parameters.
#[derive(Clone, Debug, PartialEq)]
pub struct SchedulerConfig {
    /// One of: hiku, least-connections, random, hash-mod, consistent,
    /// ch-bl, rj-ch, jsq, power-of-d.
    pub name: String,
    /// CH-BL load threshold c (paper uses the recommended 1.25).
    pub ch_bl_c: f64,
    /// Virtual nodes per worker on the hash ring.
    pub vnodes: usize,
    /// d for power-of-d-choices.
    pub power_d: usize,
    /// Independent scheduler instances (distributed scheduling ablation;
    /// VUs are sharded across instances, no synchronization between them).
    pub instances: usize,
    /// Sampled tie-break for least-loaded selection: 0 (default) keeps
    /// the exact uniform-among-ties rule — Θ(tie set) per decision, the
    /// paper's semantics, bit-identical to the seed RNG stream. d ≥ 1
    /// samples d workers with replacement and routes to the least loaded
    /// of the sample — O(d), the power-of-d-style variant that makes
    /// least-connections viable at 100k workers (DESIGN.md §6). Changes
    /// the RNG stream, so it is not bit-comparable with d = 0 runs.
    pub tie_sample_d: usize,
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        Self {
            name: "hiku".into(),
            ch_bl_c: 1.25,
            vnodes: 100,
            power_d: 2,
            instances: 1,
            tie_sample_d: 0,
        }
    }
}

/// Elastic-scaling control loop (the `autoscale` section).
///
/// The platform runs a recurring control tick (period `interval_s`) that
/// hands a cluster snapshot to the configured [`crate::autoscale`] policy;
/// the policy answers with a worker-count target and per-function pre-warm
/// pools. See `DESIGN.md` §4 for the subsystem architecture.
#[derive(Clone, Debug, PartialEq)]
pub struct AutoscaleConfig {
    /// One of: none, scheduled, reactive, predictive.
    pub policy: String,
    /// Control-tick period in seconds.
    pub interval_s: f64,
    /// Minimum worker count enforced by the reactive/predictive policies
    /// (the scheduled policy replays its event list verbatim).
    pub min_workers: usize,
    /// Maximum worker count enforced by the reactive/predictive policies.
    pub max_workers: usize,
    /// Reactive: scale up when utilization (running / (workers x vCPUs))
    /// exceeds this threshold.
    pub scale_up_util: f64,
    /// Reactive: scale down when utilization falls below this threshold
    /// (the gap between the two thresholds is the hysteresis dead band).
    pub scale_down_util: f64,
    /// Minimum seconds between two scaling actions of the same policy.
    pub cooldown_s: f64,
    /// Workers added or drained per scaling action.
    pub step: usize,
    /// Scheduled policy: comma-separated signed times in seconds, e.g.
    /// "60,120,-150" — a worker joins at 60 s and at 120 s, one drains
    /// (LIFO) at 150 s.
    pub events: String,
    /// Predictive: plan capacity so expected utilization sits at this level
    /// (headroom for burst absorption).
    pub target_util: f64,
    /// Predictive: cap on speculative sandboxes per function per tick.
    pub prewarm_max_per_tick: usize,
    /// Predictive: EWMA smoothing factor for per-function arrival rates.
    pub ewma_alpha: f64,
}

impl Default for AutoscaleConfig {
    fn default() -> Self {
        Self {
            policy: "none".into(),
            interval_s: 1.0,
            min_workers: 1,
            max_workers: 16,
            scale_up_util: 0.8,
            scale_down_util: 0.3,
            cooldown_s: 10.0,
            step: 1,
            events: String::new(),
            target_util: 0.7,
            prewarm_max_per_tick: 2,
            ewma_alpha: 0.2,
        }
    }
}

/// Dispatch-protocol parameters (the `dispatch` section): how the router
/// turns a scheduler's [`crate::scheduler::Decision`] into work.
///
/// `mode = "push"` (default) routes every request synchronously through
/// the push adapter — bit-identical to the pre-protocol engine.
/// `mode = "pull"` activates the paper's pull loop as a first-class
/// protocol: requests with a warm prospect park in the router's pending
/// queue, idle workers claim them (`on_worker_idle`), a per-function
/// wait deadline force-places stragglers, `queue_cap`/`queue_caps` bound
/// admission per function, and backlogs drain fairly via deficit-round-
/// robin over the function queues (DESIGN.md §8).
#[derive(Clone, Debug, PartialEq)]
pub struct DispatchConfig {
    /// `"push"` (synchronous assignment, the default) or `"pull"`
    /// (late binding through the pending queue).
    pub mode: String,
    /// Default **per-function** admission bound on parked requests; an
    /// `Enqueue` decision against a full per-function queue becomes a
    /// reject, so one hot function cannot crowd every other function out
    /// of admission. 0 = unbounded. The bound is per router instance —
    /// in sharded runs each shard owns a pending queue, so the global
    /// bound per function is `shards × queue_cap`.
    pub queue_cap: usize,
    /// Per-function overrides of `queue_cap`: comma-separated
    /// `function:cap` pairs, e.g. `"0:4,7:64"`. Entries for function ids
    /// outside the workload are ignored.
    pub queue_caps: String,
    /// Upper bound on how long a parked request may wait for a warm
    /// worker before the router force-places it via the scheduler's
    /// fallback, in seconds. With `adaptive_wait` the effective
    /// per-function deadline is `min(max_wait_s, ewma_cold_penalty_f)`.
    pub max_wait_s: f64,
    /// Cost-aware waiting: size each request's pull deadline from the
    /// observed per-function cold−warm start delta (an EWMA maintained by
    /// the router) instead of the single global `max_wait_s` knob —
    /// waiting is only worth as long as the cold start it might avoid
    /// (DESIGN.md §8). Default true; false pins the PR 4 fixed deadline.
    pub adaptive_wait: bool,
    /// Deficit-round-robin weights for fair backlog draining:
    /// comma-separated `function:weight` pairs (weights >= 1, default 1
    /// for every function), e.g. `"0:4"` gives function 0 four credits
    /// per DRR visit.
    pub weights: String,
    /// Fair draining on (default): wake flushes, cross-shard steal
    /// donation and idle-capacity claims pop in deficit-round-robin
    /// order. false restores the PR 4 global arrival-order FIFO (the
    /// fairness-ablation baseline).
    pub fair: bool,
    /// Sharded runs: most parked requests one shard hands off to another
    /// per epoch barrier (`ShardMsg::Handoff`); 0 disables stealing.
    pub steal_batch: usize,
    /// Floor on the adaptive per-function pull deadline, in seconds. A
    /// string of warm hits drives the cold-penalty EWMA toward 0, which
    /// would collapse `adaptive_wait` deadlines to immediate force-place;
    /// the floor keeps a minimum parking window so the pull path stays
    /// live. 0 (default) preserves the PR 5 formula exactly.
    pub min_wait_s: f64,
    /// Push-mode re-route window in seconds (DESIGN.md §11): a request
    /// queued behind a busy worker is re-offered to another worker whose
    /// slot frees within this window after the queuing (the bounded
    /// rebind hook — push mode's partial answer to pull's late binding).
    /// 0 (default) disables rebinding entirely, byte-identical to the
    /// pre-slot engine. Requires `mode = "push"` when > 0.
    pub rebind_window_s: f64,
}

impl Default for DispatchConfig {
    fn default() -> Self {
        Self {
            mode: "push".into(),
            queue_cap: 0,
            queue_caps: String::new(),
            max_wait_s: 0.5,
            adaptive_wait: true,
            weights: String::new(),
            fair: true,
            steal_batch: 8,
            min_wait_s: 0.0,
            rebind_window_s: 0.0,
        }
    }
}

/// Deterministic fault injection (the `faults` section): worker crashes
/// and recoveries, straggler slowdowns, and sandbox cold-init failures,
/// all derived from the run seed into a precomputed [`crate::faults`]
/// plan. Disabled by default — with `enabled = false` no fault events are
/// scheduled, no extra RNG streams are created, and every run is
/// byte-identical to the fault-free engine (DESIGN.md §10).
#[derive(Clone, Debug, PartialEq)]
pub struct FaultsConfig {
    /// Master switch. false (default) = zero-overhead, bit-identical to
    /// the pre-fault engine.
    pub enabled: bool,
    /// Expected worker crashes per worker per minute (Poisson process per
    /// worker, seed-derived). 0 disables random crashes.
    pub crash_rate: f64,
    /// Mean time to recover after a crash, in seconds (random crashes
    /// jitter this deterministically; explicit `crashes` entries use it
    /// verbatim).
    pub mttr_s: f64,
    /// Explicit kill schedule: `time:worker` pairs separated by `,` or
    /// `;` (use `;` inside `--set` overrides), e.g. `"10:1;40:0"`. Each
    /// entry crashes the worker at `time` and recovers it `mttr_s` later.
    pub crashes: String,
    /// Fraction of workers that become stragglers for a seed-derived
    /// episode of the run (0..=1).
    pub straggler_frac: f64,
    /// Service-time multiplier applied to executions started on a
    /// straggling worker (>= 1).
    pub straggler_slowdown: f64,
    /// Probability that a cold sandbox initialization fails (the request
    /// is retried; the failed sandbox is destroyed). Pure hash of
    /// (seed, request, attempt) — no RNG stream.
    pub init_fail_prob: f64,
    /// Retry budget per request: a request that loses more than this many
    /// executions (crash, init failure, no-capacity bounce) is metered as
    /// `failed` — never silently dropped.
    pub max_retries: u32,
    /// Base re-enqueue backoff after a lost execution, in seconds. The
    /// actual delay is deterministically jittered in [1x, 2x) by a pure
    /// hash of (seed, request, attempt).
    pub retry_backoff_s: f64,
    /// Straggler hedging: a request still running on a slowed worker
    /// after `hedge_factor x` the function's EWMA runtime is duplicated
    /// onto the pull path (first completion wins). 0 disables hedging.
    pub hedge_factor: f64,
}

impl Default for FaultsConfig {
    fn default() -> Self {
        Self {
            enabled: false,
            crash_rate: 0.0,
            mttr_s: 10.0,
            crashes: String::new(),
            straggler_frac: 0.0,
            straggler_slowdown: 4.0,
            init_fail_prob: 0.0,
            max_retries: 3,
            retry_backoff_s: 0.05,
            hedge_factor: 3.0,
        }
    }
}

impl FaultsConfig {
    /// Apply a compact `--faults` CLI spec: `key:value` pairs separated
    /// by `,` or `;`, e.g. `"crash:0.1"` or `"crash:0.2;straggle:0.25;slow:4"`.
    /// Keys: `crash` (crash_rate), `mttr`, `straggle` (straggler_frac),
    /// `slow` (straggler_slowdown), `init_fail`, `retries`, `backoff`,
    /// `hedge`. Any spec (even empty) sets `enabled = true`.
    pub fn apply_spec(&mut self, spec: &str) -> Result<(), String> {
        self.enabled = true;
        for entry in spec.split([',', ';']) {
            let entry = entry.trim();
            if entry.is_empty() {
                continue;
            }
            let (k, v) = entry
                .split_once(':')
                .ok_or_else(|| format!("bad faults entry '{entry}' (expected key:value)"))?;
            let bad = || format!("bad value in faults entry '{entry}'");
            match k.trim() {
                "crash" => self.crash_rate = v.trim().parse().map_err(|_| bad())?,
                "mttr" => self.mttr_s = v.trim().parse().map_err(|_| bad())?,
                "straggle" => self.straggler_frac = v.trim().parse().map_err(|_| bad())?,
                "slow" => self.straggler_slowdown = v.trim().parse().map_err(|_| bad())?,
                "init_fail" => self.init_fail_prob = v.trim().parse().map_err(|_| bad())?,
                "retries" => self.max_retries = v.trim().parse().map_err(|_| bad())?,
                "backoff" => self.retry_backoff_s = v.trim().parse().map_err(|_| bad())?,
                "hedge" => self.hedge_factor = v.trim().parse().map_err(|_| bad())?,
                other => return Err(format!("unknown faults key '{other}'")),
            }
        }
        Ok(())
    }
}

/// Parse an explicit crash schedule: `time:worker` pairs separated by `,`
/// or `;` (whitespace ignored, empty string = no entries).
pub fn parse_crash_list(s: &str) -> Result<Vec<(f64, usize)>, String> {
    let mut out = Vec::new();
    for entry in s.split([',', ';']) {
        let entry = entry.trim();
        if entry.is_empty() {
            continue;
        }
        let (t, w) = entry
            .split_once(':')
            .ok_or_else(|| format!("bad crash entry '{entry}' (expected time:worker)"))?;
        let t: f64 = t.trim().parse().map_err(|_| format!("bad time in crash entry '{entry}'"))?;
        let w: usize =
            w.trim().parse().map_err(|_| format!("bad worker in crash entry '{entry}'"))?;
        if !t.is_finite() || t < 0.0 {
            return Err(format!("crash time must be finite and >= 0 in '{entry}'"));
        }
        out.push((t, w));
    }
    Ok(out)
}

/// Parse a `function:value` map string (pairs separated by `,` or `;`,
/// e.g. `"0:4,7:2"`; use `;` inside `--set` overrides, whose list syntax
/// reserves the comma; whitespace around entries is ignored; empty
/// string = empty map). Shared by `dispatch.queue_caps` and
/// `dispatch.weights`.
pub fn parse_fn_map(s: &str) -> Result<Vec<(usize, u64)>, String> {
    let mut out = Vec::new();
    for entry in s.split([',', ';']) {
        let entry = entry.trim();
        if entry.is_empty() {
            continue;
        }
        let (f, v) = entry
            .split_once(':')
            .ok_or_else(|| format!("bad map entry '{entry}' (expected function:value)"))?;
        let f: usize =
            f.trim().parse().map_err(|_| format!("bad function id in map entry '{entry}'"))?;
        let v: u64 = v.trim().parse().map_err(|_| format!("bad value in map entry '{entry}'"))?;
        out.push((f, v));
    }
    Ok(out)
}

impl DispatchConfig {
    /// Dense per-function admission caps over `n` function types:
    /// `queue_cap` everywhere, overridden by `queue_caps` entries
    /// (0 = unbounded). Panics on a malformed map — run
    /// [`Config::validate`] first (every entry point does).
    pub fn caps_dense(&self, n: usize) -> Vec<usize> {
        let mut caps = vec![self.queue_cap; n];
        for (f, cap) in parse_fn_map(&self.queue_caps).expect("validated dispatch.queue_caps") {
            if f < n {
                caps[f] = cap as usize;
            }
        }
        caps
    }

    /// Sparse `(function, weight)` DRR overrides from `weights` (the
    /// [`crate::dispatch::PendingQueue`] layout input). Panics on a
    /// malformed map — run [`Config::validate`] first.
    pub fn weights_sparse(&self) -> Vec<(usize, u32)> {
        parse_fn_map(&self.weights)
            .expect("validated dispatch.weights")
            .into_iter()
            .map(|(f, w)| (f, w as u32))
            .collect()
    }
}

/// Simulation-engine execution parameters (the `sim` section).
#[derive(Clone, Debug, PartialEq)]
pub struct SimConfig {
    /// Event-core shards: OS threads the worker set is partitioned
    /// across. 1 (default) is the serial engine — bit-identical to the
    /// seed path; ≥ 2 runs the parallel core with an event-time barrier
    /// ([`crate::sim::shard`], DESIGN.md §6). Must not exceed
    /// `cluster.workers`, and the `predictive` autoscale policy requires
    /// the serial engine.
    pub shards: usize,
    /// Event-time barrier period in virtual seconds for sharded runs.
    /// With a tick-driven autoscale policy the control interval
    /// (`autoscale.interval_s`) is the barrier period instead, so global
    /// control fires exactly at barriers.
    pub barrier_s: f64,
    /// Explicit core slots per worker (DESIGN.md §11). 1 (default) keeps
    /// the legacy slot-agnostic semantics — byte-identical to the
    /// pre-slot engine; ≥ 2 switches worker capacity from
    /// `cluster.concurrency` to this slot count, tracks per-slot busy
    /// state and warm affinity, and turns pull dispatch core-granular
    /// (parked requests bind when a *slot* frees, schedulers may pin a
    /// `(worker, slot)` pair). Incompatible with `cluster.elastic`.
    pub cores_per_worker: usize,
}

impl Default for SimConfig {
    fn default() -> Self {
        Self { shards: 1, barrier_s: 1.0, cores_per_worker: 1 }
    }
}

/// PJRT runtime settings (real-time serving mode).
#[derive(Clone, Debug, PartialEq)]
pub struct RuntimeConfig {
    /// Directory holding the AOT artifact set (`manifest.json` + HLO).
    pub artifacts_dir: String,
    /// Extra sandbox-initialization latency added to a real cold start, in
    /// ms (models container/runtime startup on top of XLA compilation).
    pub cold_extra_ms: f64,
    /// Execution backend for the real-time server: `"pjrt"` (default)
    /// runs the AOT-compiled payloads and needs the artifact set;
    /// `"stub"` models each execution as a sleep of the function's
    /// Table-I cold/warm latency (scaled by `stub_speedup`) behind the
    /// same per-worker LRU payload cache — no artifacts required, so
    /// the HTTP smoke tests and benches run on a bare checkout.
    pub backend: String,
    /// Divisor applied to the stub backend's cold/warm sleep times
    /// (`backend = "stub"` only). 1.0 replays Table-I latencies in real
    /// time; the default 100 keeps smoke tests and CI fast while
    /// preserving the cold/warm ratio the scheduler reacts to.
    pub stub_speedup: f64,
}

impl Default for RuntimeConfig {
    fn default() -> Self {
        Self {
            artifacts_dir: "artifacts".into(),
            cold_extra_ms: 0.0,
            backend: "pjrt".into(),
            stub_speedup: 100.0,
        }
    }
}

/// HTTP front-door settings (the `[http]` section): the in-tree
/// HTTP/1.1 ingress that `hiku serve --http ADDR` binds in front of the
/// router (DESIGN.md §13). Entirely `std::net` — no external crates.
#[derive(Clone, Debug, PartialEq)]
pub struct HttpConfig {
    /// Default listen address for `hiku serve --http` when the flag is
    /// given without a value. Port 0 binds an ephemeral port (tests).
    pub addr: String,
    /// Connection-handler thread pool size. Each keep-alive connection
    /// occupies one handler until it closes, so this bounds concurrent
    /// connections; excess accepted connections wait in the hand-off
    /// queue until a handler frees up.
    pub io_threads: usize,
    /// Honor HTTP keep-alive (default). `false` forces
    /// `Connection: close` on every response — one request per
    /// connection, useful when debugging with one-shot clients.
    pub keep_alive: bool,
    /// Maximum accepted request body size in bytes; larger requests are
    /// refused with `413 Payload Too Large`.
    pub max_body_bytes: usize,
    /// Socket read timeout in ms for idle keep-alive connections. A
    /// handler whose connection stays silent this long closes it and
    /// returns to the pool (prevents dead peers from pinning handlers).
    pub read_timeout_ms: u64,
}

impl Default for HttpConfig {
    fn default() -> Self {
        Self {
            addr: "127.0.0.1:8080".into(),
            io_threads: 32,
            keep_alive: true,
            max_body_bytes: 65_536,
            read_timeout_ms: 5_000,
        }
    }
}

/// Observability settings (the `telemetry` section): metric storage mode,
/// request-lifecycle trace sampling and engine phase profiling. All off by
/// default — the default summary output stays byte-identical to the
/// pre-telemetry engine, and none of these knobs may touch the simulation's
/// RNG streams or event order (DESIGN.md §9).
#[derive(Clone, Debug, PartialEq)]
pub struct TelemetryConfig {
    /// Store latency/wait distributions as mergeable quantile sketches
    /// (bounded memory, `sketch_alpha` relative error) instead of exact
    /// per-request sample vectors. Off by default: exact mode is the
    /// determinism/ablation baseline.
    pub sketch: bool,
    /// Relative accuracy of the quantile sketch (DDSketch-style): any
    /// reported quantile is within `sketch_alpha * value` of the truth.
    pub sketch_alpha: f64,
    /// Request-lifecycle tracing: sample 1 of every `trace_sample`
    /// requests (hash-gated by request id, deterministic per seed/shards)
    /// and record arrival → decide → pending → bind → cold-init →
    /// service → complete spans. 0 (default) disables tracing.
    pub trace_sample: u64,
    /// Hard cap on traced requests per router instance (bounds trace
    /// memory on huge runs; sampling stops at the cap).
    pub trace_max: usize,
    /// Engine phase profiling: wall-clock timers around event pop,
    /// decide, barrier merge, handoff and the autoscale tick, surfaced as
    /// a `phases` block in `summary_json` (plus peak RSS). Wall-clock
    /// readings never feed back into simulation state.
    pub phase_profile: bool,
}

impl Default for TelemetryConfig {
    fn default() -> Self {
        Self {
            sketch: false,
            sketch_alpha: 0.005,
            trace_sample: 0,
            trace_max: 10_000,
            phase_profile: false,
        }
    }
}

/// Top-level configuration.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Config {
    /// Cluster topology and worker resources.
    pub cluster: ClusterConfig,
    /// Workload shape (VUs, functions, duration, seed).
    pub workload: WorkloadConfig,
    /// Scheduler selection and algorithm parameters.
    pub scheduler: SchedulerConfig,
    /// Elastic-scaling control loop.
    pub autoscale: AutoscaleConfig,
    /// Dispatch protocol (push/pull, admission, steal batch).
    pub dispatch: DispatchConfig,
    /// Simulation-engine execution (shards, barrier period).
    pub sim: SimConfig,
    /// PJRT runtime settings (real-time serving mode).
    pub runtime: RuntimeConfig,
    /// HTTP front-door ingress (real-time serving mode).
    pub http: HttpConfig,
    /// Observability: sketch metrics, trace sampling, phase profiling.
    pub telemetry: TelemetryConfig,
    /// Deterministic fault injection (crashes, stragglers, init failures).
    pub faults: FaultsConfig,
}

impl Config {
    /// Serialize every section (the `hiku config` dump).
    pub fn to_json(&self) -> Json {
        obj(vec![
            (
                "cluster",
                obj(vec![
                    ("workers", self.cluster.workers.into()),
                    ("mem_mb", self.cluster.mem_mb.into()),
                    ("concurrency", self.cluster.concurrency.into()),
                    ("keep_alive_s", self.cluster.keep_alive_s.into()),
                    ("elastic", self.cluster.elastic.into()),
                    ("prewarm", self.cluster.prewarm.into()),
                ]),
            ),
            (
                "workload",
                obj(vec![
                    ("base_functions", self.workload.base_functions.into()),
                    ("copies", self.workload.copies.into()),
                    ("vus", self.workload.vus.into()),
                    ("duration_s", self.workload.duration_s.into()),
                    ("think_min_s", self.workload.think_min_s.into()),
                    ("think_max_s", self.workload.think_max_s.into()),
                    ("zipf_s", self.workload.zipf_s.into()),
                    ("seed", self.workload.seed.into()),
                ]),
            ),
            (
                "scheduler",
                obj(vec![
                    ("name", self.scheduler.name.as_str().into()),
                    ("ch_bl_c", self.scheduler.ch_bl_c.into()),
                    ("vnodes", self.scheduler.vnodes.into()),
                    ("power_d", self.scheduler.power_d.into()),
                    ("instances", self.scheduler.instances.into()),
                    ("tie_sample_d", self.scheduler.tie_sample_d.into()),
                ]),
            ),
            (
                "autoscale",
                obj(vec![
                    ("policy", self.autoscale.policy.as_str().into()),
                    ("interval_s", self.autoscale.interval_s.into()),
                    ("min_workers", self.autoscale.min_workers.into()),
                    ("max_workers", self.autoscale.max_workers.into()),
                    ("scale_up_util", self.autoscale.scale_up_util.into()),
                    ("scale_down_util", self.autoscale.scale_down_util.into()),
                    ("cooldown_s", self.autoscale.cooldown_s.into()),
                    ("step", self.autoscale.step.into()),
                    ("events", self.autoscale.events.as_str().into()),
                    ("target_util", self.autoscale.target_util.into()),
                    ("prewarm_max_per_tick", self.autoscale.prewarm_max_per_tick.into()),
                    ("ewma_alpha", self.autoscale.ewma_alpha.into()),
                ]),
            ),
            (
                "dispatch",
                obj(vec![
                    ("mode", self.dispatch.mode.as_str().into()),
                    ("queue_cap", self.dispatch.queue_cap.into()),
                    ("queue_caps", self.dispatch.queue_caps.as_str().into()),
                    ("max_wait_s", self.dispatch.max_wait_s.into()),
                    ("adaptive_wait", self.dispatch.adaptive_wait.into()),
                    ("weights", self.dispatch.weights.as_str().into()),
                    ("fair", self.dispatch.fair.into()),
                    ("steal_batch", self.dispatch.steal_batch.into()),
                    ("min_wait_s", self.dispatch.min_wait_s.into()),
                    ("rebind_window_s", self.dispatch.rebind_window_s.into()),
                ]),
            ),
            (
                "sim",
                obj(vec![
                    ("shards", self.sim.shards.into()),
                    ("barrier_s", self.sim.barrier_s.into()),
                    ("cores_per_worker", self.sim.cores_per_worker.into()),
                ]),
            ),
            (
                "runtime",
                obj(vec![
                    ("artifacts_dir", self.runtime.artifacts_dir.as_str().into()),
                    ("cold_extra_ms", self.runtime.cold_extra_ms.into()),
                    ("backend", self.runtime.backend.as_str().into()),
                    ("stub_speedup", self.runtime.stub_speedup.into()),
                ]),
            ),
            (
                "http",
                obj(vec![
                    ("addr", self.http.addr.as_str().into()),
                    ("io_threads", self.http.io_threads.into()),
                    ("keep_alive", self.http.keep_alive.into()),
                    ("max_body_bytes", self.http.max_body_bytes.into()),
                    ("read_timeout_ms", self.http.read_timeout_ms.into()),
                ]),
            ),
            (
                "telemetry",
                obj(vec![
                    ("sketch", self.telemetry.sketch.into()),
                    ("sketch_alpha", self.telemetry.sketch_alpha.into()),
                    ("trace_sample", self.telemetry.trace_sample.into()),
                    ("trace_max", self.telemetry.trace_max.into()),
                    ("phase_profile", self.telemetry.phase_profile.into()),
                ]),
            ),
            (
                "faults",
                obj(vec![
                    ("enabled", self.faults.enabled.into()),
                    ("crash_rate", self.faults.crash_rate.into()),
                    ("mttr_s", self.faults.mttr_s.into()),
                    ("crashes", self.faults.crashes.as_str().into()),
                    ("straggler_frac", self.faults.straggler_frac.into()),
                    ("straggler_slowdown", self.faults.straggler_slowdown.into()),
                    ("init_fail_prob", self.faults.init_fail_prob.into()),
                    ("max_retries", (self.faults.max_retries as u64).into()),
                    ("retry_backoff_s", self.faults.retry_backoff_s.into()),
                    ("hedge_factor", self.faults.hedge_factor.into()),
                ]),
            ),
        ])
    }

    /// Build from JSON, filling omitted fields from the defaults and
    /// validating the result.
    pub fn from_json(j: &Json) -> Result<Config, ConfigError> {
        let mut cfg = Config::default();
        let missing = |p: &str| ConfigError(format!("bad or missing field {p}"));
        if let Some(c) = j.get("cluster") {
            if let Some(v) = c.get("workers") {
                cfg.cluster.workers = v.as_u64().ok_or_else(|| missing("cluster.workers"))? as usize;
            }
            if let Some(v) = c.get("mem_mb") {
                cfg.cluster.mem_mb = v.as_u64().ok_or_else(|| missing("cluster.mem_mb"))?;
            }
            if let Some(v) = c.get("concurrency") {
                cfg.cluster.concurrency =
                    v.as_u64().ok_or_else(|| missing("cluster.concurrency"))? as usize;
            }
            if let Some(v) = c.get("keep_alive_s") {
                cfg.cluster.keep_alive_s =
                    v.as_f64().ok_or_else(|| missing("cluster.keep_alive_s"))?;
            }
            if let Some(v) = c.get("elastic") {
                cfg.cluster.elastic = v.as_bool().ok_or_else(|| missing("cluster.elastic"))?;
            }
            if let Some(v) = c.get("prewarm") {
                cfg.cluster.prewarm = v.as_bool().ok_or_else(|| missing("cluster.prewarm"))?;
            }
        }
        if let Some(w) = j.get("workload") {
            if let Some(v) = w.get("base_functions") {
                cfg.workload.base_functions =
                    v.as_u64().ok_or_else(|| missing("workload.base_functions"))? as usize;
            }
            if let Some(v) = w.get("copies") {
                cfg.workload.copies = v.as_u64().ok_or_else(|| missing("workload.copies"))? as usize;
            }
            if let Some(v) = w.get("vus") {
                cfg.workload.vus = v.as_u64().ok_or_else(|| missing("workload.vus"))? as usize;
            }
            if let Some(v) = w.get("duration_s") {
                cfg.workload.duration_s = v.as_f64().ok_or_else(|| missing("workload.duration_s"))?;
            }
            if let Some(v) = w.get("think_min_s") {
                cfg.workload.think_min_s =
                    v.as_f64().ok_or_else(|| missing("workload.think_min_s"))?;
            }
            if let Some(v) = w.get("think_max_s") {
                cfg.workload.think_max_s =
                    v.as_f64().ok_or_else(|| missing("workload.think_max_s"))?;
            }
            if let Some(v) = w.get("zipf_s") {
                cfg.workload.zipf_s = v.as_f64().ok_or_else(|| missing("workload.zipf_s"))?;
            }
            if let Some(v) = w.get("seed") {
                cfg.workload.seed = v.as_u64().ok_or_else(|| missing("workload.seed"))?;
            }
        }
        if let Some(s) = j.get("scheduler") {
            if let Some(v) = s.get("name") {
                cfg.scheduler.name =
                    v.as_str().ok_or_else(|| missing("scheduler.name"))?.to_string();
            }
            if let Some(v) = s.get("ch_bl_c") {
                cfg.scheduler.ch_bl_c = v.as_f64().ok_or_else(|| missing("scheduler.ch_bl_c"))?;
            }
            if let Some(v) = s.get("vnodes") {
                cfg.scheduler.vnodes =
                    v.as_u64().ok_or_else(|| missing("scheduler.vnodes"))? as usize;
            }
            if let Some(v) = s.get("power_d") {
                cfg.scheduler.power_d =
                    v.as_u64().ok_or_else(|| missing("scheduler.power_d"))? as usize;
            }
            if let Some(v) = s.get("instances") {
                cfg.scheduler.instances =
                    v.as_u64().ok_or_else(|| missing("scheduler.instances"))? as usize;
            }
            if let Some(v) = s.get("tie_sample_d") {
                cfg.scheduler.tie_sample_d =
                    v.as_u64().ok_or_else(|| missing("scheduler.tie_sample_d"))? as usize;
            }
        }
        if let Some(a) = j.get("autoscale") {
            if let Some(v) = a.get("policy") {
                cfg.autoscale.policy =
                    v.as_str().ok_or_else(|| missing("autoscale.policy"))?.to_string();
            }
            if let Some(v) = a.get("interval_s") {
                cfg.autoscale.interval_s =
                    v.as_f64().ok_or_else(|| missing("autoscale.interval_s"))?;
            }
            if let Some(v) = a.get("min_workers") {
                cfg.autoscale.min_workers =
                    v.as_u64().ok_or_else(|| missing("autoscale.min_workers"))? as usize;
            }
            if let Some(v) = a.get("max_workers") {
                cfg.autoscale.max_workers =
                    v.as_u64().ok_or_else(|| missing("autoscale.max_workers"))? as usize;
            }
            if let Some(v) = a.get("scale_up_util") {
                cfg.autoscale.scale_up_util =
                    v.as_f64().ok_or_else(|| missing("autoscale.scale_up_util"))?;
            }
            if let Some(v) = a.get("scale_down_util") {
                cfg.autoscale.scale_down_util =
                    v.as_f64().ok_or_else(|| missing("autoscale.scale_down_util"))?;
            }
            if let Some(v) = a.get("cooldown_s") {
                cfg.autoscale.cooldown_s =
                    v.as_f64().ok_or_else(|| missing("autoscale.cooldown_s"))?;
            }
            if let Some(v) = a.get("step") {
                cfg.autoscale.step = v.as_u64().ok_or_else(|| missing("autoscale.step"))? as usize;
            }
            if let Some(v) = a.get("events") {
                cfg.autoscale.events =
                    v.as_str().ok_or_else(|| missing("autoscale.events"))?.to_string();
            }
            if let Some(v) = a.get("target_util") {
                cfg.autoscale.target_util =
                    v.as_f64().ok_or_else(|| missing("autoscale.target_util"))?;
            }
            if let Some(v) = a.get("prewarm_max_per_tick") {
                cfg.autoscale.prewarm_max_per_tick =
                    v.as_u64().ok_or_else(|| missing("autoscale.prewarm_max_per_tick"))? as usize;
            }
            if let Some(v) = a.get("ewma_alpha") {
                cfg.autoscale.ewma_alpha =
                    v.as_f64().ok_or_else(|| missing("autoscale.ewma_alpha"))?;
            }
        }
        if let Some(d) = j.get("dispatch") {
            if let Some(v) = d.get("mode") {
                cfg.dispatch.mode =
                    v.as_str().ok_or_else(|| missing("dispatch.mode"))?.to_string();
            }
            if let Some(v) = d.get("queue_cap") {
                cfg.dispatch.queue_cap =
                    v.as_u64().ok_or_else(|| missing("dispatch.queue_cap"))? as usize;
            }
            if let Some(v) = d.get("queue_caps") {
                cfg.dispatch.queue_caps =
                    v.as_str().ok_or_else(|| missing("dispatch.queue_caps"))?.to_string();
            }
            if let Some(v) = d.get("max_wait_s") {
                cfg.dispatch.max_wait_s =
                    v.as_f64().ok_or_else(|| missing("dispatch.max_wait_s"))?;
            }
            if let Some(v) = d.get("adaptive_wait") {
                cfg.dispatch.adaptive_wait =
                    v.as_bool().ok_or_else(|| missing("dispatch.adaptive_wait"))?;
            }
            if let Some(v) = d.get("weights") {
                cfg.dispatch.weights =
                    v.as_str().ok_or_else(|| missing("dispatch.weights"))?.to_string();
            }
            if let Some(v) = d.get("fair") {
                cfg.dispatch.fair = v.as_bool().ok_or_else(|| missing("dispatch.fair"))?;
            }
            if let Some(v) = d.get("steal_batch") {
                cfg.dispatch.steal_batch =
                    v.as_u64().ok_or_else(|| missing("dispatch.steal_batch"))? as usize;
            }
            if let Some(v) = d.get("min_wait_s") {
                cfg.dispatch.min_wait_s =
                    v.as_f64().ok_or_else(|| missing("dispatch.min_wait_s"))?;
            }
            if let Some(v) = d.get("rebind_window_s") {
                cfg.dispatch.rebind_window_s =
                    v.as_f64().ok_or_else(|| missing("dispatch.rebind_window_s"))?;
            }
        }
        if let Some(s) = j.get("sim") {
            if let Some(v) = s.get("shards") {
                cfg.sim.shards = v.as_u64().ok_or_else(|| missing("sim.shards"))? as usize;
            }
            if let Some(v) = s.get("barrier_s") {
                cfg.sim.barrier_s = v.as_f64().ok_or_else(|| missing("sim.barrier_s"))?;
            }
            if let Some(v) = s.get("cores_per_worker") {
                cfg.sim.cores_per_worker =
                    v.as_u64().ok_or_else(|| missing("sim.cores_per_worker"))? as usize;
            }
        }
        if let Some(r) = j.get("runtime") {
            if let Some(v) = r.get("artifacts_dir") {
                cfg.runtime.artifacts_dir =
                    v.as_str().ok_or_else(|| missing("runtime.artifacts_dir"))?.to_string();
            }
            if let Some(v) = r.get("cold_extra_ms") {
                cfg.runtime.cold_extra_ms =
                    v.as_f64().ok_or_else(|| missing("runtime.cold_extra_ms"))?;
            }
            if let Some(v) = r.get("backend") {
                cfg.runtime.backend =
                    v.as_str().ok_or_else(|| missing("runtime.backend"))?.to_string();
            }
            if let Some(v) = r.get("stub_speedup") {
                cfg.runtime.stub_speedup =
                    v.as_f64().ok_or_else(|| missing("runtime.stub_speedup"))?;
            }
        }
        if let Some(h) = j.get("http") {
            if let Some(v) = h.get("addr") {
                cfg.http.addr = v.as_str().ok_or_else(|| missing("http.addr"))?.to_string();
            }
            if let Some(v) = h.get("io_threads") {
                cfg.http.io_threads =
                    v.as_u64().ok_or_else(|| missing("http.io_threads"))? as usize;
            }
            if let Some(v) = h.get("keep_alive") {
                cfg.http.keep_alive = v.as_bool().ok_or_else(|| missing("http.keep_alive"))?;
            }
            if let Some(v) = h.get("max_body_bytes") {
                cfg.http.max_body_bytes =
                    v.as_u64().ok_or_else(|| missing("http.max_body_bytes"))? as usize;
            }
            if let Some(v) = h.get("read_timeout_ms") {
                cfg.http.read_timeout_ms =
                    v.as_u64().ok_or_else(|| missing("http.read_timeout_ms"))?;
            }
        }
        if let Some(f) = j.get("faults") {
            if let Some(v) = f.get("enabled") {
                cfg.faults.enabled = v.as_bool().ok_or_else(|| missing("faults.enabled"))?;
            }
            if let Some(v) = f.get("crash_rate") {
                cfg.faults.crash_rate = v.as_f64().ok_or_else(|| missing("faults.crash_rate"))?;
            }
            if let Some(v) = f.get("mttr_s") {
                cfg.faults.mttr_s = v.as_f64().ok_or_else(|| missing("faults.mttr_s"))?;
            }
            if let Some(v) = f.get("crashes") {
                cfg.faults.crashes =
                    v.as_str().ok_or_else(|| missing("faults.crashes"))?.to_string();
            }
            if let Some(v) = f.get("straggler_frac") {
                cfg.faults.straggler_frac =
                    v.as_f64().ok_or_else(|| missing("faults.straggler_frac"))?;
            }
            if let Some(v) = f.get("straggler_slowdown") {
                cfg.faults.straggler_slowdown =
                    v.as_f64().ok_or_else(|| missing("faults.straggler_slowdown"))?;
            }
            if let Some(v) = f.get("init_fail_prob") {
                cfg.faults.init_fail_prob =
                    v.as_f64().ok_or_else(|| missing("faults.init_fail_prob"))?;
            }
            if let Some(v) = f.get("max_retries") {
                cfg.faults.max_retries =
                    v.as_u64().ok_or_else(|| missing("faults.max_retries"))? as u32;
            }
            if let Some(v) = f.get("retry_backoff_s") {
                cfg.faults.retry_backoff_s =
                    v.as_f64().ok_or_else(|| missing("faults.retry_backoff_s"))?;
            }
            if let Some(v) = f.get("hedge_factor") {
                cfg.faults.hedge_factor =
                    v.as_f64().ok_or_else(|| missing("faults.hedge_factor"))?;
            }
        }
        if let Some(t) = j.get("telemetry") {
            if let Some(v) = t.get("sketch") {
                cfg.telemetry.sketch = v.as_bool().ok_or_else(|| missing("telemetry.sketch"))?;
            }
            if let Some(v) = t.get("sketch_alpha") {
                cfg.telemetry.sketch_alpha =
                    v.as_f64().ok_or_else(|| missing("telemetry.sketch_alpha"))?;
            }
            if let Some(v) = t.get("trace_sample") {
                cfg.telemetry.trace_sample =
                    v.as_u64().ok_or_else(|| missing("telemetry.trace_sample"))?;
            }
            if let Some(v) = t.get("trace_max") {
                cfg.telemetry.trace_max =
                    v.as_u64().ok_or_else(|| missing("telemetry.trace_max"))? as usize;
            }
            if let Some(v) = t.get("phase_profile") {
                cfg.telemetry.phase_profile =
                    v.as_bool().ok_or_else(|| missing("telemetry.phase_profile"))?;
            }
        }
        cfg.validate()?;
        Ok(cfg)
    }

    /// Read and parse a JSON config file.
    pub fn from_file(path: &str) -> Result<Config, ConfigError> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| ConfigError(format!("reading {path}: {e}")))?;
        let j = Json::parse(&text).map_err(|e| ConfigError(format!("parsing {path}: {e}")))?;
        Self::from_json(&j)
    }

    /// Apply `path.key=value` overrides (the `--set` CLI mechanism).
    pub fn apply_override(&mut self, kv: &str) -> Result<(), ConfigError> {
        let (path, value) = kv
            .split_once('=')
            .ok_or_else(|| ConfigError(format!("override '{kv}' is not path=value")))?;
        let bad = |p: &str, v: &str| ConfigError(format!("bad value '{v}' for {p}"));
        match path {
            "cluster.workers" => {
                self.cluster.workers = value.parse().map_err(|_| bad(path, value))?
            }
            "cluster.mem_mb" => self.cluster.mem_mb = value.parse().map_err(|_| bad(path, value))?,
            "cluster.concurrency" => {
                self.cluster.concurrency = value.parse().map_err(|_| bad(path, value))?
            }
            "cluster.keep_alive_s" => {
                self.cluster.keep_alive_s = value.parse().map_err(|_| bad(path, value))?
            }
            "cluster.elastic" => {
                self.cluster.elastic = value.parse().map_err(|_| bad(path, value))?
            }
            "cluster.prewarm" => {
                self.cluster.prewarm = value.parse().map_err(|_| bad(path, value))?
            }
            "workload.base_functions" => {
                self.workload.base_functions = value.parse().map_err(|_| bad(path, value))?
            }
            "workload.copies" => {
                self.workload.copies = value.parse().map_err(|_| bad(path, value))?
            }
            "workload.vus" => self.workload.vus = value.parse().map_err(|_| bad(path, value))?,
            "workload.duration_s" => {
                self.workload.duration_s = value.parse().map_err(|_| bad(path, value))?
            }
            "workload.think_min_s" => {
                self.workload.think_min_s = value.parse().map_err(|_| bad(path, value))?
            }
            "workload.think_max_s" => {
                self.workload.think_max_s = value.parse().map_err(|_| bad(path, value))?
            }
            "workload.zipf_s" => {
                self.workload.zipf_s = value.parse().map_err(|_| bad(path, value))?
            }
            "workload.seed" => self.workload.seed = value.parse().map_err(|_| bad(path, value))?,
            "scheduler.name" => self.scheduler.name = value.to_string(),
            "scheduler.ch_bl_c" => {
                self.scheduler.ch_bl_c = value.parse().map_err(|_| bad(path, value))?
            }
            "scheduler.vnodes" => {
                self.scheduler.vnodes = value.parse().map_err(|_| bad(path, value))?
            }
            "scheduler.power_d" => {
                self.scheduler.power_d = value.parse().map_err(|_| bad(path, value))?
            }
            "scheduler.instances" => {
                self.scheduler.instances = value.parse().map_err(|_| bad(path, value))?
            }
            "scheduler.tie_sample_d" => {
                self.scheduler.tie_sample_d = value.parse().map_err(|_| bad(path, value))?
            }
            "sim.shards" => self.sim.shards = value.parse().map_err(|_| bad(path, value))?,
            "sim.barrier_s" => {
                self.sim.barrier_s = value.parse().map_err(|_| bad(path, value))?
            }
            "sim.cores_per_worker" => {
                self.sim.cores_per_worker = value.parse().map_err(|_| bad(path, value))?
            }
            "dispatch.mode" => self.dispatch.mode = value.to_string(),
            "dispatch.queue_cap" => {
                self.dispatch.queue_cap = value.parse().map_err(|_| bad(path, value))?
            }
            "dispatch.queue_caps" => self.dispatch.queue_caps = value.to_string(),
            "dispatch.max_wait_s" => {
                self.dispatch.max_wait_s = value.parse().map_err(|_| bad(path, value))?
            }
            "dispatch.adaptive_wait" => {
                self.dispatch.adaptive_wait = value.parse().map_err(|_| bad(path, value))?
            }
            "dispatch.weights" => self.dispatch.weights = value.to_string(),
            "dispatch.fair" => {
                self.dispatch.fair = value.parse().map_err(|_| bad(path, value))?
            }
            "dispatch.steal_batch" => {
                self.dispatch.steal_batch = value.parse().map_err(|_| bad(path, value))?
            }
            "dispatch.min_wait_s" => {
                self.dispatch.min_wait_s = value.parse().map_err(|_| bad(path, value))?
            }
            "dispatch.rebind_window_s" => {
                self.dispatch.rebind_window_s = value.parse().map_err(|_| bad(path, value))?
            }
            "faults.enabled" => {
                self.faults.enabled = value.parse().map_err(|_| bad(path, value))?
            }
            "faults.crash_rate" => {
                self.faults.crash_rate = value.parse().map_err(|_| bad(path, value))?
            }
            "faults.mttr_s" => self.faults.mttr_s = value.parse().map_err(|_| bad(path, value))?,
            "faults.crashes" => self.faults.crashes = value.to_string(),
            "faults.straggler_frac" => {
                self.faults.straggler_frac = value.parse().map_err(|_| bad(path, value))?
            }
            "faults.straggler_slowdown" => {
                self.faults.straggler_slowdown = value.parse().map_err(|_| bad(path, value))?
            }
            "faults.init_fail_prob" => {
                self.faults.init_fail_prob = value.parse().map_err(|_| bad(path, value))?
            }
            "faults.max_retries" => {
                self.faults.max_retries = value.parse().map_err(|_| bad(path, value))?
            }
            "faults.retry_backoff_s" => {
                self.faults.retry_backoff_s = value.parse().map_err(|_| bad(path, value))?
            }
            "faults.hedge_factor" => {
                self.faults.hedge_factor = value.parse().map_err(|_| bad(path, value))?
            }
            "autoscale.policy" => self.autoscale.policy = value.to_string(),
            "autoscale.interval_s" => {
                self.autoscale.interval_s = value.parse().map_err(|_| bad(path, value))?
            }
            "autoscale.min_workers" => {
                self.autoscale.min_workers = value.parse().map_err(|_| bad(path, value))?
            }
            "autoscale.max_workers" => {
                self.autoscale.max_workers = value.parse().map_err(|_| bad(path, value))?
            }
            "autoscale.scale_up_util" => {
                self.autoscale.scale_up_util = value.parse().map_err(|_| bad(path, value))?
            }
            "autoscale.scale_down_util" => {
                self.autoscale.scale_down_util = value.parse().map_err(|_| bad(path, value))?
            }
            "autoscale.cooldown_s" => {
                self.autoscale.cooldown_s = value.parse().map_err(|_| bad(path, value))?
            }
            "autoscale.step" => {
                self.autoscale.step = value.parse().map_err(|_| bad(path, value))?
            }
            "autoscale.events" => self.autoscale.events = value.to_string(),
            "autoscale.target_util" => {
                self.autoscale.target_util = value.parse().map_err(|_| bad(path, value))?
            }
            "autoscale.prewarm_max_per_tick" => {
                self.autoscale.prewarm_max_per_tick =
                    value.parse().map_err(|_| bad(path, value))?
            }
            "autoscale.ewma_alpha" => {
                self.autoscale.ewma_alpha = value.parse().map_err(|_| bad(path, value))?
            }
            "runtime.artifacts_dir" => self.runtime.artifacts_dir = value.to_string(),
            "runtime.cold_extra_ms" => {
                self.runtime.cold_extra_ms = value.parse().map_err(|_| bad(path, value))?
            }
            "runtime.backend" => self.runtime.backend = value.to_string(),
            "runtime.stub_speedup" => {
                self.runtime.stub_speedup = value.parse().map_err(|_| bad(path, value))?
            }
            "http.addr" => self.http.addr = value.to_string(),
            "http.io_threads" => {
                self.http.io_threads = value.parse().map_err(|_| bad(path, value))?
            }
            "http.keep_alive" => {
                self.http.keep_alive = value.parse().map_err(|_| bad(path, value))?
            }
            "http.max_body_bytes" => {
                self.http.max_body_bytes = value.parse().map_err(|_| bad(path, value))?
            }
            "http.read_timeout_ms" => {
                self.http.read_timeout_ms = value.parse().map_err(|_| bad(path, value))?
            }
            "telemetry.sketch" => {
                self.telemetry.sketch = value.parse().map_err(|_| bad(path, value))?
            }
            "telemetry.sketch_alpha" => {
                self.telemetry.sketch_alpha = value.parse().map_err(|_| bad(path, value))?
            }
            "telemetry.trace_sample" => {
                self.telemetry.trace_sample = value.parse().map_err(|_| bad(path, value))?
            }
            "telemetry.trace_max" => {
                self.telemetry.trace_max = value.parse().map_err(|_| bad(path, value))?
            }
            "telemetry.phase_profile" => {
                self.telemetry.phase_profile = value.parse().map_err(|_| bad(path, value))?
            }
            _ => return Err(ConfigError(format!("unknown config path '{path}'"))),
        }
        self.validate()
    }

    /// Centralized cross-field validation (every entry point calls this).
    pub fn validate(&self) -> Result<(), ConfigError> {
        let e = |m: &str| Err(ConfigError(m.to_string()));
        if self.cluster.workers == 0 {
            return e("cluster.workers must be >= 1");
        }
        if self.cluster.concurrency == 0 {
            return e("cluster.concurrency must be >= 1");
        }
        if self.cluster.keep_alive_s <= 0.0 {
            return e("cluster.keep_alive_s must be > 0");
        }
        if self.workload.base_functions == 0 || self.workload.copies == 0 {
            return e("workload must define at least one function");
        }
        if self.workload.think_min_s < 0.0 || self.workload.think_max_s < self.workload.think_min_s
        {
            return e("workload think time range invalid");
        }
        if self.workload.duration_s <= 0.0 {
            return e("workload.duration_s must be > 0");
        }
        if self.scheduler.ch_bl_c < 1.0 {
            return e("scheduler.ch_bl_c must be >= 1.0");
        }
        if self.scheduler.vnodes == 0 {
            return e("scheduler.vnodes must be >= 1");
        }
        if self.scheduler.power_d == 0 {
            return e("scheduler.power_d must be >= 1");
        }
        if self.scheduler.instances == 0 {
            return e("scheduler.instances must be >= 1");
        }
        if !crate::autoscale::ALL_POLICIES.contains(&self.autoscale.policy.as_str()) {
            return Err(ConfigError(format!(
                "unknown autoscale.policy '{}' (expected one of {:?})",
                self.autoscale.policy,
                crate::autoscale::ALL_POLICIES
            )));
        }
        if self.autoscale.interval_s <= 0.0 {
            return e("autoscale.interval_s must be > 0");
        }
        if self.autoscale.min_workers == 0 && !self.pull_dispatch() {
            // Scale-to-zero parks arrivals in the pending queue until the
            // wake event restores capacity; push mode has nowhere to put
            // a request while the cluster is empty.
            return e("autoscale.min_workers = 0 (scale-to-zero) requires dispatch.mode = pull");
        }
        if self.autoscale.min_workers == 0 && self.sim.shards > 1 {
            // The sharded coordinator enforces one worker per shard.
            return e("autoscale.min_workers = 0 requires the serial engine (sim.shards = 1)");
        }
        if self.autoscale.max_workers < self.autoscale.min_workers {
            return e("autoscale.max_workers must be >= autoscale.min_workers");
        }
        if self.autoscale.scale_up_util <= self.autoscale.scale_down_util
            || self.autoscale.scale_down_util < 0.0
        {
            return e("autoscale utilization thresholds must satisfy 0 <= down < up");
        }
        if self.autoscale.cooldown_s < 0.0 {
            return e("autoscale.cooldown_s must be >= 0");
        }
        if self.autoscale.step == 0 {
            return e("autoscale.step must be >= 1");
        }
        if self.autoscale.target_util <= 0.0 {
            return e("autoscale.target_util must be > 0");
        }
        if self.autoscale.ewma_alpha <= 0.0 || self.autoscale.ewma_alpha > 1.0 {
            return e("autoscale.ewma_alpha must be in (0, 1]");
        }
        if self.autoscale.policy == "predictive" && self.cluster.prewarm {
            // The predictive policy's per-function pools replace the legacy
            // global heuristic; running both would double-speculate against
            // the same warm supply and corrupt the prewarm hit-rate metric.
            return e("autoscale.policy=predictive replaces cluster.prewarm; disable one");
        }
        match self.dispatch.mode.as_str() {
            "push" | "pull" => {}
            other => {
                return Err(ConfigError(format!(
                    "unknown dispatch.mode '{other}' (expected push or pull)"
                )))
            }
        }
        if self.dispatch.max_wait_s <= 0.0 {
            return e("dispatch.max_wait_s must be > 0");
        }
        if self.dispatch.min_wait_s < 0.0 || self.dispatch.min_wait_s > self.dispatch.max_wait_s {
            return e("dispatch.min_wait_s must satisfy 0 <= min_wait_s <= max_wait_s");
        }
        if let Err(m) = parse_fn_map(&self.dispatch.queue_caps) {
            return Err(ConfigError(format!("dispatch.queue_caps: {m}")));
        }
        match parse_fn_map(&self.dispatch.weights) {
            Err(m) => return Err(ConfigError(format!("dispatch.weights: {m}"))),
            Ok(pairs) => {
                if pairs.iter().any(|&(_, w)| w == 0 || w > u32::MAX as u64) {
                    return e("dispatch.weights entries must be in 1..=u32::MAX");
                }
            }
        }
        if self.sim.shards == 0 {
            return e("sim.shards must be >= 1");
        }
        if self.sim.shards > self.cluster.workers {
            return e("sim.shards must be <= cluster.workers (every shard needs a worker)");
        }
        if self.sim.barrier_s <= 0.0 {
            return e("sim.barrier_s must be > 0");
        }
        if self.sim.cores_per_worker == 0 || self.sim.cores_per_worker > 64 {
            return e("sim.cores_per_worker must be in 1..=64");
        }
        if self.sim.cores_per_worker > 1 && self.cluster.elastic {
            // Elastic workers have no fixed slot vector to bind against;
            // the slot model requires a hard per-worker capacity.
            return e("sim.cores_per_worker > 1 requires cluster.elastic = false");
        }
        if !self.dispatch.rebind_window_s.is_finite() || self.dispatch.rebind_window_s < 0.0 {
            return e("dispatch.rebind_window_s must be finite and >= 0");
        }
        if self.dispatch.rebind_window_s > 0.0 && self.dispatch.mode != "push" {
            // Pull mode already late-binds through parking; the rebind hook
            // is push mode's bounded approximation of it (DESIGN.md §11).
            return e("dispatch.rebind_window_s > 0 requires dispatch.mode = push");
        }
        if self.sim.shards > 1 && self.autoscale.policy == "predictive" {
            // The predictive policy consumes the per-arrival stream; the
            // sharded coordinator only sees epoch summaries (DESIGN.md §6).
            return e("autoscale.policy=predictive requires the serial engine (sim.shards=1)");
        }
        match self.runtime.backend.as_str() {
            "pjrt" | "stub" => {}
            other => {
                return Err(ConfigError(format!(
                    "unknown runtime.backend '{other}' (expected pjrt or stub)"
                )))
            }
        }
        if !(self.runtime.stub_speedup.is_finite() && self.runtime.stub_speedup > 0.0) {
            return e("runtime.stub_speedup must be finite and > 0");
        }
        if self.http.io_threads == 0 {
            return e("http.io_threads must be >= 1");
        }
        if self.http.max_body_bytes == 0 {
            return e("http.max_body_bytes must be >= 1");
        }
        if self.http.read_timeout_ms == 0 {
            return e("http.read_timeout_ms must be >= 1");
        }
        if self.telemetry.sketch_alpha <= 0.0 || self.telemetry.sketch_alpha >= 0.5 {
            return e("telemetry.sketch_alpha must be in (0, 0.5)");
        }
        if self.telemetry.trace_sample > 0 && self.telemetry.trace_max == 0 {
            return e("telemetry.trace_max must be >= 1 when tracing is on");
        }
        if !(self.faults.crash_rate.is_finite() && self.faults.crash_rate >= 0.0) {
            return e("faults.crash_rate must be finite and >= 0");
        }
        if !(self.faults.mttr_s.is_finite() && self.faults.mttr_s > 0.0) {
            return e("faults.mttr_s must be finite and > 0");
        }
        if !(0.0..=1.0).contains(&self.faults.straggler_frac) {
            return e("faults.straggler_frac must be in [0, 1]");
        }
        if !(self.faults.straggler_slowdown.is_finite() && self.faults.straggler_slowdown >= 1.0) {
            return e("faults.straggler_slowdown must be >= 1");
        }
        if !(0.0..1.0).contains(&self.faults.init_fail_prob) {
            return e("faults.init_fail_prob must be in [0, 1)");
        }
        if self.faults.max_retries == 0 {
            return e("faults.max_retries must be >= 1 (a retry budget of 0 drops work)");
        }
        if !(self.faults.retry_backoff_s.is_finite() && self.faults.retry_backoff_s >= 0.0) {
            return e("faults.retry_backoff_s must be finite and >= 0");
        }
        if !(self.faults.hedge_factor.is_finite() && self.faults.hedge_factor >= 0.0) {
            return e("faults.hedge_factor must be finite and >= 0");
        }
        if let Err(m) = parse_crash_list(&self.faults.crashes) {
            return Err(ConfigError(format!("faults.crashes: {m}")));
        }
        Ok(())
    }

    /// Total distinct function types in the workload.
    pub fn num_functions(&self) -> usize {
        self.workload.base_functions * self.workload.copies
    }

    /// Whether the pull dispatch protocol is active
    /// (`dispatch.mode = "pull"`).
    pub fn pull_dispatch(&self) -> bool {
        self.dispatch.mode == "pull"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_paper_setup() {
        let c = Config::default();
        assert_eq!(c.cluster.workers, 5);
        assert_eq!(c.num_functions(), 40);
        assert_eq!(c.scheduler.ch_bl_c, 1.25);
        assert_eq!(c.workload.duration_s, 300.0);
        assert!(c.validate().is_ok());
    }

    #[test]
    fn json_roundtrip() {
        let mut c = Config::default();
        c.cluster.workers = 9;
        c.scheduler.name = "ch-bl".into();
        c.workload.vus = 50;
        let j = c.to_json();
        let c2 = Config::from_json(&j).unwrap();
        assert_eq!(c, c2);
    }

    #[test]
    fn overrides() {
        let mut c = Config::default();
        c.apply_override("cluster.workers=10").unwrap();
        c.apply_override("scheduler.name=random").unwrap();
        c.apply_override("workload.zipf_s=1.1").unwrap();
        assert_eq!(c.cluster.workers, 10);
        assert_eq!(c.scheduler.name, "random");
        assert_eq!(c.workload.zipf_s, 1.1);
        assert!(c.apply_override("nope=1").is_err());
        assert!(c.apply_override("cluster.workers=abc").is_err());
        assert!(c.apply_override("cluster.workers").is_err());
    }

    #[test]
    fn http_and_backend_roundtrip_and_validation() {
        let mut c = Config::default();
        c.apply_override("runtime.backend=stub").unwrap();
        c.apply_override("runtime.stub_speedup=50").unwrap();
        c.apply_override("http.addr=0.0.0.0:9000").unwrap();
        c.apply_override("http.io_threads=8").unwrap();
        c.apply_override("http.keep_alive=false").unwrap();
        c.apply_override("http.max_body_bytes=1024").unwrap();
        c.apply_override("http.read_timeout_ms=250").unwrap();
        assert_eq!(c.runtime.backend, "stub");
        assert_eq!(c.runtime.stub_speedup, 50.0);
        assert_eq!(c.http.addr, "0.0.0.0:9000");
        assert_eq!(c.http.io_threads, 8);
        assert!(!c.http.keep_alive);
        let j = c.to_json();
        let c2 = Config::from_json(&j).unwrap();
        assert_eq!(c, c2);

        assert!(c.apply_override("runtime.backend=fpga").is_err());
        let mut c = Config::default();
        c.runtime.stub_speedup = 0.0;
        assert!(c.validate().is_err());
        let mut c = Config::default();
        c.http.io_threads = 0;
        assert!(c.validate().is_err());
        let mut c = Config::default();
        c.http.max_body_bytes = 0;
        assert!(c.validate().is_err());
        let mut c = Config::default();
        c.http.read_timeout_ms = 0;
        assert!(c.validate().is_err());
    }

    #[test]
    fn validation_rejects_bad_configs() {
        let mut c = Config::default();
        c.cluster.workers = 0;
        assert!(c.validate().is_err());
        let mut c = Config::default();
        c.scheduler.ch_bl_c = 0.5;
        assert!(c.validate().is_err());
        let mut c = Config::default();
        c.workload.think_max_s = 0.01;
        assert!(c.validate().is_err());
    }

    #[test]
    fn faults_config_roundtrip_and_overrides() {
        let mut c = Config::default();
        c.apply_override("faults.enabled=true").unwrap();
        c.apply_override("faults.crash_rate=0.2").unwrap();
        c.apply_override("faults.crashes=10:1;40:0").unwrap();
        c.apply_override("faults.max_retries=5").unwrap();
        assert!(c.faults.enabled);
        assert_eq!(c.faults.crash_rate, 0.2);
        assert_eq!(c.faults.max_retries, 5);
        let j = c.to_json();
        let c2 = Config::from_json(&j).unwrap();
        assert_eq!(c, c2);
    }

    #[test]
    fn faults_validation_rejects_bad_values() {
        let mut c = Config::default();
        c.faults.straggler_slowdown = 0.5;
        assert!(c.validate().is_err());
        let mut c = Config::default();
        c.faults.init_fail_prob = 1.5;
        assert!(c.validate().is_err());
        let mut c = Config::default();
        c.faults.max_retries = 0;
        assert!(c.validate().is_err());
        let mut c = Config::default();
        c.faults.crashes = "ten:1".into();
        assert!(c.validate().is_err());
        let mut c = Config::default();
        c.dispatch.min_wait_s = 1.0; // > max_wait_s (0.5)
        assert!(c.validate().is_err());
        c.dispatch.min_wait_s = 0.1;
        assert!(c.validate().is_ok());
    }

    #[test]
    fn faults_spec_parsing() {
        let mut f = FaultsConfig::default();
        f.apply_spec("crash:0.1;straggle:0.25;slow:4;retries:2").unwrap();
        assert!(f.enabled);
        assert_eq!(f.crash_rate, 0.1);
        assert_eq!(f.straggler_frac, 0.25);
        assert_eq!(f.straggler_slowdown, 4.0);
        assert_eq!(f.max_retries, 2);
        assert!(FaultsConfig::default().apply_spec("bogus:1").is_err());
        assert!(FaultsConfig::default().apply_spec("crash").is_err());
        let mut empty = FaultsConfig::default();
        empty.apply_spec("").unwrap();
        assert!(empty.enabled);

        let list = parse_crash_list("10:1; 40.5:0").unwrap();
        assert_eq!(list, vec![(10.0, 1), (40.5, 0)]);
        assert!(parse_crash_list("-1:0").is_err());
        assert!(parse_crash_list("5").is_err());
        assert!(parse_crash_list("").unwrap().is_empty());
    }

    #[test]
    fn partial_json_uses_defaults() {
        let j = Json::parse(r#"{"cluster": {"workers": 3}}"#).unwrap();
        let c = Config::from_json(&j).unwrap();
        assert_eq!(c.cluster.workers, 3);
        assert_eq!(c.workload.vus, WorkloadConfig::default().vus);
        assert_eq!(c.autoscale.policy, "none");
    }

    #[test]
    fn autoscale_roundtrip_and_overrides() {
        let mut c = Config::default();
        c.apply_override("autoscale.policy=reactive").unwrap();
        c.apply_override("autoscale.max_workers=12").unwrap();
        c.apply_override("autoscale.cooldown_s=5.5").unwrap();
        c.apply_override("autoscale.events=60;-120").unwrap();
        assert_eq!(c.autoscale.policy, "reactive");
        assert_eq!(c.autoscale.max_workers, 12);
        assert_eq!(c.autoscale.cooldown_s, 5.5);
        let j = c.to_json();
        let c2 = Config::from_json(&j).unwrap();
        assert_eq!(c, c2);
    }

    #[test]
    fn sim_section_roundtrip_and_validation() {
        let mut c = Config::default();
        assert_eq!(c.sim.shards, 1, "serial engine by default");
        c.apply_override("sim.shards=4").unwrap();
        c.apply_override("sim.barrier_s=0.5").unwrap();
        c.apply_override("scheduler.tie_sample_d=2").unwrap();
        assert_eq!(c.sim.shards, 4);
        assert_eq!(c.scheduler.tie_sample_d, 2);
        let j = c.to_json();
        let c2 = Config::from_json(&j).unwrap();
        assert_eq!(c, c2);
        // More shards than workers cannot partition.
        let mut c = Config::default();
        c.cluster.workers = 3;
        c.sim.shards = 4;
        assert!(c.validate().is_err());
        let mut c = Config::default();
        c.sim.shards = 0;
        assert!(c.validate().is_err());
        let mut c = Config::default();
        c.sim.barrier_s = 0.0;
        assert!(c.validate().is_err());
        // Predictive autoscale needs the serial engine's arrival feed.
        let mut c = Config::default();
        c.cluster.workers = 8;
        c.sim.shards = 2;
        c.autoscale.policy = "predictive".into();
        assert!(c.validate().is_err());
        c.autoscale.policy = "reactive".into();
        assert!(c.validate().is_ok());
    }

    #[test]
    fn slot_config_roundtrip_and_validation() {
        let c = Config::default();
        assert_eq!(c.sim.cores_per_worker, 1, "slot-agnostic by default");
        assert_eq!(c.dispatch.rebind_window_s, 0.0, "rebind off by default");
        let mut c = Config::default();
        c.apply_override("sim.cores_per_worker=4").unwrap();
        c.apply_override("dispatch.rebind_window_s=0.25").unwrap();
        assert_eq!(c.sim.cores_per_worker, 4);
        assert_eq!(c.dispatch.rebind_window_s, 0.25);
        assert!(c.validate().is_ok(), "push + rebind + cores is valid");
        let j = c.to_json();
        let c2 = Config::from_json(&j).unwrap();
        assert_eq!(c, c2);
        // Bounds: 0 and > 64 cores rejected.
        let mut c = Config::default();
        c.sim.cores_per_worker = 0;
        assert!(c.validate().is_err());
        c.sim.cores_per_worker = 65;
        assert!(c.validate().is_err());
        c.sim.cores_per_worker = 64;
        assert!(c.validate().is_ok());
        // Slots need a hard per-worker capacity: elastic must be off.
        let mut c = Config::default();
        c.sim.cores_per_worker = 2;
        c.cluster.elastic = true;
        assert!(c.validate().is_err(), "cores > 1 under elastic must fail");
        c.cluster.elastic = false;
        assert!(c.validate().is_ok());
        // Rebind window: finite, non-negative, push-only.
        let mut c = Config::default();
        c.dispatch.rebind_window_s = -0.1;
        assert!(c.validate().is_err());
        c.dispatch.rebind_window_s = f64::NAN;
        assert!(c.validate().is_err());
        c.dispatch.rebind_window_s = 0.5;
        c.dispatch.mode = "pull".into();
        assert!(c.validate().is_err(), "rebind under pull must fail");
        c.dispatch.mode = "push".into();
        assert!(c.validate().is_ok());
    }

    #[test]
    fn dispatch_section_roundtrip_and_validation() {
        let c = Config::default();
        assert_eq!(c.dispatch.mode, "push", "push dispatch by default");
        assert!(!c.pull_dispatch());
        assert!(c.dispatch.fair, "fair (DRR) draining is the default");
        assert!(c.dispatch.adaptive_wait, "cost-aware waiting is the default");
        let mut c = Config::default();
        c.apply_override("dispatch.mode=pull").unwrap();
        c.apply_override("dispatch.queue_cap=256").unwrap();
        c.apply_override("dispatch.queue_caps=0:4,7:64").unwrap();
        c.apply_override("dispatch.max_wait_s=0.25").unwrap();
        c.apply_override("dispatch.adaptive_wait=false").unwrap();
        c.apply_override("dispatch.weights=0:4").unwrap();
        c.apply_override("dispatch.fair=false").unwrap();
        c.apply_override("dispatch.steal_batch=4").unwrap();
        assert!(c.pull_dispatch());
        assert_eq!(c.dispatch.queue_cap, 256);
        assert!(!c.dispatch.adaptive_wait && !c.dispatch.fair);
        let j = c.to_json();
        let c2 = Config::from_json(&j).unwrap();
        assert_eq!(c, c2);
        // Bad mode / bad wait rejected.
        let mut c = Config::default();
        c.dispatch.mode = "lazy".into();
        assert!(c.validate().is_err());
        let mut c = Config::default();
        c.dispatch.max_wait_s = 0.0;
        assert!(c.validate().is_err());
        // Scale-to-zero needs pull dispatch and the serial engine.
        let mut c = Config::default();
        c.autoscale.min_workers = 0;
        assert!(c.validate().is_err(), "min_workers=0 under push must fail");
        c.dispatch.mode = "pull".into();
        assert!(c.validate().is_ok());
        c.cluster.workers = 8;
        c.sim.shards = 2;
        assert!(c.validate().is_err(), "min_workers=0 sharded must fail");
    }

    #[test]
    fn dispatch_fn_maps_parse_and_validate() {
        assert_eq!(parse_fn_map("").unwrap(), vec![]);
        assert_eq!(parse_fn_map("0:4, 7:2").unwrap(), vec![(0, 4), (7, 2)]);
        assert!(parse_fn_map("0=4").is_err(), "colon separator required");
        assert!(parse_fn_map("x:4").is_err());
        assert!(parse_fn_map("0:y").is_err());
        // Malformed maps are rejected at validation.
        let mut c = Config::default();
        c.dispatch.queue_caps = "nope".into();
        assert!(c.validate().is_err());
        let mut c = Config::default();
        c.dispatch.weights = "0:0".into(); // weight 0 is meaningless in DRR
        assert!(c.validate().is_err());
        c.dispatch.weights = "0:3".into();
        assert!(c.validate().is_ok());
        // Dense caps: default everywhere, overrides where given, ids
        // beyond the workload ignored.
        let mut c = Config::default();
        c.dispatch.queue_cap = 16;
        c.dispatch.queue_caps = "1:4,99:8".into();
        let caps = c.dispatch.caps_dense(3);
        assert_eq!(caps, vec![16, 4, 16]);
        assert_eq!(c.dispatch.weights_sparse(), vec![]);
        c.dispatch.weights = "2:5".into();
        assert_eq!(c.dispatch.weights_sparse(), vec![(2, 5)]);
    }

    #[test]
    fn telemetry_section_roundtrip_and_validation() {
        let c = Config::default();
        assert!(!c.telemetry.sketch, "exact metrics by default");
        assert_eq!(c.telemetry.trace_sample, 0, "tracing off by default");
        assert!(!c.telemetry.phase_profile, "profiling off by default");
        let mut c = Config::default();
        c.apply_override("telemetry.sketch=true").unwrap();
        c.apply_override("telemetry.sketch_alpha=0.01").unwrap();
        c.apply_override("telemetry.trace_sample=16").unwrap();
        c.apply_override("telemetry.trace_max=500").unwrap();
        c.apply_override("telemetry.phase_profile=true").unwrap();
        assert!(c.telemetry.sketch && c.telemetry.phase_profile);
        assert_eq!(c.telemetry.trace_sample, 16);
        let j = c.to_json();
        let c2 = Config::from_json(&j).unwrap();
        assert_eq!(c, c2);
        // Bad accuracy / trace cap rejected.
        let mut c = Config::default();
        c.telemetry.sketch_alpha = 0.0;
        assert!(c.validate().is_err());
        let mut c = Config::default();
        c.telemetry.sketch_alpha = 0.7;
        assert!(c.validate().is_err());
        let mut c = Config::default();
        c.telemetry.trace_sample = 8;
        c.telemetry.trace_max = 0;
        assert!(c.validate().is_err());
        c.telemetry.trace_max = 100;
        assert!(c.validate().is_ok());
    }

    #[test]
    fn autoscale_validation_rejects_bad_configs() {
        let mut c = Config::default();
        c.autoscale.policy = "bogus".into();
        assert!(c.validate().is_err());
        let mut c = Config::default();
        c.autoscale.interval_s = 0.0;
        assert!(c.validate().is_err());
        let mut c = Config::default();
        c.autoscale.max_workers = 0;
        assert!(c.validate().is_err());
        let mut c = Config::default();
        c.autoscale.scale_down_util = 0.9; // above scale_up_util: no dead band
        assert!(c.validate().is_err());
        let mut c = Config::default();
        c.autoscale.ewma_alpha = 1.5;
        assert!(c.validate().is_err());
        let mut c = Config::default();
        c.autoscale.policy = "predictive".into();
        c.cluster.prewarm = true; // double speculation: rejected
        assert!(c.validate().is_err());
        c.cluster.prewarm = false;
        assert!(c.validate().is_ok());
    }
}
