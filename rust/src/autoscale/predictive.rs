//! Predictive scaling and pre-warming from per-function arrival forecasts.
//!
//! Two estimators run per function, fed by every arrival:
//!
//! - an **EWMA of the instantaneous arrival rate** (1/inter-arrival),
//!   tracking the smooth component of demand;
//! - a **log₂ inter-arrival histogram**, whose low quantile gives a
//!   burst-robust rate estimate: during a burst the short inter-arrivals
//!   pile into the low bins long before the EWMA catches up.
//!
//! The forecast rate is the max of the two. From it the policy derives
//!
//! - the **worker target** via Little's law: expected concurrent
//!   executions `Σ_f rate_f · exec_f` over the per-worker slot budget
//!   `concurrency · target_util` (the `1 - target_util` slack is the
//!   burst headroom), clamped to `[min_workers, max_workers]`; scale-up
//!   applies immediately, scale-down one worker per cooldown window;
//! - **per-function pre-warm pools**: enough idle sandboxes to cover the
//!   expected concurrency of each function, topped up by at most
//!   `prewarm_max_per_tick` speculative initializations per tick —
//!   this replaces the global `cluster.prewarm` heuristic with
//!   per-function pools sized by the forecast.

use super::{AutoscaleObs, AutoscalePolicy, ScaleDecision};
use crate::config::AutoscaleConfig;
use crate::workload::spec::FunctionId;

/// Histogram bin k covers inter-arrivals in [2^k, 2^(k+1)) milliseconds;
/// 16 bins span 1 ms .. ~65 s.
const HIST_BINS: usize = 16;

/// Per-function arrival forecaster (EWMA + inter-arrival histogram).
pub struct Forecaster {
    alpha: f64,
    ewma_rate: Vec<f64>,
    last_t: Vec<f64>,
    hist: Vec<[u32; HIST_BINS]>,
}

impl Forecaster {
    /// A forecaster with EWMA smoothing factor `alpha`.
    pub fn new(alpha: f64) -> Self {
        Self { alpha, ewma_rate: Vec::new(), last_t: Vec::new(), hist: Vec::new() }
    }

    fn grow(&mut self, f: FunctionId) {
        if f >= self.ewma_rate.len() {
            self.ewma_rate.resize(f + 1, 0.0);
            self.last_t.resize(f + 1, -1.0);
            self.hist.resize(f + 1, [0; HIST_BINS]);
        }
    }

    /// Feed one arrival of function `f` at time `t`.
    pub fn on_arrival(&mut self, f: FunctionId, t: f64) {
        self.grow(f);
        let last = self.last_t[f];
        if last >= 0.0 && t > last {
            let dt = t - last;
            let inst = 1.0 / dt;
            self.ewma_rate[f] = self.alpha * inst + (1.0 - self.alpha) * self.ewma_rate[f];
            let ms = dt * 1000.0;
            let bin = if ms < 1.0 { 0 } else { (ms.log2() as usize).min(HIST_BINS - 1) };
            self.hist[f][bin] = self.hist[f][bin].saturating_add(1);
        }
        self.last_t[f] = t;
    }

    /// Inter-arrival quantile in seconds from the histogram (bin upper
    /// edge: pessimistic, i.e. rate-underestimating within a bin).
    fn interarrival_quantile_s(&self, f: FunctionId, q: f64) -> Option<f64> {
        let h = self.hist.get(f)?;
        let total: u64 = h.iter().map(|&c| c as u64).sum();
        if total < 8 {
            return None; // too few samples to call it a distribution
        }
        let want = (q * total as f64).ceil().max(1.0) as u64;
        let mut acc = 0u64;
        for (bin, &c) in h.iter().enumerate() {
            acc += c as u64;
            if acc >= want {
                return Some((1u64 << (bin + 1)) as f64 / 1000.0);
            }
        }
        None
    }

    /// Forecast arrival rate (req/s): max of the EWMA and the burst-mode
    /// estimate (inverse 25th-percentile inter-arrival).
    pub fn rate(&self, f: FunctionId) -> f64 {
        let ewma = self.ewma_rate.get(f).copied().unwrap_or(0.0);
        let burst = self
            .interarrival_quantile_s(f, 0.25)
            .map(|dt| 1.0 / dt)
            .unwrap_or(0.0);
        ewma.max(burst)
    }

    /// Forecast rate as of `now`. Both estimators only update on
    /// arrivals, so a function that goes silent would otherwise pin its
    /// burst-era rate forever; cap the estimate hyperbolically by the
    /// observed silence (a function quiet for `s` seconds cannot plausibly
    /// sustain much more than ~2/s req/s), so stale forecasts decay and
    /// release capacity.
    pub fn rate_at(&self, f: FunctionId, now: f64) -> f64 {
        let base = self.rate(f);
        let last = self.last_t.get(f).copied().unwrap_or(-1.0);
        if last < 0.0 {
            return 0.0;
        }
        let silence = now - last;
        if silence <= 0.0 {
            return base;
        }
        base.min(2.0 / silence)
    }

    /// Functions the forecaster has seen at least one arrival for.
    pub fn len(&self) -> usize {
        self.ewma_rate.len()
    }

    /// True when no arrivals have been observed yet.
    pub fn is_empty(&self) -> bool {
        self.ewma_rate.is_empty()
    }
}

/// Forecast-driven scaling: per-function EWMA arrival rates drive a
/// Little's-law worker target and per-function pre-warm pools. See the
/// module docs in [`crate::autoscale`].
pub struct Predictive {
    forecaster: Forecaster,
    min_workers: usize,
    max_workers: usize,
    target_util: f64,
    cooldown_s: f64,
    prewarm_cap: usize,
    last_down_t: f64,
}

impl Predictive {
    /// Build from the `[autoscale]` config section.
    pub fn from_config(cfg: &AutoscaleConfig) -> Self {
        Self {
            forecaster: Forecaster::new(cfg.ewma_alpha),
            min_workers: cfg.min_workers,
            max_workers: cfg.max_workers,
            target_util: cfg.target_util,
            cooldown_s: cfg.cooldown_s,
            prewarm_cap: cfg.prewarm_max_per_tick,
            last_down_t: f64::NEG_INFINITY,
        }
    }

    /// Expose the forecast (diagnostics / tests).
    pub fn forecast_rate(&self, f: FunctionId) -> f64 {
        self.forecaster.rate(f)
    }
}

impl AutoscalePolicy for Predictive {
    fn name(&self) -> &'static str {
        "predictive"
    }

    fn on_arrival(&mut self, f: FunctionId, t: f64) {
        self.forecaster.on_arrival(f, t);
    }

    fn tick(&mut self, obs: &AutoscaleObs) -> ScaleDecision {
        let mut d = ScaleDecision::default();

        // Little's law per function: expected concurrent executions.
        let mut demand = 0.0;
        for (f, &exec_s) in obs.mean_exec_s.iter().enumerate() {
            let rate = self.forecaster.rate_at(f, obs.now);
            if rate <= 0.0 || exec_s <= 0.0 {
                continue;
            }
            let df = rate * exec_s;
            demand += df;
            // Pre-warm pool: keep ceil(df) instances warm per function.
            let want = df.ceil() as usize;
            let have = obs.warm_supply.get(f).copied().unwrap_or(0);
            let deficit = want.saturating_sub(have).min(self.prewarm_cap);
            if deficit > 0 {
                d.prewarm.push((f, deficit));
            }
        }

        // Worker target with burst headroom; demand can also come straight
        // from visible backlog when forecasts lag (queued requests).
        let slots_per_worker = obs.concurrency as f64 * self.target_util;
        let backlog = obs.total_running.max(obs.total_queued) as f64;
        let needed = demand.max(backlog * self.target_util);
        let target =
            ((needed / slots_per_worker).ceil() as usize).clamp(self.min_workers, self.max_workers);

        if target > obs.active_workers {
            // Scale up immediately: pre-warming only helps if the capacity
            // exists before the burst peaks.
            d.target_workers = Some(target);
        } else if target < obs.active_workers && obs.now - self.last_down_t >= self.cooldown_s {
            // Scale down gently: one worker per cooldown window, so a lull
            // between bursts does not flush the warm pool.
            d.target_workers = Some(obs.active_workers - 1);
            self.last_down_t = obs.now;
        }
        d
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> AutoscaleConfig {
        AutoscaleConfig {
            policy: "predictive".into(),
            min_workers: 1,
            max_workers: 8,
            target_util: 0.7,
            cooldown_s: 10.0,
            prewarm_max_per_tick: 2,
            ewma_alpha: 0.2,
            ..Default::default()
        }
    }

    #[test]
    fn forecaster_converges_on_steady_rate() {
        let mut fc = Forecaster::new(0.2);
        for i in 0..200 {
            fc.on_arrival(0, i as f64 * 0.1); // 10 req/s
        }
        let r = fc.rate(0);
        assert!((5.0..=20.0).contains(&r), "rate {r} far from 10 req/s");
    }

    #[test]
    fn histogram_catches_bursts_faster_than_ewma() {
        let mut fc = Forecaster::new(0.05); // sluggish EWMA
        let mut t = 0.0;
        for _ in 0..50 {
            fc.on_arrival(0, t);
            t += 1.0; // 1 req/s baseline
        }
        for _ in 0..30 {
            fc.on_arrival(0, t);
            t += 0.01; // 100 req/s burst
        }
        assert!(fc.rate(0) > 10.0, "burst not detected: {}", fc.rate(0));
    }

    #[test]
    fn unknown_function_has_zero_rate() {
        let fc = Forecaster::new(0.2);
        assert_eq!(fc.rate(7), 0.0);
        assert_eq!(fc.rate_at(7, 100.0), 0.0);
    }

    #[test]
    fn stale_forecast_decays_with_silence() {
        let mut fc = Forecaster::new(0.2);
        for i in 0..200 {
            fc.on_arrival(0, i as f64 * 0.05); // 20 req/s until t=10
        }
        let fresh = fc.rate_at(0, 10.0);
        assert!(fresh > 5.0, "active forecast {fresh} should be near 20");
        let stale = fc.rate_at(0, 110.0); // silent for 100 s
        assert!(stale <= 2.0 / 99.0, "stale forecast {stale} must decay");
    }

    fn obs_with<'a>(
        now: f64,
        active: usize,
        warm: &'a [usize],
        exec: &'a [f64],
    ) -> AutoscaleObs<'a> {
        AutoscaleObs {
            now,
            active_workers: active,
            concurrency: 4,
            total_running: 0,
            total_queued: 0,
            warm_supply: warm,
            mean_exec_s: exec,
        }
    }

    #[test]
    fn prewarm_pool_covers_forecast_deficit() {
        let mut p = Predictive::from_config(&cfg());
        for i in 0..100 {
            p.on_arrival(0, i as f64 * 0.1); // ~10 req/s
        }
        let exec = [0.4]; // demand ~ 4 concurrent
        let d = p.tick(&obs_with(10.0, 2, &[1], &exec));
        let pool: Vec<_> = d.prewarm.iter().filter(|&&(f, _)| f == 0).collect();
        assert_eq!(pool.len(), 1);
        let n = pool[0].1;
        assert!((1..=2).contains(&n), "deficit {n} should be capped at 2");
    }

    #[test]
    fn no_prewarm_when_supply_covers_demand() {
        let mut p = Predictive::from_config(&cfg());
        for i in 0..100 {
            p.on_arrival(0, i as f64 * 0.1);
        }
        let exec = [0.4];
        let d = p.tick(&obs_with(10.0, 2, &[8], &exec));
        assert!(d.prewarm.is_empty(), "warm supply 8 covers demand ~4: {:?}", d.prewarm);
    }

    #[test]
    fn scales_up_for_forecast_demand_and_down_slowly() {
        let mut p = Predictive::from_config(&cfg());
        for i in 0..400 {
            p.on_arrival(0, i as f64 * 0.025); // ~40 req/s
        }
        let exec = [0.5]; // demand ~ 20 concurrent -> ceil(20 / 2.8) = 8 workers
        let d = p.tick(&obs_with(10.0, 2, &[0], &exec));
        let up = d.target_workers.expect("must scale up");
        assert!(up > 4, "forecast demand should ask for several workers, got {up}");

        // Demand gone: downscale is one worker per cooldown window.
        let mut q = Predictive::from_config(&cfg());
        let d1 = q.tick(&obs_with(100.0, 6, &[], &[]));
        assert_eq!(d1.target_workers, Some(5));
        let d2 = q.tick(&obs_with(101.0, 5, &[], &[]));
        assert_eq!(d2.target_workers, None, "cooldown gates the next drain");
        let d3 = q.tick(&obs_with(110.0, 5, &[], &[]));
        assert_eq!(d3.target_workers, Some(4));
    }
}
