//! Policy-driven elastic scaling and predictive pre-warming.
//!
//! The paper motivates Hiku with the auto-scaling disruption story (§II-C:
//! how many function→worker assignments survive a scale event), but its
//! testbed only ever *replays* scale events. This subsystem closes the
//! loop: a recurring control tick hands an [`AutoscaleObs`] snapshot of the
//! cluster to an [`AutoscalePolicy`], which answers with a worker-count
//! target and per-function pre-warm pools. The simulator (and the
//! real-time server) apply the decision through the same
//! `on_worker_added`/`on_worker_removed` scheduler notifications the
//! scripted scale events already use, so every scheduling algorithm is
//! exercised unchanged.
//!
//! Policies (config `autoscale.policy`):
//!
//! - [`NoScaling`] (`none`) — the static cluster (default; bit-identical
//!   to runs without the subsystem).
//! - [`Scheduled`] (`scheduled`) — replays an explicit event list at exact
//!   times; subsumes the old `run_scaled`/`run_scale_events` entry points.
//! - [`Reactive`] (`reactive`) — utilization thresholds with a hysteresis
//!   dead band, cooldown, and min/max worker bounds (the classic
//!   K8s-HPA-style loop; cf. Kaffes et al., "Practical Scheduling for
//!   Real-World Serverless Computing").
//! - [`Predictive`] (`predictive`) — per-function arrival-rate forecasting
//!   (EWMA + inter-arrival histograms) drives both the worker-count target
//!   (Little's-law demand with headroom) and per-function pre-warm pools,
//!   replacing the global `cluster.prewarm` heuristic (cf. Nguyen et al.,
//!   "Taming Cold Starts: Proactive Serverless Scheduling with MPC").
//!
//! Determinism: policies are pure state machines over the observation
//! stream — no wall clock, no RNG — so a simulated run under a fixed
//! (config, seed) stays bit-reproducible with autoscaling enabled.

pub mod predictive;
pub mod reactive;
pub mod scheduled;

use crate::config::AutoscaleConfig;
use crate::workload::spec::FunctionId;

pub use predictive::Predictive;
pub use reactive::Reactive;
pub use scheduled::{NoScaling, Scheduled};

/// Cluster snapshot handed to the policy on every control tick. All
/// quantities are restricted to the *active* worker set (drained workers
/// finishing in-flight work are excluded).
pub struct AutoscaleObs<'a> {
    /// Current (virtual or wall-clock) time in seconds.
    pub now: f64,
    /// Workers currently eligible for selection.
    pub active_workers: usize,
    /// Execution slots (vCPUs) per worker.
    pub concurrency: usize,
    /// Executions currently running across active workers.
    pub total_running: usize,
    /// Requests queued at active workers (0 in elastic mode).
    pub total_queued: usize,
    /// Per-function warm supply: idle + initializing sandboxes across the
    /// active workers. Empty when the backend cannot observe sandboxes.
    pub warm_supply: &'a [usize],
    /// Per-function mean warm execution time in seconds.
    pub mean_exec_s: &'a [f64],
}

impl AutoscaleObs<'_> {
    /// Slot utilization: running executions over available vCPU slots.
    /// Can exceed 1.0 in elastic mode (time-shared vCPUs).
    pub fn utilization(&self) -> f64 {
        let slots = (self.active_workers * self.concurrency) as f64;
        if slots == 0.0 {
            0.0
        } else {
            self.total_running as f64 / slots
        }
    }
}

/// What a policy wants done. An empty decision means "hold".
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ScaleDecision {
    /// Desired active-worker count; the platform adds/drains workers one at
    /// a time (LIFO drain) until it matches. `None` = no change.
    pub target_workers: Option<usize>,
    /// Per-function speculative sandboxes to initialize this tick.
    pub prewarm: Vec<(FunctionId, usize)>,
}

/// An elastic-scaling policy. Object-safe (mirrors the [`crate::scheduler::Scheduler`]
/// contract) so the platform can swap policies from config.
pub trait AutoscalePolicy: Send {
    /// Stable policy name (the config `autoscale.policy` vocabulary).
    fn name(&self) -> &'static str;

    /// Exact-time (time, up) scale events to pre-schedule at run start.
    /// Only the scheduled policy uses this; it keeps the event times exact
    /// instead of quantizing them to the control tick.
    fn scheduled_events(&self) -> Vec<(f64, bool)> {
        Vec::new()
    }

    /// Whether the platform should run the recurring control tick for this
    /// policy. Event-list policies return false and skip the tick entirely.
    fn tick_driven(&self) -> bool {
        true
    }

    /// A request for function `f` arrived at time `t` (forecaster feed).
    fn on_arrival(&mut self, _f: FunctionId, _t: f64) {}

    /// One control tick: observe the cluster, decide.
    fn tick(&mut self, _obs: &AutoscaleObs) -> ScaleDecision {
        ScaleDecision::default()
    }
}

/// Policy names accepted by `autoscale.policy`.
pub const ALL_POLICIES: [&str; 4] = ["none", "scheduled", "reactive", "predictive"];

/// Construct the policy a config asks for.
pub fn make_policy(cfg: &AutoscaleConfig) -> Result<Box<dyn AutoscalePolicy>, String> {
    let p: Box<dyn AutoscalePolicy> = match cfg.policy.as_str() {
        "none" => Box::new(NoScaling),
        "scheduled" => Box::new(Scheduled::parse(&cfg.events)?),
        "reactive" => Box::new(Reactive::from_config(cfg)),
        "predictive" => Box::new(Predictive::from_config(cfg)),
        other => return Err(format!("unknown autoscale policy '{other}'")),
    };
    Ok(p)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_constructs_all_policies() {
        for name in ALL_POLICIES {
            let cfg = AutoscaleConfig { policy: name.into(), ..Default::default() };
            let p = make_policy(&cfg).unwrap();
            assert_eq!(p.name(), name);
        }
        let bad = AutoscaleConfig { policy: "bogus".into(), ..Default::default() };
        assert!(make_policy(&bad).is_err());
    }

    #[test]
    fn none_policy_is_inert() {
        let mut p = NoScaling;
        assert!(!p.tick_driven());
        assert!(p.scheduled_events().is_empty());
        let obs = AutoscaleObs {
            now: 1.0,
            active_workers: 2,
            concurrency: 4,
            total_running: 8,
            total_queued: 3,
            warm_supply: &[],
            mean_exec_s: &[],
        };
        assert_eq!(p.tick(&obs), ScaleDecision::default());
    }

    #[test]
    fn utilization_math() {
        let obs = AutoscaleObs {
            now: 0.0,
            active_workers: 2,
            concurrency: 4,
            total_running: 6,
            total_queued: 0,
            warm_supply: &[],
            mean_exec_s: &[],
        };
        assert!((obs.utilization() - 0.75).abs() < 1e-12);
    }
}
