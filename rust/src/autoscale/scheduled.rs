//! The inert policy and the explicit-event-list policy.
//!
//! `Scheduled` is the closed-loop home of the repo's original
//! externally-scripted scaling (the long-removed `run_scaled` /
//! `run_scale_events` entry points): the event list is pre-scheduled at
//! run start at its *exact* times (not quantized to the control tick),
//! so replays were bit-identical to the legacy entry points.

use super::{AutoscaleObs, AutoscalePolicy, ScaleDecision};

/// `none`: the static cluster. Never ticks, never scales.
pub struct NoScaling;

impl AutoscalePolicy for NoScaling {
    fn name(&self) -> &'static str {
        "none"
    }

    fn tick_driven(&self) -> bool {
        false
    }
}

/// `scheduled`: replay an explicit (time, up) event list.
pub struct Scheduled {
    /// (time, up) in caller order. Order is preserved verbatim: two events
    /// at the same timestamp fire in list order (FIFO tie-breaking in the
    /// event queue), which the LIFO-drain tests rely on.
    events: Vec<(f64, bool)>,
}

impl Scheduled {
    /// A policy replaying the given (time, up) event list verbatim.
    pub fn new(events: Vec<(f64, bool)>) -> Self {
        Self { events }
    }

    /// Parse an event spec: separator-delimited signed times, e.g.
    /// `"60,120,-150"` — up at 60 s and 120 s, down (LIFO drain) at 150 s.
    /// Accepts `,`, `;`, or whitespace as separators (`;` survives the
    /// comma-splitting `--set` CLI mechanism) and an optional `+` prefix.
    pub fn parse(spec: &str) -> Result<Self, String> {
        let mut events = Vec::new();
        for tok in spec.split(|c: char| c == ',' || c == ';' || c.is_whitespace()) {
            let tok = tok.trim();
            if tok.is_empty() {
                continue;
            }
            let (up, num) = match tok.strip_prefix('-') {
                Some(rest) => (false, rest),
                None => (true, tok.strip_prefix('+').unwrap_or(tok)),
            };
            let t: f64 = num
                .parse()
                .map_err(|_| format!("autoscale.events: bad time '{tok}'"))?;
            if !t.is_finite() || t < 0.0 {
                return Err(format!("autoscale.events: time '{tok}' must be >= 0"));
            }
            events.push((t, up));
        }
        Ok(Self::new(events))
    }

    /// Number of scheduled events.
    pub fn len(&self) -> usize {
        self.events.len()
    }

    /// True when the event list is empty.
    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }
}

impl AutoscalePolicy for Scheduled {
    fn name(&self) -> &'static str {
        "scheduled"
    }

    fn scheduled_events(&self) -> Vec<(f64, bool)> {
        self.events.clone()
    }

    fn tick_driven(&self) -> bool {
        false
    }

    fn tick(&mut self, _obs: &AutoscaleObs) -> ScaleDecision {
        // Events are pre-scheduled exactly; nothing to do per tick.
        ScaleDecision::default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_signed_times() {
        let s = Scheduled::parse("60,120,-150").unwrap();
        assert_eq!(s.scheduled_events(), vec![(60.0, true), (120.0, true), (150.0, false)]);
    }

    #[test]
    fn parse_alternate_separators_and_plus() {
        let s = Scheduled::parse(" +30; -45.5  90 ").unwrap();
        assert_eq!(s.scheduled_events(), vec![(30.0, true), (45.5, false), (90.0, true)]);
    }

    #[test]
    fn parse_preserves_duplicate_times_in_order() {
        // LIFO-drain semantics depend on same-time events staying FIFO.
        let s = Scheduled::parse("-30,-30,60").unwrap();
        assert_eq!(s.scheduled_events(), vec![(30.0, false), (30.0, false), (60.0, true)]);
    }

    #[test]
    fn parse_empty_is_no_events() {
        assert!(Scheduled::parse("").unwrap().is_empty());
        assert!(Scheduled::parse(" , ; ").unwrap().is_empty());
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(Scheduled::parse("abc").is_err());
        assert!(Scheduled::parse("1e400").is_err(), "infinite time rejected");
    }
}
