//! Reactive (threshold) scaling: the classic horizontal-autoscaler loop.
//!
//! Scale up when slot utilization crosses `scale_up_util` (or requests are
//! queueing), scale down when it falls below `scale_down_util`; the gap
//! between the thresholds is the hysteresis dead band and `cooldown_s`
//! rate-limits consecutive actions, so a bursty trace does not make the
//! cluster flap. Purely reactive: capacity arrives only *after* load is
//! visible, so every scale-up serves its first requests cold — the
//! baseline the predictive policy is measured against.

use super::{AutoscaleObs, AutoscalePolicy, ScaleDecision};
use crate::config::AutoscaleConfig;

/// Utilization-threshold scaling with hysteresis, cooldown and bounds
/// (the classic HPA-style loop). See the module docs in
/// [`crate::autoscale`].
pub struct Reactive {
    min_workers: usize,
    max_workers: usize,
    up_util: f64,
    down_util: f64,
    cooldown_s: f64,
    step: usize,
    /// Time of the last scaling action; f64::NEG_INFINITY before the first.
    last_action_t: f64,
}

impl Reactive {
    /// Build from the `[autoscale]` config section.
    pub fn from_config(cfg: &AutoscaleConfig) -> Self {
        Self {
            min_workers: cfg.min_workers,
            max_workers: cfg.max_workers,
            up_util: cfg.scale_up_util,
            down_util: cfg.scale_down_util,
            cooldown_s: cfg.cooldown_s,
            step: cfg.step.max(1),
            last_action_t: f64::NEG_INFINITY,
        }
    }
}

impl AutoscalePolicy for Reactive {
    fn name(&self) -> &'static str {
        "reactive"
    }

    fn tick(&mut self, obs: &AutoscaleObs) -> ScaleDecision {
        let mut d = ScaleDecision::default();
        if obs.now - self.last_action_t < self.cooldown_s {
            return d;
        }
        let util = obs.utilization();
        if util > self.up_util || obs.total_queued > 0 {
            let target = obs.active_workers.saturating_add(self.step).min(self.max_workers);
            if target > obs.active_workers {
                self.last_action_t = obs.now;
                d.target_workers = Some(target);
            }
        } else if util < self.down_util {
            let target = obs.active_workers.saturating_sub(self.step).max(self.min_workers);
            if target < obs.active_workers {
                self.last_action_t = obs.now;
                d.target_workers = Some(target);
            }
        }
        d
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn policy() -> Reactive {
        Reactive::from_config(&AutoscaleConfig {
            policy: "reactive".into(),
            min_workers: 2,
            max_workers: 6,
            scale_up_util: 0.8,
            scale_down_util: 0.3,
            cooldown_s: 10.0,
            step: 1,
            ..Default::default()
        })
    }

    fn obs(now: f64, active: usize, running: usize, queued: usize) -> ScaleDecision {
        // Helper builds the obs and ticks a fresh borrow each call site.
        let o = AutoscaleObs {
            now,
            active_workers: active,
            concurrency: 4,
            total_running: running,
            total_queued: queued,
            warm_supply: &[],
            mean_exec_s: &[],
        };
        let mut p = policy();
        p.tick(&o)
    }

    #[test]
    fn scales_up_on_high_utilization() {
        assert_eq!(obs(0.0, 3, 11, 0).target_workers, Some(4)); // 11/12 > 0.8
    }

    #[test]
    fn scales_up_on_queueing() {
        assert_eq!(obs(0.0, 3, 2, 5).target_workers, Some(4));
    }

    #[test]
    fn dead_band_holds() {
        assert_eq!(obs(0.0, 3, 6, 0).target_workers, None); // 0.5: between thresholds
    }

    #[test]
    fn scales_down_when_idle_but_respects_min() {
        assert_eq!(obs(0.0, 4, 1, 0).target_workers, Some(3)); // 1/16 < 0.3
        assert_eq!(obs(0.0, 2, 0, 0).target_workers, None, "min bound holds");
    }

    #[test]
    fn respects_max_bound() {
        assert_eq!(obs(0.0, 6, 24, 9).target_workers, None, "max bound holds");
    }

    #[test]
    fn cooldown_rate_limits() {
        let mut p = policy();
        let hot = |now| AutoscaleObs {
            now,
            active_workers: 3,
            concurrency: 4,
            total_running: 12,
            total_queued: 0,
            warm_supply: &[],
            mean_exec_s: &[],
        };
        assert_eq!(p.tick(&hot(0.0)).target_workers, Some(4));
        assert_eq!(p.tick(&hot(5.0)).target_workers, None, "inside cooldown");
        assert_eq!(p.tick(&hot(10.0)).target_workers, Some(4), "cooldown elapsed");
    }
}
