//! Deterministic fault-injection plans: worker crashes/recoveries,
//! straggler slowdown episodes, and pure-hash draws for retry backoff
//! jitter and sandbox cold-init failures.
//!
//! # Determinism contract (DESIGN.md §10)
//!
//! Fault schedules are a pure function of `(FaultsConfig, workers,
//! duration, seed)`. The plan generator uses its **own** per-worker
//! [`Pcg64`] instances seeded by hashing the run seed with the worker id
//! — it never touches (or splits from) the engine's scheduler/service
//! streams, so enabling faults leaves every fault-free random draw
//! bit-identical, and disabling them restores the exact pre-fault event
//! stream. Per-request draws (backoff jitter, init-failure coins) are
//! stateless hashes of `(seed, request, attempt)` so they are immune to
//! event-interleaving order.
//!
//! In the sharded engine each shard generates a plan over its own local
//! worker slice using its shard seed, which makes failure runs
//! bit-reproducible per `(seed, shards)` — the same contract the rest of
//! the engine keeps.

use crate::config::{parse_crash_list, FaultsConfig};
use crate::util::hashing::mix64;
use crate::util::rng::Pcg64;

/// Salt folded into the run seed for fault streams, so fault draws can
/// never collide with the engine's `^ 0x51D0_C0DE` scheduler/service
/// streams or the coordinator's `^ 0x5AAD_C0DE` stream.
const FAULT_SALT: u64 = 0xFA17_0BAD_5EED_0001;

/// Per-worker stream separation (golden-ratio stride, same idiom as the
/// shard-seed derivation).
const WORKER_STRIDE: u64 = 0x9E37_79B9_7F4A_7C15;

/// A precomputed, immutable schedule of fault injections for one engine
/// (or one shard). Timestamps are simulation seconds; worker ids are
/// local to the engine that generated the plan. Each list is sorted by
/// `(time, worker)` so event scheduling order is deterministic.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct FaultPlan {
    /// `(time, worker)` — worker crashes: all sandboxes (busy included)
    /// are destroyed and in-flight work is re-enqueued by the engine.
    pub crashes: Vec<(f64, usize)>,
    /// `(time, worker)` — a crashed worker rejoins, cold.
    pub recoveries: Vec<(f64, usize)>,
    /// `(time, worker, multiplier)` — set the worker's service-time
    /// multiplier (`1.0` ends a straggler episode).
    pub stragglers: Vec<(f64, usize, f64)>,
}

impl FaultPlan {
    /// True when the plan schedules nothing.
    pub fn is_empty(&self) -> bool {
        self.crashes.is_empty() && self.recoveries.is_empty() && self.stragglers.is_empty()
    }

    /// Generate the schedule for `workers` workers over `[0, duration_s)`.
    ///
    /// Random crashes follow an independent Poisson process per worker
    /// (rate `crash_rate` per minute); each crash recovers after a
    /// deterministically jittered `mttr_s` in `[0.5x, 1.5x)`. Recoveries
    /// that would land past `duration_s` are dropped — the worker simply
    /// stays dead to the end of the run, and the retry budget (not a
    /// recovery) bounds how long parked work waits. Explicit
    /// [`FaultsConfig::crashes`] entries use `mttr_s` verbatim. Straggler
    /// episodes pick `straggler_frac` of workers (an independent coin per
    /// worker) and slow them by `straggler_slowdown` for a seed-derived
    /// window in the middle of the run.
    pub fn generate(cfg: &FaultsConfig, workers: usize, duration_s: f64, seed: u64) -> FaultPlan {
        let mut plan = FaultPlan::default();
        if !cfg.enabled {
            return plan;
        }
        for w in 0..workers {
            let mut rng =
                Pcg64::new(seed ^ FAULT_SALT ^ (w as u64).wrapping_mul(WORKER_STRIDE));
            if cfg.crash_rate > 0.0 {
                let rate_per_s = cfg.crash_rate / 60.0;
                let mut t = rng.exponential(rate_per_s);
                while t < duration_s {
                    plan.crashes.push((t, w));
                    let down = cfg.mttr_s * (0.5 + rng.next_f64());
                    let up_at = t + down;
                    if up_at < duration_s {
                        plan.recoveries.push((up_at, w));
                    } else {
                        // Dead to the end; no more crashes for this worker.
                        break;
                    }
                    t = up_at + rng.exponential(rate_per_s);
                }
            }
            if cfg.straggler_frac > 0.0 && rng.next_f64() < cfg.straggler_frac {
                let start = duration_s * (0.1 + 0.4 * rng.next_f64());
                let end = start + duration_s * (0.2 + 0.3 * rng.next_f64());
                plan.stragglers.push((start, w, cfg.straggler_slowdown));
                if end < duration_s {
                    plan.stragglers.push((end, w, 1.0));
                }
            }
        }
        // Explicit kill schedule (already validated by Config::validate;
        // entries addressing workers outside this engine are skipped,
        // which is how sharded runs partition a global schedule).
        for (t, w) in parse_crash_list(&cfg.crashes).unwrap_or_default() {
            if w < workers && t < duration_s {
                plan.crashes.push((t, w));
                let up_at = t + cfg.mttr_s;
                if up_at < duration_s {
                    plan.recoveries.push((up_at, w));
                }
            }
        }
        plan.crashes.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
        plan.recoveries.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
        plan.stragglers.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
        plan
    }
}

/// Stateless uniform draw in `[0, 1)` for per-request fault decisions
/// (init-failure coins). Hashing `(seed, request, attempt)` makes the
/// draw independent of event interleaving: the same request's attempt
/// sees the same coin at any shard count.
#[inline]
pub fn fault_coin(seed: u64, request: u64, attempt: u32) -> f64 {
    let h = mix64(seed ^ FAULT_SALT ^ mix64(request).wrapping_add(attempt as u64));
    // 53-bit mantissa, same construction as Pcg64::next_f64.
    (h >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
}

/// Deterministically jittered retry backoff: `base * [1, 2)`, keyed by
/// `(seed, request, attempt)` so colliding retries de-synchronize without
/// consuming any RNG stream. Returns 0 when `base` is 0.
#[inline]
pub fn retry_backoff(base: f64, seed: u64, request: u64, attempt: u32) -> f64 {
    base * (1.0 + fault_coin(seed ^ 0xB0FF, request, attempt))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg_with(f: impl FnOnce(&mut FaultsConfig)) -> FaultsConfig {
        let mut c = FaultsConfig { enabled: true, ..FaultsConfig::default() };
        f(&mut c);
        c
    }

    #[test]
    fn disabled_plan_is_empty() {
        let c = FaultsConfig::default();
        assert!(FaultPlan::generate(&c, 8, 300.0, 42).is_empty());
    }

    #[test]
    fn plan_is_deterministic_and_seed_sensitive() {
        let c = cfg_with(|c| {
            c.crash_rate = 1.0;
            c.straggler_frac = 0.5;
        });
        let a = FaultPlan::generate(&c, 8, 300.0, 42);
        let b = FaultPlan::generate(&c, 8, 300.0, 42);
        let d = FaultPlan::generate(&c, 8, 300.0, 43);
        assert_eq!(a, b);
        assert_ne!(a, d);
        assert!(!a.crashes.is_empty());
    }

    #[test]
    fn plan_respects_duration_and_ordering() {
        let c = cfg_with(|c| {
            c.crash_rate = 2.0;
            c.straggler_frac = 1.0;
        });
        let p = FaultPlan::generate(&c, 16, 120.0, 7);
        for &(t, w) in &p.crashes {
            assert!((0.0..120.0).contains(&t));
            assert!(w < 16);
        }
        for &(t, _) in &p.recoveries {
            assert!(t < 120.0);
        }
        assert!(p.crashes.windows(2).all(|v| v[0].0 <= v[1].0), "crashes unsorted");
        assert!(p.recoveries.windows(2).all(|v| v[0].0 <= v[1].0), "recoveries unsorted");
        assert!(p.stragglers.windows(2).all(|v| v[0].0 <= v[1].0), "stragglers unsorted");
        // Every recovery follows a crash of the same worker.
        for &(rt, rw) in &p.recoveries {
            assert!(p.crashes.iter().any(|&(ct, cw)| cw == rw && ct < rt));
        }
        // straggler_frac = 1.0 => every worker gets an episode.
        let slowed: std::collections::BTreeSet<usize> =
            p.stragglers.iter().map(|&(_, w, _)| w).collect();
        assert_eq!(slowed.len(), 16);
    }

    #[test]
    fn explicit_crash_schedule() {
        let c = cfg_with(|c| {
            c.crashes = "10:1;40:0".into();
            c.mttr_s = 5.0;
        });
        let p = FaultPlan::generate(&c, 4, 100.0, 1);
        assert_eq!(p.crashes, vec![(10.0, 1), (40.0, 0)]);
        assert_eq!(p.recoveries, vec![(15.0, 1), (45.0, 0)]);
        // Out-of-range worker ids are skipped (sharded partitioning).
        let p2 = FaultPlan::generate(&c, 1, 100.0, 1);
        assert_eq!(p2.crashes, vec![(40.0, 0)]);
    }

    #[test]
    fn hash_draws_are_stable_and_uniformish() {
        assert_eq!(fault_coin(42, 7, 0), fault_coin(42, 7, 0));
        assert_ne!(fault_coin(42, 7, 0), fault_coin(42, 7, 1));
        assert_ne!(fault_coin(42, 7, 0), fault_coin(43, 7, 0));
        let n = 10_000;
        let mean: f64 =
            (0..n).map(|i| fault_coin(9, i, 0)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
        let b = retry_backoff(0.05, 42, 7, 1);
        assert!((0.05..0.10).contains(&b), "backoff {b}");
        assert_eq!(retry_backoff(0.0, 42, 7, 1), 0.0);
    }
}
