//! Miniature benchmark harness (no criterion vendored in this image).
//!
//! Provides criterion-like ergonomics for the `rust/benches/*` targets
//! (declared with `harness = false`): warmup, calibrated iteration counts,
//! mean/std/min reporting in adaptive units, and a `Reporter` that prints
//! paper-style table rows. Wall-clock timing via `std::time::Instant`.

use crate::stats::OnlineStats;
use std::time::{Duration, Instant};

/// One measured benchmark result.
#[derive(Clone, Debug)]
pub struct Measurement {
    /// Benchmark label.
    pub name: String,
    /// Total timed iterations across all sample batches.
    pub iters: u64,
    /// Mean wall time per iteration in nanoseconds.
    pub mean_ns: f64,
    /// Standard deviation across sample batches, in nanoseconds.
    pub std_ns: f64,
    /// Fastest sample-batch mean, in nanoseconds.
    pub min_ns: f64,
}

impl Measurement {
    /// Mean per-iteration time as a `Duration`.
    pub fn mean(&self) -> Duration {
        Duration::from_nanos(self.mean_ns as u64)
    }
}

fn fmt_ns(ns: f64) -> String {
    if ns < 1_000.0 {
        format!("{ns:.1} ns")
    } else if ns < 1_000_000.0 {
        format!("{:.2} us", ns / 1_000.0)
    } else if ns < 1_000_000_000.0 {
        format!("{:.2} ms", ns / 1_000_000.0)
    } else {
        format!("{:.3} s", ns / 1_000_000_000.0)
    }
}

/// Benchmark runner with criterion-like calibration.
pub struct Bench {
    /// Target wall time per measurement phase.
    pub measure_time: Duration,
    /// Wall time spent warming up (and calibrating the batch size).
    pub warmup_time: Duration,
    /// Number of sample batches for std estimation.
    pub samples: usize,
}

impl Default for Bench {
    fn default() -> Self {
        Self {
            measure_time: Duration::from_millis(600),
            warmup_time: Duration::from_millis(150),
            samples: 12,
        }
    }
}

impl Bench {
    /// The default calibration (600 ms measure, 150 ms warmup).
    pub fn new() -> Self {
        Default::default()
    }

    /// Quick preset for long-running end-to-end benches (few iterations).
    pub fn coarse() -> Self {
        Self {
            measure_time: Duration::from_millis(1500),
            warmup_time: Duration::ZERO,
            samples: 3,
        }
    }

    /// Measure `f`, returning timing stats. `f` is called repeatedly; use
    /// `std::hint::black_box` inside to defeat DCE.
    pub fn run<F: FnMut()>(&self, name: &str, mut f: F) -> Measurement {
        // Warmup + per-iteration estimate.
        // detlint:allow(R2) -- bench harness measures real elapsed time by definition
        let wstart = Instant::now();
        let mut warm_iters = 0u64;
        while wstart.elapsed() < self.warmup_time || warm_iters == 0 {
            f();
            warm_iters += 1;
            if warm_iters > 1_000_000 {
                break;
            }
        }
        let est_ns = (wstart.elapsed().as_nanos() as f64 / warm_iters as f64).max(0.5);

        // Batch size so each sample lasts measure_time/samples.
        let per_sample_ns = self.measure_time.as_nanos() as f64 / self.samples as f64;
        let batch = ((per_sample_ns / est_ns).ceil() as u64).max(1);

        let mut stats = OnlineStats::new();
        let mut total_iters = 0u64;
        for _ in 0..self.samples {
            // detlint:allow(R2) -- bench harness measures real elapsed time by definition
            let t0 = Instant::now();
            for _ in 0..batch {
                f();
            }
            let ns = t0.elapsed().as_nanos() as f64 / batch as f64;
            stats.push(ns);
            total_iters += batch;
        }
        Measurement {
            name: name.to_string(),
            iters: total_iters,
            mean_ns: stats.mean(),
            std_ns: stats.std(),
            min_ns: stats.min(),
        }
    }

    /// Measure and print in one call.
    pub fn report<F: FnMut()>(&self, name: &str, f: F) -> Measurement {
        let m = self.run(name, f);
        println!(
            "{:<44} {:>12} +/- {:>10}  (min {:>10}, {} iters)",
            m.name,
            fmt_ns(m.mean_ns),
            fmt_ns(m.std_ns),
            fmt_ns(m.min_ns),
            m.iters
        );
        m
    }
}

/// Table printer for paper-figure benches: aligned columns, a header, and
/// a trailing comparison against a baseline row.
pub struct Reporter {
    header_printed: bool,
    columns: Vec<String>,
}

impl Reporter {
    /// A table with the given column headers.
    pub fn new(columns: &[&str]) -> Self {
        Self {
            header_printed: false,
            columns: columns.iter().map(|s| s.to_string()).collect(),
        }
    }

    /// Print one row (the header prints lazily before the first row).
    pub fn row(&mut self, cells: &[String]) {
        if !self.header_printed {
            let head: Vec<String> = self.columns.iter().map(|c| format!("{c:>14}")).collect();
            println!("{}", head.join(" "));
            self.header_printed = true;
        }
        let row: Vec<String> = cells.iter().map(|c| format!("{c:>14}")).collect();
        println!("{}", row.join(" "));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_sane() {
        let b = Bench {
            measure_time: Duration::from_millis(30),
            warmup_time: Duration::from_millis(5),
            samples: 3,
        };
        let mut acc = 0u64;
        let m = b.run("noop-ish", || {
            acc = std::hint::black_box(acc.wrapping_add(1));
        });
        assert!(m.mean_ns > 0.0 && m.mean_ns < 1_000_000.0, "{:?}", m);
        assert!(m.iters > 0);
        assert!(m.min_ns <= m.mean_ns * 1.5);
    }

    #[test]
    fn formats_units() {
        assert!(fmt_ns(12.0).contains("ns"));
        assert!(fmt_ns(12_000.0).contains("us"));
        assert!(fmt_ns(12_000_000.0).contains("ms"));
        assert!(fmt_ns(12_000_000_000.0).ends_with(" s"));
    }
}
