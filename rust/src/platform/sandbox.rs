//! Sandbox: one virtualized execution environment for one function type.
//!
//! Implements the lifecycle of Fig 2 in the paper: a sandbox is created on a
//! cold start (initializing -> busy), becomes idle after execution, can be
//! reused by requests of the *same function type only* (warm start), and is
//! evicted after the keep-alive timeout or under memory pressure.

use crate::workload::spec::FunctionId;

/// Process-unique (per worker) sandbox identifier.
pub type SandboxId = u64;

/// Sandbox lifecycle states (Fig 2). `Initializing` exists as a distinct
/// state for the real-time backend where initialization (XLA compilation)
/// has observable duration; the simulator folds init time into the first
/// execution and transitions Created->Busy directly.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SandboxState {
    /// Being created/compiled; cannot serve requests yet.
    Initializing,
    /// Warm and ready to serve its function type.
    Idle,
    /// Currently executing a request.
    Busy,
}

/// One sandbox instance and its lifecycle state.
#[derive(Clone, Debug)]
pub struct Sandbox {
    /// Identifier, unique within its worker.
    pub id: SandboxId,
    /// The single function type this sandbox can serve.
    pub function: FunctionId,
    /// Current lifecycle state (Fig 2).
    pub state: SandboxState,
    /// Memory footprint in MB, held for the sandbox's whole lifetime.
    pub mem_mb: u64,
    /// Time this sandbox last became idle (valid when state == Idle).
    pub idle_since: f64,
    /// Monotonic reuse counter; guards stale keep-alive expiry events:
    /// an expiry scheduled for (sandbox, epoch) only fires if the sandbox
    /// is still idle in the same epoch.
    pub epoch: u64,
    /// Number of executions served (1 cold + n-1 warm).
    pub executions: u64,
    /// True for a speculatively created (pre-warmed) sandbox that has not
    /// yet served its first execution; cleared on first use so each
    /// speculation is counted as at most one hit.
    pub prewarmed: bool,
    /// Creation timestamp (virtual seconds).
    pub created_at: f64,
}

impl Sandbox {
    /// A fresh `Initializing` sandbox created at `now`.
    pub fn new(id: SandboxId, function: FunctionId, mem_mb: u64, now: f64) -> Self {
        Self {
            id,
            function,
            state: SandboxState::Initializing,
            mem_mb,
            idle_since: now,
            epoch: 0,
            executions: 0,
            prewarmed: false,
            created_at: now,
        }
    }

    /// Initializing/Idle -> Busy. Returns false on an illegal transition.
    pub fn start_execution(&mut self) -> bool {
        match self.state {
            SandboxState::Initializing | SandboxState::Idle => {
                self.state = SandboxState::Busy;
                self.executions += 1;
                true
            }
            SandboxState::Busy => false,
        }
    }

    /// Initializing -> Idle (pre-warming completed). Returns the idle epoch.
    pub fn finish_init(&mut self, now: f64) -> Option<u64> {
        if self.state != SandboxState::Initializing {
            return None;
        }
        self.state = SandboxState::Idle;
        self.idle_since = now;
        self.epoch += 1;
        Some(self.epoch)
    }

    /// Busy -> Idle at time `now`. Returns the new idle epoch.
    pub fn finish_execution(&mut self, now: f64) -> Option<u64> {
        if self.state != SandboxState::Busy {
            return None;
        }
        self.state = SandboxState::Idle;
        self.idle_since = now;
        self.epoch += 1;
        Some(self.epoch)
    }

    /// True when idle (warm and reusable).
    pub fn is_idle(&self) -> bool {
        self.state == SandboxState::Idle
    }

    /// True when executing.
    pub fn is_busy(&self) -> bool {
        self.state == SandboxState::Busy
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lifecycle_transitions() {
        let mut sb = Sandbox::new(1, 7, 256, 0.0);
        assert_eq!(sb.state, SandboxState::Initializing);
        assert!(sb.start_execution());
        assert!(sb.is_busy());
        assert!(!sb.start_execution(), "busy sandbox cannot start again");
        let e1 = sb.finish_execution(1.5).unwrap();
        assert!(sb.is_idle());
        assert_eq!(sb.idle_since, 1.5);
        assert!(sb.start_execution());
        let e2 = sb.finish_execution(3.0).unwrap();
        assert!(e2 > e1, "epoch must advance per idle period");
        assert_eq!(sb.executions, 2);
    }

    #[test]
    fn finish_requires_busy() {
        let mut sb = Sandbox::new(1, 0, 128, 0.0);
        assert_eq!(sb.finish_execution(1.0), None);
    }
}
