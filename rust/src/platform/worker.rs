//! Worker: memory pool + sandbox table + execution slots + FIFO admission
//! queue + LRU evictor (the "evictor component" of Fig 1).
//!
//! The worker is a passive state machine over virtual time: the simulator
//! (or the real-time server) drives it and owns the clock. All transitions
//! that destroy sandboxes report the evicted function types so the caller
//! can deliver the paper's eviction notifications to the scheduler (§IV-A).

use super::sandbox::{Sandbox, SandboxId};
use crate::workload::spec::FunctionId;
use std::collections::VecDeque;

/// Dense worker index (0-based; the active set is a prefix).
pub type WorkerId = usize;

/// A request admitted to a worker but waiting for a free execution slot.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct QueuedRequest {
    /// The router-assigned request id.
    pub request_id: u64,
    /// Function the request invokes.
    pub function: FunctionId,
    /// Sandbox memory footprint the execution will need, in MB.
    pub mem_mb: u64,
    /// When the request entered the queue (virtual seconds).
    pub queued_at: f64,
}

/// Outcome of handing a request to a worker.
#[derive(Clone, Debug, PartialEq)]
pub enum AssignOutcome {
    /// Execution started immediately.
    Started(StartInfo),
    /// All execution slots busy; request queued FIFO at the worker.
    Queued,
}

/// Details of a started execution.
#[derive(Clone, Debug, PartialEq)]
pub struct StartInfo {
    /// Sandbox the execution runs in.
    pub sandbox: SandboxId,
    /// True if a new sandbox had to be created (cold start).
    pub cold: bool,
    /// Function types whose idle sandboxes were force-evicted to make room
    /// (memory pressure). One entry per evicted sandbox.
    pub evicted: Vec<FunctionId>,
    /// Request id (echoed for queued starts).
    pub request_id: u64,
    /// Queue delay experienced at the worker (0 for immediate starts).
    pub queue_delay_s: f64,
    /// Core slot the execution occupies (`None` at `cores = 1`, where the
    /// worker is slot-agnostic and capacity is plain `concurrency`).
    pub slot: Option<u32>,
}

/// Why an eviction happened (metrics/ablation).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EvictReason {
    /// The sandbox sat idle past the keep-alive timeout.
    KeepAliveExpired,
    /// An idle sandbox was reclaimed to make room.
    MemoryPressure,
}

/// One worker node: memory pool, sandbox table, execution slots.
#[derive(Clone, Debug)]
pub struct Worker {
    /// This worker's id (its index in the cluster).
    pub id: WorkerId,
    /// Sandbox memory pool size in MB.
    pub mem_capacity_mb: u64,
    /// Memory currently held by sandboxes, in MB.
    pub mem_used_mb: u64,
    /// Maximum concurrent executions (vCPU slots).
    pub concurrency: usize,
    /// Explicit core slots (DESIGN.md §11). `1` keeps the legacy
    /// slot-agnostic semantics where capacity is `concurrency`; `> 1`
    /// switches capacity to `cores` and tracks per-slot busy state plus
    /// a per-slot warm-affinity memory (the function that last ran there).
    cores: usize,
    /// `slot_busy[s]` = an execution currently occupies core slot `s`.
    /// Empty at `cores = 1`.
    slot_busy: Vec<bool>,
    /// Function that last occupied slot `s` (`usize::MAX` = never used).
    /// Deliberately *not* cleared on release: it is the warm-affinity
    /// signal `decide` uses to route a function back to "its" core.
    slot_fn: Vec<usize>,
    /// Busy sandbox -> occupied slot (only while executing; `cores > 1`).
    sandbox_slot: Vec<(SandboxId, u32)>,
    running: usize,
    sandboxes: Vec<Sandbox>,
    queue: VecDeque<QueuedRequest>,
    next_sandbox_id: SandboxId,
    /// Non-busy (idle + initializing) sandbox count per function — the
    /// worker's contribution to the cluster's incremental warm-supply
    /// aggregate. Updated at every sandbox state transition; always equals
    /// what [`Worker::warm_counts_into`] would recount.
    warm_by_fn: Vec<u32>,
    /// Journal of warm-count deltas since the cluster last drained it
    /// (see `Cluster::sync_after`). Mirrors `warm_by_fn` updates 1:1.
    pub(crate) warm_deltas: Vec<(FunctionId, i32)>,
    // ---- counters (metrics) ----
    /// Executions that required creating a sandbox (cold starts).
    pub total_cold: u64,
    /// Executions served by an existing idle sandbox (warm starts).
    pub total_warm: u64,
    /// Idle sandboxes evicted under memory pressure.
    pub total_evictions_pressure: u64,
    /// Idle sandboxes evicted by keep-alive expiry.
    pub total_evictions_keepalive: u64,
    /// Speculative sandboxes created via [`Worker::prewarm`].
    pub total_prewarm_spawned: u64,
    /// Warm starts served by a pre-warmed sandbox's first use.
    pub total_prewarm_hits: u64,
}

impl Worker {
    /// An empty worker with the given memory pool and vCPU slots.
    pub fn new(id: WorkerId, mem_capacity_mb: u64, concurrency: usize) -> Self {
        Self {
            id,
            mem_capacity_mb,
            mem_used_mb: 0,
            concurrency,
            cores: 1,
            slot_busy: Vec::new(),
            slot_fn: Vec::new(),
            sandbox_slot: Vec::new(),
            running: 0,
            sandboxes: Vec::new(),
            queue: VecDeque::new(),
            next_sandbox_id: 1,
            warm_by_fn: Vec::new(),
            warm_deltas: Vec::new(),
            total_cold: 0,
            total_warm: 0,
            total_evictions_pressure: 0,
            total_evictions_keepalive: 0,
            total_prewarm_spawned: 0,
            total_prewarm_hits: 0,
        }
    }

    /// Builder: give the worker `cores` explicit core slots. At `cores = 1`
    /// (or 0, clamped) the worker keeps the legacy slot-agnostic semantics;
    /// at `cores > 1` capacity becomes `cores` and per-slot state is live.
    pub fn with_cores(mut self, cores: usize) -> Self {
        self.cores = cores.max(1);
        if self.cores > 1 {
            self.slot_busy = vec![false; self.cores];
            self.slot_fn = vec![usize::MAX; self.cores];
        }
        self
    }

    // ---- inspection -------------------------------------------------------

    /// Executions currently running.
    pub fn running(&self) -> usize {
        self.running
    }

    /// Configured core slots (1 = legacy slot-agnostic mode).
    pub fn cores(&self) -> usize {
        self.cores
    }

    /// Execution capacity: `cores` when slot-granular, else `concurrency`.
    pub fn cap(&self) -> usize {
        if self.cores > 1 {
            self.cores
        } else {
            self.concurrency
        }
    }

    /// Free execution slots right now.
    pub fn free_slots(&self) -> usize {
        self.cap().saturating_sub(self.running)
    }

    /// Lowest-index free slot whose last occupant was `f` (warm affinity),
    /// if any. `None` at `cores = 1`.
    pub fn warm_free_slot(&self, f: FunctionId) -> Option<u32> {
        if self.cores <= 1 {
            return None;
        }
        (0..self.cores)
            .find(|&s| !self.slot_busy[s] && self.slot_fn[s] == f)
            .map(|s| s as u32)
    }

    /// Per-slot view for invariant checks: (busy flags, last-function memory).
    /// Both empty at `cores = 1`.
    pub fn slot_state(&self) -> (&[bool], &[usize]) {
        (&self.slot_busy, &self.slot_fn)
    }

    /// Requests waiting in the FIFO admission queue.
    pub fn queue_len(&self) -> usize {
        self.queue.len()
    }

    /// Outstanding work at this worker (running + queued).
    pub fn load(&self) -> usize {
        self.running + self.queue.len()
    }

    /// Free sandbox-pool memory in MB (saturating at 0).
    pub fn mem_free_mb(&self) -> u64 {
        // Elastic mode tolerates the busy set transiently exceeding the
        // pool, so this must saturate (0 free), not underflow.
        self.mem_capacity_mb.saturating_sub(self.mem_used_mb)
    }

    /// Whether an idle (warm) sandbox for `f` exists here.
    pub fn has_idle(&self, f: FunctionId) -> bool {
        self.sandboxes.iter().any(|s| s.function == f && s.is_idle())
    }

    /// Idle (warm) sandboxes for `f`.
    pub fn idle_count(&self, f: FunctionId) -> usize {
        self.sandboxes.iter().filter(|s| s.function == f && s.is_idle()).count()
    }

    /// Total sandboxes on this worker, in any state.
    pub fn num_sandboxes(&self) -> usize {
        self.sandboxes.len()
    }

    /// The next sandbox id this worker will hand out. Every sandbox that
    /// has ever existed here has a strictly smaller id (ids are never
    /// reused, even across [`Worker::crash`]), so callers can treat the
    /// watermark as a crash epoch: a completion whose sandbox id is below
    /// the watermark recorded at crash time refers to destroyed state.
    pub fn sandbox_watermark(&self) -> SandboxId {
        self.next_sandbox_id
    }

    /// Look up a sandbox by id.
    pub fn sandbox(&self, id: SandboxId) -> Option<&Sandbox> {
        self.sandboxes.iter().find(|s| s.id == id)
    }

    fn sandbox_mut(&mut self, id: SandboxId) -> Option<&mut Sandbox> {
        self.sandboxes.iter_mut().find(|s| s.id == id)
    }

    /// This worker's non-busy sandbox counts per function (dense by
    /// FunctionId; shorter than the registry when tail functions never ran
    /// here).
    pub fn warm_by_fn(&self) -> &[u32] {
        &self.warm_by_fn
    }

    // ---- incremental warm accounting --------------------------------------
    //
    // Called at every transition that changes a sandbox's non-busy status
    // (Idle/Initializing vs Busy/destroyed). The per-worker counter and
    // the delta journal move together so the cluster aggregate can be
    // updated incrementally without rescanning sandboxes.

    #[inline]
    fn note_warm_up(&mut self, f: FunctionId) {
        if f >= self.warm_by_fn.len() {
            self.warm_by_fn.resize(f + 1, 0);
        }
        self.warm_by_fn[f] += 1;
        self.warm_deltas.push((f, 1));
    }

    #[inline]
    fn note_warm_down(&mut self, f: FunctionId) {
        debug_assert!(self.warm_by_fn.get(f).copied().unwrap_or(0) > 0, "warm underflow f={f}");
        self.warm_by_fn[f] -= 1;
        self.warm_deltas.push((f, -1));
    }

    // ---- request path -----------------------------------------------------

    /// A request for `f` (with sandbox footprint `mem_mb`) arrives at `now`.
    pub fn assign(
        &mut self,
        request_id: u64,
        f: FunctionId,
        mem_mb: u64,
        now: f64,
    ) -> AssignOutcome {
        self.assign_with_slot(request_id, f, mem_mb, now, None)
    }

    /// Slot-granular assignment: like [`Worker::assign`] but with an
    /// optional preferred core slot (from a scheduler `AssignSlot`
    /// decision). The preference is best-effort — if the slot is busy by
    /// the time the request lands, the worker falls back to its own
    /// deterministic pick (lowest free warm-affine slot, else lowest free
    /// index). Ignored at `cores = 1`.
    pub fn assign_with_slot(
        &mut self,
        request_id: u64,
        f: FunctionId,
        mem_mb: u64,
        now: f64,
        preferred_slot: Option<u32>,
    ) -> AssignOutcome {
        assert!(
            mem_mb * self.cap() as u64 <= self.mem_capacity_mb,
            "worker {} cannot ever fit {} x {mem_mb} MB",
            self.id,
            self.cap()
        );
        if self.running >= self.cap() {
            self.queue.push_back(QueuedRequest { request_id, function: f, mem_mb, queued_at: now });
            return AssignOutcome::Queued;
        }
        AssignOutcome::Started(self.start_execution(request_id, f, mem_mb, now, 0.0, preferred_slot))
    }

    /// Claim a core slot for `f` (`cores > 1` only). Determinism rule
    /// (DESIGN.md §11): honor the preferred slot if free, else the
    /// lowest-index free slot whose last occupant was `f`, else the lowest
    /// free index. Records the warm-affinity memory.
    fn occupy_slot(&mut self, f: FunctionId, preferred: Option<u32>) -> Option<u32> {
        if self.cores <= 1 {
            return None;
        }
        let pick = match preferred {
            Some(p) if (p as usize) < self.cores && !self.slot_busy[p as usize] => p as usize,
            _ => {
                let mut first_free = None;
                let mut chosen = None;
                for s in 0..self.cores {
                    if self.slot_busy[s] {
                        continue;
                    }
                    if self.slot_fn[s] == f {
                        chosen = Some(s);
                        break;
                    }
                    if first_free.is_none() {
                        first_free = Some(s);
                    }
                }
                chosen
                    .or(first_free)
                    .expect("occupy_slot: no free slot despite running < cores")
            }
        };
        self.slot_busy[pick] = true;
        self.slot_fn[pick] = f;
        Some(pick as u32)
    }

    /// Release the slot held by `sandbox`, keeping the warm-affinity memory.
    fn release_slot(&mut self, sandbox: SandboxId) {
        if self.cores <= 1 {
            return;
        }
        if let Some(pos) = self.sandbox_slot.iter().position(|&(sb, _)| sb == sandbox) {
            let (_, slot) = self.sandbox_slot.swap_remove(pos);
            debug_assert!(self.slot_busy[slot as usize], "releasing a free slot");
            self.slot_busy[slot as usize] = false;
        }
    }

    /// Start executing `f`, reusing an idle sandbox (warm) or creating one
    /// (cold, evicting idle LRU sandboxes under memory pressure).
    fn start_execution(
        &mut self,
        request_id: u64,
        f: FunctionId,
        mem_mb: u64,
        now: f64,
        queue_delay_s: f64,
        preferred_slot: Option<u32>,
    ) -> StartInfo {
        debug_assert!(self.running < self.cap());
        self.running += 1;
        let slot = self.occupy_slot(f, preferred_slot);

        // Warm path: most-recently-idle sandbox of this type (stack reuse
        // keeps the hottest sandbox warm, like OpenLambda's handler cache).
        if let Some(idx) = self
            .sandboxes
            .iter()
            .enumerate()
            .filter(|(_, s)| s.function == f && s.is_idle())
            .max_by(|(_, a), (_, b)| a.idle_since.partial_cmp(&b.idle_since).unwrap())
            .map(|(i, _)| i)
        {
            let (sandbox, was_prewarmed) = {
                let sb = &mut self.sandboxes[idx];
                let ok = sb.start_execution();
                debug_assert!(ok);
                (sb.id, std::mem::replace(&mut sb.prewarmed, false))
            };
            if was_prewarmed {
                self.total_prewarm_hits += 1;
            }
            self.total_warm += 1;
            self.note_warm_down(f);
            if let Some(s) = slot {
                self.sandbox_slot.push((sandbox, s));
            }
            return StartInfo {
                sandbox,
                cold: false,
                evicted: Vec::new(),
                request_id,
                queue_delay_s,
                slot,
            };
        }

        // Cold path: free memory, then create.
        let evicted = self.make_room(mem_mb);
        let id = self.next_sandbox_id;
        self.next_sandbox_id += 1;
        let mut sb = Sandbox::new(id, f, mem_mb, now);
        let ok = sb.start_execution();
        debug_assert!(ok);
        self.mem_used_mb += mem_mb;
        debug_assert!(self.mem_used_mb <= self.mem_capacity_mb);
        self.sandboxes.push(sb);
        self.total_cold += 1;
        if let Some(s) = slot {
            self.sandbox_slot.push((id, s));
        }
        StartInfo { sandbox: id, cold: true, evicted, request_id, queue_delay_s, slot }
    }

    /// Evict idle sandboxes (LRU: least-recently-idle first) until `mem_mb`
    /// fits. Panics if the invariant `concurrency * max_mem <= capacity` is
    /// violated (checked at assign).
    fn make_room(&mut self, mem_mb: u64) -> Vec<FunctionId> {
        let mut evicted = Vec::new();
        while self.mem_used_mb + mem_mb > self.mem_capacity_mb {
            let victim = self
                .sandboxes
                .iter()
                .enumerate()
                .filter(|(_, s)| s.is_idle())
                .min_by(|(_, a), (_, b)| a.idle_since.partial_cmp(&b.idle_since).unwrap())
                .map(|(i, _)| i);
            match victim {
                Some(i) => {
                    let sb = self.sandboxes.swap_remove(i);
                    self.mem_used_mb -= sb.mem_mb;
                    self.total_evictions_pressure += 1;
                    self.note_warm_down(sb.function);
                    evicted.push(sb.function);
                }
                None => panic!(
                    "worker {}: memory exhausted by busy sandboxes ({} used / {} cap, need {mem_mb})",
                    self.id, self.mem_used_mb, self.mem_capacity_mb
                ),
            }
        }
        evicted
    }

    /// An execution finished at `now`. The sandbox becomes idle (keep-alive
    /// countdown starts); if requests are queued, the next one starts
    /// immediately. Returns (idle epoch for expiry scheduling, optional
    /// started queued request).
    pub fn complete(
        &mut self,
        sandbox: SandboxId,
        now: f64,
    ) -> (Option<(SandboxId, u64)>, Option<StartInfo>) {
        let sb = self.sandbox_mut(sandbox).expect("completing unknown sandbox");
        let f_done = sb.function;
        let epoch = sb.finish_execution(now).expect("completing non-busy sandbox");
        debug_assert!(self.running > 0);
        self.running -= 1;
        self.release_slot(sandbox);
        self.note_warm_up(f_done);

        let mut started = None;
        if let Some(q) = self.queue.pop_front() {
            let info = self.start_execution(
                q.request_id,
                q.function,
                q.mem_mb,
                now,
                now - q.queued_at,
                None,
            );
            started = Some(info);
        }
        // If the sandbox we just idled got reused by the queued start, no
        // expiry should be scheduled for it.
        let still_idle = self.sandbox(sandbox).map(|s| s.is_idle()).unwrap_or(false);
        let expiry = if still_idle { Some((sandbox, epoch)) } else { None };
        (expiry, started)
    }

    // ---- elastic mode (OpenLambda-like, no admission queue) --------------
    //
    // The paper's OpenLambda workers do not bound concurrent executions at
    // the vCPU count: every arriving request gets a sandbox immediately and
    // the vCPUs are time-shared (the simulator models the slowdown with a
    // congestion multiplier). Memory pressure only ever reclaims *idle*
    // sandboxes; the busy set may transiently exceed the pool (admission
    // control is out of scope, as in OpenLambda).

    /// Elastic assignment: always starts an execution immediately.
    pub fn assign_elastic(
        &mut self,
        request_id: u64,
        f: FunctionId,
        mem_mb: u64,
        now: f64,
    ) -> StartInfo {
        self.running += 1;

        if let Some(idx) = self
            .sandboxes
            .iter()
            .enumerate()
            .filter(|(_, s)| s.function == f && s.is_idle())
            .max_by(|(_, a), (_, b)| a.idle_since.partial_cmp(&b.idle_since).unwrap())
            .map(|(i, _)| i)
        {
            let (sandbox, was_prewarmed) = {
                let sb = &mut self.sandboxes[idx];
                let ok = sb.start_execution();
                debug_assert!(ok);
                (sb.id, std::mem::replace(&mut sb.prewarmed, false))
            };
            if was_prewarmed {
                self.total_prewarm_hits += 1;
            }
            self.total_warm += 1;
            self.note_warm_down(f);
            return StartInfo {
                sandbox,
                cold: false,
                evicted: Vec::new(),
                request_id,
                queue_delay_s: 0.0,
                slot: None,
            };
        }

        // Cold: reclaim idle LRU sandboxes while over capacity; busy
        // overflow is tolerated.
        let evicted = self.trim_idle_lru(mem_mb);
        let id = self.next_sandbox_id;
        self.next_sandbox_id += 1;
        let mut sb = Sandbox::new(id, f, mem_mb, now);
        let ok = sb.start_execution();
        debug_assert!(ok);
        self.mem_used_mb += mem_mb;
        self.sandboxes.push(sb);
        self.total_cold += 1;
        StartInfo { sandbox: id, cold: true, evicted, request_id, queue_delay_s: 0.0, slot: None }
    }

    /// Evict idle LRU sandboxes while admitting `incoming_mb` would exceed
    /// the pool; stops when no idle sandbox remains.
    fn trim_idle_lru(&mut self, incoming_mb: u64) -> Vec<FunctionId> {
        let mut evicted = Vec::new();
        while self.mem_used_mb + incoming_mb > self.mem_capacity_mb {
            let victim = self
                .sandboxes
                .iter()
                .enumerate()
                .filter(|(_, s)| s.is_idle())
                .min_by(|(_, a), (_, b)| a.idle_since.partial_cmp(&b.idle_since).unwrap())
                .map(|(i, _)| i);
            match victim {
                Some(i) => {
                    let sb = self.sandboxes.swap_remove(i);
                    self.mem_used_mb -= sb.mem_mb;
                    self.total_evictions_pressure += 1;
                    self.note_warm_down(sb.function);
                    evicted.push(sb.function);
                }
                None => break, // only busy sandboxes left: overflow
            }
        }
        evicted
    }

    /// Elastic completion: the sandbox idles, then the idle pool is trimmed
    /// back under the capacity (the just-idled sandbox is MRU, so it is
    /// reclaimed last). Returns (keep-alive handle if the sandbox survived,
    /// evicted function types).
    pub fn complete_elastic(
        &mut self,
        sandbox: SandboxId,
        now: f64,
    ) -> (Option<(SandboxId, u64)>, Vec<FunctionId>) {
        let sb = self.sandbox_mut(sandbox).expect("completing unknown sandbox");
        let f_done = sb.function;
        let epoch = sb.finish_execution(now).expect("completing non-busy sandbox");
        debug_assert!(self.running > 0);
        self.running -= 1;
        self.note_warm_up(f_done);
        let evicted = self.trim_idle_lru(0);
        let survived = self.sandbox(sandbox).map(|s| s.is_idle()).unwrap_or(false);
        let expiry = if survived { Some((sandbox, epoch)) } else { None };
        (expiry, evicted)
    }

    /// Speculatively create an Initializing sandbox for `f` (predictive
    /// pre-warming, cf. Kim & Roh [24]). Never evicts for speculation:
    /// returns None when the pool cannot fit the instance as-is.
    pub fn prewarm(&mut self, f: FunctionId, mem_mb: u64, now: f64) -> Option<SandboxId> {
        if self.mem_used_mb + mem_mb > self.mem_capacity_mb {
            return None;
        }
        let id = self.next_sandbox_id;
        self.next_sandbox_id += 1;
        self.mem_used_mb += mem_mb;
        let mut sb = Sandbox::new(id, f, mem_mb, now);
        sb.prewarmed = true;
        self.sandboxes.push(sb);
        self.total_prewarm_spawned += 1;
        self.note_warm_up(f);
        Some(id)
    }

    /// Pre-warm initialization finished: the sandbox becomes idle and can
    /// serve warm starts. Returns (function, epoch) for advertisement.
    pub fn finish_prewarm(&mut self, sandbox: SandboxId, now: f64) -> Option<(FunctionId, u64)> {
        let sb = self.sandbox_mut(sandbox)?;
        let f = sb.function;
        let epoch = sb.finish_init(now)?;
        Some((f, epoch))
    }

    /// Sandboxes of `f` currently initializing (pre-warm in flight).
    pub fn initializing_count(&self, f: FunctionId) -> usize {
        use super::sandbox::SandboxState;
        self.sandboxes
            .iter()
            .filter(|s| s.function == f && s.state == SandboxState::Initializing)
            .count()
    }

    /// Warm supply per function in one pass: counts idle *and* initializing
    /// sandboxes into `out[f]` (the autoscale observation; avoids the
    /// O(functions x sandboxes) cost of per-function queries).
    pub fn warm_counts_into(&self, out: &mut [usize]) {
        use super::sandbox::SandboxState;
        for s in &self.sandboxes {
            if s.state != SandboxState::Busy && s.function < out.len() {
                out[s.function] += 1;
            }
        }
    }

    /// Keep-alive sweep: evict every sandbox that has been idle since
    /// `cutoff` or earlier. The simulator calls this on a periodic tick
    /// (O(1) events per simulated second) instead of scheduling one expiry
    /// event per idle period — same semantics to within the sweep interval.
    pub fn sweep_keepalive(&mut self, cutoff: f64) -> Vec<FunctionId> {
        let mut evicted = Vec::new();
        let mut i = 0;
        while i < self.sandboxes.len() {
            if self.sandboxes[i].is_idle() && self.sandboxes[i].idle_since <= cutoff {
                let sb = self.sandboxes.swap_remove(i);
                self.mem_used_mb -= sb.mem_mb;
                self.total_evictions_keepalive += 1;
                self.note_warm_down(sb.function);
                evicted.push(sb.function);
            } else {
                i += 1;
            }
        }
        evicted
    }

    /// Drain: evict every idle sandbox (scale-down). Busy sandboxes finish
    /// normally; the router stops selecting this worker.
    pub fn drain_idle(&mut self) -> Vec<FunctionId> {
        let mut evicted = Vec::new();
        let mut i = 0;
        while i < self.sandboxes.len() {
            if self.sandboxes[i].is_idle() {
                let sb = self.sandboxes.swap_remove(i);
                self.mem_used_mb -= sb.mem_mb;
                self.total_evictions_pressure += 1;
                self.note_warm_down(sb.function);
                evicted.push(sb.function);
            } else {
                i += 1;
            }
        }
        evicted
    }

    /// Fault injection: the worker crashes. Every sandbox is destroyed
    /// regardless of state (busy executions die with it), the admission
    /// queue is dropped, and memory/slot accounting zeroes out. Returns
    /// the queued requests that were lost (the router re-enqueues them
    /// with the in-flight ones) and the `(function, idle_since)` pairs of
    /// the idle sandboxes that died — the router's warm bank uses these
    /// for warm-state handoff within the keep-alive window (DESIGN.md
    /// §10). `next_sandbox_id` is deliberately *not* reset: sandbox ids
    /// never recycle within a worker, which is what lets the engine drop
    /// stale `Completion` events from before the crash.
    pub fn crash(&mut self) -> (Vec<QueuedRequest>, Vec<(FunctionId, f64)>) {
        let mut warm = Vec::new();
        for sb in std::mem::take(&mut self.sandboxes) {
            if sb.is_idle() {
                warm.push((sb.function, sb.idle_since));
                self.note_warm_down(sb.function);
            } else if sb.state == super::sandbox::SandboxState::Initializing {
                self.note_warm_down(sb.function);
            }
        }
        self.mem_used_mb = 0;
        self.running = 0;
        // Slot state dies with the node: busy slots free, and the
        // warm-affinity memory is wiped (a replacement node shares nothing
        // with its predecessor's cores).
        self.slot_busy.iter_mut().for_each(|b| *b = false);
        self.slot_fn.iter_mut().for_each(|f| *f = usize::MAX);
        self.sandbox_slot.clear();
        let queued = std::mem::take(&mut self.queue).into_iter().collect();
        (queued, warm)
    }

    /// Remove a specific request from the admission queue (push-mode
    /// rebind, DESIGN.md §11), preserving FIFO order of the rest. Returns
    /// the queued record so the caller can re-place it elsewhere.
    pub fn remove_queued(&mut self, request_id: u64) -> Option<QueuedRequest> {
        let pos = self.queue.iter().position(|q| q.request_id == request_id)?;
        self.queue.remove(pos)
    }

    /// Keep-alive expiry for (sandbox, epoch) fires at `_now`. Evicts only
    /// if the sandbox is still idle in the same epoch (otherwise the event
    /// is stale — the sandbox was reused or already evicted). Returns the
    /// evicted function type if the eviction happened.
    pub fn expire_keepalive(&mut self, sandbox: SandboxId, epoch: u64) -> Option<FunctionId> {
        let idx = self.sandboxes.iter().position(|s| s.id == sandbox)?;
        let sb = &self.sandboxes[idx];
        if !sb.is_idle() || sb.epoch != epoch {
            return None;
        }
        let sb = self.sandboxes.swap_remove(idx);
        self.mem_used_mb -= sb.mem_mb;
        self.total_evictions_keepalive += 1;
        self.note_warm_down(sb.function);
        Some(sb.function)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn worker() -> Worker {
        Worker::new(0, 1024, 2)
    }

    #[test]
    fn cold_then_warm() {
        let mut w = worker();
        let out = w.assign(1, 7, 256, 0.0);
        let info = match out {
            AssignOutcome::Started(i) => i,
            _ => panic!("expected start"),
        };
        assert!(info.cold);
        assert_eq!(w.running(), 1);
        let (expiry, started) = w.complete(info.sandbox, 1.0);
        assert!(expiry.is_some());
        assert!(started.is_none());
        assert_eq!(w.running(), 0);
        // Same function again: warm.
        match w.assign(2, 7, 256, 2.0) {
            AssignOutcome::Started(i) => {
                assert!(!i.cold);
                assert_eq!(i.sandbox, info.sandbox);
            }
            _ => panic!("expected warm start"),
        }
        assert_eq!(w.total_cold, 1);
        assert_eq!(w.total_warm, 1);
    }

    #[test]
    fn different_function_is_cold() {
        let mut w = worker();
        let i1 = match w.assign(1, 1, 256, 0.0) {
            AssignOutcome::Started(i) => i,
            _ => panic!(),
        };
        w.complete(i1.sandbox, 0.5);
        match w.assign(2, 2, 256, 1.0) {
            AssignOutcome::Started(i) => assert!(i.cold, "different type must cold-start"),
            _ => panic!(),
        }
    }

    #[test]
    fn concurrency_limit_queues() {
        let mut w = worker();
        assert!(matches!(w.assign(1, 1, 256, 0.0), AssignOutcome::Started(_)));
        assert!(matches!(w.assign(2, 2, 256, 0.0), AssignOutcome::Started(_)));
        assert!(matches!(w.assign(3, 3, 256, 0.0), AssignOutcome::Queued));
        assert_eq!(w.queue_len(), 1);
        assert_eq!(w.load(), 3);
    }

    #[test]
    fn queued_request_starts_on_completion() {
        let mut w = worker();
        let i1 = match w.assign(1, 1, 256, 0.0) {
            AssignOutcome::Started(i) => i,
            _ => panic!(),
        };
        let _i2 = w.assign(2, 2, 256, 0.0);
        assert!(matches!(w.assign(3, 1, 256, 0.0), AssignOutcome::Queued));
        let (_, started) = w.complete(i1.sandbox, 2.0);
        let s = started.expect("queued request must start");
        assert_eq!(s.request_id, 3);
        assert!(!s.cold, "queued request for same type reuses the idled sandbox");
        assert!((s.queue_delay_s - 2.0).abs() < 1e-12);
        assert_eq!(w.queue_len(), 0);
    }

    #[test]
    fn memory_pressure_evicts_lru() {
        let mut w = Worker::new(0, 768, 2); // fits 3 x 256
        // Create three idle sandboxes for functions 1, 2, 3.
        for (rid, f) in [(1u64, 1usize), (2, 2), (3, 3)] {
            let i = match w.assign(rid, f, 256, rid as f64) {
                AssignOutcome::Started(i) => i,
                _ => panic!(),
            };
            w.complete(i.sandbox, rid as f64 + 0.25);
        }
        assert_eq!(w.num_sandboxes(), 3);
        assert_eq!(w.mem_free_mb(), 0);
        // A 4th type must evict the least-recently-idle (function 1).
        match w.assign(4, 4, 256, 10.0) {
            AssignOutcome::Started(i) => {
                assert!(i.cold);
                assert_eq!(i.evicted, vec![1]);
            }
            _ => panic!(),
        }
        assert!(!w.has_idle(1));
        assert!(w.has_idle(2) && w.has_idle(3));
        assert_eq!(w.total_evictions_pressure, 1);
    }

    #[test]
    fn keepalive_expiry_and_stale_epochs() {
        let mut w = worker();
        let i = match w.assign(1, 5, 256, 0.0) {
            AssignOutcome::Started(i) => i,
            _ => panic!(),
        };
        let (expiry, _) = w.complete(i.sandbox, 1.0);
        let (sb, epoch) = expiry.unwrap();
        // Reuse before expiry: stale event must be ignored.
        let i2 = match w.assign(2, 5, 256, 2.0) {
            AssignOutcome::Started(i) => i,
            _ => panic!(),
        };
        assert_eq!(i2.sandbox, sb);
        assert_eq!(w.expire_keepalive(sb, epoch), None, "stale expiry must not fire");
        let (expiry2, _) = w.complete(i2.sandbox, 3.0);
        let (sb2, epoch2) = expiry2.unwrap();
        assert_eq!(w.expire_keepalive(sb2, epoch2), Some(5));
        assert_eq!(w.num_sandboxes(), 0);
        assert_eq!(w.mem_used_mb, 0);
        assert_eq!(w.total_evictions_keepalive, 1);
    }

    #[test]
    #[should_panic(expected = "cannot ever fit")]
    fn oversized_function_rejected() {
        let mut w = Worker::new(0, 256, 2);
        w.assign(1, 1, 256, 0.0); // 2 slots x 256 MB > 256 MB capacity
    }

    // ---- elastic mode ----------------------------------------------------

    #[test]
    fn elastic_never_queues() {
        let mut w = Worker::new(0, 1024, 2);
        for rid in 0..6 {
            let info = w.assign_elastic(rid, rid as usize, 128, 0.0);
            assert!(info.cold);
        }
        assert_eq!(w.running(), 6, "elastic mode admits beyond concurrency");
        assert_eq!(w.queue_len(), 0);
    }

    #[test]
    fn elastic_busy_overflow_then_trim() {
        let mut w = Worker::new(0, 512, 2);
        // 3 busy x 256 MB = 768 > 512: overflow tolerated while busy.
        let infos: Vec<_> = (0..3).map(|rid| w.assign_elastic(rid, rid as usize, 256, 0.0)).collect();
        assert!(w.mem_used_mb > w.mem_capacity_mb);
        // While the busy set alone exceeds the pool, completions reclaim
        // the just-idled sandbox immediately (nothing can be kept warm).
        let (expiry, ev1) = w.complete_elastic(infos[0].sandbox, 1.0);
        assert_eq!(ev1, vec![0], "idled sandbox reclaimed under busy overflow");
        assert!(expiry.is_none(), "reclaimed sandbox must not be advertised");
        // 2 busy x 256 = 512 = cap: the next completion can keep its idle.
        let (expiry2, ev2) = w.complete_elastic(infos[1].sandbox, 2.0);
        assert!(ev2.is_empty());
        assert!(expiry2.is_some(), "sandbox fits now and is advertised");
        assert!(w.mem_used_mb <= w.mem_capacity_mb);
    }

    #[test]
    fn sweep_keepalive_evicts_by_cutoff() {
        let mut w = Worker::new(0, 1024, 4);
        let a = w.assign_elastic(1, 1, 128, 0.0);
        let b = w.assign_elastic(2, 2, 128, 0.0);
        w.complete_elastic(a.sandbox, 1.0);
        w.complete_elastic(b.sandbox, 5.0);
        let evicted = w.sweep_keepalive(2.0); // cutoff: idle_since <= 2.0
        assert_eq!(evicted, vec![1]);
        assert!(w.has_idle(2));
        assert_eq!(w.total_evictions_keepalive, 1);
    }

    #[test]
    fn prewarm_lifecycle() {
        let mut w = Worker::new(0, 512, 4);
        let sb = w.prewarm(9, 256, 0.0).expect("fits");
        assert_eq!(w.initializing_count(9), 1);
        assert!(!w.has_idle(9), "initializing sandbox is not yet warm");
        // No eviction for speculation: a second 256 MB prewarm over
        // capacity is refused (256 used + 256 = 512 cap; third denied).
        assert!(w.prewarm(8, 256, 0.0).is_some());
        assert!(w.prewarm(7, 256, 0.0).is_none());
        let (f, _epoch) = w.finish_prewarm(sb, 1.0).unwrap();
        assert_eq!(f, 9);
        assert!(w.has_idle(9));
        // The pre-warmed instance serves a warm start and counts as a hit.
        let info = w.assign_elastic(1, 9, 256, 2.0);
        assert!(!info.cold);
        assert_eq!(info.sandbox, sb);
        assert_eq!(w.total_prewarm_spawned, 2);
        assert_eq!(w.total_prewarm_hits, 1);
        // Reusing the same sandbox again is NOT a second speculation hit.
        w.complete_elastic(info.sandbox, 3.0);
        let again = w.assign_elastic(2, 9, 256, 4.0);
        assert!(!again.cold);
        assert_eq!(w.total_prewarm_hits, 1, "hit counted at most once per speculation");
    }

    #[test]
    fn warm_counts_single_pass() {
        let mut w = Worker::new(0, 1024, 4);
        let a = w.assign_elastic(1, 1, 128, 0.0);
        let _b = w.assign_elastic(2, 2, 128, 0.0); // stays busy
        w.complete_elastic(a.sandbox, 1.0); // idle f=1
        w.prewarm(1, 128, 1.5); // initializing f=1
        let mut counts = vec![0usize; 3];
        w.warm_counts_into(&mut counts);
        assert_eq!(counts, vec![0, 2, 0], "idle + initializing counted, busy excluded");
    }

    /// Property: the incremental per-function warm counters always equal a
    /// fresh recount of sandbox states, across random op sequences touching
    /// every transition (assign, complete, prewarm, finish, sweep).
    #[test]
    fn prop_warm_by_fn_matches_recount() {
        use crate::prop_assert;
        use crate::util::prop::{check, PropConfig};
        check("worker-warm-counters", PropConfig { cases: 120, ..Default::default() }, |rng, size| {
            let nf = 4;
            let mut w = Worker::new(0, 2048, 2);
            let mut busy: Vec<SandboxId> = Vec::new();
            let mut initializing: Vec<SandboxId> = Vec::new();
            let mut rid = 0u64;
            let mut t = 0.0;
            for _ in 0..size * 3 {
                t += 0.25;
                match rng.index(5) {
                    0 | 1 => {
                        let f = rng.index(nf);
                        let info = w.assign_elastic(rid, f, 256, t);
                        busy.push(info.sandbox);
                        rid += 1;
                    }
                    2 => {
                        if !busy.is_empty() {
                            let i = rng.index(busy.len());
                            let sb = busy.swap_remove(i);
                            w.complete_elastic(sb, t);
                        }
                    }
                    3 => {
                        let f = rng.index(nf);
                        if let Some(sb) = w.prewarm(f, 256, t) {
                            initializing.push(sb);
                        }
                    }
                    _ => {
                        if initializing.is_empty() {
                            w.sweep_keepalive(t - 5.0);
                        } else {
                            let i = rng.index(initializing.len());
                            let sb = initializing.swap_remove(i);
                            w.finish_prewarm(sb, t);
                        }
                    }
                }
                w.warm_deltas.clear(); // the journal is the cluster's concern
                let mut recount = vec![0usize; nf];
                w.warm_counts_into(&mut recount);
                for (f, &want) in recount.iter().enumerate() {
                    let have = w.warm_by_fn().get(f).copied().unwrap_or(0) as usize;
                    prop_assert!(have == want, "f={}: counter {} != recount {}", f, have, want);
                }
            }
            Ok(())
        });
    }

    #[test]
    fn crash_destroys_everything_but_keeps_id_monotonic() {
        let mut w = Worker::new(0, 1024, 1);
        let a = w.assign_elastic(1, 1, 128, 0.0);
        w.complete_elastic(a.sandbox, 1.0); // idle f=1
        let b = w.assign_elastic(2, 2, 128, 2.0); // busy f=2
        w.prewarm(3, 128, 2.5); // initializing f=3
        assert!(matches!(w.assign(4, 2, 128, 3.0), AssignOutcome::Queued));
        let (queued, warm) = w.crash();
        assert_eq!(queued.len(), 1);
        assert_eq!(queued[0].request_id, 4);
        assert_eq!(warm, vec![(1, 1.0)], "only idle sandboxes carry warm state");
        assert_eq!(w.running(), 0);
        assert_eq!(w.num_sandboxes(), 0);
        assert_eq!(w.mem_used_mb, 0);
        assert_eq!(w.queue_len(), 0);
        // Warm counters hit zero (crash journals the downs).
        let mut recount = vec![0usize; 4];
        w.warm_counts_into(&mut recount);
        assert_eq!(recount, vec![0; 4]);
        for f in 0..4 {
            assert_eq!(w.warm_by_fn().get(f).copied().unwrap_or(0), 0);
        }
        // Sandbox ids never recycle: a post-crash cold start gets a fresh id.
        let c = w.assign_elastic(5, 2, 128, 4.0);
        assert!(c.cold);
        assert!(c.sandbox > b.sandbox, "sandbox ids must stay monotonic across crashes");
    }

    // ---- core slots (DESIGN.md §11) --------------------------------------

    #[test]
    fn cores_switch_capacity_and_track_slots() {
        let mut w = Worker::new(0, 2048, 1).with_cores(3);
        assert_eq!(w.cap(), 3, "cores > 1 overrides concurrency as capacity");
        assert_eq!(w.free_slots(), 3);
        let i1 = match w.assign(1, 7, 256, 0.0) {
            AssignOutcome::Started(i) => i,
            _ => panic!(),
        };
        assert_eq!(i1.slot, Some(0), "first start takes the lowest free slot");
        let i2 = match w.assign(2, 8, 256, 0.0) {
            AssignOutcome::Started(i) => i,
            _ => panic!(),
        };
        assert_eq!(i2.slot, Some(1));
        assert_eq!(w.free_slots(), 1);
        // Completion frees the slot but keeps the affinity memory.
        w.complete(i1.sandbox, 1.0);
        assert_eq!(w.free_slots(), 2);
        assert_eq!(w.warm_free_slot(7), Some(0));
        assert_eq!(w.warm_free_slot(9), None);
        // Same function returns to "its" core even though slot 2 is free too.
        let i3 = match w.assign(3, 7, 256, 2.0) {
            AssignOutcome::Started(i) => i,
            _ => panic!(),
        };
        assert_eq!(i3.slot, Some(0), "warm-affine slot wins over lowest free index");
        assert!(!i3.cold);
    }

    #[test]
    fn preferred_slot_honored_and_falls_back_when_busy() {
        let mut w = Worker::new(0, 2048, 1).with_cores(4);
        let i1 = match w.assign_with_slot(1, 5, 256, 0.0, Some(2)) {
            AssignOutcome::Started(i) => i,
            _ => panic!(),
        };
        assert_eq!(i1.slot, Some(2), "free preferred slot is honored");
        let i2 = match w.assign_with_slot(2, 6, 256, 0.0, Some(2)) {
            AssignOutcome::Started(i) => i,
            _ => panic!(),
        };
        assert_eq!(i2.slot, Some(0), "busy preference falls back to lowest free index");
        let (busy, fns) = w.slot_state();
        assert_eq!(busy, &[true, false, true, false]);
        assert_eq!(fns[2], 5);
        assert_eq!(fns[0], 6);
    }

    #[test]
    fn slot_capacity_queues_and_queued_start_takes_freed_slot() {
        let mut w = Worker::new(0, 2048, 8).with_cores(2);
        let i1 = match w.assign(1, 1, 256, 0.0) {
            AssignOutcome::Started(i) => i,
            _ => panic!(),
        };
        assert!(matches!(w.assign(2, 2, 256, 0.0), AssignOutcome::Started(_)));
        // Concurrency is 8 but cores = 2: third request queues.
        assert!(matches!(w.assign(3, 3, 256, 0.0), AssignOutcome::Queued));
        let (_, started) = w.complete(i1.sandbox, 1.0);
        let s = started.expect("queued request binds to the freed slot");
        assert_eq!(s.slot, Some(0));
        assert_eq!(w.free_slots(), 0);
    }

    #[test]
    fn crash_wipes_slot_state() {
        let mut w = Worker::new(0, 2048, 1).with_cores(2);
        let i1 = match w.assign(1, 4, 256, 0.0) {
            AssignOutcome::Started(i) => i,
            _ => panic!(),
        };
        w.complete(i1.sandbox, 1.0); // slot 0 free, affinity f=4
        w.assign(2, 5, 256, 2.0); // no warm match for 5: lowest free index = slot 0
        w.crash();
        assert_eq!(w.free_slots(), 2);
        let (busy, fns) = w.slot_state();
        assert!(busy.iter().all(|&b| !b));
        assert!(fns.iter().all(|&f| f == usize::MAX), "affinity memory dies with the node");
        assert_eq!(w.warm_free_slot(4), None);
    }

    #[test]
    fn remove_queued_preserves_order() {
        let mut w = Worker::new(0, 2048, 1).with_cores(1);
        assert!(matches!(w.assign(1, 1, 256, 0.0), AssignOutcome::Started(_)));
        for rid in 2..=4 {
            assert!(matches!(w.assign(rid, 1, 256, 0.0), AssignOutcome::Queued));
        }
        let q = w.remove_queued(3).expect("rid 3 is queued");
        assert_eq!(q.request_id, 3);
        assert_eq!(w.remove_queued(3), None, "second removal finds nothing");
        assert_eq!(w.queue_len(), 2);
        // Remaining FIFO order intact: 2 then 4.
        let q2 = w.remove_queued(2).unwrap();
        let q4 = w.remove_queued(4).unwrap();
        assert_eq!((q2.request_id, q4.request_id), (2, 4));
    }

    #[test]
    fn drain_idle_reclaims_everything_idle() {
        let mut w = Worker::new(0, 1024, 4);
        let a = w.assign_elastic(1, 1, 128, 0.0);
        let b = w.assign_elastic(2, 2, 128, 0.0);
        w.complete_elastic(a.sandbox, 1.0);
        // b stays busy.
        let mut evicted = w.drain_idle();
        evicted.sort_unstable();
        assert_eq!(evicted, vec![1]);
        assert_eq!(w.running(), 1);
        assert_eq!(w.num_sandboxes(), 1);
    }
}
