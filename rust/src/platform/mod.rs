//! FaaS platform substrate: workers, sandboxes, memory pools, eviction.
//!
//! This is the OpenLambda-equivalent the paper runs on (see Fig 1/Fig 2 of
//! the paper and DESIGN.md §2 for the substitution argument).

pub mod cluster;
pub mod sandbox;
pub mod worker;

pub use cluster::{BatchCompletion, Cluster, ClusterTotals};
pub use sandbox::{Sandbox, SandboxId, SandboxState};
pub use worker::{AssignOutcome, EvictReason, QueuedRequest, StartInfo, Worker, WorkerId};
