//! Cluster: the set of workers plus cluster-wide inspection helpers that
//! the schedulers consume (load vectors, idle-instance views) — and, since
//! the event-core overhaul, the *incrementally maintained aggregates* that
//! replace the engine's per-tick full-cluster scans:
//!
//! - per-function warm supply (idle + initializing sandboxes over the
//!   active worker set) — the autoscale observation and the pre-warm
//!   heuristic's supply term, read in O(functions) instead of
//!   O(workers × functions);
//! - total running / total queued over the active set — O(1) reads;
//! - a bucketed min-load index over worker loads — `spawn_prewarm`'s
//!   least-loaded-fitting placement in O(tie set) instead of O(workers).
//!
//! ## Invariants
//!
//! The aggregates stay exact only if every worker mutation goes through
//! the `Cluster` wrapper methods ([`Cluster::assign`],
//! [`Cluster::complete`], [`Cluster::sweep_keepalive`], …), which snapshot
//! running/queued around the call and drain the worker's warm-delta
//! journal into the aggregate. `worker_mut` remains public for tests and
//! read-modify experiments, but simulator code must not mutate workers
//! through it. Workers are active in the LIFO prefix `0..active`;
//! [`Cluster::set_active`] moves boundary workers' contributions in and
//! out of every aggregate, so drained workers (finishing in-flight work)
//! are excluded exactly as the seed's `0..active_workers` scans excluded
//! them. `tests/determinism.rs` pins the equivalence run-for-run.

use super::worker::{AssignOutcome, StartInfo, Worker, WorkerId};
use crate::config::ClusterConfig;
use crate::platform::sandbox::SandboxId;
use crate::util::loadidx::{LoadSummary, MinLoadIndex};
use crate::workload::spec::FunctionId;

/// Per-completion result from [`Cluster::complete_batch`]: the union of
/// what [`Cluster::complete`] (queue mode) and [`Cluster::complete_elastic`]
/// report, so batched and one-at-a-time dispatch share a post-processing
/// path.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct BatchCompletion {
    /// Keep-alive handle `(sandbox, epoch)` if the sandbox idled and
    /// survived — the caller advertises it to the scheduler.
    pub expiry: Option<(SandboxId, u64)>,
    /// Queue mode: a queued request that started on the freed slot.
    pub started: Option<StartInfo>,
    /// Elastic mode: function types whose idle sandboxes were reclaimed
    /// while trimming the pool back to capacity.
    pub evicted: Vec<FunctionId>,
}

/// The worker set plus incrementally maintained cluster-wide aggregates.
/// See the module docs for the invariants.
#[derive(Clone, Debug)]
pub struct Cluster {
    /// The worker nodes, indexed by [`WorkerId`].
    pub workers: Vec<Worker>,
    /// Workers `0..active` are eligible for selection; the suffix is
    /// draining (scale-down is LIFO).
    active: usize,
    /// Bucketed min-load index over `worker.load()` (running + queued).
    load_index: MinLoadIndex,
    /// Executions running across active workers.
    agg_running: usize,
    /// Requests queued at active workers.
    agg_queued: usize,
    /// Non-busy (idle + initializing) sandboxes per function across active
    /// workers. i64 so transient delta application can never underflow.
    warm_agg: Vec<i64>,
    /// Core slots per worker (1 = legacy slot-agnostic mode); every worker
    /// in the cluster shares the same value.
    cores: usize,
    /// Free execution slots across active workers, maintained
    /// incrementally in `sync_after` / `set_active` (per-worker
    /// `cap().saturating_sub(running)`, summed).
    agg_free_slots: usize,
}

impl Cluster {
    /// A cluster of `cfg.workers` identical workers, all active.
    pub fn new(cfg: &ClusterConfig) -> Self {
        Self::new_with_cores(cfg, 1)
    }

    /// A cluster whose workers each expose `cores` explicit core slots
    /// (DESIGN.md §11). `cores = 1` is [`Cluster::new`] exactly.
    pub fn new_with_cores(cfg: &ClusterConfig, cores: usize) -> Self {
        let cores = cores.max(1);
        let workers: Vec<Worker> = (0..cfg.workers)
            .map(|id| Worker::new(id, cfg.mem_mb, cfg.concurrency).with_cores(cores))
            .collect();
        let agg_free_slots = workers.iter().map(|w| w.free_slots()).sum();
        Self {
            workers,
            active: cfg.workers,
            load_index: MinLoadIndex::new(cfg.workers),
            agg_running: 0,
            agg_queued: 0,
            warm_agg: Vec::new(),
            cores,
            agg_free_slots,
        }
    }

    /// Total workers, active and draining.
    pub fn len(&self) -> usize {
        self.workers.len()
    }

    /// True when the cluster holds no workers at all.
    pub fn is_empty(&self) -> bool {
        self.workers.is_empty()
    }

    /// Borrow a worker for inspection.
    pub fn worker(&self, id: WorkerId) -> &Worker {
        &self.workers[id]
    }

    /// Raw mutable access. Mutating a worker through this bypasses the
    /// incremental aggregates — simulator code must use the wrappers below.
    pub fn worker_mut(&mut self, id: WorkerId) -> &mut Worker {
        &mut self.workers[id]
    }

    /// Per-worker load snapshot (running + queued).
    pub fn loads(&self) -> Vec<usize> {
        self.workers.iter().map(|w| w.load()).collect()
    }

    /// Workers that currently hold an idle sandbox for `f`.
    pub fn workers_with_idle(&self, f: FunctionId) -> Vec<WorkerId> {
        self.workers.iter().filter(|w| w.has_idle(f)).map(|w| w.id).collect()
    }

    /// Aggregate cold/warm/eviction counters across workers.
    pub fn totals(&self) -> ClusterTotals {
        let mut t = ClusterTotals::default();
        for w in &self.workers {
            t.cold += w.total_cold;
            t.warm += w.total_warm;
            t.evictions_pressure += w.total_evictions_pressure;
            t.evictions_keepalive += w.total_evictions_keepalive;
            t.prewarm_spawned += w.total_prewarm_spawned;
            t.prewarm_hits += w.total_prewarm_hits;
        }
        t
    }

    // ---- incremental aggregates (active worker set) ------------------------

    /// Workers currently eligible for selection.
    pub fn active_workers(&self) -> usize {
        self.active
    }

    /// Executions running across active workers (O(1)).
    pub fn total_running(&self) -> usize {
        self.agg_running
    }

    /// Requests queued at active workers (O(1)).
    pub fn total_queued(&self) -> usize {
        self.agg_queued
    }

    /// Warm supply (idle + initializing) for `f` across active workers.
    pub fn warm_nonbusy(&self, f: FunctionId) -> usize {
        self.warm_agg.get(f).map(|&v| v.max(0) as usize).unwrap_or(0)
    }

    /// Fill `out[f]` with the warm supply per function (O(functions)).
    pub fn warm_supply_into(&self, out: &mut [usize]) {
        for (f, o) in out.iter_mut().enumerate() {
            *o = self.warm_nonbusy(f);
        }
    }

    /// Least-loaded active worker with at least `mem_mb` free, lowest id
    /// among ties — identical to
    /// `(0..active).filter(fit).min_by_key(load)` but O(tie set).
    pub fn least_loaded_fitting(&self, mem_mb: u64) -> Option<WorkerId> {
        self.load_index.least_loaded_where(|w| self.workers[w].mem_free_mb() >= mem_mb)
    }

    /// O(1) digest of the active workers' load state — the shard barrier
    /// payload ([`LoadSummary`] merges across disjoint worker sets). The
    /// index tracks loads, not slots, so the free-slot field is stamped
    /// here from the cluster's incremental aggregate.
    pub fn load_summary(&self) -> LoadSummary {
        let mut s = self.load_index.summary();
        s.free_slots = self.agg_free_slots as u64;
        s
    }

    /// Core slots per worker (1 = legacy slot-agnostic mode).
    pub fn cores(&self) -> usize {
        self.cores
    }

    /// Free execution slots across active workers (O(1)).
    pub fn total_free_slots(&self) -> usize {
        self.agg_free_slots
    }

    /// Free execution slots on worker `w` right now.
    pub fn worker_free_slots(&self, w: WorkerId) -> usize {
        self.workers[w].free_slots()
    }

    /// Lowest-index free slot on `w` warm-affine to `f` (`None` at
    /// `cores = 1` or when no such slot is free).
    pub fn warm_free_slot(&self, w: WorkerId, f: FunctionId) -> Option<u32> {
        self.workers[w].warm_free_slot(f)
    }

    /// Append a new (inactive) worker; activate it with `set_active`.
    pub fn push_worker(&mut self, mem_mb: u64, concurrency: usize) -> WorkerId {
        let id = self.workers.len();
        self.workers.push(Worker::new(id, mem_mb, concurrency).with_cores(self.cores));
        self.load_index.add_worker();
        id
    }

    /// Grow or shrink the active prefix, moving boundary workers'
    /// contributions (running, queued, warm counts, load-index membership)
    /// in or out of the aggregates. `n = 0` parks the whole cluster
    /// (scale-to-zero; the engine only drains that far under pull
    /// dispatch, where arrivals park in the pending queue).
    pub fn set_active(&mut self, n: usize) {
        assert!(
            n <= self.workers.len(),
            "active {n} out of range 0..={}",
            self.workers.len()
        );
        while self.active < n {
            let w = self.active;
            // Any undrained journal entries are already reflected in the
            // worker's own counters, which we add wholesale below.
            self.workers[w].warm_deltas.clear();
            self.agg_running += self.workers[w].running();
            self.agg_queued += self.workers[w].queue_len();
            self.agg_free_slots += self.workers[w].free_slots();
            self.apply_worker_warm(w, 1);
            self.active += 1;
        }
        while self.active > n {
            let w = self.active - 1;
            self.workers[w].warm_deltas.clear();
            self.agg_running -= self.workers[w].running();
            self.agg_queued -= self.workers[w].queue_len();
            self.agg_free_slots -= self.workers[w].free_slots();
            self.apply_worker_warm(w, -1);
            self.active -= 1;
        }
        self.load_index.set_active(n);
    }

    /// Add (`sign`=1) or remove (`sign`=-1) worker `w`'s warm counts.
    fn apply_worker_warm(&mut self, w: WorkerId, sign: i64) {
        // Copy out to keep the borrows disjoint; scale events are rare.
        let counts: Vec<u32> = self.workers[w].warm_by_fn().to_vec();
        if counts.len() > self.warm_agg.len() {
            self.warm_agg.resize(counts.len(), 0);
        }
        for (f, &c) in counts.iter().enumerate() {
            self.warm_agg[f] += sign * c as i64;
            debug_assert!(self.warm_agg[f] >= 0, "warm aggregate underflow f={f}");
        }
    }

    /// Post-op bookkeeping: apply the worker's running/queued delta and
    /// drain its warm-delta journal into the aggregates (discarded when
    /// the worker is drained, exactly as the seed's scans skipped it).
    fn sync_after(&mut self, w: WorkerId, before: (usize, usize)) {
        let (run_before, q_before) = before;
        let (run_after, q_after) = self.snapshot(w);
        self.load_index.set_load(w, (run_after + q_after) as u32);
        let is_active = w < self.active;
        if is_active {
            // Free-slot delta follows the running delta (per-worker
            // saturating form so elastic busy-overflow stays exact).
            let cap = self.workers[w].cap();
            self.agg_free_slots = self.agg_free_slots + cap.saturating_sub(run_after)
                - cap.saturating_sub(run_before);
        }
        let mut deltas = std::mem::take(&mut self.workers[w].warm_deltas);
        if is_active {
            for &(f, d) in &deltas {
                if f >= self.warm_agg.len() {
                    self.warm_agg.resize(f + 1, 0);
                }
                self.warm_agg[f] += d as i64;
                debug_assert!(self.warm_agg[f] >= 0, "warm aggregate underflow f={f}");
            }
            self.agg_running = self.agg_running + run_after - run_before;
            self.agg_queued = self.agg_queued + q_after - q_before;
        }
        deltas.clear();
        self.workers[w].warm_deltas = deltas; // hand the buffer back
    }

    #[inline]
    fn snapshot(&self, w: WorkerId) -> (usize, usize) {
        let wk = &self.workers[w];
        (wk.running(), wk.queue_len())
    }

    // ---- accounted worker operations (the simulator's mutation API) -------

    /// Queue-mode assignment (started or queued), with incremental
    /// aggregate accounting.
    pub fn assign(
        &mut self,
        w: WorkerId,
        request_id: u64,
        f: FunctionId,
        mem_mb: u64,
        now: f64,
    ) -> AssignOutcome {
        let before = self.snapshot(w);
        let out = self.workers[w].assign(request_id, f, mem_mb, now);
        self.sync_after(w, before);
        out
    }

    /// Slot-granular queue-mode assignment: like [`Cluster::assign`] but
    /// forwarding a preferred core slot (best-effort; see
    /// [`crate::platform::worker::Worker::assign_with_slot`]).
    #[allow(clippy::too_many_arguments)]
    pub fn assign_slot(
        &mut self,
        w: WorkerId,
        request_id: u64,
        f: FunctionId,
        mem_mb: u64,
        now: f64,
        preferred_slot: Option<u32>,
    ) -> AssignOutcome {
        let before = self.snapshot(w);
        let out = self.workers[w].assign_with_slot(request_id, f, mem_mb, now, preferred_slot);
        self.sync_after(w, before);
        out
    }

    /// Pull a specific request back out of `w`'s admission queue
    /// (push-mode rebind), with aggregate accounting.
    pub fn remove_queued(
        &mut self,
        w: WorkerId,
        request_id: u64,
    ) -> Option<super::worker::QueuedRequest> {
        let before = self.snapshot(w);
        let out = self.workers[w].remove_queued(request_id);
        self.sync_after(w, before);
        out
    }

    /// Elastic-mode assignment (always starts), with incremental
    /// aggregate accounting.
    pub fn assign_elastic(
        &mut self,
        w: WorkerId,
        request_id: u64,
        f: FunctionId,
        mem_mb: u64,
        now: f64,
    ) -> StartInfo {
        let before = self.snapshot(w);
        let out = self.workers[w].assign_elastic(request_id, f, mem_mb, now);
        self.sync_after(w, before);
        out
    }

    /// Queue-mode completion: the sandbox idles and a queued request may
    /// start. Aggregates updated incrementally.
    pub fn complete(
        &mut self,
        w: WorkerId,
        sandbox: SandboxId,
        now: f64,
    ) -> (Option<(SandboxId, u64)>, Option<StartInfo>) {
        let before = self.snapshot(w);
        let out = self.workers[w].complete(sandbox, now);
        self.sync_after(w, before);
        out
    }

    /// Elastic-mode completion: the sandbox idles, then the idle pool is
    /// trimmed back to capacity. Aggregates updated incrementally.
    pub fn complete_elastic(
        &mut self,
        w: WorkerId,
        sandbox: SandboxId,
        now: f64,
    ) -> (Option<(SandboxId, u64)>, Vec<FunctionId>) {
        let before = self.snapshot(w);
        let out = self.workers[w].complete_elastic(sandbox, now);
        self.sync_after(w, before);
        out
    }

    /// Complete several same-tick executions on one worker with a *single*
    /// aggregate sync (the batch-coalescing optimization, DESIGN.md §6).
    /// The worker-side operations run in the given order, exactly as the
    /// one-at-a-time calls would; only the snapshot/journal/load-index
    /// bookkeeping is amortized across the batch, so the final worker and
    /// aggregate state — and every per-completion result — are identical
    /// to sequential [`Cluster::complete`] / [`Cluster::complete_elastic`]
    /// calls (property-tested in `tests/determinism.rs`).
    pub fn complete_batch(
        &mut self,
        w: WorkerId,
        sandboxes: &[SandboxId],
        elastic: bool,
        now: f64,
    ) -> Vec<BatchCompletion> {
        let before = self.snapshot(w);
        let out = sandboxes
            .iter()
            .map(|&sb| {
                if elastic {
                    let (expiry, evicted) = self.workers[w].complete_elastic(sb, now);
                    BatchCompletion { expiry, started: None, evicted }
                } else {
                    let (expiry, started) = self.workers[w].complete(sb, now);
                    BatchCompletion { expiry, started, evicted: Vec::new() }
                }
            })
            .collect();
        self.sync_after(w, before);
        out
    }

    /// Speculatively create an Initializing sandbox for `f` on `w`
    /// (never evicts; `None` when it does not fit).
    pub fn prewarm(&mut self, w: WorkerId, f: FunctionId, mem_mb: u64, now: f64) -> Option<SandboxId> {
        let before = self.snapshot(w);
        let out = self.workers[w].prewarm(f, mem_mb, now);
        self.sync_after(w, before);
        out
    }

    /// A speculative sandbox finished initializing; it becomes idle.
    pub fn finish_prewarm(
        &mut self,
        w: WorkerId,
        sandbox: SandboxId,
        now: f64,
    ) -> Option<(FunctionId, u64)> {
        let before = self.snapshot(w);
        let out = self.workers[w].finish_prewarm(sandbox, now);
        self.sync_after(w, before);
        out
    }

    /// Evict `w`'s sandboxes idle since `cutoff` or earlier (keep-alive).
    pub fn sweep_keepalive(&mut self, w: WorkerId, cutoff: f64) -> Vec<FunctionId> {
        let before = self.snapshot(w);
        let out = self.workers[w].sweep_keepalive(cutoff);
        self.sync_after(w, before);
        out
    }

    /// Evict every idle sandbox on `w` (scale-down drain).
    pub fn drain_idle(&mut self, w: WorkerId) -> Vec<FunctionId> {
        let before = self.snapshot(w);
        let out = self.workers[w].drain_idle();
        self.sync_after(w, before);
        out
    }

    /// Fault injection: worker `w` crashes — every sandbox (busy
    /// included) is destroyed and the admission queue dropped, with the
    /// aggregates kept exact through the usual snapshot/journal sync.
    /// Returns what [`crate::platform::worker::Worker::crash`] returns:
    /// the lost queued requests and the `(function, idle_since)` warm
    /// state that died (for the router's warm-handoff bank).
    pub fn crash(
        &mut self,
        w: WorkerId,
    ) -> (Vec<super::worker::QueuedRequest>, Vec<(FunctionId, f64)>) {
        let before = self.snapshot(w);
        let out = self.workers[w].crash();
        self.sync_after(w, before);
        out
    }

    /// Precise per-sandbox keep-alive expiry (ignores stale epochs).
    pub fn expire_keepalive(
        &mut self,
        w: WorkerId,
        sandbox: SandboxId,
        epoch: u64,
    ) -> Option<FunctionId> {
        let before = self.snapshot(w);
        let out = self.workers[w].expire_keepalive(sandbox, epoch);
        self.sync_after(w, before);
        out
    }
}

/// Cluster-wide lifetime counters, summed over all workers.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ClusterTotals {
    /// Cold starts.
    pub cold: u64,
    /// Warm starts.
    pub warm: u64,
    /// Evictions under memory pressure (incl. scale-down drains).
    pub evictions_pressure: u64,
    /// Evictions by keep-alive expiry.
    pub evictions_keepalive: u64,
    /// Speculative (pre-warm) sandboxes created.
    pub prewarm_spawned: u64,
    /// Warm starts served by a pre-warmed sandbox's first use.
    pub prewarm_hits: u64,
}

impl ClusterTotals {
    /// Cold starts over all starts (0 when nothing ran).
    pub fn cold_rate(&self) -> f64 {
        let total = self.cold + self.warm;
        if total == 0 {
            0.0
        } else {
            self.cold as f64 / total as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::platform::worker::AssignOutcome;
    use crate::prop_assert;
    use crate::util::prop::{check, PropConfig};

    #[test]
    fn cluster_construction() {
        let c = Cluster::new(&ClusterConfig::default());
        assert_eq!(c.len(), 5);
        assert_eq!(c.loads(), vec![0; 5]);
        assert_eq!(c.active_workers(), 5);
        assert_eq!(c.total_running(), 0);
        assert_eq!(c.total_queued(), 0);
    }

    #[test]
    fn totals_and_idle_views() {
        let mut c = Cluster::new(&ClusterConfig { workers: 2, ..Default::default() });
        let info = match c.assign(0, 1, 3, 256, 0.0) {
            AssignOutcome::Started(i) => i,
            _ => panic!(),
        };
        assert_eq!(c.workers_with_idle(3), Vec::<usize>::new());
        assert_eq!(c.total_running(), 1);
        assert_eq!(c.warm_nonbusy(3), 0);
        c.complete(0, info.sandbox, 1.0);
        assert_eq!(c.workers_with_idle(3), vec![0]);
        assert_eq!(c.total_running(), 0);
        assert_eq!(c.warm_nonbusy(3), 1);
        let t = c.totals();
        assert_eq!(t.cold, 1);
        assert_eq!(t.warm, 0);
        assert!((t.cold_rate() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn set_active_moves_contributions() {
        let mut c = Cluster::new(&ClusterConfig { workers: 3, ..Default::default() });
        // Worker 2 gets one running execution and one idle sandbox.
        let a = c.assign_elastic(2, 1, 7, 256, 0.0);
        c.complete_elastic(2, a.sandbox, 1.0);
        c.assign_elastic(2, 2, 8, 256, 2.0);
        assert_eq!(c.total_running(), 1);
        assert_eq!(c.warm_nonbusy(7), 1);
        // Drain worker 2: its contributions leave the aggregates.
        c.set_active(2);
        assert_eq!(c.active_workers(), 2);
        assert_eq!(c.total_running(), 0);
        assert_eq!(c.warm_nonbusy(7), 0);
        // Its in-flight completion while drained is not counted...
        let (_, _) = c.complete_elastic(2, a.sandbox + 1, 3.0);
        assert_eq!(c.total_running(), 0);
        // ...but re-activation restores the current state exactly.
        c.set_active(3);
        assert_eq!(c.warm_nonbusy(7), 1);
        assert_eq!(c.warm_nonbusy(8), 1);
        assert_eq!(c.total_running(), 0);
    }

    #[test]
    fn least_loaded_fitting_matches_scan() {
        let mut c = Cluster::new(&ClusterConfig { workers: 3, mem_mb: 512, ..Default::default() });
        // Workers 0 and 1 take one execution each (1's fills its memory);
        // worker 2 stays empty and must win as the least-loaded fit.
        c.assign_elastic(0, 1, 1, 128, 0.0);
        c.assign_elastic(1, 2, 2, 512, 0.0);
        assert_eq!(c.least_loaded_fitting(128), Some(2));
        // Among load-1 workers only worker 0 has room for 256 MB.
        c.assign_elastic(2, 3, 3, 128, 0.0);
        assert_eq!(c.least_loaded_fitting(256), Some(0));
        // Nothing fits a huge footprint.
        assert_eq!(c.least_loaded_fitting(4096), None);
    }

    #[test]
    fn crash_keeps_aggregates_exact() {
        let mut c = Cluster::new(&ClusterConfig { workers: 2, ..Default::default() });
        // Worker 0: one idle (f=7), one busy (f=8). Worker 1: one busy.
        let a = c.assign_elastic(0, 1, 7, 256, 0.0);
        c.complete_elastic(0, a.sandbox, 1.0);
        c.assign_elastic(0, 2, 8, 256, 2.0);
        c.assign_elastic(1, 3, 9, 256, 2.0);
        assert_eq!(c.total_running(), 2);
        assert_eq!(c.warm_nonbusy(7), 1);
        let (queued, warm) = c.crash(0);
        assert!(queued.is_empty());
        assert_eq!(warm, vec![(7, 1.0)]);
        // Aggregates match a full rescan: only worker 1's execution left.
        assert_eq!(c.total_running(), 1);
        assert_eq!(c.total_queued(), 0);
        assert_eq!(c.warm_nonbusy(7), 0);
        assert_eq!(c.loads(), vec![0, 1]);
        assert_eq!(c.least_loaded_fitting(256), Some(0));
    }

    #[test]
    fn cores_cluster_tracks_free_slots() {
        let cfg = ClusterConfig { workers: 2, mem_mb: 2048, concurrency: 1, ..Default::default() };
        let mut c = Cluster::new_with_cores(&cfg, 2);
        assert_eq!(c.cores(), 2);
        assert_eq!(c.total_free_slots(), 4);
        assert_eq!(c.load_summary().free_slots, 4);
        let info = match c.assign_slot(1, 1, 3, 256, 0.0, Some(1)) {
            AssignOutcome::Started(i) => i,
            _ => panic!(),
        };
        assert_eq!(info.slot, Some(1));
        assert_eq!(c.total_free_slots(), 3);
        assert_eq!(c.worker_free_slots(1), 1);
        c.complete(1, info.sandbox, 1.0);
        assert_eq!(c.total_free_slots(), 4);
        assert_eq!(c.warm_free_slot(1, 3), Some(1), "affinity survives completion");
        // Drained workers leave the aggregate; pushed workers join on
        // activation with the configured core count.
        c.set_active(1);
        assert_eq!(c.total_free_slots(), 2);
        let id = c.push_worker(2048, 1);
        assert_eq!(c.worker(id).cores(), 2);
        c.set_active(3);
        assert_eq!(c.total_free_slots(), 6);
    }

    /// Property: after arbitrary wrapped-op sequences with scale events,
    /// every aggregate equals the seed's full scan over the active prefix.
    #[test]
    fn prop_aggregates_match_full_scan() {
        check("cluster-aggregates", PropConfig { cases: 100, ..Default::default() }, |rng, size| {
            let workers = 2 + rng.index(4);
            let nf = 5usize;
            let cfg = ClusterConfig { workers, mem_mb: 2048, concurrency: 2, ..Default::default() };
            let mut c = Cluster::new(&cfg);
            let elastic = rng.index(2) == 0;
            let mut busy: Vec<(WorkerId, SandboxId)> = Vec::new();
            let mut t = 0.0;
            for _ in 0..size * 4 {
                t += 0.2;
                match rng.index(6) {
                    0 | 1 => {
                        let w = rng.index(c.len());
                        let f = rng.index(nf);
                        if elastic {
                            let info = c.assign_elastic(w, 0, f, 256, t);
                            busy.push((w, info.sandbox));
                        } else if let AssignOutcome::Started(info) = c.assign(w, 0, f, 256, t) {
                            busy.push((w, info.sandbox));
                        }
                    }
                    2 => {
                        if !busy.is_empty() {
                            let i = rng.index(busy.len());
                            let (w, sb) = busy.swap_remove(i);
                            if elastic {
                                c.complete_elastic(w, sb, t);
                            } else {
                                let (_, started) = c.complete(w, sb, t);
                                if let Some(info) = started {
                                    busy.push((w, info.sandbox));
                                }
                            }
                        }
                    }
                    3 => {
                        let w = rng.index(c.len());
                        let f = rng.index(nf);
                        if let Some(sb) = c.prewarm(w, f, 256, t) {
                            c.finish_prewarm(w, sb, t);
                        }
                    }
                    4 => {
                        let w = rng.index(c.len());
                        c.sweep_keepalive(w, t - 3.0);
                    }
                    _ => {
                        let n = 1 + rng.index(c.len());
                        c.set_active(n);
                    }
                }
                // Full-scan ground truth over the active prefix.
                let active = c.active_workers();
                let mut warm = vec![0usize; nf];
                let mut running = 0;
                let mut queued = 0;
                for w in 0..active {
                    c.worker(w).warm_counts_into(&mut warm);
                    running += c.worker(w).running();
                    queued += c.worker(w).queue_len();
                }
                prop_assert!(
                    c.total_running() == running,
                    "running {} != {}",
                    c.total_running(),
                    running
                );
                prop_assert!(
                    c.total_queued() == queued,
                    "queued {} != {}",
                    c.total_queued(),
                    queued
                );
                let free: usize = (0..active).map(|w| c.worker(w).free_slots()).sum();
                prop_assert!(
                    c.total_free_slots() == free,
                    "free slots {} != {}",
                    c.total_free_slots(),
                    free
                );
                prop_assert!(
                    c.load_summary().free_slots == free as u64,
                    "summary free_slots {} != {}",
                    c.load_summary().free_slots,
                    free
                );
                for (f, &want) in warm.iter().enumerate() {
                    prop_assert!(
                        c.warm_nonbusy(f) == want,
                        "warm f={}: {} != {}",
                        f,
                        c.warm_nonbusy(f),
                        want
                    );
                }
                // Placement query vs the seed linear scan.
                for &mem in &[256u64, 1024, 4096] {
                    let scan = (0..active)
                        .filter(|&w| c.worker(w).mem_free_mb() >= mem)
                        .min_by_key(|&w| c.worker(w).load());
                    prop_assert!(
                        c.least_loaded_fitting(mem) == scan,
                        "fit({mem}): {:?} != {:?}",
                        c.least_loaded_fitting(mem),
                        scan
                    );
                }
            }
            Ok(())
        });
    }
}
