//! Cluster: the set of workers plus cluster-wide inspection helpers that
//! the schedulers consume (load vectors, idle-instance views).

use super::worker::{Worker, WorkerId};
use crate::config::ClusterConfig;
use crate::workload::spec::FunctionId;

#[derive(Clone, Debug)]
pub struct Cluster {
    pub workers: Vec<Worker>,
}

impl Cluster {
    pub fn new(cfg: &ClusterConfig) -> Self {
        let workers = (0..cfg.workers)
            .map(|id| Worker::new(id, cfg.mem_mb, cfg.concurrency))
            .collect();
        Self { workers }
    }

    pub fn len(&self) -> usize {
        self.workers.len()
    }

    pub fn is_empty(&self) -> bool {
        self.workers.is_empty()
    }

    pub fn worker(&self, id: WorkerId) -> &Worker {
        &self.workers[id]
    }

    pub fn worker_mut(&mut self, id: WorkerId) -> &mut Worker {
        &mut self.workers[id]
    }

    /// Per-worker load snapshot (running + queued).
    pub fn loads(&self) -> Vec<usize> {
        self.workers.iter().map(|w| w.load()).collect()
    }

    /// Workers that currently hold an idle sandbox for `f`.
    pub fn workers_with_idle(&self, f: FunctionId) -> Vec<WorkerId> {
        self.workers.iter().filter(|w| w.has_idle(f)).map(|w| w.id).collect()
    }

    /// Aggregate cold/warm/eviction counters across workers.
    pub fn totals(&self) -> ClusterTotals {
        let mut t = ClusterTotals::default();
        for w in &self.workers {
            t.cold += w.total_cold;
            t.warm += w.total_warm;
            t.evictions_pressure += w.total_evictions_pressure;
            t.evictions_keepalive += w.total_evictions_keepalive;
            t.prewarm_spawned += w.total_prewarm_spawned;
            t.prewarm_hits += w.total_prewarm_hits;
        }
        t
    }
}

#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ClusterTotals {
    pub cold: u64,
    pub warm: u64,
    pub evictions_pressure: u64,
    pub evictions_keepalive: u64,
    pub prewarm_spawned: u64,
    pub prewarm_hits: u64,
}

impl ClusterTotals {
    pub fn cold_rate(&self) -> f64 {
        let total = self.cold + self.warm;
        if total == 0 {
            0.0
        } else {
            self.cold as f64 / total as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::platform::worker::AssignOutcome;

    #[test]
    fn cluster_construction() {
        let c = Cluster::new(&ClusterConfig::default());
        assert_eq!(c.len(), 5);
        assert_eq!(c.loads(), vec![0; 5]);
    }

    #[test]
    fn totals_and_idle_views() {
        let mut c = Cluster::new(&ClusterConfig { workers: 2, ..Default::default() });
        let info = match c.worker_mut(0).assign(1, 3, 256, 0.0) {
            AssignOutcome::Started(i) => i,
            _ => panic!(),
        };
        assert_eq!(c.workers_with_idle(3), Vec::<usize>::new());
        c.worker_mut(0).complete(info.sandbox, 1.0);
        assert_eq!(c.workers_with_idle(3), vec![0]);
        let t = c.totals();
        assert_eq!(t.cold, 1);
        assert_eq!(t.warm, 0);
        assert!((t.cold_rate() - 1.0).abs() < 1e-12);
    }
}
