//! In-tree HTTP/1.1 front door for the real-time server (DESIGN.md §13):
//! a `std::net::TcpListener` ingress routing real traffic through the
//! [`Server`](super::Server) lifecycle API. Zero external dependencies —
//! no tokio, no hyper; the request parser, the connection pool and the
//! response writer all live in this file.
//!
//! Threading model: one acceptor thread pushes accepted connections onto
//! a `Mutex<VecDeque> + Condvar` hand-off queue; `http.io_threads`
//! handler threads pop connections and own them until close (keep-alive
//! loop with a read timeout so dead peers cannot pin a handler). Each
//! in-flight request blocks its handler on [`ServerClient::invoke`], so
//! `io_threads` bounds both concurrent connections and concurrent
//! HTTP-admitted requests.
//!
//! Routes:
//!
//! | method & path        | reply                                          |
//! |----------------------|------------------------------------------------|
//! | `POST /invoke/{id}`  | `200` completed / `429` rejected / `500` failed |
//! | `POST /prewarm/{id}` | `202` speculative warmup queued                |
//! | `GET /summary`       | `200` live run summary (JSON)                  |
//! | `GET /healthz`       | `200 {"ok":true}`                              |
//!
//! plus `400` (malformed request), `404` (unknown route or function id),
//! `413` (body over `http.max_body_bytes`) and `503` (server shut down).

use super::{InvokeOutcome, Server, ServerClient};
use crate::config::{Config, HttpConfig};
use crate::metrics::RunMetrics;
use std::collections::VecDeque;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

/// A running HTTP ingress: the listener, its acceptor + handler threads,
/// and the [`Server`] they front. Binding an ephemeral port
/// (`"127.0.0.1:0"`) and reading [`HttpIngress::local_addr`] makes the
/// ingress directly usable from in-process tests and benches.
pub struct HttpIngress {
    server: Option<Server>,
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    acceptor: Option<std::thread::JoinHandle<()>>,
    pool: Vec<std::thread::JoinHandle<()>>,
}

/// The connection hand-off queue between the acceptor and the handlers.
type ConnQueue = Arc<(Mutex<VecDeque<TcpStream>>, Condvar)>;

impl HttpIngress {
    /// Start a [`Server`] for `cfg` and bind the HTTP front door on
    /// `addr` (e.g. `"127.0.0.1:8080"`, or port `0` for an ephemeral
    /// port). Handler-pool size, keep-alive, body cap and read timeout
    /// come from `cfg.http`.
    pub fn start(cfg: &Config, addr: &str) -> Result<HttpIngress, String> {
        let server = Server::start(cfg)?;
        let listener = TcpListener::bind(addr).map_err(|e| format!("bind {addr}: {e}"))?;
        let local = listener.local_addr().map_err(|e| format!("local_addr: {e}"))?;
        let stop = Arc::new(AtomicBool::new(false));
        let queue: ConnQueue = Arc::new((Mutex::new(VecDeque::new()), Condvar::new()));

        let mut pool = Vec::new();
        for i in 0..cfg.http.io_threads.max(1) {
            let queue = Arc::clone(&queue);
            let stop = Arc::clone(&stop);
            let client = server.client();
            let hcfg = cfg.http.clone();
            pool.push(
                std::thread::Builder::new()
                    .name(format!("http-io-{i}"))
                    .spawn(move || handler_loop(&queue, &stop, &client, &hcfg))
                    .map_err(|e| format!("spawn handler: {e}"))?,
            );
        }
        let acceptor = {
            let queue = Arc::clone(&queue);
            let stop = Arc::clone(&stop);
            std::thread::Builder::new()
                .name("http-accept".into())
                .spawn(move || {
                    for conn in listener.incoming() {
                        if stop.load(Ordering::SeqCst) {
                            break;
                        }
                        if let Ok(stream) = conn {
                            let (lock, cv) = &*queue;
                            lock.lock().expect("conn queue poisoned").push_back(stream);
                            cv.notify_one();
                        }
                    }
                })
                .map_err(|e| format!("spawn acceptor: {e}"))?
        };
        crate::log_info!(
            "server",
            "http ingress listening on {} ({} handler threads)",
            local,
            cfg.http.io_threads.max(1)
        );
        Ok(HttpIngress { server: Some(server), addr: local, stop, acceptor: Some(acceptor), pool })
    }

    /// The bound listen address (resolves port 0 to the ephemeral port).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// A new client handle into the fronted [`Server`] (for in-process
    /// callers that want to bypass the socket).
    pub fn client(&self) -> ServerClient {
        self.server.as_ref().expect("ingress active").client()
    }

    /// Stop accepting, join the handler pool, drain outstanding requests
    /// and shut the fronted server down, returning the run's metrics.
    pub fn stop(mut self) -> Result<RunMetrics, String> {
        self.stop.store(true, Ordering::SeqCst);
        // Unblock the acceptor with a wake-up connection to ourselves;
        // handlers drain it (instant EOF) and observe the stop flag.
        let _ = TcpStream::connect(self.addr);
        if let Some(a) = self.acceptor.take() {
            let _ = a.join();
        }
        for h in self.pool.drain(..) {
            let _ = h.join();
        }
        let server = self.server.take().ok_or_else(|| "ingress already stopped".to_string())?;
        server.drain()?;
        server.shutdown()
    }
}

impl Drop for HttpIngress {
    fn drop(&mut self) {
        // Best-effort: release the acceptor so its thread can exit even
        // if `stop()` was never called. The fronted `Server` tears itself
        // down via its own Drop.
        self.stop.store(true, Ordering::SeqCst);
        let _ = TcpStream::connect(self.addr);
    }
}

/// Handler thread body: pop connections off the hand-off queue and own
/// each until it closes. Exits when the stop flag is set and the queue
/// is empty.
fn handler_loop(queue: &ConnQueue, stop: &AtomicBool, client: &ServerClient, cfg: &HttpConfig) {
    let (lock, cv) = &**queue;
    loop {
        let conn = {
            let mut q = lock.lock().expect("conn queue poisoned");
            loop {
                if let Some(c) = q.pop_front() {
                    break Some(c);
                }
                if stop.load(Ordering::SeqCst) {
                    break None;
                }
                let (guard, _) =
                    cv.wait_timeout(q, Duration::from_millis(100)).expect("conn queue poisoned");
                q = guard;
            }
        };
        let Some(stream) = conn else { return };
        let _ = handle_connection(stream, client, cfg, stop);
    }
}

/// One parsed HTTP request (the subset the front door understands).
struct Request {
    method: String,
    path: String,
    keep_alive: bool,
    /// The request body. Admission/invoke routes ignore it today, but
    /// the parser must consume it to keep the keep-alive stream framed.
    #[allow(dead_code)]
    body: Vec<u8>,
}

enum ReadError {
    /// Socket error or read timeout — close the connection silently.
    Io,
    /// Syntactically invalid request — answer 400 and close.
    Malformed(&'static str),
    /// Body over `http.max_body_bytes` — answer 413 and close.
    TooLarge,
}

/// Serve one connection: keep-alive request loop with per-read timeout.
fn handle_connection(
    stream: TcpStream,
    client: &ServerClient,
    cfg: &HttpConfig,
    stop: &AtomicBool,
) -> std::io::Result<()> {
    stream.set_read_timeout(Some(Duration::from_millis(cfg.read_timeout_ms.max(1))))?;
    let _ = stream.set_nodelay(true);
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut out = stream;
    loop {
        let req = match read_request(&mut reader, cfg.max_body_bytes) {
            Ok(Some(r)) => r,
            Ok(None) => return Ok(()), // clean close (EOF between requests)
            Err(ReadError::Io) => return Ok(()), // timeout/reset: drop quietly
            Err(ReadError::Malformed(why)) => {
                let body = format!("{{\"error\":\"{why}\"}}");
                let _ = write_response(&mut out, 400, "Bad Request", body.as_bytes(), false);
                return Ok(());
            }
            Err(ReadError::TooLarge) => {
                let body = b"{\"error\":\"body too large\"}";
                let _ = write_response(&mut out, 413, "Payload Too Large", body, false);
                return Ok(());
            }
        };
        let keep = cfg.keep_alive && req.keep_alive && !stop.load(Ordering::SeqCst);
        let (status, reason, body) = route(client, &req);
        write_response(&mut out, status, reason, body.as_bytes(), keep)?;
        if !keep {
            return Ok(());
        }
    }
}

/// Read and parse one HTTP/1.x request off the connection. `Ok(None)`
/// means the peer closed cleanly between requests.
fn read_request(
    reader: &mut BufReader<TcpStream>,
    max_body: usize,
) -> Result<Option<Request>, ReadError> {
    let mut line = String::new();
    match reader.read_line(&mut line) {
        Ok(0) => return Ok(None),
        Ok(_) => {}
        Err(_) => return Err(ReadError::Io),
    }
    let line = line.trim_end();
    if line.is_empty() {
        return Err(ReadError::Malformed("empty request line"));
    }
    let mut parts = line.split_whitespace();
    let method = parts.next().unwrap_or("").to_string();
    let path = match parts.next() {
        Some(p) => p.to_string(),
        None => return Err(ReadError::Malformed("missing path")),
    };
    let version = match parts.next() {
        Some(v) => v,
        None => return Err(ReadError::Malformed("missing version")),
    };
    if !version.starts_with("HTTP/1.") {
        return Err(ReadError::Malformed("unsupported protocol version"));
    }
    let http10 = version == "HTTP/1.0";
    // HTTP/1.1 defaults to keep-alive; 1.0 must opt in.
    let mut keep_alive = !http10;
    let mut content_length = 0usize;
    for _ in 0..128 {
        let mut h = String::new();
        match reader.read_line(&mut h) {
            Ok(0) => return Err(ReadError::Malformed("truncated headers")),
            Ok(_) => {}
            Err(_) => return Err(ReadError::Io),
        }
        let h = h.trim_end();
        if h.is_empty() {
            if content_length > max_body {
                return Err(ReadError::TooLarge);
            }
            let mut body = vec![0u8; content_length];
            if reader.read_exact(&mut body).is_err() {
                return Err(ReadError::Io);
            }
            return Ok(Some(Request { method, path, keep_alive, body }));
        }
        if let Some((name, value)) = h.split_once(':') {
            let value = value.trim();
            if name.eq_ignore_ascii_case("content-length") {
                content_length = match value.parse() {
                    Ok(n) => n,
                    Err(_) => return Err(ReadError::Malformed("bad content-length")),
                };
            } else if name.eq_ignore_ascii_case("connection") {
                if value.eq_ignore_ascii_case("close") {
                    keep_alive = false;
                } else if value.eq_ignore_ascii_case("keep-alive") {
                    keep_alive = true;
                }
            }
        }
    }
    Err(ReadError::Malformed("too many headers"))
}

/// Dispatch one parsed request to the router API and render the reply.
fn route(client: &ServerClient, req: &Request) -> (u16, &'static str, String) {
    let path = req.path.split('?').next().unwrap_or("");
    match req.method.as_str() {
        "GET" if path == "/healthz" => (200, "OK", "{\"ok\":true}".to_string()),
        "GET" if path == "/summary" => match client.summary() {
            Ok(j) => (200, "OK", j.to_string_compact()),
            Err(e) => (503, "Service Unavailable", err_body(&e)),
        },
        "POST" => {
            if let Some(id) = path.strip_prefix("/invoke/") {
                match parse_fn(client, id) {
                    None => (404, "Not Found", err_body("unknown function")),
                    Some(f) => match client.invoke(f) {
                        Ok(InvokeOutcome::Completed { worker, cold, latency_s }) => (
                            200,
                            "OK",
                            format!(
                                "{{\"outcome\":\"completed\",\"function\":{f},\"worker\":{worker},\
                                 \"cold\":{cold},\"latency_ms\":{:.3}}}",
                                latency_s * 1000.0
                            ),
                        ),
                        Ok(InvokeOutcome::Rejected) => {
                            (429, "Too Many Requests", "{\"outcome\":\"rejected\"}".to_string())
                        }
                        Ok(InvokeOutcome::Failed) => {
                            (500, "Internal Server Error", "{\"outcome\":\"failed\"}".to_string())
                        }
                        Err(e) => (503, "Service Unavailable", err_body(&e)),
                    },
                }
            } else if let Some(id) = path.strip_prefix("/prewarm/") {
                match parse_fn(client, id) {
                    None => (404, "Not Found", err_body("unknown function")),
                    Some(f) => match client.prewarm(f) {
                        Ok(()) => (202, "Accepted", "{\"outcome\":\"prewarm\"}".to_string()),
                        Err(e) => (503, "Service Unavailable", err_body(&e)),
                    },
                }
            } else {
                (404, "Not Found", err_body("no such route"))
            }
        }
        _ => (404, "Not Found", err_body("no such route")),
    }
}

/// Parse a path segment as an in-range function id.
fn parse_fn(client: &ServerClient, seg: &str) -> Option<usize> {
    seg.parse::<usize>().ok().filter(|&f| f < client.num_functions())
}

/// A minimal JSON error body (the message is always internal text —
/// no user input is echoed, so no escaping is needed).
fn err_body(msg: &str) -> String {
    format!("{{\"error\":\"{}\"}}", msg.replace('"', "'"))
}

/// Write one HTTP/1.1 response with a JSON body.
fn write_response(
    out: &mut TcpStream,
    status: u16,
    reason: &str,
    body: &[u8],
    keep_alive: bool,
) -> std::io::Result<()> {
    let conn = if keep_alive { "keep-alive" } else { "close" };
    write!(
        out,
        "HTTP/1.1 {status} {reason}\r\nContent-Type: application/json\r\nContent-Length: {}\r\nConnection: {conn}\r\n\r\n",
        body.len()
    )?;
    out.write_all(body)?;
    out.flush()
}
