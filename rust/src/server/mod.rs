//! Real-time serving backend: scheduler (router) thread + worker threads
//! executing the AOT-compiled PJRT payloads. This is the end-to-end
//! validation path — the same Scheduler trait and metrics as the simulator,
//! but with wall-clock time and real XLA compilation as the cold start.
//!
//! Topology (vLLM-router-like leader/worker):
//!
//! ```text
//!   router thread ──ExecMsg──▶ worker 0 thread (PJRT engine + LRU cache)
//!        ▲  │                  worker 1 thread
//!        │  └─────ExecMsg────▶ ...
//!        └──Response(+evictions)─────────────┘
//! ```
//!
//! Workers are OS threads with `std::sync::mpsc` channels (no tokio is
//! vendored in this image; the request path is compute-bound so a
//! thread-per-worker model is the right shape anyway).

use crate::autoscale::{make_policy, AutoscaleObs, AutoscalePolicy as _};
use crate::config::Config;
use crate::metrics::RunMetrics;
use crate::runtime::{Engine, Manifest};
use crate::scheduler::{make_scheduler, SchedCtx};
use crate::util::rng::Pcg64;
use crate::workload::loadgen::Workload;
use crate::workload::spec::FunctionRegistry;
use std::sync::mpsc;
use std::time::{Duration, Instant};

/// Request sent to a worker thread.
struct ExecMsg {
    rid: u64,
    /// Payload (base-app) name to execute.
    payload: String,
    /// Function type id (for eviction notifications).
    function: usize,
    seed: u32,
}

/// Worker -> router response.
struct Response {
    rid: u64,
    worker: usize,
    function: usize,
    cold: bool,
    digest: [f32; 2],
    /// Function ids evicted from this worker's cache (by payload name
    /// mapping; see `payload_to_functions`).
    evicted_payloads: Vec<String>,
}

/// Spawn one worker thread owning a PJRT engine.
fn spawn_worker(
    id: usize,
    artifacts_dir: String,
    capacity: usize,
    rx: mpsc::Receiver<ExecMsg>,
    tx: mpsc::Sender<Result<Response, String>>,
) -> std::thread::JoinHandle<()> {
    std::thread::spawn(move || {
        let mut engine = match Engine::from_dir(&artifacts_dir, capacity) {
            Ok(e) => e,
            Err(e) => {
                let _ = tx.send(Err(format!("worker {id}: {e}")));
                return;
            }
        };
        while let Ok(msg) = rx.recv() {
            match engine.execute(&msg.payload, msg.seed) {
                Ok(r) => {
                    let _ = tx.send(Ok(Response {
                        rid: msg.rid,
                        worker: id,
                        function: msg.function,
                        cold: r.cold,
                        digest: r.digest,
                        evicted_payloads: r.evicted,
                    }));
                }
                Err(e) => {
                    let _ = tx.send(Err(format!("worker {id}: {e}")));
                }
            }
        }
    })
}

/// Serve `n_requests` through the real-time cluster, closed-loop over the
/// configured VUs, and return the usual metrics. Think times come from the
/// workload config (scale them down for demos — wall-clock!).
pub fn serve_n_requests(cfg: &Config, n_requests: usize) -> Result<RunMetrics, String> {
    let manifest = Manifest::load(&cfg.runtime.artifacts_dir)?;
    let registry = FunctionRegistry::functionbench(cfg.workload.copies);
    // Each function copy maps to its base app's payload artifact.
    let payload_of: Vec<String> = (0..registry.len())
        .map(|f| registry.app(f).name.to_string())
        .collect();
    for p in &payload_of {
        if manifest.get(p).is_none() {
            return Err(format!("artifact for payload '{p}' missing; run `make artifacts`"));
        }
    }

    // Autoscaling (reactive/predictive): spawn the full `max_workers`
    // thread pool up front but only route to the `active` prefix; the
    // policy moves the boundary. The `scheduled` policy is sim-only (its
    // exact-time replay has no meaning against wall clock) and behaves
    // like `none` here.
    let autoscaling = matches!(cfg.autoscale.policy.as_str(), "reactive" | "predictive");
    let workers = if autoscaling {
        cfg.autoscale.max_workers.max(cfg.cluster.workers)
    } else {
        cfg.cluster.workers
    };
    let mut active = cfg.cluster.workers.min(workers);
    // Cache capacity from the memory pool: one executable per ~256 MB of
    // configured sandbox memory (same pressure model as the simulator).
    let capacity = ((cfg.cluster.mem_mb / 256).max(1) as usize).min(registry.len());

    let (resp_tx, resp_rx) = mpsc::channel::<Result<Response, String>>();
    let mut work_tx = Vec::new();
    let mut handles = Vec::new();
    for w in 0..workers {
        let (tx, rx) = mpsc::channel::<ExecMsg>();
        handles.push(spawn_worker(
            w,
            cfg.runtime.artifacts_dir.clone(),
            capacity,
            rx,
            resp_tx.clone(),
        ));
        work_tx.push(tx);
    }

    crate::log_info!(
        "server",
        "starting {} PJRT workers ({} active, cache capacity {}), scheduler {}, autoscale {}",
        workers,
        active,
        capacity,
        cfg.scheduler.name,
        cfg.autoscale.policy
    );
    let mut scheduler = make_scheduler(&cfg.scheduler, active)?;
    let mut policy = make_policy(&cfg.autoscale)?;
    let mean_exec_s: Vec<f64> =
        (0..registry.len()).map(|f| registry.app(f).warm_ms / 1000.0).collect();
    let mut last_tick = Instant::now();
    let mut sched_rng = Pcg64::new(cfg.workload.seed ^ 0x5EED);
    let workload = Workload::generate(&cfg.workload, registry.len(), cfg.workload.seed);
    let vus = cfg.workload.vus.min(n_requests.max(1));

    // Imbalance columns track workers that have ever been active (the
    // simulator's add_worker convention) — not the idle thread pool.
    let mut metrics = RunMetrics::new(
        &cfg.scheduler.name,
        active,
        vus,
        1.0, // duration finalized after the run (wall-clock)
    );
    let mut imbalance_cols = active;
    metrics.record_scale(0.0, active);
    let start = Instant::now();
    let mut loads = vec![0u32; workers];
    let mut issued = 0usize;
    let mut completed = 0usize;
    // Per-request bookkeeping.
    let mut arrival: Vec<Instant> = Vec::new();
    let mut vu_of: Vec<usize> = Vec::new();
    let mut step_of: Vec<usize> = Vec::new();
    // VU cursors and wake times.
    let mut vu_step = vec![0usize; vus];
    let mut wake: Vec<(Instant, usize)> = (0..vus).map(|v| (start, v)).collect();

    while completed < n_requests {
        // Autoscale control tick (wall clock). The policy only ever moves
        // the active boundary; threads beyond it sit idle on their channel.
        if autoscaling && last_tick.elapsed().as_secs_f64() >= cfg.autoscale.interval_s {
            last_tick = Instant::now();
            let total_running: usize = loads[..active].iter().map(|&l| l as usize).sum();
            let obs = AutoscaleObs {
                now: start.elapsed().as_secs_f64(),
                active_workers: active,
                concurrency: cfg.cluster.concurrency,
                total_running,
                total_queued: 0,
                // The PJRT workers warm on first execution and expose no
                // speculative-init hook, so the warm supply is opaque here
                // and pre-warm plans are applied by the simulator only.
                warm_supply: &[],
                mean_exec_s: &mean_exec_s,
            };
            let d = policy.tick(&obs);
            if let Some(target) = d.target_workers {
                let target = target.clamp(1, workers);
                while active < target {
                    scheduler.on_worker_added(active);
                    active += 1;
                    if active > imbalance_cols {
                        metrics.imbalance.add_worker();
                        imbalance_cols = active;
                    }
                    metrics.record_scale(start.elapsed().as_secs_f64(), active);
                }
                while active > target {
                    active -= 1;
                    scheduler.on_worker_removed(active);
                    metrics.record_scale(start.elapsed().as_secs_f64(), active);
                }
            }
        }
        // Wake any due VUs (issue their next request).
        let now = Instant::now();
        let mut i = 0;
        while i < wake.len() {
            if wake[i].0 <= now && issued < n_requests {
                let vu = wake[i].1;
                wake.swap_remove(i);
                let step = vu_step[vu];
                if step >= workload.vus[vu].steps.len() {
                    continue;
                }
                // ---- issue the VU's next request ----
                let f = workload.vus[vu].steps[step].function;
                let rid = arrival.len() as u64;
                policy.on_arrival(f, start.elapsed().as_secs_f64());
                let w = {
                    let mut ctx = SchedCtx::new(&loads[..active], &mut sched_rng);
                    scheduler.select(f, &mut ctx)
                };
                loads[w] += 1;
                metrics.record_assignment(w, start.elapsed().as_secs_f64());
                arrival.push(Instant::now());
                vu_of.push(vu);
                step_of.push(step);
                work_tx[w]
                    .send(ExecMsg {
                        rid,
                        payload: payload_of[f].clone(),
                        function: f,
                        seed: (rid as u32).wrapping_mul(2654435761),
                    })
                    .map_err(|_| "worker channel closed".to_string())?;
                issued += 1;
            } else {
                i += 1;
            }
        }
        // Wait for a response (or the next VU wake time).
        let timeout = wake
            .iter()
            .map(|(t, _)| t.saturating_duration_since(now))
            .min()
            .unwrap_or(Duration::from_millis(5))
            .max(Duration::from_micros(100));
        match resp_rx.recv_timeout(timeout) {
            Ok(Ok(r)) => {
                loads[r.worker] -= 1;
                // Eviction notifications: every function copy whose payload
                // was evicted from this worker's cache.
                for p in &r.evicted_payloads {
                    for f in 0..registry.len() {
                        if &payload_of[f] == p {
                            scheduler.on_evict(r.worker, f);
                        }
                    }
                }
                // Drained workers (beyond the active boundary) must not
                // re-advertise idle capacity.
                if r.worker < active {
                    let mut ctx = SchedCtx::new(&loads[..active], &mut sched_rng);
                    scheduler.on_complete(r.worker, r.function, &mut ctx);
                }
                let rid = r.rid as usize;
                let lat = arrival[rid].elapsed().as_secs_f64();
                metrics.record_response(lat, r.cold, 0.0, start.elapsed().as_secs_f64());
                debug_assert!(r.digest.iter().all(|d| d.is_finite()));
                completed += 1;
                // Closed loop: schedule the VU's next step.
                let vu = vu_of[rid];
                let think = workload.vus[vu].steps[step_of[rid]].think_s;
                vu_step[vu] = step_of[rid] + 1;
                wake.push((Instant::now() + Duration::from_secs_f64(think), vu));
            }
            Ok(Err(e)) => return Err(e),
            Err(mpsc::RecvTimeoutError::Timeout) => {}
            Err(mpsc::RecvTimeoutError::Disconnected) => {
                return Err("all workers disconnected".into());
            }
        }
    }

    metrics.duration_s = start.elapsed().as_secs_f64();
    metrics.finalize_scaling(metrics.duration_s);
    // Drop senders so workers exit; join them.
    drop(work_tx);
    drop(resp_tx);
    for h in handles {
        let _ = h.join();
    }
    Ok(metrics)
}

#[cfg(test)]
mod tests {
    // Real-time server tests live in rust/tests/e2e.rs (they need built
    // artifacts and real wall-clock time).
}
