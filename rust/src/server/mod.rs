//! Real-time serving backend: scheduler (router) thread + worker threads
//! executing the AOT-compiled PJRT payloads (or their latency-model
//! stubs). This is the end-to-end validation path — the same Scheduler
//! trait and metrics as the simulator, but with wall-clock time and real
//! XLA compilation as the cold start.
//!
//! Topology (vLLM-router-like leader/worker):
//!
//! ```text
//!   clients ──RouterMsg::Invoke──▶ router thread ──ExecMsg──▶ worker 0 (engine + LRU cache)
//!   (HTTP ingress, [`ServerClient`])     ▲  │                 worker 1
//!                                        │  └──────ExecMsg──▶ ...
//!                                        └─RouterMsg::Worker(Response)──┘
//! ```
//!
//! Workers are OS threads with `std::sync::mpsc` channels (no tokio is
//! vendored in this image; the request path is compute-bound so a
//! thread-per-worker model is the right shape anyway). The router owns
//! one unified [`RouterMsg`] receiver multiplexing client commands and
//! worker responses — `std::sync::mpsc` has no `select`, so a single
//! channel is the only way to block on both.
//!
//! The public surface is the [`Server`] lifecycle API: `Server::start`
//! brings the cluster up, [`ServerClient`] handles issue requests from
//! any thread (the HTTP front door in [`http`] is one such client), and
//! `Server::shutdown` tears the cluster down and returns the run's
//! [`RunMetrics`]. [`serve_n_requests`] survives as a thin closed-loop
//! compatibility wrapper over that API.
//!
//! Execution backends (`runtime.backend`): `"pjrt"` runs the AOT
//! artifact set; `"stub"` models each execution as a sleep of the
//! function's Table-I cold/warm latency (scaled by
//! `runtime.stub_speedup`) behind the same per-worker LRU payload
//! cache — no artifacts required, so HTTP smoke tests, benches and CI
//! run on a bare checkout.

pub mod http;

use crate::autoscale::{make_policy, AutoscaleObs, AutoscalePolicy};
use crate::config::Config;
use crate::dispatch::PendingQueue;
use crate::faults::{fault_coin, retry_backoff, FaultPlan};
use crate::metrics::RunMetrics;
use crate::runtime::{Engine, Manifest};
use crate::scheduler::{
    make_scheduler, Decision, DispatchCtx, Pull, SchedCtx, SchedCtxBuilder, Scheduler,
};
use crate::util::json::Json;
use crate::util::rng::Pcg64;
use crate::workload::loadgen::Workload;
use crate::workload::spec::FunctionRegistry;
use std::collections::BTreeSet;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

/// Request sent to a worker thread.
struct ExecMsg {
    rid: u64,
    /// Payload (base-app) name to execute.
    payload: String,
    /// Function type id (for eviction notifications).
    function: usize,
    seed: u32,
    /// Injected straggler delay (`faults.straggler_slowdown`): the worker
    /// sleeps this long before executing, inflating its service time the
    /// way the simulator multiplies execution durations. Zero when fault
    /// injection is off.
    delay: Duration,
    /// Speculative pre-warm: execute purely to populate the worker's
    /// cache. No request is waiting on the result.
    prewarm: bool,
}

/// Worker -> router response.
struct Response {
    rid: u64,
    worker: usize,
    function: usize,
    cold: bool,
    digest: [f32; 2],
    /// Payload names evicted from this worker's cache.
    evicted_payloads: Vec<String>,
    /// Echo of [`ExecMsg::prewarm`].
    prewarm: bool,
}

/// Everything the router thread can receive: client commands and worker
/// responses share one channel (`std::sync::mpsc` has no `select`).
enum RouterMsg {
    /// Admit-and-execute one request for `function`; the outcome is sent
    /// on `reply` when the request resolves.
    Invoke { function: usize, reply: mpsc::Sender<InvokeOutcome> },
    /// Speculatively warm `function` on one worker (anti-affinity spread).
    Prewarm { function: usize },
    /// Snapshot the live metrics as a summary JSON object.
    Summary { reply: mpsc::Sender<Json> },
    /// Reply (with `()`) once no admitted request is outstanding.
    Drain { reply: mpsc::Sender<()> },
    /// Stop the router loop; workers are joined and metrics finalized.
    Shutdown,
    /// A worker's execution result (or its fatal error).
    Worker(Box<Result<Response, String>>),
}

/// How one admitted-or-refused request resolved, as observed by the
/// issuing client.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum InvokeOutcome {
    /// The request executed to completion.
    Completed {
        /// Worker that produced the result.
        worker: usize,
        /// Whether the execution was a cold start.
        cold: bool,
        /// End-to-end latency (arrival at the router to response), seconds.
        latency_s: f64,
    },
    /// Admission refused (queue-cap overflow or scheduler reject).
    Rejected,
    /// The request exhausted its fault retry budget.
    Failed,
}

/// Cloneable handle issuing requests into a running [`Server`]'s router.
/// Every method is synchronous: it blocks the calling thread until the
/// router answers, so each concurrent in-flight request needs its own
/// thread (the HTTP handler pool, the loadgen connections, a VU thread).
#[derive(Clone)]
pub struct ServerClient {
    cmd_tx: mpsc::Sender<RouterMsg>,
    functions: usize,
}

impl ServerClient {
    /// Issue one request for `function` and block until it resolves.
    ///
    /// Errors only on lifecycle misuse: an out-of-range function id or a
    /// server that shut down mid-request. Scheduling refusals and fault
    /// losses are values ([`InvokeOutcome::Rejected`] /
    /// [`InvokeOutcome::Failed`]), not errors.
    pub fn invoke(&self, function: usize) -> Result<InvokeOutcome, String> {
        if function >= self.functions {
            return Err(format!(
                "unknown function id {function} (workload has {})",
                self.functions
            ));
        }
        let (tx, rx) = mpsc::channel();
        self.cmd_tx
            .send(RouterMsg::Invoke { function, reply: tx })
            .map_err(|_| "server is shut down".to_string())?;
        rx.recv().map_err(|_| "server closed before the request resolved".to_string())
    }

    /// Ask the router to speculatively warm `function` on one worker
    /// (placement-aware: least-loaded live worker not already warm for
    /// its payload). Fire-and-forget; a no-op when every live worker is
    /// already warm or warming.
    pub fn prewarm(&self, function: usize) -> Result<(), String> {
        if function >= self.functions {
            return Err(format!(
                "unknown function id {function} (workload has {})",
                self.functions
            ));
        }
        self.cmd_tx
            .send(RouterMsg::Prewarm { function })
            .map_err(|_| "server is shut down".to_string())
    }

    /// Snapshot the run's live summary (the simulator's summary keys plus
    /// `arrivals`, `failed` and `outstanding`).
    pub fn summary(&self) -> Result<Json, String> {
        let (tx, rx) = mpsc::channel();
        self.cmd_tx
            .send(RouterMsg::Summary { reply: tx })
            .map_err(|_| "server is shut down".to_string())?;
        rx.recv().map_err(|_| "server closed before answering".to_string())
    }

    /// Block until no admitted request is outstanding.
    pub fn drain(&self) -> Result<(), String> {
        let (tx, rx) = mpsc::channel();
        self.cmd_tx
            .send(RouterMsg::Drain { reply: tx })
            .map_err(|_| "server is shut down".to_string())?;
        rx.recv().map_err(|_| "server closed before draining".to_string())
    }

    /// Number of functions in the served workload (valid ids are
    /// `0..num_functions()`).
    pub fn num_functions(&self) -> usize {
        self.functions
    }
}

/// A running real-time cluster: router thread + worker threads, brought
/// up by [`Server::start`] and torn down by [`Server::shutdown`] (which
/// returns the run's [`RunMetrics`]). Requests come in through
/// [`ServerClient`] handles — `Server`'s own `invoke`/`drain`/`summary`
/// are conveniences over an internal client.
pub struct Server {
    client: ServerClient,
    router: Option<std::thread::JoinHandle<Result<RunMetrics, String>>>,
}

impl Server {
    /// Start the cluster described by `cfg`: spawn the worker pool (PJRT
    /// or stub per `runtime.backend`), the router thread, and return the
    /// running server. Fails fast if the PJRT artifact set is missing.
    pub fn start(cfg: &Config) -> Result<Server, String> {
        let registry = FunctionRegistry::functionbench(cfg.workload.copies);
        // Each function copy maps to its base app's payload artifact.
        let payload_of: Vec<String> =
            (0..registry.len()).map(|f| registry.app(f).name.to_string()).collect();
        let stub = cfg.runtime.backend == "stub";
        if !stub {
            let manifest = Manifest::load(&cfg.runtime.artifacts_dir)?;
            for p in &payload_of {
                if manifest.get(p).is_none() {
                    return Err(format!(
                        "artifact for payload '{p}' missing; run `make artifacts`"
                    ));
                }
            }
        }

        // Autoscaling (reactive/predictive): spawn the full `max_workers`
        // thread pool up front but only route to the `active` prefix; the
        // policy moves the boundary. The `scheduled` policy is sim-only
        // (its exact-time replay has no meaning against wall clock) and
        // behaves like `none` here.
        let autoscaling = matches!(cfg.autoscale.policy.as_str(), "reactive" | "predictive");
        let workers = if autoscaling {
            cfg.autoscale.max_workers.max(cfg.cluster.workers)
        } else {
            cfg.cluster.workers
        };
        let active = cfg.cluster.workers.min(workers);
        // Cache capacity from the memory pool: one executable per ~256 MB
        // of configured sandbox memory (same pressure model as the
        // simulator).
        let capacity = ((cfg.cluster.mem_mb / 256).max(1) as usize).min(registry.len());

        // Distinct payload latency specs for the stub backend.
        let payload_specs: Vec<(String, f64, f64)> = {
            let mut v: Vec<(String, f64, f64)> = Vec::new();
            for f in 0..registry.len() {
                let app = registry.app(f);
                if !v.iter().any(|(n, _, _)| n == app.name) {
                    v.push((app.name.to_string(), app.cold_ms, app.warm_ms));
                }
            }
            v
        };

        let (tx, rx) = mpsc::channel::<RouterMsg>();
        let mut work_tx = Vec::new();
        let mut handles = Vec::new();
        for w in 0..workers {
            let (wtx, wrx) = mpsc::channel::<ExecMsg>();
            handles.push(if stub {
                spawn_stub_worker(
                    w,
                    capacity,
                    payload_specs.clone(),
                    cfg.runtime.cold_extra_ms,
                    cfg.runtime.stub_speedup,
                    wrx,
                    tx.clone(),
                )
            } else {
                spawn_worker(w, cfg.runtime.artifacts_dir.clone(), capacity, wrx, tx.clone())
            });
            work_tx.push(wtx);
        }

        crate::log_info!(
            "server",
            "starting {} {} workers ({} active, cache capacity {}), scheduler {}, autoscale {}",
            workers,
            cfg.runtime.backend,
            active,
            capacity,
            cfg.scheduler.name,
            cfg.autoscale.policy
        );
        let scheduler = make_scheduler(&cfg.scheduler, active)?;
        let policy = make_policy(&cfg.autoscale)?;
        let mean_exec_s: Vec<f64> =
            (0..registry.len()).map(|f| registry.app(f).warm_ms / 1000.0).collect();

        // Imbalance columns track workers that have ever been active (the
        // simulator's add_worker convention) — not the idle thread pool.
        // The telemetry surface matches the simulator's: sketch mode,
        // lifecycle tracing (span times are wall-clock seconds since
        // server start), and deterministic hash-gate sampling by rid.
        let mut metrics = RunMetrics::with_telemetry(
            &cfg.scheduler.name,
            active,
            cfg.workload.vus,
            1.0, // duration finalized at shutdown (wall-clock)
            &cfg.telemetry,
        );
        metrics.record_scale(0.0, active);
        metrics.faults_enabled = cfg.faults.enabled;
        let faults_on = cfg.faults.enabled;
        let plan = if faults_on {
            FaultPlan::generate(&cfg.faults, workers, cfg.workload.duration_s, cfg.workload.seed)
        } else {
            FaultPlan::default()
        };

        let functions = registry.len();
        let cap_f = cfg.dispatch.caps_dense(functions);
        let pending_q = PendingQueue::with_layout(functions, &cfg.dispatch.weights_sparse());
        let router = Router {
            cfg: cfg.clone(),
            registry,
            payload_of,
            scheduler,
            policy,
            mean_exec_s,
            rx,
            work_tx,
            handles,
            workers,
            active,
            autoscaling,
            last_tick: Instant::now(),
            sched_rng: Pcg64::new(cfg.workload.seed ^ 0x5EED),
            metrics,
            imbalance_cols: active,
            start: Instant::now(),
            loads: vec![0u32; workers],
            completed: 0,
            rejected: 0,
            failed: 0,
            outstanding: 0,
            arrival: Vec::new(),
            dispatched: Vec::new(),
            fn_of: Vec::new(),
            attempts: Vec::new(),
            reply_of: Vec::new(),
            pull: cfg.pull_dispatch(),
            fair: cfg.dispatch.fair,
            pending_q,
            cap_f,
            deadlines: Vec::new(),
            inflight_f: vec![0usize; functions],
            cold_lat_ewma: vec![0.0f64; functions],
            warm_lat_ewma: vec![0.0f64; functions],
            faults_on,
            plan,
            next_crash: 0,
            next_recover: 0,
            next_strag: 0,
            dead: vec![false; workers],
            last_crash: vec![None; workers],
            slow: vec![1.0f64; workers],
            retry_at: Vec::new(),
            warm_sets: vec![BTreeSet::new(); workers],
            prewarmed: BTreeSet::new(),
            drains: Vec::new(),
        };
        let handle = std::thread::Builder::new()
            .name("hiku-router".into())
            .spawn(move || router.run())
            .map_err(|e| format!("spawn router: {e}"))?;
        Ok(Server { client: ServerClient { cmd_tx: tx, functions }, router: Some(handle) })
    }

    /// A new cloneable client handle for this server.
    pub fn client(&self) -> ServerClient {
        self.client.clone()
    }

    /// Convenience for [`ServerClient::invoke`] on the internal client.
    pub fn invoke(&self, function: usize) -> Result<InvokeOutcome, String> {
        self.client.invoke(function)
    }

    /// Convenience for [`ServerClient::prewarm`] on the internal client.
    pub fn prewarm(&self, function: usize) -> Result<(), String> {
        self.client.prewarm(function)
    }

    /// Convenience for [`ServerClient::summary`] on the internal client.
    pub fn summary(&self) -> Result<Json, String> {
        self.client.summary()
    }

    /// Convenience for [`ServerClient::drain`] on the internal client.
    pub fn drain(&self) -> Result<(), String> {
        self.client.drain()
    }

    /// Number of functions in the served workload.
    pub fn num_functions(&self) -> usize {
        self.client.functions
    }

    /// Stop the router, join the workers, and return the finalized run
    /// metrics. In-flight requests are abandoned (their clients see an
    /// error) — call [`Server::drain`] first for a clean stop.
    pub fn shutdown(mut self) -> Result<RunMetrics, String> {
        let _ = self.client.cmd_tx.send(RouterMsg::Shutdown);
        let handle = self.router.take().ok_or_else(|| "server already shut down".to_string())?;
        handle.join().map_err(|_| "router thread panicked".to_string())?
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        // Best-effort teardown when the server is dropped without an
        // explicit shutdown (e.g. on an early error-return in a caller).
        if let Some(handle) = self.router.take() {
            let _ = self.client.cmd_tx.send(RouterMsg::Shutdown);
            let _ = handle.join();
        }
    }
}

/// Spawn one worker thread owning a PJRT engine.
fn spawn_worker(
    id: usize,
    artifacts_dir: String,
    capacity: usize,
    rx: mpsc::Receiver<ExecMsg>,
    tx: mpsc::Sender<RouterMsg>,
) -> std::thread::JoinHandle<()> {
    std::thread::spawn(move || {
        let mut engine = match Engine::from_dir(&artifacts_dir, capacity) {
            Ok(e) => e,
            Err(e) => {
                let _ = tx.send(RouterMsg::Worker(Box::new(Err(format!("worker {id}: {e}")))));
                return;
            }
        };
        while let Ok(msg) = rx.recv() {
            if !msg.delay.is_zero() {
                std::thread::sleep(msg.delay);
            }
            let out = match engine.execute(&msg.payload, msg.seed) {
                Ok(r) => Ok(Response {
                    rid: msg.rid,
                    worker: id,
                    function: msg.function,
                    cold: r.cold,
                    digest: r.digest,
                    evicted_payloads: r.evicted,
                    prewarm: msg.prewarm,
                }),
                Err(e) => Err(format!("worker {id}: {e}")),
            };
            if tx.send(RouterMsg::Worker(Box::new(out))).is_err() {
                return;
            }
        }
    })
}

/// Spawn one stub worker thread: the same per-worker LRU payload cache
/// and cold/warm distinction as the PJRT engine, but each execution is a
/// sleep of the function's Table-I latency divided by
/// `runtime.stub_speedup` instead of a real XLA run. Keeps the full
/// router/scheduler/dispatch path hot without the artifact set.
fn spawn_stub_worker(
    id: usize,
    capacity: usize,
    specs: Vec<(String, f64, f64)>,
    cold_extra_ms: f64,
    speedup: f64,
    rx: mpsc::Receiver<ExecMsg>,
    tx: mpsc::Sender<RouterMsg>,
) -> std::thread::JoinHandle<()> {
    std::thread::spawn(move || {
        // (payload, last-used tick): a tiny LRU, evicting beyond capacity.
        let mut cache: Vec<(String, u64)> = Vec::new();
        let mut tick: u64 = 0;
        while let Ok(msg) = rx.recv() {
            if !msg.delay.is_zero() {
                std::thread::sleep(msg.delay);
            }
            tick += 1;
            let mut evicted = Vec::new();
            let cold = if let Some(entry) = cache.iter_mut().find(|e| e.0 == msg.payload) {
                entry.1 = tick;
                false
            } else {
                cache.push((msg.payload.clone(), tick));
                while cache.len() > capacity {
                    let mut lru = 0;
                    for (i, e) in cache.iter().enumerate() {
                        if e.1 < cache[lru].1 {
                            lru = i;
                        }
                    }
                    evicted.push(cache.remove(lru).0);
                }
                true
            };
            let (cold_ms, warm_ms) = specs
                .iter()
                .find(|s| s.0 == msg.payload)
                .map(|s| (s.1, s.2))
                .unwrap_or((100.0, 10.0));
            let base_ms = if cold { cold_ms + cold_extra_ms } else { warm_ms };
            std::thread::sleep(Duration::from_secs_f64(base_ms / 1000.0 / speedup));
            let digest = [(msg.seed % 997) as f32 * 1e-3, msg.function as f32];
            let out = Ok(Response {
                rid: msg.rid,
                worker: id,
                function: msg.function,
                cold,
                digest,
                evicted_payloads: evicted,
                prewarm: msg.prewarm,
            });
            if tx.send(RouterMsg::Worker(Box::new(out))).is_err() {
                return;
            }
        }
    })
}

/// The straggler delay injected for one execution on worker `w`: the
/// extra service time a `slowdown`× multiplier adds on top of the
/// function's nominal warm latency. Zero for non-stragglers (the
/// faults-off fast path — every worker's multiplier is 1).
fn straggler_delay(slow: &[f64], w: usize, warm_ms: f64) -> Duration {
    let m = slow.get(w).copied().unwrap_or(1.0);
    if m > 1.0 {
        Duration::from_secs_f64(warm_ms / 1000.0 * (m - 1.0))
    } else {
        Duration::ZERO
    }
}

/// The router's scheduler-context builder: the shared
/// [`SchedCtx::builder`] entry point with the server's avoid-mask
/// convention baked in (the same helper shape as the simulator's
/// `sched_ctx`, keeping the construction sites from drifting).
fn router_ctx<'a>(
    loads: &'a [u32],
    rng: &'a mut Pcg64,
    dead: Option<&'a [bool]>,
) -> SchedCtxBuilder<'a> {
    SchedCtx::builder(loads, rng).avoid(dead)
}

/// The router thread's state and event loop: admission, dispatch,
/// pull-claims, autoscale ticks, fault replay, pre-warm placement and
/// metrics — everything the old `serve_n_requests` body did, minus the
/// closed-loop VU driver (now a client-side concern).
struct Router {
    cfg: Config,
    registry: FunctionRegistry,
    payload_of: Vec<String>,
    scheduler: Box<dyn Scheduler>,
    policy: Box<dyn AutoscalePolicy>,
    mean_exec_s: Vec<f64>,
    rx: mpsc::Receiver<RouterMsg>,
    work_tx: Vec<mpsc::Sender<ExecMsg>>,
    handles: Vec<std::thread::JoinHandle<()>>,
    workers: usize,
    active: usize,
    autoscaling: bool,
    last_tick: Instant,
    sched_rng: Pcg64,
    metrics: RunMetrics,
    imbalance_cols: usize,
    start: Instant,
    loads: Vec<u32>,
    completed: usize,
    rejected: usize,
    failed: usize,
    /// Admitted requests not yet resolved (completed or failed).
    outstanding: usize,
    // Per-request bookkeeping, indexed by rid.
    arrival: Vec<Instant>,
    /// When the request was handed to a worker (== arrival for immediate
    /// assigns; re-stamped when a parked request is claimed or
    /// force-placed). The adaptive-wait EWMAs read dispatch -> response,
    /// NOT arrival -> response: end-to-end latency would include the
    /// pending wait itself and self-inflate the cold-warm delta.
    dispatched: Vec<Instant>,
    fn_of: Vec<usize>,
    attempts: Vec<u32>,
    reply_of: Vec<mpsc::Sender<InvokeOutcome>>,
    // Pull dispatch: pending queue + wall-clock wait deadlines.
    pull: bool,
    fair: bool,
    pending_q: PendingQueue,
    cap_f: Vec<usize>,
    deadlines: Vec<(Instant, u64)>,
    inflight_f: Vec<usize>,
    /// Adaptive waiting: per-function EWMAs of observed cold and warm
    /// response latency; their delta is the cold penalty waiting can
    /// avoid, and it caps the wall-clock wait deadline.
    cold_lat_ewma: Vec<f64>,
    warm_lat_ewma: Vec<f64>,
    // Wall-clock fault injection (`[faults]`): the seed-derived plan the
    // simulator installs, replayed against wall-clock seconds since
    // start. A "crashed" worker thread is not killed (it may be
    // mid-execute); the router marks it dead, routes around it, and
    // treats any response whose dispatch predates the crash as lost.
    faults_on: bool,
    plan: FaultPlan,
    next_crash: usize,
    next_recover: usize,
    next_strag: usize,
    dead: Vec<bool>,
    /// Most recent crash instant per worker (never cleared): a response
    /// dispatched before it refers to state the crash destroyed.
    last_crash: Vec<Option<Instant>>,
    slow: Vec<f64>,
    retry_at: Vec<(Instant, u64)>,
    /// Per-worker mirror of cached payload names, maintained from
    /// cold/eviction responses: the router-side warm-placement map that
    /// pre-warm spreading and the autoscaler's warm-supply signal read.
    warm_sets: Vec<BTreeSet<String>>,
    /// Outstanding speculative warmups: (worker, payload) pairs spawned
    /// but not yet repaid by a warm hit (metered as `prewarm_hits`).
    prewarmed: BTreeSet<(usize, String)>,
    /// Pending drain waiters, answered when `outstanding` hits zero.
    drains: Vec<mpsc::Sender<()>>,
}

impl Router {
    fn run(mut self) -> Result<RunMetrics, String> {
        loop {
            self.autoscale_tick();
            self.apply_fault_plan()?;
            self.expire_deadlines()?;
            let timeout = self.next_timeout();
            match self.rx.recv_timeout(timeout) {
                Ok(RouterMsg::Invoke { function, reply }) => self.on_invoke(function, reply)?,
                Ok(RouterMsg::Prewarm { function }) => {
                    self.spawn_prewarm(function);
                }
                Ok(RouterMsg::Summary { reply }) => {
                    let snapshot = self.summary();
                    let _ = reply.send(snapshot);
                }
                Ok(RouterMsg::Drain { reply }) => {
                    self.drains.push(reply);
                    self.check_drains();
                }
                Ok(RouterMsg::Shutdown) => break,
                Ok(RouterMsg::Worker(res)) => match *res {
                    Ok(r) => self.on_response(r)?,
                    Err(e) => return Err(e),
                },
                Err(mpsc::RecvTimeoutError::Timeout) => {}
                // All command senders and workers gone: nothing can ever
                // arrive again — finalize as an implicit shutdown.
                Err(mpsc::RecvTimeoutError::Disconnected) => break,
            }
        }
        self.finish()
    }

    /// How long the event loop may sleep in `recv_timeout`: until the
    /// next wall-clock obligation (pull deadline, retry backoff, fault
    /// event, autoscale tick), floored at 100 µs so a hot router cannot
    /// busy-spin.
    fn next_timeout(&self) -> Duration {
        let now = Instant::now();
        let mut timeout = Duration::from_millis(25);
        for (t, _) in &self.deadlines {
            timeout = timeout.min(t.saturating_duration_since(now));
        }
        for (t, _) in &self.retry_at {
            timeout = timeout.min(t.saturating_duration_since(now));
        }
        // Pending fault-plan events are wall-clock scheduled outside the
        // deadline lists — poll often enough to apply them promptly.
        if self.faults_on
            && (self.next_crash < self.plan.crashes.len()
                || self.next_recover < self.plan.recoveries.len()
                || self.next_strag < self.plan.stragglers.len())
        {
            timeout = timeout.min(Duration::from_millis(20));
        }
        if self.autoscaling {
            let rem =
                (self.cfg.autoscale.interval_s - self.last_tick.elapsed().as_secs_f64()).max(0.0);
            timeout = timeout.min(Duration::from_secs_f64(rem));
        }
        timeout.max(Duration::from_micros(100))
    }

    /// Autoscale control tick (wall clock). The policy only ever moves
    /// the active boundary; threads beyond it sit idle on their channel.
    /// Unlike the pre-`Server` loop, the observation now carries the
    /// live queue depth and a real per-function warm supply (from the
    /// router's warm-set mirror), and the policy's speculative pre-warm
    /// plans are applied through the placement-aware spawn path.
    fn autoscale_tick(&mut self) {
        if !self.autoscaling
            || self.last_tick.elapsed().as_secs_f64() < self.cfg.autoscale.interval_s
        {
            return;
        }
        self.last_tick = Instant::now();
        let total_running: usize = self.loads[..self.active].iter().map(|&l| l as usize).sum();
        let warm_supply: Vec<usize> = (0..self.registry.len())
            .map(|f| {
                (0..self.active)
                    .filter(|&w| !self.dead[w] && self.warm_sets[w].contains(&self.payload_of[f]))
                    .count()
            })
            .collect();
        let obs = AutoscaleObs {
            now: self.start.elapsed().as_secs_f64(),
            active_workers: self.active,
            concurrency: self.cfg.cluster.concurrency,
            total_running,
            total_queued: self.pending_q.len(),
            warm_supply: &warm_supply,
            mean_exec_s: &self.mean_exec_s,
        };
        let d = self.policy.tick(&obs);
        if let Some(target) = d.target_workers {
            let target = target.clamp(1, self.workers);
            while self.active < target {
                self.scheduler.on_worker_added(self.active);
                self.active += 1;
                if self.active > self.imbalance_cols {
                    self.metrics.imbalance.add_worker();
                    self.imbalance_cols = self.active;
                }
                self.metrics.record_scale(self.start.elapsed().as_secs_f64(), self.active);
            }
            while self.active > target {
                self.active -= 1;
                self.scheduler.on_worker_removed(self.active);
                self.metrics.record_scale(self.start.elapsed().as_secs_f64(), self.active);
            }
        }
        for (f, n) in d.prewarm {
            for _ in 0..n {
                if !self.spawn_prewarm(f) {
                    break;
                }
            }
        }
    }

    /// Speculatively warm function `f` on one worker, anti-affinity
    /// spread: among live active workers neither warm nor already warming
    /// for `f`'s payload, pick the least loaded (lowest id on ties) and
    /// execute the payload once off the request path. Returns false when
    /// no such worker exists (nothing to spread to).
    fn spawn_prewarm(&mut self, f: usize) -> bool {
        if f >= self.registry.len() {
            return false;
        }
        let payload = &self.payload_of[f];
        let mut best: Option<usize> = None;
        for w in 0..self.active {
            if self.dead[w]
                || self.warm_sets[w].contains(payload)
                || self.prewarmed.contains(&(w, payload.clone()))
            {
                continue;
            }
            let better = match best {
                None => true,
                Some(b) => self.loads[w] < self.loads[b],
            };
            if better {
                best = Some(w);
            }
        }
        let Some(w) = best else { return false };
        let msg = ExecMsg {
            rid: u64::MAX,
            payload: payload.clone(),
            function: f,
            seed: 0x9E37,
            delay: Duration::ZERO,
            prewarm: true,
        };
        let payload = payload.clone();
        if self.work_tx[w].send(msg).is_err() {
            return false;
        }
        self.loads[w] += 1;
        self.prewarmed.insert((w, payload));
        self.metrics.prewarm_spawned += 1;
        true
    }

    /// Apply fault-plan events whose wall-clock time has passed, then
    /// re-dispatch retries whose backoff elapsed.
    fn apply_fault_plan(&mut self) -> Result<(), String> {
        if !self.faults_on {
            return Ok(());
        }
        let now_s = self.start.elapsed().as_secs_f64();
        while self.next_crash < self.plan.crashes.len()
            && self.plan.crashes[self.next_crash].0 <= now_s
        {
            let (_, w) = self.plan.crashes[self.next_crash];
            self.next_crash += 1;
            if !self.dead[w] {
                self.dead[w] = true;
                self.last_crash[w] = Some(Instant::now());
                self.metrics.worker_crashes += 1;
                crate::log_info!("server", "fault: worker {} crashed at t={:.2}s", w, now_s);
            }
        }
        while self.next_recover < self.plan.recoveries.len()
            && self.plan.recoveries[self.next_recover].0 <= now_s
        {
            let (_, w) = self.plan.recoveries[self.next_recover];
            self.next_recover += 1;
            if self.dead[w] {
                self.dead[w] = false;
                self.metrics.worker_recoveries += 1;
                if let Some(c) = self.last_crash[w] {
                    self.metrics.recovery_latency_ms.push(c.elapsed().as_secs_f64() * 1000.0);
                }
                crate::log_info!("server", "fault: worker {} recovered at t={:.2}s", w, now_s);
            }
        }
        while self.next_strag < self.plan.stragglers.len()
            && self.plan.stragglers[self.next_strag].0 <= now_s
        {
            let (_, w, m) = self.plan.stragglers[self.next_strag];
            self.next_strag += 1;
            self.slow[w] = m.max(1.0);
        }
        let now = Instant::now();
        let mut i = 0;
        while i < self.retry_at.len() {
            if self.retry_at[i].0 > now {
                i += 1;
                continue;
            }
            let (_, rid) = self.retry_at.swap_remove(i);
            let f = self.fn_of[rid as usize];
            let w = self.select(f);
            if self.dead[w] {
                // No live worker took it — the avoid mask is advisory and
                // every candidate was dead. Burn another attempt; the
                // budget bounds how long the request can wait for a
                // recovery.
                let t_s = self.start.elapsed().as_secs_f64();
                self.metrics.trace.record(rid, f, "bind", t_s, t_s, Some(w), "dead-bind");
                self.fault_retry(rid);
                continue;
            }
            self.loads[w] += 1;
            self.inflight_f[f] += 1;
            let t_s = self.start.elapsed().as_secs_f64();
            self.metrics.record_assignment(w, t_s);
            self.metrics.trace.record(rid, f, "bind", t_s, t_s, Some(w), "retry");
            self.dispatched[rid as usize] = Instant::now();
            self.send_to(rid, f, w)?;
        }
        Ok(())
    }

    /// Pull dispatch: force-place parked requests whose wait deadline
    /// passed (warm if the completing workers re-advertised, fallback
    /// placement otherwise). Like the simulator, an expired deadline
    /// drains its function's queue oldest-first up to the expired
    /// request, so adaptive deadlines never reorder a function's line.
    fn expire_deadlines(&mut self) -> Result<(), String> {
        if !self.pull || self.deadlines.is_empty() {
            return Ok(());
        }
        let now = Instant::now();
        let mut i = 0;
        while i < self.deadlines.len() {
            if self.deadlines[i].0 > now {
                i += 1;
                continue;
            }
            let (_, rid) = self.deadlines.swap_remove(i);
            let f = self.fn_of[rid as usize];
            if !self.pending_q.is_waiting(rid) {
                continue; // already claimed by an idle worker
            }
            loop {
                let Some(head) = self.pending_q.pop_fn(f) else { break };
                let w = self.select(f);
                self.bind_parked(head, f, w, "deadline")?;
                if head == rid {
                    break;
                }
            }
        }
        Ok(())
    }

    /// Scheduler fallback selection for function `f` over the active
    /// prefix (avoiding crash-marked workers when faults are on).
    fn select(&mut self, f: usize) -> usize {
        let active = self.active;
        let mut ctx = router_ctx(
            &self.loads[..active],
            &mut self.sched_rng,
            self.faults_on.then_some(&self.dead[..active]),
        )
        .build();
        self.scheduler.select(f, &mut ctx)
    }

    /// Admit one request for `f`: the scheduler decides, and the request
    /// is assigned, parked (pull mode), or refused. `reply` resolves when
    /// the request does.
    fn on_invoke(&mut self, f: usize, reply: mpsc::Sender<InvokeOutcome>) -> Result<(), String> {
        let rid = self.arrival.len() as u64;
        let t_s = self.start.elapsed().as_secs_f64();
        self.metrics.trace.record(rid, f, "arrival", t_s, t_s, None, "");
        self.policy.on_arrival(f, t_s);
        let active = self.active;
        let decision = {
            let dispatch = if self.pull {
                Some(DispatchCtx {
                    inflight_f: self.inflight_f[f],
                    pending_f: self.pending_q.len_fn(f),
                })
            } else {
                None
            };
            let mut ctx = router_ctx(
                &self.loads[..active],
                &mut self.sched_rng,
                self.faults_on.then_some(&self.dead[..active]),
            )
            .dispatch(dispatch)
            .build();
            self.scheduler.decide(f, &mut ctx)
        };
        let refuse = match decision {
            Decision::Reject(_) => true,
            // An Enqueue against a full per-function queue (or outside
            // the pull protocol) is an admission refusal — the cap
            // isolates the overflow to this function.
            Decision::Enqueue => {
                !self.pull || (self.cap_f[f] > 0 && self.pending_q.len_fn(f) >= self.cap_f[f])
            }
            // The real-time server does not track core slots: a slot pin
            // degrades to a plain worker assignment.
            Decision::Assign(_) | Decision::AssignSlot(_, _) => false,
        };
        if refuse {
            self.metrics.trace.record(rid, f, "decide", t_s, t_s, None, "reject");
            self.metrics.record_reject(f);
            self.rejected += 1;
            let _ = reply.send(InvokeOutcome::Rejected);
            return Ok(());
        }
        let now = Instant::now();
        self.arrival.push(now);
        self.dispatched.push(now);
        self.fn_of.push(f);
        self.attempts.push(0);
        self.reply_of.push(reply);
        self.outstanding += 1;
        match decision {
            Decision::Assign(w) | Decision::AssignSlot(w, _) => {
                self.metrics.trace.record(rid, f, "decide", t_s, t_s, Some(w), "assign");
                self.loads[w] += 1;
                self.inflight_f[f] += 1;
                self.metrics.record_assignment(w, self.start.elapsed().as_secs_f64());
                self.send_to(rid, f, w)?;
            }
            _ => {
                self.metrics.trace.record(rid, f, "decide", t_s, t_s, None, "enqueue");
                self.pending_q.push(rid, f);
                self.metrics.record_enqueue(self.pending_q.len());
                let wait = self.wait_for(f);
                self.deadlines.push((Instant::now() + Duration::from_secs_f64(wait), rid));
            }
        }
        Ok(())
    }

    /// One worker's result: bookkeeping, warm-set mirror maintenance,
    /// pull/idle claims for the now-idle worker, fault-loss filtering,
    /// and resolution of the waiting client.
    fn on_response(&mut self, r: Response) -> Result<(), String> {
        self.loads[r.worker] -= 1;
        if !r.prewarm {
            self.inflight_f[r.function] -= 1;
        }
        // Warm-set mirror: after this response the payload is cached on
        // the worker, minus whatever its LRU pushed out. Eviction
        // notifications fan out to every function copy of the payload.
        self.warm_sets[r.worker].insert(self.payload_of[r.function].clone());
        for p in &r.evicted_payloads {
            self.warm_sets[r.worker].remove(p);
            self.prewarmed.remove(&(r.worker, p.clone()));
            for f in 0..self.registry.len() {
                if &self.payload_of[f] == p {
                    self.scheduler.on_evict(r.worker, f);
                }
            }
        }
        // A warm start on a (worker, payload) we speculatively warmed is
        // the speculation paying off.
        if !r.prewarm
            && !r.cold
            && self.prewarmed.remove(&(r.worker, self.payload_of[r.function].clone()))
        {
            self.metrics.prewarm_hits += 1;
        }
        // Drained workers (beyond the active boundary) and crash-marked
        // workers must not re-advertise idle capacity or claim parked
        // work.
        if r.worker < self.active && !self.dead[r.worker] {
            self.worker_idle(r.worker, r.function)?;
        }
        if r.prewarm {
            // Nothing is waiting on a speculative warmup.
            return Ok(());
        }
        // Fault injection: a response whose dispatch predates the
        // worker's most recent crash refers to state the crash destroyed
        // — the result is lost. A cold execution may also fail
        // initialization (seed-derived coin, same construction as the
        // simulator). Either way the request is not resolved; it consumes
        // a retry attempt. Worker bookkeeping above already ran: the slot
        // is genuinely free, only the result is discarded.
        if self.faults_on {
            let i = r.rid as usize;
            let crashed = self.last_crash[r.worker].is_some_and(|c| self.dispatched[i] < c);
            let init_fail = !crashed
                && r.cold
                && self.cfg.faults.init_fail_prob > 0.0
                && fault_coin(self.cfg.workload.seed, r.rid, self.attempts[i])
                    < self.cfg.faults.init_fail_prob;
            if crashed || init_fail {
                let now_s = self.start.elapsed().as_secs_f64();
                if crashed {
                    self.metrics.trace.record(
                        r.rid, r.function, "crash", now_s, now_s, Some(r.worker), "lost",
                    );
                } else {
                    self.metrics.init_failures += 1;
                    self.metrics.trace.record(
                        r.rid, r.function, "init_fail", now_s, now_s, Some(r.worker), "",
                    );
                }
                self.fault_retry(r.rid);
                return Ok(());
            }
        }
        let rid = r.rid as usize;
        let lat = self.arrival[rid].elapsed().as_secs_f64();
        if self.pull {
            // Feed the adaptive-deadline EWMAs from the dispatch ->
            // response latency: the cold−warm delta of the *service* is
            // the observed cold penalty. (End-to-end latency would
            // include the pending wait and self-inflate the delta.)
            const WAIT_ALPHA: f64 = 0.2;
            let service_lat = self.dispatched[rid].elapsed().as_secs_f64();
            let e = if r.cold {
                &mut self.cold_lat_ewma[r.function]
            } else {
                &mut self.warm_lat_ewma[r.function]
            };
            *e = if *e > 0.0 {
                WAIT_ALPHA * service_lat + (1.0 - WAIT_ALPHA) * *e
            } else {
                service_lat
            };
        }
        let resp_s = self.start.elapsed().as_secs_f64();
        self.metrics.record_response(lat, r.cold, 0.0, resp_s);
        if self.metrics.trace.sampled(r.rid) {
            // No observable init boundary on the real workers (PJRT
            // compilation happens inside execute), so the whole dispatch
            // -> response window is one `service` span; its `cold`/`warm`
            // detail carries the split.
            let disp_s = self.dispatched[rid].duration_since(self.start).as_secs_f64();
            let kind = if r.cold { "cold" } else { "warm" };
            self.metrics.trace.record(
                r.rid, r.function, "service", disp_s, resp_s, Some(r.worker), kind,
            );
            self.metrics.trace.record(
                r.rid, r.function, "complete", resp_s, resp_s, Some(r.worker), kind,
            );
        }
        debug_assert!(r.digest.iter().all(|d| d.is_finite()));
        self.completed += 1;
        self.resolve(
            r.rid,
            InvokeOutcome::Completed { worker: r.worker, cold: r.cold, latency_s: lat },
        );
        Ok(())
    }

    /// Pull dispatch for a now-idle worker: claim a parked request first
    /// (a warm start); only advertise through `on_complete` when nothing
    /// is waiting, then offer idle capacity to the prospect-less backlog
    /// in DRR order (same rule as the simulator).
    fn worker_idle(&mut self, w: usize, f: usize) -> Result<(), String> {
        let mut claimed = false;
        if self.pull && !self.pending_q.is_empty() {
            let p = {
                let active = self.active;
                let dispatch = Some(DispatchCtx {
                    inflight_f: self.inflight_f[f],
                    pending_f: self.pending_q.len_fn(f),
                });
                let mut ctx = router_ctx(
                    &self.loads[..active],
                    &mut self.sched_rng,
                    self.faults_on.then_some(&self.dead[..active]),
                )
                .dispatch(dispatch)
                .build();
                self.scheduler.on_worker_idle(w, f, &mut ctx)
            };
            if let Pull::Function(pf) = p {
                if let Some(rid2) = self.pending_q.pop_fn(pf) {
                    self.bind_parked(rid2, pf, w, "pull")?;
                    claimed = true;
                }
            }
        }
        if !claimed {
            {
                let active = self.active;
                let mut ctx = router_ctx(
                    &self.loads[..active],
                    &mut self.sched_rng,
                    self.faults_on.then_some(&self.dead[..active]),
                )
                .build();
                self.scheduler.on_complete(w, f, &mut ctx);
            }
            if self.pull && !self.pending_q.is_empty() {
                let inflight = &self.inflight_f;
                let eligible = |g: usize| inflight[g] == 0;
                let got = if self.fair {
                    self.pending_q.pop_fair_where(eligible)
                } else {
                    self.pending_q.pop_arrival_where(eligible)
                };
                if let Some((rid2, pf)) = got {
                    self.bind_parked(rid2, pf, w, "idle")?;
                }
            }
        }
        Ok(())
    }

    /// Bind a parked request `rid` (function `f`) to worker `w`: load
    /// and inflight bookkeeping, assignment/wait metrics, the dispatch
    /// stamp the adaptive-wait EWMAs read, and the send. The single
    /// definition keeps the three claim paths — deadline drain, warm
    /// claim, idle-capacity claim — from drifting apart.
    fn bind_parked(&mut self, rid: u64, f: usize, w: usize, kind: &'static str) -> Result<(), String> {
        self.loads[w] += 1;
        self.inflight_f[f] += 1;
        let now_s = self.start.elapsed().as_secs_f64();
        let arr_s = self.arrival[rid as usize].duration_since(self.start).as_secs_f64();
        self.metrics.record_assignment(w, now_s);
        self.metrics.record_pending_wait(f, now_s - arr_s);
        self.metrics.trace.record(rid, f, "pending", arr_s, now_s, None, "");
        self.metrics.trace.record(rid, f, "bind", now_s, now_s, Some(w), kind);
        self.dispatched[rid as usize] = Instant::now();
        self.send_to(rid, f, w)
    }

    /// Dispatch one execution message to worker `w` (straggler delay
    /// included when faults are on).
    fn send_to(&mut self, rid: u64, f: usize, w: usize) -> Result<(), String> {
        let delay = straggler_delay(&self.slow, w, self.registry.app(f).warm_ms);
        self.work_tx[w]
            .send(ExecMsg {
                rid,
                payload: self.payload_of[f].clone(),
                function: f,
                seed: (rid as u32).wrapping_mul(2654435761),
                delay,
                prewarm: false,
            })
            .map_err(|_| "worker channel closed".to_string())
    }

    /// The wall-clock pull deadline for function `f` (see
    /// `dispatch.adaptive_wait`): `min(max_wait_s, ewma cold − warm)`
    /// floored at 1 ms and `dispatch.min_wait_s`.
    fn wait_for(&self, f: usize) -> f64 {
        let base = self.cfg.dispatch.max_wait_s;
        if !self.cfg.dispatch.adaptive_wait
            || self.cold_lat_ewma[f] <= 0.0
            || self.warm_lat_ewma[f] <= 0.0
        {
            return base;
        }
        // A noisy non-positive delta means "no observed cold penalty",
        // i.e. waiting cannot pay — place almost at once; min_wait_s then
        // floors the deadline so a transiently tiny estimate cannot
        // collapse the wait to an instant force-place.
        base.min((self.cold_lat_ewma[f] - self.warm_lat_ewma[f]).max(0.001))
            .max(self.cfg.dispatch.min_wait_s)
    }

    /// Consume one retry attempt for request `rid` after a fault loss (a
    /// crashed worker's lost result, a cold-init failure, or a
    /// dead-worker bind). Either schedules a deterministically jittered
    /// backoff re-dispatch or — budget exhausted — meters the request as
    /// `failed` and resolves its client, so no admitted request is ever
    /// silently dropped.
    fn fault_retry(&mut self, rid: u64) {
        let i = rid as usize;
        let att = self.attempts[i];
        let now_s = self.start.elapsed().as_secs_f64();
        if att >= self.cfg.faults.max_retries {
            self.failed += 1;
            self.metrics.failed += 1;
            self.metrics.trace.record(rid, self.fn_of[i], "failed", now_s, now_s, None, "budget");
            self.resolve(rid, InvokeOutcome::Failed);
            return;
        }
        self.attempts[i] = att + 1;
        self.metrics.retried += 1;
        let backoff =
            retry_backoff(self.cfg.faults.retry_backoff_s, self.cfg.workload.seed, rid, att + 1);
        self.metrics.trace.record(rid, self.fn_of[i], "retry", now_s, now_s, None, "backoff");
        self.retry_at.push((Instant::now() + Duration::from_secs_f64(backoff), rid));
    }

    /// Resolve request `rid` toward its client and settle drain waiters.
    fn resolve(&mut self, rid: u64, outcome: InvokeOutcome) {
        let _ = self.reply_of[rid as usize].send(outcome);
        self.outstanding -= 1;
        self.check_drains();
    }

    fn check_drains(&mut self) {
        if self.outstanding == 0 {
            for d in self.drains.drain(..) {
                let _ = d.send(());
            }
        }
    }

    /// The live summary: the simulator's summary keys (duration and
    /// arrivals refreshed to now) plus the server-only conservation
    /// fields `arrivals`, `failed` and `outstanding`
    /// (`arrivals == completed + rejected + failed` once drained).
    fn summary(&mut self) -> Json {
        self.metrics.duration_s = self.start.elapsed().as_secs_f64();
        self.metrics.arrivals = self.arrival.len() as u64 + self.rejected as u64;
        let mut j = self.metrics.summary_json();
        if let Json::Obj(m) = &mut j {
            m.insert("arrivals".to_string(), Json::Num(self.metrics.arrivals as f64));
            m.insert("failed".to_string(), Json::Num(self.failed as f64));
            m.insert("outstanding".to_string(), Json::Num(self.outstanding as f64));
        }
        j
    }

    /// Finalize metrics, drop the work channels so workers exit, join
    /// them, and hand the metrics back.
    fn finish(mut self) -> Result<RunMetrics, String> {
        self.metrics.duration_s = self.start.elapsed().as_secs_f64();
        let d = self.metrics.duration_s;
        self.metrics.finalize_scaling(d);
        // Conservation surface (same identity as the simulator): every
        // admitted request resolved as completed or failed; refusals
        // never entered `arrival`.
        self.metrics.arrivals = self.arrival.len() as u64 + self.rejected as u64;
        drop(self.work_tx);
        for h in self.handles {
            let _ = h.join();
        }
        Ok(self.metrics)
    }
}

/// Serve `n_requests` through the real-time cluster, closed-loop over the
/// configured VUs, and return the usual metrics — the original entry
/// point, now a thin compatibility wrapper over the [`Server`] lifecycle
/// API: one client thread per VU replays its scripted
/// invoke-think sequence through [`ServerClient::invoke`] until the
/// request budget is spent, then the server drains and shuts down. Think
/// times come from the workload config (scale them down for demos —
/// wall-clock!).
///
/// The dispatch protocol applies exactly as documented on [`Server`]:
/// pull-mode parking and claims, per-function admission caps, adaptive
/// wall-clock wait deadlines, DRR idle-capacity claims, and (with
/// `faults.enabled`) the seed-derived fault plan replayed against wall
/// clock. A request counts as *resolved* when it completes, is rejected,
/// or exhausts its fault retry budget — the run serves `n_requests`
/// resolutions. (Scale-to-zero stays sim-only: the worker pool never
/// drops below one active worker.)
pub fn serve_n_requests(cfg: &Config, n_requests: usize) -> Result<RunMetrics, String> {
    let mut cfg = cfg.clone();
    cfg.workload.vus = cfg.workload.vus.min(n_requests.max(1)).max(1);
    let server = Server::start(&cfg)?;
    let workload = Workload::generate(&cfg.workload, server.num_functions(), cfg.workload.seed);
    let issued = Arc::new(AtomicUsize::new(0));
    let mut vu_threads = Vec::new();
    for script in workload.vus.into_iter().take(cfg.workload.vus) {
        let client = server.client();
        let issued = Arc::clone(&issued);
        vu_threads.push(std::thread::spawn(move || {
            for step in &script.steps {
                // Issuing (assigned, parked, or refused) spends budget —
                // the same accounting as the original closed loop.
                if issued.fetch_add(1, Ordering::SeqCst) >= n_requests {
                    break;
                }
                if client.invoke(step.function).is_err() {
                    break;
                }
                std::thread::sleep(Duration::from_secs_f64(step.think_s));
            }
        }));
    }
    for h in vu_threads {
        let _ = h.join();
    }
    server.drain()?;
    server.shutdown()
}

#[cfg(test)]
mod tests {
    // Real-time server tests live in rust/tests/e2e.rs (PJRT backend;
    // they need built artifacts) and rust/tests/http.rs (stub backend;
    // they run anywhere).
}
