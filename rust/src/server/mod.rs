//! Real-time serving backend: scheduler (router) thread + worker threads
//! executing the AOT-compiled PJRT payloads. This is the end-to-end
//! validation path — the same Scheduler trait and metrics as the simulator,
//! but with wall-clock time and real XLA compilation as the cold start.
//!
//! Topology (vLLM-router-like leader/worker):
//!
//! ```text
//!   router thread ──ExecMsg──▶ worker 0 thread (PJRT engine + LRU cache)
//!        ▲  │                  worker 1 thread
//!        │  └─────ExecMsg────▶ ...
//!        └──Response(+evictions)─────────────┘
//! ```
//!
//! Workers are OS threads with `std::sync::mpsc` channels (no tokio is
//! vendored in this image; the request path is compute-bound so a
//! thread-per-worker model is the right shape anyway).

use crate::autoscale::{make_policy, AutoscaleObs, AutoscalePolicy as _};
use crate::config::Config;
use crate::dispatch::PendingQueue;
use crate::faults::{fault_coin, retry_backoff, FaultPlan};
use crate::metrics::RunMetrics;
use crate::runtime::{Engine, Manifest};
use crate::scheduler::{make_scheduler, Decision, DispatchCtx, Pull, SchedCtx};
use crate::util::rng::Pcg64;
use crate::workload::loadgen::Workload;
use crate::workload::spec::FunctionRegistry;
use std::sync::mpsc;
use std::time::{Duration, Instant};

/// Request sent to a worker thread.
struct ExecMsg {
    rid: u64,
    /// Payload (base-app) name to execute.
    payload: String,
    /// Function type id (for eviction notifications).
    function: usize,
    seed: u32,
    /// Injected straggler delay (`faults.straggler_slowdown`): the worker
    /// sleeps this long before executing, inflating its service time the
    /// way the simulator multiplies execution durations. Zero when fault
    /// injection is off.
    delay: Duration,
}

/// Worker -> router response.
struct Response {
    rid: u64,
    worker: usize,
    function: usize,
    cold: bool,
    digest: [f32; 2],
    /// Function ids evicted from this worker's cache (by payload name
    /// mapping; see `payload_to_functions`).
    evicted_payloads: Vec<String>,
}

/// Spawn one worker thread owning a PJRT engine.
fn spawn_worker(
    id: usize,
    artifacts_dir: String,
    capacity: usize,
    rx: mpsc::Receiver<ExecMsg>,
    tx: mpsc::Sender<Result<Response, String>>,
) -> std::thread::JoinHandle<()> {
    std::thread::spawn(move || {
        let mut engine = match Engine::from_dir(&artifacts_dir, capacity) {
            Ok(e) => e,
            Err(e) => {
                let _ = tx.send(Err(format!("worker {id}: {e}")));
                return;
            }
        };
        while let Ok(msg) = rx.recv() {
            if !msg.delay.is_zero() {
                std::thread::sleep(msg.delay);
            }
            match engine.execute(&msg.payload, msg.seed) {
                Ok(r) => {
                    let _ = tx.send(Ok(Response {
                        rid: msg.rid,
                        worker: id,
                        function: msg.function,
                        cold: r.cold,
                        digest: r.digest,
                        evicted_payloads: r.evicted,
                    }));
                }
                Err(e) => {
                    let _ = tx.send(Err(format!("worker {id}: {e}")));
                }
            }
        }
    })
}

/// Bind a parked request `rid` (function `f`) to worker `w`: load and
/// inflight bookkeeping, assignment/wait metrics, the dispatch stamp the
/// adaptive-wait EWMAs read, and the send. The single definition keeps
/// the three claim paths — deadline drain, warm claim, idle-capacity
/// claim — from drifting apart.
#[allow(clippy::too_many_arguments)]
fn bind_parked(
    rid: u64,
    f: usize,
    w: usize,
    kind: &'static str,
    loads: &mut [u32],
    inflight_f: &mut [usize],
    dispatched: &mut [Instant],
    arrival: &[Instant],
    metrics: &mut RunMetrics,
    start: Instant,
    work_tx: &[mpsc::Sender<ExecMsg>],
    payload_of: &[String],
    delay: Duration,
) -> Result<(), String> {
    loads[w] += 1;
    inflight_f[f] += 1;
    let now_s = start.elapsed().as_secs_f64();
    let arr_s = arrival[rid as usize].duration_since(start).as_secs_f64();
    metrics.record_assignment(w, now_s);
    metrics.record_pending_wait(f, now_s - arr_s);
    metrics.trace.record(rid, f, "pending", arr_s, now_s, None, "");
    metrics.trace.record(rid, f, "bind", now_s, now_s, Some(w), kind);
    dispatched[rid as usize] = Instant::now();
    send_to(work_tx, payload_of, rid, f, w, delay)
}

/// Dispatch one execution message to worker `w`.
fn send_to(
    work_tx: &[mpsc::Sender<ExecMsg>],
    payload_of: &[String],
    rid: u64,
    f: usize,
    w: usize,
    delay: Duration,
) -> Result<(), String> {
    work_tx[w]
        .send(ExecMsg {
            rid,
            payload: payload_of[f].clone(),
            function: f,
            seed: (rid as u32).wrapping_mul(2654435761),
            delay,
        })
        .map_err(|_| "worker channel closed".to_string())
}

/// The straggler delay injected for one execution on worker `w`: the
/// extra service time a `slowdown`× multiplier adds on top of the
/// function's nominal warm latency. Zero for non-stragglers (the
/// faults-off fast path — every worker's multiplier is 1).
fn straggler_delay(slow: &[f64], w: usize, warm_ms: f64) -> Duration {
    let m = slow.get(w).copied().unwrap_or(1.0);
    if m > 1.0 {
        Duration::from_secs_f64(warm_ms / 1000.0 * (m - 1.0))
    } else {
        Duration::ZERO
    }
}

/// Consume one retry attempt for request `rid` after a fault loss (a
/// crashed worker's lost result, a cold-init failure, or a dead-worker
/// bind). Either schedules a deterministically jittered backoff
/// re-dispatch or — budget exhausted — meters the request as `failed` and
/// wakes its VU, so no admitted request is ever silently dropped.
#[allow(clippy::too_many_arguments)]
fn fault_retry_wallclock(
    rid: u64,
    cfg: &Config,
    attempts: &mut [u32],
    retry_at: &mut Vec<(Instant, u64)>,
    failed: &mut usize,
    metrics: &mut RunMetrics,
    start: Instant,
    workload: &Workload,
    vu_of: &[usize],
    step_of: &[usize],
    fn_of: &[usize],
    vu_step: &mut [usize],
    wake: &mut Vec<(Instant, usize)>,
) {
    let i = rid as usize;
    let att = attempts[i];
    let now_s = start.elapsed().as_secs_f64();
    if att >= cfg.faults.max_retries {
        *failed += 1;
        metrics.failed += 1;
        metrics.trace.record(rid, fn_of[i], "failed", now_s, now_s, None, "budget");
        let vu = vu_of[i];
        let think = workload.vus[vu].steps[step_of[i]].think_s;
        vu_step[vu] = step_of[i] + 1;
        wake.push((Instant::now() + Duration::from_secs_f64(think), vu));
        return;
    }
    attempts[i] = att + 1;
    metrics.retried += 1;
    let backoff = retry_backoff(cfg.faults.retry_backoff_s, cfg.workload.seed, rid, att + 1);
    metrics.trace.record(rid, fn_of[i], "retry", now_s, now_s, None, "backoff");
    retry_at.push((Instant::now() + Duration::from_secs_f64(backoff), rid));
}

/// Serve `n_requests` through the real-time cluster, closed-loop over the
/// configured VUs, and return the usual metrics. Think times come from the
/// workload config (scale them down for demos — wall-clock!).
///
/// The dispatch protocol applies here too: under `dispatch.mode = "pull"`
/// requests with a warm prospect park in the router's pending queue,
/// completing workers claim them, and wall-clock wait deadlines
/// force-place stragglers. The fair-dispatcher semantics match the
/// simulator's: admission caps are per function (`dispatch.queue_cap` +
/// `dispatch.queue_caps`, rejects metered per function), idle capacity
/// claims prospect-less backlog in deficit-round-robin order
/// (`dispatch.fair`/`dispatch.weights`), and with
/// `dispatch.adaptive_wait` each function's wall-clock deadline is
/// `min(max_wait_s, ewma_cold_latency − ewma_warm_latency)` — the
/// observed cost of the cold start waiting might avoid. A request counts
/// as *resolved* when it completes, is rejected, or exhausts its fault
/// retry budget — the run serves `n_requests` resolutions. (Scale-to-zero
/// stays sim-only: the PJRT worker pool never drops below one active
/// worker.)
///
/// With `faults.enabled` the seed-derived fault plan replays against wall
/// clock: crash-marked workers are routed around and their in-flight
/// results discarded on arrival (consuming the request's retry budget),
/// stragglers execute behind an injected service delay, and recoveries
/// restore routing — the wall-clock mirror of the simulator's fault
/// events.
pub fn serve_n_requests(cfg: &Config, n_requests: usize) -> Result<RunMetrics, String> {
    let manifest = Manifest::load(&cfg.runtime.artifacts_dir)?;
    let registry = FunctionRegistry::functionbench(cfg.workload.copies);
    // Each function copy maps to its base app's payload artifact.
    let payload_of: Vec<String> = (0..registry.len())
        .map(|f| registry.app(f).name.to_string())
        .collect();
    for p in &payload_of {
        if manifest.get(p).is_none() {
            return Err(format!("artifact for payload '{p}' missing; run `make artifacts`"));
        }
    }

    // Autoscaling (reactive/predictive): spawn the full `max_workers`
    // thread pool up front but only route to the `active` prefix; the
    // policy moves the boundary. The `scheduled` policy is sim-only (its
    // exact-time replay has no meaning against wall clock) and behaves
    // like `none` here.
    let autoscaling = matches!(cfg.autoscale.policy.as_str(), "reactive" | "predictive");
    let workers = if autoscaling {
        cfg.autoscale.max_workers.max(cfg.cluster.workers)
    } else {
        cfg.cluster.workers
    };
    let mut active = cfg.cluster.workers.min(workers);
    // Cache capacity from the memory pool: one executable per ~256 MB of
    // configured sandbox memory (same pressure model as the simulator).
    let capacity = ((cfg.cluster.mem_mb / 256).max(1) as usize).min(registry.len());

    let (resp_tx, resp_rx) = mpsc::channel::<Result<Response, String>>();
    let mut work_tx = Vec::new();
    let mut handles = Vec::new();
    for w in 0..workers {
        let (tx, rx) = mpsc::channel::<ExecMsg>();
        handles.push(spawn_worker(
            w,
            cfg.runtime.artifacts_dir.clone(),
            capacity,
            rx,
            resp_tx.clone(),
        ));
        work_tx.push(tx);
    }

    crate::log_info!(
        "server",
        "starting {} PJRT workers ({} active, cache capacity {}), scheduler {}, autoscale {}",
        workers,
        active,
        capacity,
        cfg.scheduler.name,
        cfg.autoscale.policy
    );
    let mut scheduler = make_scheduler(&cfg.scheduler, active)?;
    let mut policy = make_policy(&cfg.autoscale)?;
    let mean_exec_s: Vec<f64> =
        (0..registry.len()).map(|f| registry.app(f).warm_ms / 1000.0).collect();
    let mut last_tick = Instant::now();
    let mut sched_rng = Pcg64::new(cfg.workload.seed ^ 0x5EED);
    let workload = Workload::generate(&cfg.workload, registry.len(), cfg.workload.seed);
    let vus = cfg.workload.vus.min(n_requests.max(1));

    // Imbalance columns track workers that have ever been active (the
    // simulator's add_worker convention) — not the idle thread pool. The
    // telemetry surface matches the simulator's: sketch mode, lifecycle
    // tracing (span times are wall-clock seconds since server start), and
    // the same deterministic hash-gate sampling by request id.
    let mut metrics = RunMetrics::with_telemetry(
        &cfg.scheduler.name,
        active,
        vus,
        1.0, // duration finalized after the run (wall-clock)
        &cfg.telemetry,
    );
    let mut imbalance_cols = active;
    metrics.record_scale(0.0, active);
    let start = Instant::now();
    let mut loads = vec![0u32; workers];
    // Dispatch attempts (assigned, parked, or rejected) — gates issuing.
    let mut issued = 0usize;
    let mut completed = 0usize;
    let mut rejected = 0usize;
    // Per-request bookkeeping.
    let mut arrival: Vec<Instant> = Vec::new();
    // When the request was handed to a worker (== arrival for immediate
    // assigns; re-stamped when a parked request is claimed or
    // force-placed). The adaptive-wait EWMAs read dispatch -> response,
    // NOT arrival -> response: end-to-end latency would include the
    // pending wait itself and self-inflate the cold-warm delta.
    let mut dispatched: Vec<Instant> = Vec::new();
    let mut vu_of: Vec<usize> = Vec::new();
    let mut step_of: Vec<usize> = Vec::new();
    let mut fn_of: Vec<usize> = Vec::new();
    // VU cursors and wake times.
    let mut vu_step = vec![0usize; vus];
    let mut wake: Vec<(Instant, usize)> = (0..vus).map(|v| (start, v)).collect();
    // Pull dispatch: router pending queue + wall-clock wait deadlines.
    let pull = cfg.pull_dispatch();
    let fair = cfg.dispatch.fair;
    let mut pending_q =
        PendingQueue::with_layout(registry.len(), &cfg.dispatch.weights_sparse());
    let cap_f = cfg.dispatch.caps_dense(registry.len());
    let mut deadlines: Vec<(Instant, u64)> = Vec::new();
    let mut inflight_f = vec![0usize; registry.len()];
    // Adaptive waiting: per-function EWMAs of observed cold and warm
    // response latency; their delta is the cold penalty waiting can
    // avoid, and it caps the wall-clock wait deadline.
    let mut cold_lat_ewma = vec![0.0f64; registry.len()];
    let mut warm_lat_ewma = vec![0.0f64; registry.len()];
    let adaptive = cfg.dispatch.adaptive_wait;
    let wait_for = |f: usize, cold: &[f64], warm: &[f64]| -> f64 {
        let base = cfg.dispatch.max_wait_s;
        if !adaptive || cold[f] <= 0.0 || warm[f] <= 0.0 {
            return base;
        }
        // Floor at 1 ms: a noisy non-positive delta means "no observed
        // cold penalty", i.e. waiting cannot pay — place almost at once.
        // `dispatch.min_wait_s` then floors the adaptive deadline so a
        // transiently tiny cold-penalty estimate cannot collapse the
        // wait to an instant force-place.
        base.min((cold[f] - warm[f]).max(0.001)).max(cfg.dispatch.min_wait_s)
    };

    // ---- wall-clock fault injection (`[faults]`) ----
    // The same seed-derived plan the simulator installs, replayed against
    // wall-clock seconds since server start. A "crashed" worker thread is
    // not killed (it may be mid-execute); instead the router marks it
    // dead, routes around it (the scheduler avoid mask), and treats any
    // response whose dispatch predates the crash as lost — the request
    // consumes a retry attempt exactly like the simulator's re-enqueue.
    let faults_on = cfg.faults.enabled;
    let plan = if faults_on {
        FaultPlan::generate(&cfg.faults, workers, cfg.workload.duration_s, cfg.workload.seed)
    } else {
        FaultPlan::default()
    };
    let (mut next_crash, mut next_recover, mut next_strag) = (0usize, 0usize, 0usize);
    let mut dead = vec![false; workers];
    // Most recent crash instant per worker (never cleared): a response
    // dispatched before it refers to state the crash destroyed.
    let mut last_crash: Vec<Option<Instant>> = vec![None; workers];
    let mut slow = vec![1.0f64; workers];
    let mut attempts: Vec<u32> = Vec::new();
    let mut retry_at: Vec<(Instant, u64)> = Vec::new();
    let mut failed = 0usize;
    metrics.faults_enabled = faults_on;

    while completed + rejected + failed < n_requests {
        // Autoscale control tick (wall clock). The policy only ever moves
        // the active boundary; threads beyond it sit idle on their channel.
        if autoscaling && last_tick.elapsed().as_secs_f64() >= cfg.autoscale.interval_s {
            last_tick = Instant::now();
            let total_running: usize = loads[..active].iter().map(|&l| l as usize).sum();
            let obs = AutoscaleObs {
                now: start.elapsed().as_secs_f64(),
                active_workers: active,
                concurrency: cfg.cluster.concurrency,
                total_running,
                total_queued: 0,
                // The PJRT workers warm on first execution and expose no
                // speculative-init hook, so the warm supply is opaque here
                // and pre-warm plans are applied by the simulator only.
                warm_supply: &[],
                mean_exec_s: &mean_exec_s,
            };
            let d = policy.tick(&obs);
            if let Some(target) = d.target_workers {
                let target = target.clamp(1, workers);
                while active < target {
                    scheduler.on_worker_added(active);
                    active += 1;
                    if active > imbalance_cols {
                        metrics.imbalance.add_worker();
                        imbalance_cols = active;
                    }
                    metrics.record_scale(start.elapsed().as_secs_f64(), active);
                }
                while active > target {
                    active -= 1;
                    scheduler.on_worker_removed(active);
                    metrics.record_scale(start.elapsed().as_secs_f64(), active);
                }
            }
        }
        // Apply fault-plan events whose wall-clock time has passed, then
        // re-dispatch retries whose backoff elapsed.
        if faults_on {
            let now_s = start.elapsed().as_secs_f64();
            while next_crash < plan.crashes.len() && plan.crashes[next_crash].0 <= now_s {
                let (_, w) = plan.crashes[next_crash];
                next_crash += 1;
                if !dead[w] {
                    dead[w] = true;
                    last_crash[w] = Some(Instant::now());
                    metrics.worker_crashes += 1;
                    crate::log_info!("server", "fault: worker {} crashed at t={:.2}s", w, now_s);
                }
            }
            while next_recover < plan.recoveries.len()
                && plan.recoveries[next_recover].0 <= now_s
            {
                let (_, w) = plan.recoveries[next_recover];
                next_recover += 1;
                if dead[w] {
                    dead[w] = false;
                    metrics.worker_recoveries += 1;
                    if let Some(c) = last_crash[w] {
                        metrics.recovery_latency_ms.push(c.elapsed().as_secs_f64() * 1000.0);
                    }
                    crate::log_info!("server", "fault: worker {} recovered at t={:.2}s", w, now_s);
                }
            }
            while next_strag < plan.stragglers.len() && plan.stragglers[next_strag].0 <= now_s {
                let (_, w, m) = plan.stragglers[next_strag];
                next_strag += 1;
                slow[w] = m.max(1.0);
            }
            let now = Instant::now();
            let mut i = 0;
            while i < retry_at.len() {
                if retry_at[i].0 > now {
                    i += 1;
                    continue;
                }
                let (_, rid) = retry_at.swap_remove(i);
                let f = fn_of[rid as usize];
                let w = {
                    let mut ctx = SchedCtx::new(&loads[..active], &mut sched_rng)
                        .with_avoid(&dead[..active]);
                    scheduler.select(f, &mut ctx)
                };
                if dead[w] {
                    // No live worker took it — the avoid mask is advisory
                    // and every candidate was dead. Burn another attempt;
                    // the budget bounds how long the request can wait for
                    // a recovery.
                    let t_s = start.elapsed().as_secs_f64();
                    metrics.trace.record(rid, f, "bind", t_s, t_s, Some(w), "dead-bind");
                    fault_retry_wallclock(
                        rid, cfg, &mut attempts, &mut retry_at, &mut failed, &mut metrics,
                        start, &workload, &vu_of, &step_of, &fn_of, &mut vu_step, &mut wake,
                    );
                    continue;
                }
                loads[w] += 1;
                inflight_f[f] += 1;
                let t_s = start.elapsed().as_secs_f64();
                metrics.record_assignment(w, t_s);
                metrics.trace.record(rid, f, "bind", t_s, t_s, Some(w), "retry");
                dispatched[rid as usize] = Instant::now();
                send_to(
                    &work_tx,
                    &payload_of,
                    rid,
                    f,
                    w,
                    straggler_delay(&slow, w, registry.app(f).warm_ms),
                )?;
            }
        }
        // Pull dispatch: force-place parked requests whose wait deadline
        // passed (warm if the completing workers re-advertised, fallback
        // placement otherwise). Like the simulator, an expired deadline
        // drains its function's queue oldest-first up to the expired
        // request, so adaptive deadlines never reorder a function's line.
        if pull && !deadlines.is_empty() {
            let now = Instant::now();
            let mut i = 0;
            while i < deadlines.len() {
                if deadlines[i].0 > now {
                    i += 1;
                    continue;
                }
                let (_, rid) = deadlines.swap_remove(i);
                let f = fn_of[rid as usize];
                if !pending_q.is_waiting(rid) {
                    continue; // already claimed by an idle worker
                }
                loop {
                    let Some(head) = pending_q.pop_fn(f) else { break };
                    let w = {
                        let mut ctx = SchedCtx::new(&loads[..active], &mut sched_rng);
                        if faults_on {
                            ctx = ctx.with_avoid(&dead[..active]);
                        }
                        scheduler.select(f, &mut ctx)
                    };
                    bind_parked(
                        head,
                        f,
                        w,
                        "deadline",
                        &mut loads,
                        &mut inflight_f,
                        &mut dispatched,
                        &arrival,
                        &mut metrics,
                        start,
                        &work_tx,
                        &payload_of,
                        straggler_delay(&slow, w, registry.app(f).warm_ms),
                    )?;
                    if head == rid {
                        break;
                    }
                }
            }
        }
        // Wake any due VUs (issue their next request).
        let now = Instant::now();
        let mut i = 0;
        while i < wake.len() {
            if wake[i].0 <= now && issued < n_requests {
                let vu = wake[i].1;
                wake.swap_remove(i);
                let step = vu_step[vu];
                if step >= workload.vus[vu].steps.len() {
                    continue;
                }
                // ---- issue the VU's next request ----
                let f = workload.vus[vu].steps[step].function;
                let rid = arrival.len() as u64;
                let t_s = start.elapsed().as_secs_f64();
                metrics.trace.record(rid, f, "arrival", t_s, t_s, None, "");
                policy.on_arrival(f, t_s);
                let decision = {
                    let mut ctx = SchedCtx::new(&loads[..active], &mut sched_rng);
                    if faults_on {
                        ctx = ctx.with_avoid(&dead[..active]);
                    }
                    if pull {
                        ctx.dispatch = Some(DispatchCtx {
                            inflight_f: inflight_f[f],
                            pending_f: pending_q.len_fn(f),
                        });
                    }
                    scheduler.decide(f, &mut ctx)
                };
                let refuse = match decision {
                    Decision::Reject(_) => true,
                    // An Enqueue against a full per-function queue (or
                    // outside the pull protocol) is an admission refusal
                    // — the cap isolates the overflow to this function.
                    Decision::Enqueue => {
                        !pull || (cap_f[f] > 0 && pending_q.len_fn(f) >= cap_f[f])
                    }
                    // The real-time server does not track core slots: a
                    // slot pin degrades to a plain worker assignment.
                    Decision::Assign(_) | Decision::AssignSlot(_, _) => false,
                };
                if refuse {
                    metrics.trace.record(rid, f, "decide", t_s, t_s, None, "reject");
                    metrics.record_reject(f);
                    rejected += 1;
                    // The VU observes the refusal and thinks on.
                    let think = workload.vus[vu].steps[step].think_s;
                    vu_step[vu] = step + 1;
                    wake.push((Instant::now() + Duration::from_secs_f64(think), vu));
                } else {
                    let now = Instant::now();
                    arrival.push(now);
                    dispatched.push(now);
                    vu_of.push(vu);
                    step_of.push(step);
                    fn_of.push(f);
                    attempts.push(0);
                    match decision {
                        Decision::Assign(w) | Decision::AssignSlot(w, _) => {
                            metrics.trace.record(rid, f, "decide", t_s, t_s, Some(w), "assign");
                            loads[w] += 1;
                            inflight_f[f] += 1;
                            metrics.record_assignment(w, start.elapsed().as_secs_f64());
                            send_to(
                                &work_tx,
                                &payload_of,
                                rid,
                                f,
                                w,
                                straggler_delay(&slow, w, registry.app(f).warm_ms),
                            )?;
                        }
                        _ => {
                            metrics.trace.record(rid, f, "decide", t_s, t_s, None, "enqueue");
                            pending_q.push(rid, f);
                            metrics.record_enqueue(pending_q.len());
                            let wait = wait_for(f, &cold_lat_ewma, &warm_lat_ewma);
                            deadlines
                                .push((Instant::now() + Duration::from_secs_f64(wait), rid));
                        }
                    }
                }
                issued += 1;
            } else {
                i += 1;
            }
        }
        // Wait for a response (or the next VU wake / pull deadline).
        let mut timeout = wake
            .iter()
            .map(|(t, _)| t.saturating_duration_since(now))
            .min()
            .unwrap_or(Duration::from_millis(5));
        for (t, _) in &deadlines {
            timeout = timeout.min(t.saturating_duration_since(now));
        }
        for (t, _) in &retry_at {
            timeout = timeout.min(t.saturating_duration_since(now));
        }
        // Pending fault-plan events are wall-clock scheduled outside the
        // wake/deadline lists — poll often enough to apply them promptly.
        if faults_on
            && (next_crash < plan.crashes.len()
                || next_recover < plan.recoveries.len()
                || next_strag < plan.stragglers.len())
        {
            timeout = timeout.min(Duration::from_millis(20));
        }
        let timeout = timeout.max(Duration::from_micros(100));
        match resp_rx.recv_timeout(timeout) {
            Ok(Ok(r)) => {
                loads[r.worker] -= 1;
                inflight_f[r.function] -= 1;
                // Eviction notifications: every function copy whose payload
                // was evicted from this worker's cache.
                for p in &r.evicted_payloads {
                    for f in 0..registry.len() {
                        if &payload_of[f] == p {
                            scheduler.on_evict(r.worker, f);
                        }
                    }
                }
                // Drained workers (beyond the active boundary) and
                // crash-marked workers must not re-advertise idle
                // capacity or claim parked work.
                if r.worker < active && !dead[r.worker] {
                    // Pull dispatch: the now-idle worker claims a parked
                    // request first (a warm start); it only advertises
                    // through on_complete when nothing is waiting.
                    let mut claimed = false;
                    if pull && !pending_q.is_empty() {
                        let p = {
                            let mut ctx = SchedCtx::new(&loads[..active], &mut sched_rng)
                                .with_dispatch(DispatchCtx {
                                    inflight_f: inflight_f[r.function],
                                    pending_f: pending_q.len_fn(r.function),
                                });
                            if faults_on {
                                ctx = ctx.with_avoid(&dead[..active]);
                            }
                            scheduler.on_worker_idle(r.worker, r.function, &mut ctx)
                        };
                        if let Pull::Function(pf) = p {
                            if let Some(rid2) = pending_q.pop_fn(pf) {
                                bind_parked(
                                    rid2,
                                    pf,
                                    r.worker,
                                    "pull",
                                    &mut loads,
                                    &mut inflight_f,
                                    &mut dispatched,
                                    &arrival,
                                    &mut metrics,
                                    start,
                                    &work_tx,
                                    &payload_of,
                                    straggler_delay(&slow, r.worker, registry.app(pf).warm_ms),
                                )?;
                                claimed = true;
                            }
                        }
                    }
                    if !claimed {
                        {
                            let mut ctx = SchedCtx::new(&loads[..active], &mut sched_rng);
                            if faults_on {
                                ctx = ctx.with_avoid(&dead[..active]);
                            }
                            scheduler.on_complete(r.worker, r.function, &mut ctx);
                        }
                        // Idle-capacity fairness claim (same rule as the
                        // simulator): serve the backlog's next request
                        // among functions whose warm prospect is gone, in
                        // DRR order — the advertisement above survives.
                        if pull && !pending_q.is_empty() {
                            let eligible = |g: usize| inflight_f[g] == 0;
                            let got = if fair {
                                pending_q.pop_fair_where(eligible)
                            } else {
                                pending_q.pop_arrival_where(eligible)
                            };
                            if let Some((rid2, pf)) = got {
                                bind_parked(
                                    rid2,
                                    pf,
                                    r.worker,
                                    "idle",
                                    &mut loads,
                                    &mut inflight_f,
                                    &mut dispatched,
                                    &arrival,
                                    &mut metrics,
                                    start,
                                    &work_tx,
                                    &payload_of,
                                    straggler_delay(&slow, r.worker, registry.app(pf).warm_ms),
                                )?;
                            }
                        }
                    }
                }
                // Fault injection: a response whose dispatch predates the
                // worker's most recent crash refers to state the crash
                // destroyed — the result is lost. A cold execution may
                // also fail initialization (seed-derived coin, same
                // construction as the simulator). Either way the request
                // is not resolved; it consumes a retry attempt. Worker
                // bookkeeping above already ran: the slot is genuinely
                // free, only the result is discarded.
                if faults_on {
                    let i = r.rid as usize;
                    let crashed = last_crash[r.worker].is_some_and(|c| dispatched[i] < c);
                    let init_fail = !crashed
                        && r.cold
                        && cfg.faults.init_fail_prob > 0.0
                        && fault_coin(cfg.workload.seed, r.rid, attempts[i])
                            < cfg.faults.init_fail_prob;
                    if crashed || init_fail {
                        let now_s = start.elapsed().as_secs_f64();
                        if crashed {
                            metrics.trace.record(
                                r.rid, r.function, "crash", now_s, now_s, Some(r.worker), "lost",
                            );
                        } else {
                            metrics.init_failures += 1;
                            metrics.trace.record(
                                r.rid, r.function, "init_fail", now_s, now_s, Some(r.worker), "",
                            );
                        }
                        fault_retry_wallclock(
                            r.rid, cfg, &mut attempts, &mut retry_at, &mut failed, &mut metrics,
                            start, &workload, &vu_of, &step_of, &fn_of, &mut vu_step, &mut wake,
                        );
                        continue;
                    }
                }
                let rid = r.rid as usize;
                let lat = arrival[rid].elapsed().as_secs_f64();
                if pull {
                    // Feed the adaptive-deadline EWMAs from the
                    // dispatch -> response latency: the cold−warm delta
                    // of the *service* is the observed cold penalty.
                    // (End-to-end latency would include the pending wait
                    // and self-inflate the delta.)
                    const WAIT_ALPHA: f64 = 0.2;
                    let service_lat = dispatched[rid].elapsed().as_secs_f64();
                    let e = if r.cold {
                        &mut cold_lat_ewma[r.function]
                    } else {
                        &mut warm_lat_ewma[r.function]
                    };
                    *e = if *e > 0.0 {
                        WAIT_ALPHA * service_lat + (1.0 - WAIT_ALPHA) * *e
                    } else {
                        service_lat
                    };
                }
                let resp_s = start.elapsed().as_secs_f64();
                metrics.record_response(lat, r.cold, 0.0, resp_s);
                if metrics.trace.sampled(r.rid) {
                    // No observable init boundary on the real workers
                    // (PJRT compilation happens inside execute), so the
                    // whole dispatch -> response window is one `service`
                    // span; its `cold`/`warm` detail carries the split.
                    let disp_s = dispatched[rid].duration_since(start).as_secs_f64();
                    let kind = if r.cold { "cold" } else { "warm" };
                    metrics.trace.record(
                        r.rid, r.function, "service", disp_s, resp_s, Some(r.worker), kind,
                    );
                    metrics.trace.record(
                        r.rid, r.function, "complete", resp_s, resp_s, Some(r.worker), kind,
                    );
                }
                debug_assert!(r.digest.iter().all(|d| d.is_finite()));
                completed += 1;
                // Closed loop: schedule the VU's next step.
                let vu = vu_of[rid];
                let think = workload.vus[vu].steps[step_of[rid]].think_s;
                vu_step[vu] = step_of[rid] + 1;
                wake.push((Instant::now() + Duration::from_secs_f64(think), vu));
            }
            Ok(Err(e)) => return Err(e),
            Err(mpsc::RecvTimeoutError::Timeout) => {}
            Err(mpsc::RecvTimeoutError::Disconnected) => {
                return Err("all workers disconnected".into());
            }
        }
    }

    metrics.duration_s = start.elapsed().as_secs_f64();
    metrics.finalize_scaling(metrics.duration_s);
    // Conservation surface (same identity as the simulator): every
    // admitted request resolved as completed or failed; refusals never
    // entered `arrival`.
    metrics.arrivals = arrival.len() as u64 + rejected as u64;
    // Drop senders so workers exit; join them.
    drop(work_tx);
    drop(resp_tx);
    for h in handles {
        let _ = h.join();
    }
    Ok(metrics)
}

#[cfg(test)]
mod tests {
    // Real-time server tests live in rust/tests/e2e.rs (they need built
    // artifacts and real wall-clock time).
}
