//! # Hiku: pull-based scheduling for serverless computing
//!
//! A full reproduction of "Hiku: Pull-Based Scheduling for Serverless
//! Computing" (Akbari & Hauswirth, CCGRID 2025) as a three-layer
//! Rust + JAX + Pallas system. Start at the repository `README.md` for
//! the quickstart; `DESIGN.md` holds the architecture reference and
//! `EXPERIMENTS.md` the paper-vs-measured results and bench commands.
//!
//! - [`scheduler`] — the paper's contribution: Hiku (Algorithm 1) plus all
//!   baseline scheduling algorithms, behind the decision-based dispatch
//!   protocol (`decide -> Assign | Enqueue | Reject`).
//! - [`dispatch`] — router-owned dispatch infrastructure: the pending
//!   queue behind `Enqueue` (per-function FIFO, deterministic ordering).
//! - [`faults`] — deterministic fault injection: seed-derived crash /
//!   straggler / init-failure plans driving the recovery path
//!   (re-enqueue + retry budget + warm-state handoff, DESIGN.md §10).
//! - [`platform`] — the FaaS substrate: workers, sandboxes, keep-alive.
//! - [`autoscale`] — policy-driven elastic scaling and predictive
//!   pre-warming (closes the §II-C auto-scaling loop).
//! - [`workload`] — FunctionBench registry, Azure-like traces, load gen.
//! - [`sim`] — deterministic discrete-event simulator (the paper's cluster
//!   experiments, Figs 10-17): calendar-queue event core, incremental load
//!   accounting, and the sharded parallel engine ([`sim::shard`]) that
//!   partitions workers across OS threads behind an event-time barrier.
//! - [`runtime`]/[`server`] — PJRT-backed real-time serving of the AOT
//!   compiled payloads (end-to-end validation).
//!
//! Determinism is the crate-wide contract: every run is a pure function
//! of (config, seed) — including autoscaled, pre-warmed and sharded runs
//! (per shard count) — which turns every figure into a regression test.
//! See `DESIGN.md` §3 for the rules and `tests/determinism.rs` for the
//! enforcement. The rulebook itself is machine-checked: `tools/detlint`
//! (DESIGN.md §12) lints the tree for unordered iteration, wall-clock
//! reads, and ambient randomness, and CI runs it as a blocking job.

// The tree has never needed `unsafe` (the sharded engine uses std sync
// primitives only); forbid locks that in — `allow` can't re-enable it.
#![forbid(unsafe_code)]
// Promoted to `-D missing_docs` in CI (job `rust`, docs gate step).
#![warn(missing_docs)]

pub mod autoscale;
pub mod bench;
pub mod config;
pub mod dispatch;
pub mod faults;
pub mod logging;
pub mod metrics;
pub mod platform;
pub mod report;
pub mod runtime;
pub mod scheduler;
pub mod server;
pub mod sim;
pub mod stats;
pub mod util;
pub mod workload;
