//! # Hiku: pull-based scheduling for serverless computing
//!
//! A full reproduction of "Hiku: Pull-Based Scheduling for Serverless
//! Computing" (Akbari & Hauswirth, CCGRID 2025) as a three-layer
//! Rust + JAX + Pallas system. See DESIGN.md for the architecture and
//! EXPERIMENTS.md for paper-vs-measured results.
//!
//! - [`scheduler`] — the paper's contribution: Hiku (Algorithm 1) plus all
//!   baseline scheduling algorithms.
//! - [`platform`] — the FaaS substrate: workers, sandboxes, keep-alive.
//! - [`autoscale`] — policy-driven elastic scaling and predictive
//!   pre-warming (closes the §II-C auto-scaling loop).
//! - [`workload`] — FunctionBench registry, Azure-like traces, load gen.
//! - [`sim`] — deterministic discrete-event simulator (the paper's cluster
//!   experiments, Figs 10-17).
//! - [`runtime`]/[`server`] — PJRT-backed real-time serving of the AOT
//!   compiled payloads (end-to-end validation).

pub mod autoscale;
pub mod bench;
pub mod config;
pub mod logging;
pub mod platform;
pub mod metrics;
pub mod report;
pub mod runtime;
pub mod scheduler;
pub mod server;
pub mod sim;
pub mod stats;
pub mod util;
pub mod workload;
