//! Leveled logger (no `log`/`env_logger` wiring needed on the hot path).
//!
//! The level is a process-global atomic read with Relaxed ordering, so a
//! disabled log site costs one load + branch. Set via `HIKU_LOG`
//! (error|warn|info|debug|trace) or programmatically with `set_level`.

use std::sync::atomic::{AtomicU8, Ordering};

/// Log severity, most to least severe.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    /// Unrecoverable or data-corrupting conditions.
    Error = 0,
    /// Suspicious but survivable conditions.
    Warn = 1,
    /// High-level progress (the default level).
    Info = 2,
    /// Per-decision detail (scale events, routing).
    Debug = 3,
    /// Per-event firehose.
    Trace = 4,
}

impl Level {
    /// Parse a level name (case-insensitive; `HIKU_LOG` values).
    pub fn from_str(s: &str) -> Option<Level> {
        match s.to_ascii_lowercase().as_str() {
            "error" => Some(Level::Error),
            "warn" | "warning" => Some(Level::Warn),
            "info" => Some(Level::Info),
            "debug" => Some(Level::Debug),
            "trace" => Some(Level::Trace),
            _ => None,
        }
    }

    /// Uppercase display name.
    pub fn name(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN",
            Level::Info => "INFO",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }
}

static LEVEL: AtomicU8 = AtomicU8::new(2); // Info
static INIT: std::sync::Once = std::sync::Once::new();

/// Initialize from HIKU_LOG if set. Idempotent.
pub fn init() {
    INIT.call_once(|| {
        if let Ok(v) = std::env::var("HIKU_LOG") {
            if let Some(l) = Level::from_str(&v) {
                set_level(l);
            }
        }
    });
}

/// Set the process-global log level.
pub fn set_level(l: Level) {
    LEVEL.store(l as u8, Ordering::Relaxed);
}

/// The current process-global log level.
pub fn level() -> Level {
    match LEVEL.load(Ordering::Relaxed) {
        0 => Level::Error,
        1 => Level::Warn,
        2 => Level::Info,
        3 => Level::Debug,
        _ => Level::Trace,
    }
}

/// Whether a message at level `l` would be emitted (one relaxed load).
#[inline]
pub fn enabled(l: Level) -> bool {
    (l as u8) <= LEVEL.load(Ordering::Relaxed)
}

/// Emit one log line to stderr if `l` is enabled (use the `log_*!` macros).
pub fn log(l: Level, target: &str, msg: std::fmt::Arguments) {
    if enabled(l) {
        eprintln!("[{:5}] {}: {}", l.name(), target, msg);
    }
}

/// Log at [`Level::Error`] with `format!` arguments.
#[macro_export]
macro_rules! log_error { ($target:expr, $($arg:tt)*) => { $crate::logging::log($crate::logging::Level::Error, $target, format_args!($($arg)*)) } }
/// Log at [`Level::Warn`] with `format!` arguments.
#[macro_export]
macro_rules! log_warn { ($target:expr, $($arg:tt)*) => { $crate::logging::log($crate::logging::Level::Warn, $target, format_args!($($arg)*)) } }
/// Log at [`Level::Info`] with `format!` arguments.
#[macro_export]
macro_rules! log_info { ($target:expr, $($arg:tt)*) => { $crate::logging::log($crate::logging::Level::Info, $target, format_args!($($arg)*)) } }
/// Log at [`Level::Debug`] with `format!` arguments.
#[macro_export]
macro_rules! log_debug { ($target:expr, $($arg:tt)*) => { $crate::logging::log($crate::logging::Level::Debug, $target, format_args!($($arg)*)) } }
/// Log at [`Level::Trace`] with `format!` arguments.
#[macro_export]
macro_rules! log_trace { ($target:expr, $($arg:tt)*) => { $crate::logging::log($crate::logging::Level::Trace, $target, format_args!($($arg)*)) } }

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_parse() {
        assert_eq!(Level::from_str("info"), Some(Level::Info));
        assert_eq!(Level::from_str("WARN"), Some(Level::Warn));
        assert_eq!(Level::from_str("bogus"), None);
    }

    #[test]
    fn level_ordering_gates() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info);
    }
}
