//! Router-side dispatch-protocol infrastructure: the pending queue behind
//! [`crate::scheduler::Decision::Enqueue`] (DESIGN.md §8).
//!
//! The queue is **router-owned** (one per engine or server instance, not
//! per scheduler): schedulers only answer `decide()`; parking, admission,
//! wait deadlines, pulls and cross-shard steals are the router's job.
//! Ordering is deterministic by construction — per-function FIFO for
//! pulls, global arrival FIFO for deadline flushes and steals, no hashing
//! and no ambient state — so a run under a fixed (config, seed) replays
//! bit-for-bit.
//!
//! Representation: one `VecDeque` per function (the pull order) plus a
//! global arrival-ordered mirror, lazily invalidated through a
//! per-request waiting flag. Pops skip stale mirror entries, so both
//! views stay amortized O(1) per operation without cross-linked nodes.

use std::collections::VecDeque;

use crate::workload::spec::FunctionId;

/// Per-function FIFO pending queues with a global arrival-order view.
/// Requests are identified by the router's dense request id.
#[derive(Debug, Default)]
pub struct PendingQueue {
    /// Per-function FIFO of waiting request ids (pull order).
    queues: Vec<VecDeque<u64>>,
    /// Global arrival-ordered (rid, function) mirror (flush/steal order).
    order: VecDeque<(u64, FunctionId)>,
    /// `waiting[rid]`: the request is currently parked. Entries in the
    /// queues above whose flag is false are stale and skipped on pop.
    waiting: Vec<bool>,
    /// Parked requests right now (live entries only).
    len: usize,
    /// Parked requests per function (live entries only).
    len_f: Vec<usize>,
}

impl PendingQueue {
    /// An empty pending queue.
    pub fn new() -> Self {
        Self::default()
    }

    /// Parked requests across all functions.
    pub fn len(&self) -> usize {
        self.len
    }

    /// True when nothing is parked.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Parked requests waiting for function `f`.
    pub fn len_fn(&self, f: FunctionId) -> usize {
        self.len_f.get(f).copied().unwrap_or(0)
    }

    /// Whether request `rid` is currently parked.
    pub fn is_waiting(&self, rid: u64) -> bool {
        self.waiting.get(rid as usize).copied().unwrap_or(false)
    }

    /// Park request `rid` (a request for function `f`). Ids must be
    /// unique per queue lifetime (the router's dense request ids are).
    pub fn push(&mut self, rid: u64, f: FunctionId) {
        let i = rid as usize;
        if i >= self.waiting.len() {
            self.waiting.resize(i + 1, false);
        }
        debug_assert!(!self.waiting[i], "request {rid} parked twice");
        self.waiting[i] = true;
        if f >= self.queues.len() {
            self.queues.resize_with(f + 1, VecDeque::new);
            self.len_f.resize(f + 1, 0);
        }
        self.queues[f].push_back(rid);
        self.order.push_back((rid, f));
        self.len += 1;
        self.len_f[f] += 1;
    }

    /// Claim the oldest request parked for `f` (an idle worker's pull).
    pub fn pop_fn(&mut self, f: FunctionId) -> Option<u64> {
        let q = self.queues.get_mut(f)?;
        while let Some(rid) = q.pop_front() {
            if self.waiting[rid as usize] {
                self.waiting[rid as usize] = false;
                self.len -= 1;
                self.len_f[f] -= 1;
                return Some(rid);
            }
            // Stale mirror entry (cancelled or claimed globally): skip.
        }
        None
    }

    /// Claim the globally oldest parked request, any function (the
    /// deadline-flush and steal order).
    pub fn pop_oldest(&mut self) -> Option<(u64, FunctionId)> {
        while let Some((rid, f)) = self.order.pop_front() {
            if self.waiting[rid as usize] {
                self.waiting[rid as usize] = false;
                self.len -= 1;
                self.len_f[f] -= 1;
                return Some((rid, f));
            }
        }
        None
    }

    /// Un-park request `rid` for `f` without claiming it (deadline fired,
    /// request stolen, …). Returns false when it was not parked.
    pub fn cancel(&mut self, rid: u64, f: FunctionId) -> bool {
        let i = rid as usize;
        if !self.waiting.get(i).copied().unwrap_or(false) {
            return false;
        }
        self.waiting[i] = false;
        self.len -= 1;
        self.len_f[f] -= 1;
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn per_function_fifo_and_counts() {
        let mut pq = PendingQueue::new();
        assert!(pq.is_empty());
        pq.push(0, 2);
        pq.push(1, 0);
        pq.push(2, 2);
        assert_eq!(pq.len(), 3);
        assert_eq!(pq.len_fn(2), 2);
        assert!(pq.is_waiting(0) && pq.is_waiting(1) && pq.is_waiting(2));
        assert_eq!(pq.pop_fn(2), Some(0), "oldest of f=2 first");
        assert_eq!(pq.pop_fn(2), Some(2));
        assert_eq!(pq.pop_fn(2), None);
        assert_eq!(pq.len(), 1);
        assert!(!pq.is_waiting(0));
        assert_eq!(pq.pop_fn(7), None, "unknown function is empty");
    }

    #[test]
    fn global_order_interleaves_functions() {
        let mut pq = PendingQueue::new();
        pq.push(10, 1);
        pq.push(11, 0);
        pq.push(12, 1);
        assert_eq!(pq.pop_oldest(), Some((10, 1)));
        assert_eq!(pq.pop_oldest(), Some((11, 0)));
        assert_eq!(pq.pop_oldest(), Some((12, 1)));
        assert_eq!(pq.pop_oldest(), None);
        assert!(pq.is_empty());
    }

    #[test]
    fn cancel_and_stale_entries_are_skipped() {
        let mut pq = PendingQueue::new();
        pq.push(0, 3);
        pq.push(1, 3);
        pq.push(2, 3);
        assert!(pq.cancel(1, 3), "cancel a parked request");
        assert!(!pq.cancel(1, 3), "double-cancel is a no-op");
        assert_eq!(pq.len(), 2);
        assert_eq!(pq.len_fn(3), 2);
        // The per-function pop skips the cancelled id.
        assert_eq!(pq.pop_fn(3), Some(0));
        assert_eq!(pq.pop_fn(3), Some(2));
        // The global mirror's stale entries are skipped too.
        pq.push(4, 1);
        assert_eq!(pq.pop_oldest(), Some((4, 1)));
        assert!(pq.is_empty());
    }

    #[test]
    fn cross_view_claims_invalidate_each_other() {
        let mut pq = PendingQueue::new();
        pq.push(0, 0);
        pq.push(1, 1);
        // Claimed through the per-function view; the global mirror must
        // not hand it out again.
        assert_eq!(pq.pop_fn(0), Some(0));
        assert_eq!(pq.pop_oldest(), Some((1, 1)));
        assert_eq!(pq.pop_oldest(), None);
        // And the other way around.
        pq.push(2, 1);
        assert_eq!(pq.pop_oldest(), Some((2, 1)));
        assert_eq!(pq.pop_fn(1), None);
        assert_eq!(pq.len(), 0);
    }
}
