//! Router-side dispatch-protocol infrastructure: the pending queue behind
//! [`crate::scheduler::Decision::Enqueue`] (DESIGN.md §8).
//!
//! The queue is **router-owned** (one per engine or server instance, not
//! per scheduler): schedulers only answer `decide()`; parking, admission,
//! wait deadlines, pulls and cross-shard steals are the router's job.
//! Ordering is deterministic by construction — per-function FIFO for
//! pulls, and **deficit-round-robin (DRR) over the function queues** for
//! every multi-request drain (wake flushes, cross-shard steal donation,
//! idle-capacity claims) — no hashing and no ambient state — so a run
//! under a fixed (config, seed) replays bit-for-bit.
//!
//! ## Fair draining (DRR)
//!
//! PR 4 drained the backlog in global arrival order, which lets one hot
//! function monopolize every flush and steal (the per-function-granularity
//! fairness problem of Kaffes et al.). [`PendingQueue::pop_fair`] replaces
//! that with deficit-round-robin: a cursor walks the function queues in
//! **fixed function-id order**; a visited non-empty queue is recharged
//! with `weight_f` credits (config `dispatch.weights`, default 1) when its
//! deficit is zero, serves one request per call, and keeps the cursor
//! until its credits are spent or it empties; empty (or filtered-out)
//! queues forfeit nothing but their turn, and an *emptied* queue resets
//! its deficit to zero (inactive queues accumulate no credit — standard
//! DRR). The cursor/deficit state is part of the router, so the drain
//! order is a pure function of the push/pop history — the determinism
//! rule documented in DESIGN.md §8. The PR 4 arrival order survives as
//! [`PendingQueue::pop_arrival`] for the `dispatch.fair = false` ablation
//! baseline (request ids are dense and allocated in arrival order, so the
//! globally oldest request is the minimum live id across queue heads).
//!
//! Representation: one `VecDeque` per function (FIFO in arrival order)
//! plus a per-request waiting flag; `cancel` marks entries stale in place
//! and pops skip them, so every operation stays amortized O(1) (pops
//! O(active functions) at worst for the cursor walk / head scan).
//!
//! ## Fault re-parking
//!
//! The failure model (DESIGN.md §10) re-enters the queue through plain
//! `push`: a request displaced by a worker crash, a failed cold init, or
//! a straggler hedge is re-parked at the **tail** of its function queue —
//! it lost its original slot along with its worker. That is exactly the
//! FIFO contract for `pop_fn`/`pop_fair` (per-function order is
//! push order), but it relaxes `pop_arrival`'s "globally oldest first"
//! to per-queue-head oldest: a re-pushed old id sits behind younger
//! siblings until they drain, so the head scan may briefly prefer a
//! younger head elsewhere. Ordering stays a pure function of the
//! push/pop history either way — fault runs replay bit-for-bit.

use std::collections::VecDeque;

use crate::workload::spec::FunctionId;

/// Runtime-class split for the head-of-line-blocking breakdown
/// (DESIGN.md §11): a function whose registry `warm_ms` is at or below
/// this threshold is "short". Short functions are the ones core-granular
/// scheduling protects — at worker granularity they queue behind long
/// executions on a busy node even while sibling cores idle. The 200 ms
/// line splits the base app suite cleanly (linpack 58 / float_operation
/// 94 / json 105 / matmul 125 / pyaes 149 vs gzip 303 / chameleon 392 /
/// dd 549).
pub const SHORT_CLASS_WARM_MS: f64 = 200.0;

/// Whether a function with the given registry `warm_ms` is short-class.
#[inline]
pub fn is_short_class(warm_ms: f64) -> bool {
    warm_ms <= SHORT_CLASS_WARM_MS
}

/// Per-function FIFO pending queues drained fairly (DRR) or in global
/// arrival order. Requests are identified by the router's dense request
/// id, which is allocated in arrival order.
#[derive(Debug, Default)]
pub struct PendingQueue {
    /// Per-function FIFO of waiting request ids (pull order).
    queues: Vec<VecDeque<u64>>,
    /// `waiting[rid]`: the request is currently parked. Entries in the
    /// queues above whose flag is false are stale and skipped on pop.
    waiting: Vec<bool>,
    /// Parked requests right now (live entries only).
    len: usize,
    /// Parked requests per function (live entries only).
    len_f: Vec<usize>,
    /// DRR weight per function (`dispatch.weights`; default 1 — plain
    /// round-robin). Grows in lockstep with `queues`.
    weights: Vec<u32>,
    /// DRR credits left for the cursor's current visit of each queue.
    deficit: Vec<u32>,
    /// Next function id the DRR cursor visits (fixed-id-order walk).
    cursor: usize,
    /// Telemetry: requests ever parked (monotone; survives pops).
    pushed: u64,
    /// Telemetry: high-water mark of the live queue depth.
    peak: usize,
}

impl PendingQueue {
    /// An empty pending queue (every function weighted 1).
    pub fn new() -> Self {
        Self::default()
    }

    /// An empty pending queue pre-sized for `functions` function types
    /// with the given `(function, weight)` DRR overrides (weights default
    /// to 1; entries beyond `functions` are ignored — they can never be
    /// parked).
    pub fn with_layout(functions: usize, weights: &[(usize, u32)]) -> Self {
        let mut q = Self {
            queues: Vec::new(),
            waiting: Vec::new(),
            len: 0,
            len_f: Vec::new(),
            weights: Vec::new(),
            deficit: Vec::new(),
            cursor: 0,
            pushed: 0,
            peak: 0,
        };
        q.grow_functions(functions);
        for &(f, w) in weights {
            if f < functions {
                q.weights[f] = w.max(1);
            }
        }
        q
    }

    /// Ensure the per-function tables cover function ids `< n`.
    fn grow_functions(&mut self, n: usize) {
        if n > self.queues.len() {
            self.queues.resize_with(n, VecDeque::new);
            self.len_f.resize(n, 0);
            self.weights.resize(n, 1);
            self.deficit.resize(n, 0);
        }
    }

    /// Parked requests across all functions.
    pub fn len(&self) -> usize {
        self.len
    }

    /// True when nothing is parked.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Parked requests waiting for function `f`.
    pub fn len_fn(&self, f: FunctionId) -> usize {
        self.len_f.get(f).copied().unwrap_or(0)
    }

    /// Whether request `rid` is currently parked.
    pub fn is_waiting(&self, rid: u64) -> bool {
        self.waiting.get(rid as usize).copied().unwrap_or(false)
    }

    /// Park request `rid` (a request for function `f`). Ids must be
    /// unique per queue lifetime (the router's dense request ids are).
    pub fn push(&mut self, rid: u64, f: FunctionId) {
        let i = rid as usize;
        if i >= self.waiting.len() {
            self.waiting.resize(i + 1, false);
        }
        debug_assert!(!self.waiting[i], "request {rid} parked twice");
        self.waiting[i] = true;
        self.grow_functions(f + 1);
        self.queues[f].push_back(rid);
        self.len += 1;
        self.len_f[f] += 1;
        self.pushed += 1;
        if self.len > self.peak {
            self.peak = self.len;
        }
    }

    /// Requests ever parked over the queue's lifetime (telemetry; never
    /// decremented by pops or cancels).
    pub fn pushed(&self) -> u64 {
        self.pushed
    }

    /// High-water mark of the live queue depth (telemetry).
    pub fn peak_len(&self) -> usize {
        self.peak
    }

    /// Pop the oldest *live* entry of `f`'s queue. Caller guarantees
    /// `len_f[f] > 0`; stale (cancelled) heads are dropped on the way.
    /// Enforces the DRR invariant on every exit path: an emptied queue
    /// forfeits its remaining deficit (inactive queues hold no credit),
    /// whether it was emptied by a fair pop, a warm pull (`pop_fn`) or a
    /// deadline drain.
    fn pop_live(&mut self, f: FunctionId) -> u64 {
        loop {
            let rid = self.queues[f].pop_front().expect("len_f > 0 implies a live entry");
            if self.waiting[rid as usize] {
                self.waiting[rid as usize] = false;
                self.len -= 1;
                self.len_f[f] -= 1;
                if self.len_f[f] == 0 {
                    self.deficit[f] = 0;
                }
                return rid;
            }
        }
    }

    /// The oldest live request id parked for `f`, without claiming it
    /// (stale heads are dropped on the way).
    fn front_live(&mut self, f: FunctionId) -> Option<u64> {
        if self.len_f.get(f).copied().unwrap_or(0) == 0 {
            return None;
        }
        loop {
            let &rid = self.queues[f].front().expect("len_f > 0 implies a live entry");
            if self.waiting[rid as usize] {
                return Some(rid);
            }
            self.queues[f].pop_front();
        }
    }

    /// Advance the DRR cursor one step in fixed function-id order.
    fn advance_cursor(&mut self) {
        self.cursor = if self.cursor + 1 >= self.queues.len() { 0 } else { self.cursor + 1 };
    }

    /// Claim the oldest request parked for `f` (an idle worker's pull).
    pub fn pop_fn(&mut self, f: FunctionId) -> Option<u64> {
        if self.len_f.get(f).copied().unwrap_or(0) == 0 {
            return None;
        }
        Some(self.pop_live(f))
    }

    /// Claim the next request in deficit-round-robin order — the fair
    /// drain used by wake flushes, steal donation and idle-capacity
    /// claims (`dispatch.fair = true`, the default). See the module docs
    /// for the determinism rule.
    pub fn pop_fair(&mut self) -> Option<(u64, FunctionId)> {
        self.pop_fair_where(|_| true)
    }

    /// [`PendingQueue::pop_fair`] restricted to functions for which
    /// `eligible` holds (e.g. "no warm prospect in flight"). Ineligible
    /// queues keep their deficit and are skipped; returns `None` when no
    /// eligible function has a parked request.
    pub fn pop_fair_where(
        &mut self,
        mut eligible: impl FnMut(FunctionId) -> bool,
    ) -> Option<(u64, FunctionId)> {
        if self.len == 0 {
            return None;
        }
        let n = self.queues.len();
        for _ in 0..n {
            let f = self.cursor;
            if self.len_f[f] == 0 {
                // Inactive queues accumulate no credit (standard DRR).
                self.deficit[f] = 0;
                self.advance_cursor();
                continue;
            }
            if !eligible(f) {
                self.advance_cursor();
                continue;
            }
            if self.deficit[f] == 0 {
                self.deficit[f] = self.weights[f];
            }
            self.deficit[f] -= 1;
            let rid = self.pop_live(f); // resets the deficit if f emptied
            if self.len_f[f] == 0 || self.deficit[f] == 0 {
                self.advance_cursor();
            }
            return Some((rid, f));
        }
        None
    }

    /// Claim the globally oldest parked request — the PR 4 drain order,
    /// kept as the `dispatch.fair = false` ablation baseline. Request ids
    /// are dense and allocated in arrival order, so "oldest" is the
    /// minimum live id across queue heads (O(functions) per pop).
    pub fn pop_arrival(&mut self) -> Option<(u64, FunctionId)> {
        self.pop_arrival_where(|_| true)
    }

    /// [`PendingQueue::pop_arrival`] restricted to functions for which
    /// `eligible` holds.
    pub fn pop_arrival_where(
        &mut self,
        mut eligible: impl FnMut(FunctionId) -> bool,
    ) -> Option<(u64, FunctionId)> {
        if self.len == 0 {
            return None;
        }
        let mut best: Option<(u64, FunctionId)> = None;
        for f in 0..self.queues.len() {
            if self.len_f[f] == 0 || !eligible(f) {
                continue;
            }
            let head = self.front_live(f).expect("len_f > 0 implies a live entry");
            let older = match best {
                Some((rid, _)) => head < rid,
                None => true,
            };
            if older {
                best = Some((head, f));
            }
        }
        let (_, f) = best?;
        Some((self.pop_live(f), f))
    }

    /// Un-park request `rid` for `f` without claiming it (deadline fired,
    /// request stolen, …). Returns false when it was not parked.
    pub fn cancel(&mut self, rid: u64, f: FunctionId) -> bool {
        let i = rid as usize;
        if !self.waiting.get(i).copied().unwrap_or(false) {
            return false;
        }
        self.waiting[i] = false;
        self.len -= 1;
        self.len_f[f] -= 1;
        if self.len_f[f] == 0 {
            self.deficit[f] = 0; // an emptied queue forfeits its credit
        }
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn per_function_fifo_and_counts() {
        let mut pq = PendingQueue::new();
        assert!(pq.is_empty());
        pq.push(0, 2);
        pq.push(1, 0);
        pq.push(2, 2);
        assert_eq!(pq.len(), 3);
        assert_eq!(pq.len_fn(2), 2);
        assert!(pq.is_waiting(0) && pq.is_waiting(1) && pq.is_waiting(2));
        assert_eq!(pq.pop_fn(2), Some(0), "oldest of f=2 first");
        assert_eq!(pq.pop_fn(2), Some(2));
        assert_eq!(pq.pop_fn(2), None);
        assert_eq!(pq.len(), 1);
        assert!(!pq.is_waiting(0));
        assert_eq!(pq.pop_fn(7), None, "unknown function is empty");
    }

    #[test]
    fn arrival_order_interleaves_functions() {
        let mut pq = PendingQueue::new();
        pq.push(10, 1);
        pq.push(11, 0);
        pq.push(12, 1);
        assert_eq!(pq.pop_arrival(), Some((10, 1)));
        assert_eq!(pq.pop_arrival(), Some((11, 0)));
        assert_eq!(pq.pop_arrival(), Some((12, 1)));
        assert_eq!(pq.pop_arrival(), None);
        assert!(pq.is_empty());
    }

    #[test]
    fn fair_pop_round_robins_across_functions() {
        // Function 0 monopolizes the arrival order; DRR still alternates.
        let mut pq = PendingQueue::with_layout(3, &[]);
        for rid in 0..4 {
            pq.push(rid, 0);
        }
        pq.push(4, 2);
        pq.push(5, 2);
        let order: Vec<(u64, FunctionId)> = std::iter::from_fn(|| pq.pop_fair()).collect();
        assert_eq!(order, vec![(0, 0), (4, 2), (1, 0), (5, 2), (2, 0), (3, 0)]);
        assert!(pq.is_empty());
    }

    #[test]
    fn fair_pop_honors_weights() {
        // Weight 2 on function 1: it serves two per visit.
        let mut pq = PendingQueue::with_layout(2, &[(1, 2)]);
        for rid in 0..3 {
            pq.push(rid, 0);
        }
        for rid in 3..7 {
            pq.push(rid, 1);
        }
        let order: Vec<FunctionId> =
            std::iter::from_fn(|| pq.pop_fair()).map(|(_, f)| f).collect();
        assert_eq!(order, vec![0, 1, 1, 0, 1, 1, 0]);
    }

    #[test]
    fn fair_pop_filter_skips_ineligible_functions() {
        let mut pq = PendingQueue::with_layout(3, &[]);
        pq.push(0, 0);
        pq.push(1, 1);
        pq.push(2, 2);
        // Only function 1 is eligible.
        assert_eq!(pq.pop_fair_where(|f| f == 1), Some((1, 1)));
        assert_eq!(pq.pop_fair_where(|f| f == 1), None, "nothing eligible left");
        assert_eq!(pq.len(), 2, "ineligible requests stay parked");
        // Arrival-order variant honors the same filter.
        assert_eq!(pq.pop_arrival_where(|f| f == 2), Some((2, 2)));
        assert_eq!(pq.pop_arrival(), Some((0, 0)));
    }

    #[test]
    fn cancel_and_stale_entries_are_skipped() {
        let mut pq = PendingQueue::new();
        pq.push(0, 3);
        pq.push(1, 3);
        pq.push(2, 3);
        assert!(pq.cancel(1, 3), "cancel a parked request");
        assert!(!pq.cancel(1, 3), "double-cancel is a no-op");
        assert_eq!(pq.len(), 2);
        assert_eq!(pq.len_fn(3), 2);
        // The per-function pop skips the cancelled id.
        assert_eq!(pq.pop_fn(3), Some(0));
        assert_eq!(pq.pop_fn(3), Some(2));
        // Both drain orders skip stale entries too.
        pq.push(4, 1);
        pq.push(5, 1);
        assert!(pq.cancel(4, 1));
        assert_eq!(pq.pop_fair(), Some((5, 1)));
        assert!(pq.is_empty());
    }

    #[test]
    fn cross_view_claims_invalidate_each_other() {
        let mut pq = PendingQueue::new();
        pq.push(0, 0);
        pq.push(1, 1);
        // Claimed through the per-function view; the drains must not hand
        // it out again.
        assert_eq!(pq.pop_fn(0), Some(0));
        assert_eq!(pq.pop_fair(), Some((1, 1)));
        assert_eq!(pq.pop_fair(), None);
        // And the other way around.
        pq.push(2, 1);
        assert_eq!(pq.pop_arrival(), Some((2, 1)));
        assert_eq!(pq.pop_fn(1), None);
        assert_eq!(pq.len(), 0);
    }

    #[test]
    fn telemetry_counters_track_pushes_and_peak() {
        let mut pq = PendingQueue::new();
        assert_eq!(pq.pushed(), 0);
        assert_eq!(pq.peak_len(), 0);
        pq.push(0, 0);
        pq.push(1, 1);
        pq.push(2, 0);
        assert_eq!(pq.peak_len(), 3);
        assert_eq!(pq.pop_fair(), Some((0, 0)));
        pq.push(3, 1);
        // Depth never re-reached 3+1, so the peak stays at 3; pushes are
        // monotone regardless of pops/cancels.
        assert_eq!(pq.peak_len(), 3);
        assert!(pq.cancel(1, 1));
        assert_eq!(pq.pushed(), 4);
        assert_eq!(pq.peak_len(), 3);
    }

    #[test]
    fn emptied_queue_forfeits_deficit() {
        // Weight 3 on function 0, but only one request: after it drains,
        // the unused credit must not leak into the next burst.
        let mut pq = PendingQueue::with_layout(2, &[(0, 3)]);
        pq.push(0, 0);
        pq.push(1, 1);
        assert_eq!(pq.pop_fair(), Some((0, 0)));
        assert_eq!(pq.pop_fair(), Some((1, 1)));
        // New burst: function 0 recharges from zero (3 credits), serving
        // three in a row before yielding.
        for rid in 2..6 {
            pq.push(rid, 0);
        }
        pq.push(6, 1);
        let order: Vec<FunctionId> =
            std::iter::from_fn(|| pq.pop_fair()).map(|(_, f)| f).collect();
        assert_eq!(order, vec![0, 0, 0, 1, 0]);
    }
}
