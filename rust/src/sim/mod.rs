//! Deterministic discrete-event simulation of the FaaS cluster — the
//! engine behind every Fig 10-17 reproduction (see DESIGN.md §2 for why a
//! simulator substitutes for the paper's 6-VM AWS testbed, and §4 for the
//! autoscale control loop layered on top).
//!
//! [`run_once`]/[`run_trace`] are the policy-driven entry points: all
//! auto-scaling comes from `cfg.autoscale`, and `cfg.sim.shards` picks
//! the engine (1 = serial, ≥ 2 = the sharded parallel core in
//! [`shard`]). Externally-scripted scaling goes through
//! `cfg.autoscale.policy = "scheduled"` + `cfg.autoscale.events` (the
//! `run_scaled`/`run_scale_events` shims that predated it are gone).

pub mod engine;
pub mod events;
pub mod shard;

pub use engine::{run_once, run_trace, Simulation};
#[cfg(feature = "ref-heap")]
pub use engine::{run_once_reference, run_trace_reference};
pub use events::{Event, EventQueue};
pub use shard::{run_sharded, run_sharded_trace, ShardMsg};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;

    fn small_cfg(sched: &str, vus: usize) -> Config {
        let mut cfg = Config::default();
        cfg.scheduler.name = sched.into();
        cfg.workload.vus = vus;
        cfg.workload.duration_s = 30.0;
        cfg
    }

    #[test]
    fn sim_conserves_requests() {
        // Every issued request completes exactly once (closed loop drains).
        for sched in crate::scheduler::PAPER_SCHEDULERS {
            let m = run_once(&small_cfg(sched, 10), 1).unwrap();
            assert_eq!(m.issued, m.completed, "{sched}: issued != completed");
            assert!(m.completed > 100, "{sched}: suspiciously few requests ({})", m.completed);
            assert_eq!(m.cold_starts + m.warm_starts, m.completed);
        }
    }

    #[test]
    fn sim_deterministic_under_seed() {
        let a = run_once(&small_cfg("hiku", 10), 7).unwrap();
        let b = run_once(&small_cfg("hiku", 10), 7).unwrap();
        assert_eq!(a.completed, b.completed);
        assert_eq!(a.cold_starts, b.cold_starts);
        let (mut a, mut b) = (a, b);
        assert_eq!(a.mean_latency_ms(), b.mean_latency_ms());
        assert_eq!(a.mean_cv(), b.mean_cv());
    }

    #[test]
    fn sim_seed_sensitivity() {
        let a = run_once(&small_cfg("hiku", 10), 1).unwrap();
        let b = run_once(&small_cfg("hiku", 10), 2).unwrap();
        assert_ne!(
            (a.completed, a.cold_starts),
            (b.completed, b.cold_starts),
            "different seeds should differ"
        );
    }

    #[test]
    fn workers_all_see_traffic_under_hiku() {
        let m = run_once(&small_cfg("hiku", 20), 3).unwrap();
        let totals = m.imbalance.totals();
        assert!(totals.iter().all(|&t| t > 0.0), "idle worker under hiku: {totals:?}");
    }

    #[test]
    fn hiku_beats_random_on_cold_rate() {
        // The headline qualitative claim (Fig 13) at small scale.
        let hiku = run_once(&small_cfg("hiku", 20), 4).unwrap();
        let random = run_once(&small_cfg("random", 20), 4).unwrap();
        assert!(
            hiku.cold_rate() < random.cold_rate(),
            "hiku {} vs random {}",
            hiku.cold_rate(),
            random.cold_rate()
        );
    }

    #[test]
    fn latencies_positive_and_bounded() {
        let mut m = run_once(&small_cfg("ch-bl", 10), 5).unwrap();
        let p0 = m.latency_percentile_ms(0.0);
        let p100 = m.latency_percentile_ms(100.0);
        assert!(p0 > 0.0, "non-positive latency {p0}");
        assert!(p100 < 60_000.0, "implausible tail {p100} ms");
    }
}
