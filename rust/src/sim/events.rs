//! Discrete-event queue with deterministic FIFO tie-breaking — two events
//! at the same timestamp fire in insertion order, which makes whole
//! simulations bit-reproducible under a seed.
//!
//! ## Event core: calendar queue
//!
//! The seed implementation was a `BinaryHeap` over `(time, seq)`. At
//! production scale (10k–100k workers) the heap holds hundreds of
//! thousands of pending events and every push/pop walks ~log n cache-cold
//! levels — `sim_engine_perf` showed it dominating the hot loop. The
//! replacement is a classic calendar queue (R. Brown, CACM 1988): events
//! hash into `nbuckets` time buckets of width `width` seconds, pops scan
//! one "year" (a rotation of the bucket ring) from the current clock, and
//! the structure resizes itself (bucket count from occupancy, width from
//! the observed event-time span) so push and pop are amortized O(1).
//!
//! ## Determinism argument
//!
//! Pop order must be *exactly* ascending `(time, seq)` — not just
//! approximately time-sorted — or simulations stop being bit-reproducible.
//! The calendar queue guarantees this structurally:
//!
//! 1. Every entry stores `key = time.to_bits()`. Times are finite and
//!    non-negative (asserted on push), so IEEE-754 bit patterns order
//!    exactly like the times themselves and `(key, seq)` is a total order
//!    with no float comparisons.
//! 2. Every entry stores its virtual bucket number `vb = ⌊t/width⌋`,
//!    computed once at insertion (and recomputed on resize) with the same
//!    `t * inv_width` expression the pop scan uses. Since `t ↦ vb` is
//!    monotone (IEEE multiplication and truncation are monotone), entries
//!    in *earlier* lap positions can never have *later* times.
//! 3. A pop scans bucket positions `vb = ⌊now/width⌋, …` upward; within a
//!    bucket it takes the minimum `(key, seq)` entry and pops it only if
//!    its stored `vb` is due (`entry.vb <= vb`). If the minimum entry of a
//!    bucket is not due, no entry of that bucket is (monotonicity again),
//!    so skipping the bucket is exact. Events with equal times always land
//!    in the same bucket (same `t` ⇒ same `vb`), where the `seq` component
//!    breaks the tie FIFO.
//! 4. If a full rotation finds nothing due (all events more than one
//!    "year" ahead), a direct search returns the global `(key, seq)`
//!    minimum.
//!
//! The seed heap is kept behind the `ref-heap` feature (on by default) as
//! [`EventQueue::reference`]; `tests/determinism.rs` proves whole-run
//! bit-equivalence and the property tests below prove pop-order
//! equivalence under randomized interleavings.

#[cfg(feature = "ref-heap")]
use std::collections::BinaryHeap;

use crate::platform::SandboxId;
use crate::platform::WorkerId;

/// Simulation events.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Event {
    /// A virtual user issues the invocation at `step` of its script.
    Arrival { vu: usize, step: usize },
    /// An execution finishes on a worker.
    Completion { worker: WorkerId, sandbox: SandboxId, request: u64 },
    /// Keep-alive countdown for an idle sandbox elapsed (used by the
    /// precise per-sandbox expiry mode; the engine defaults to SweepTick).
    KeepAlive { worker: WorkerId, sandbox: SandboxId, epoch: u64 },
    /// Periodic keep-alive sweep across all workers (O(1) events/s).
    SweepTick,
    /// An open-loop trace arrival (trace replay mode).
    TraceArrival { index: usize },
    /// Auto-scaling: one worker joins (up) or drains out of the cluster.
    Scale { up: bool },
    /// Recurring autoscale control tick: the engine snapshots the cluster
    /// and asks the configured [`crate::autoscale::AutoscalePolicy`].
    AutoscaleTick,
    /// Pre-warming policy tick (1 Hz when cluster.prewarm is on).
    PreWarmTick,
    /// A speculative sandbox finished initializing.
    PreWarmDone { worker: WorkerId, sandbox: SandboxId },
    /// Pull dispatch: a parked request's wait deadline expired — the
    /// router force-places it if it is still waiting (no-op otherwise).
    PullDeadline { request: u64 },
    /// Scale-to-zero: an arrival hit an empty cluster; restore one worker
    /// and flush the pending queue (pull dispatch only).
    Wake,
    /// Fault injection: the worker crashes — every sandbox (busy included)
    /// is destroyed and in-flight work is re-enqueued with a retry budget
    /// ([`crate::faults`], DESIGN.md §10).
    WorkerFail { worker: WorkerId },
    /// Fault injection: a crashed worker rejoins the cluster, cold.
    WorkerRecover { worker: WorkerId },
    /// Fault injection: set the worker's service-time multiplier
    /// (`mult = 1.0` ends a straggler episode).
    StragglerSet { worker: WorkerId, mult: f64 },
    /// Fault recovery: a lost request's jittered backoff elapsed —
    /// re-enqueue it (pull) or re-select a worker (push).
    RetryEnqueue { request: u64 },
    /// Straggler hedging: if the request is still held by a slowed worker
    /// past its EWMA-runtime deadline, duplicate it onto the pull path
    /// (first completion wins).
    HedgeCheck { request: u64 },
}

/// One scheduled event. `key` is the event time's IEEE bit pattern (times
/// are finite and >= 0, so `u64` ordering == time ordering); `vb` is the
/// virtual bucket number under the calendar's current width (unused by the
/// reference heap).
#[derive(Clone, Copy, Debug)]
struct Entry {
    key: u64,
    vb: u64,
    seq: u64,
    event: Event,
}

impl Entry {
    #[inline]
    fn time(&self) -> f64 {
        f64::from_bits(self.key)
    }
}

#[cfg(feature = "ref-heap")]
impl PartialEq for Entry {
    fn eq(&self, other: &Self) -> bool {
        self.key == other.key && self.seq == other.seq
    }
}
#[cfg(feature = "ref-heap")]
impl Eq for Entry {}

#[cfg(feature = "ref-heap")]
impl Ord for Entry {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // Reversed (key, seq) so BinaryHeap pops the minimum — the seed
        // heap's exact ordering.
        other.key.cmp(&self.key).then_with(|| other.seq.cmp(&self.seq))
    }
}
#[cfg(feature = "ref-heap")]
impl PartialOrd for Entry {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

const MIN_BUCKETS: usize = 16;
const MAX_BUCKETS: usize = 1 << 21;
const MIN_WIDTH: f64 = 1e-9;

/// The calendar (bucket ring). Buckets are unsorted `Vec`s: with the
/// occupancy the resize policy maintains (~0.5–2 entries/bucket), a linear
/// min-scan of a tiny contiguous bucket beats any per-bucket ordering
/// structure.
#[derive(Debug)]
struct Calendar {
    buckets: Vec<Vec<Entry>>,
    /// `buckets.len() - 1`; bucket count is a power of two.
    mask: usize,
    /// Bucket width in (virtual) seconds.
    width: f64,
    inv_width: f64,
    count: usize,
}

impl Calendar {
    fn new() -> Self {
        Self {
            buckets: (0..MIN_BUCKETS).map(|_| Vec::new()).collect(),
            mask: MIN_BUCKETS - 1,
            width: 1.0,
            inv_width: 1.0,
            count: 0,
        }
    }

    #[inline]
    fn vb_of(&self, t: f64) -> u64 {
        // Non-negative finite t: the cast truncates toward zero == floor.
        (t * self.inv_width) as u64
    }

    fn push(&mut self, key: u64, seq: u64, event: Event) {
        let vb = self.vb_of(f64::from_bits(key));
        let idx = (vb as usize) & self.mask;
        self.buckets[idx].push(Entry { key, vb, seq, event });
        self.count += 1;
        if self.count > 2 * (self.mask + 1) && self.mask + 1 < MAX_BUCKETS {
            self.rebuild();
        }
    }

    /// Index of the minimum `(key, seq)` entry in a non-empty bucket.
    fn min_pos(bucket: &[Entry]) -> usize {
        let mut mi = 0;
        for (i, e) in bucket.iter().enumerate().skip(1) {
            if (e.key, e.seq) < (bucket[mi].key, bucket[mi].seq) {
                mi = i;
            }
        }
        mi
    }

    /// Locate the globally minimum `(key, seq)` entry without removing it:
    /// returns its (bucket index, position). `now` is the queue clock (all
    /// entries are at or after it).
    fn find_min(&self, now: f64) -> (usize, usize) {
        debug_assert!(self.count > 0);
        let nbuckets = self.mask + 1;
        let start_vb = self.vb_of(now);
        for k in 0..nbuckets {
            let vb = start_vb + k as u64;
            let idx = (vb as usize) & self.mask;
            if self.buckets[idx].is_empty() {
                continue;
            }
            let mi = Self::min_pos(&self.buckets[idx]);
            if self.buckets[idx][mi].vb <= vb {
                return (idx, mi);
            }
            // The bucket's minimum is beyond this rotation; by vb
            // monotonicity so is everything else in it.
        }
        // Nothing due within one full rotation: the next event is more
        // than a "year" ahead. Direct search for the global minimum (the
        // shrink policy keeps this path rare).
        let mut best: Option<(usize, usize)> = None;
        let mut best_key = (u64::MAX, u64::MAX);
        for (bi, bucket) in self.buckets.iter().enumerate() {
            for (i, e) in bucket.iter().enumerate() {
                if (e.key, e.seq) < best_key {
                    best_key = (e.key, e.seq);
                    best = Some((bi, i));
                }
            }
        }
        best.expect("count > 0 but no entry found")
    }

    /// Remove the entry at (bucket, position) found by [`Calendar::find_min`].
    fn remove_at(&mut self, bi: usize, i: usize) -> Entry {
        let e = self.buckets[bi].swap_remove(i);
        self.count -= 1;
        self.maybe_shrink();
        e
    }

    /// Remove and return the globally minimum `(key, seq)` entry.
    fn pop(&mut self, now: f64) -> Entry {
        let (bi, i) = self.find_min(now);
        self.remove_at(bi, i)
    }

    fn maybe_shrink(&mut self) {
        if self.mask + 1 > MIN_BUCKETS && self.count * 8 < self.mask + 1 {
            self.rebuild();
        }
    }

    /// Re-derive bucket count from occupancy and width from the observed
    /// event-time span, then redistribute. Deterministic: geometry is a
    /// pure function of current contents.
    fn rebuild(&mut self) {
        let mut entries: Vec<Entry> = Vec::with_capacity(self.count);
        for bucket in &mut self.buckets {
            entries.append(bucket);
        }
        debug_assert_eq!(entries.len(), self.count);
        let target = (self.count.max(1) * 2).next_power_of_two().clamp(MIN_BUCKETS, MAX_BUCKETS);
        if self.count >= 2 {
            let mut min_key = u64::MAX;
            let mut max_key = 0u64;
            for e in &entries {
                min_key = min_key.min(e.key);
                max_key = max_key.max(e.key);
            }
            let span = f64::from_bits(max_key) - f64::from_bits(min_key);
            if span > 0.0 {
                // Aim for ~0.5 events per bucket across the occupied span.
                self.width = (span / self.count as f64 * 2.0).max(MIN_WIDTH);
            }
        }
        self.inv_width = 1.0 / self.width;
        if self.buckets.len() != target {
            self.buckets = (0..target).map(|_| Vec::new()).collect();
        }
        self.mask = target - 1;
        for e in entries {
            let vb = self.vb_of(e.time());
            let idx = (vb as usize) & self.mask;
            self.buckets[idx].push(Entry { vb, ..e });
        }
    }
}

/// Storage backend: the calendar queue, or (reference builds) the seed's
/// binary heap for bit-equivalence testing and before/after benchmarks.
#[derive(Debug)]
enum Store {
    Calendar(Calendar),
    #[cfg(feature = "ref-heap")]
    Heap(BinaryHeap<Entry>),
}

/// Min event queue with a virtual clock, FIFO at equal timestamps.
#[derive(Debug)]
pub struct EventQueue {
    store: Store,
    seq: u64,
    now: f64,
    len: usize,
    peak_len: usize,
    popped: u64,
}

impl Default for EventQueue {
    fn default() -> Self {
        Self::new()
    }
}

impl EventQueue {
    /// The production event core (calendar queue).
    pub fn new() -> Self {
        Self {
            store: Store::Calendar(Calendar::new()),
            seq: 0,
            now: 0.0,
            len: 0,
            peak_len: 0,
            popped: 0,
        }
    }

    /// The seed `BinaryHeap` event core, kept as the bit-exact reference
    /// implementation for the equivalence suite and the perf sweep.
    #[cfg(feature = "ref-heap")]
    pub fn reference() -> Self {
        Self {
            store: Store::Heap(BinaryHeap::new()),
            seq: 0,
            now: 0.0,
            len: 0,
            peak_len: 0,
            popped: 0,
        }
    }

    /// The virtual clock: the timestamp of the last popped event.
    pub fn now(&self) -> f64 {
        self.now
    }

    /// Pending (scheduled, not yet popped) events.
    pub fn len(&self) -> usize {
        self.len
    }

    /// True when no events are pending.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// High-water mark of pending events (perf diagnostics).
    pub fn peak_len(&self) -> usize {
        self.peak_len
    }

    /// Total events popped so far (the bench's events/s numerator).
    pub fn popped(&self) -> u64 {
        self.popped
    }

    /// Schedule `event` at absolute time `t` (must be >= now, finite and
    /// non-negative — the bit-pattern ordering relies on it).
    pub fn push_at(&mut self, t: f64, event: Event) {
        assert!(t.is_finite() && t >= 0.0, "non-finite or negative event time");
        debug_assert!(t >= self.now, "scheduling into the past: {t} < {}", self.now);
        // Normalize -0.0 to +0.0: its sign-bit pattern would otherwise
        // sort as the largest u64 key and break the (key, seq) order.
        let key = (t + 0.0).to_bits();
        match &mut self.store {
            Store::Calendar(c) => c.push(key, self.seq, event),
            #[cfg(feature = "ref-heap")]
            Store::Heap(h) => h.push(Entry { key, vb: 0, seq: self.seq, event }),
        }
        self.seq += 1;
        self.len += 1;
        if self.len > self.peak_len {
            self.peak_len = self.len;
        }
    }

    /// Schedule `event` after a delay from the current clock.
    pub fn push_after(&mut self, delay: f64, event: Event) {
        self.push_at(self.now + delay.max(0.0), event);
    }

    /// Pop the earliest event, advancing the clock to its timestamp.
    pub fn pop(&mut self) -> Option<(f64, Event)> {
        if self.len == 0 {
            return None;
        }
        let e = match &mut self.store {
            Store::Calendar(c) => c.pop(self.now),
            #[cfg(feature = "ref-heap")]
            Store::Heap(h) => h.pop().expect("len > 0"),
        };
        self.len -= 1;
        self.popped += 1;
        let t = e.time();
        debug_assert!(t >= self.now);
        self.now = t;
        Some((t, e.event))
    }

    /// Timestamp of the earliest pending event without removing it.
    pub fn peek_time(&self) -> Option<f64> {
        if self.len == 0 {
            return None;
        }
        match &self.store {
            Store::Calendar(c) => {
                let (bi, i) = c.find_min(self.now);
                Some(c.buckets[bi][i].time())
            }
            #[cfg(feature = "ref-heap")]
            Store::Heap(h) => h.peek().map(Entry::time),
        }
    }

    /// Pop the earliest event only if `pred(time, &event)` accepts it;
    /// bookkeeping (clock, counters) matches [`EventQueue::pop`] exactly.
    /// One minimum-search per call whether or not the pop happens — this
    /// backs the epoch-bounded draining of the sharded engine
    /// ([`EventQueue::pop_before`]) and the engine's same-tick completion
    /// coalescing without a separate peek + pop double scan.
    pub fn pop_if<F>(&mut self, pred: F) -> Option<(f64, Event)>
    where
        F: FnOnce(f64, &Event) -> bool,
    {
        if self.len == 0 {
            return None;
        }
        let e = match &mut self.store {
            Store::Calendar(c) => {
                let (bi, i) = c.find_min(self.now);
                let head = c.buckets[bi][i];
                if !pred(head.time(), &head.event) {
                    return None;
                }
                c.remove_at(bi, i)
            }
            #[cfg(feature = "ref-heap")]
            Store::Heap(h) => {
                let head = *h.peek().expect("len > 0");
                if !pred(head.time(), &head.event) {
                    return None;
                }
                h.pop().expect("len > 0")
            }
        };
        self.len -= 1;
        self.popped += 1;
        let t = e.time();
        debug_assert!(t >= self.now);
        self.now = t;
        Some((t, e.event))
    }

    /// Pop the earliest event if it is strictly before `limit` — the
    /// sharded engine's epoch boundary rule (events exactly at a barrier
    /// epoch belong to the next epoch, after control actions applied at
    /// the barrier).
    pub fn pop_before(&mut self, limit: f64) -> Option<(f64, Event)> {
        self.pop_if(|t, _| t < limit)
    }

    /// Advance the clock to `t` without popping, so control actions
    /// injected at a barrier (scale, pre-warm) are timestamped at the
    /// epoch boundary rather than at the shard's last local event. No
    /// pending event may be earlier than `t`.
    pub fn advance_to(&mut self, t: f64) {
        debug_assert!(
            self.peek_time().map_or(true, |pt| pt >= t),
            "advancing the clock past a pending event"
        );
        if t > self.now {
            self.now = t;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::prop::{check, PropConfig};

    #[test]
    fn time_ordering() {
        let mut q = EventQueue::new();
        q.push_at(3.0, Event::Arrival { vu: 3, step: 0 });
        q.push_at(1.0, Event::Arrival { vu: 1, step: 0 });
        q.push_at(2.0, Event::Arrival { vu: 2, step: 0 });
        let order: Vec<usize> = std::iter::from_fn(|| q.pop()).map(|(_, e)| match e {
            Event::Arrival { vu, .. } => vu,
            _ => unreachable!(),
        })
        .collect();
        assert_eq!(order, vec![1, 2, 3]);
    }

    #[test]
    fn fifo_tie_breaking() {
        let mut q = EventQueue::new();
        for vu in 0..10 {
            q.push_at(5.0, Event::Arrival { vu, step: 0 });
        }
        let order: Vec<usize> = std::iter::from_fn(|| q.pop()).map(|(_, e)| match e {
            Event::Arrival { vu, .. } => vu,
            _ => unreachable!(),
        })
        .collect();
        assert_eq!(order, (0..10).collect::<Vec<_>>(), "same-time events must be FIFO");
    }

    #[test]
    fn clock_advances_monotonically() {
        let mut q = EventQueue::new();
        q.push_at(1.0, Event::TraceArrival { index: 0 });
        q.pop();
        assert_eq!(q.now(), 1.0);
        q.push_after(0.5, Event::TraceArrival { index: 1 });
        let (t, _) = q.pop().unwrap();
        assert!((t - 1.5).abs() < 1e-12);
    }

    #[test]
    #[should_panic(expected = "non-finite")]
    fn rejects_nan_time() {
        let mut q = EventQueue::new();
        q.push_at(f64::NAN, Event::TraceArrival { index: 0 });
    }

    #[test]
    #[should_panic(expected = "non-finite or negative")]
    fn rejects_negative_time() {
        let mut q = EventQueue::new();
        q.push_at(-1.0, Event::TraceArrival { index: 0 });
    }

    #[test]
    fn negative_zero_sorts_as_zero() {
        // -0.0 passes the non-negative guard; its sign-bit pattern must
        // not leak into the key order (it would sort as the largest u64).
        let mut q = EventQueue::new();
        q.push_at(-0.0, Event::TraceArrival { index: 0 });
        q.push_at(1.0, Event::TraceArrival { index: 1 });
        let (t0, e0) = q.pop().unwrap();
        assert_eq!(t0, 0.0);
        assert_eq!(e0, Event::TraceArrival { index: 0 });
        let (t1, _) = q.pop().unwrap();
        assert_eq!(t1, 1.0);
    }

    #[test]
    fn order_survives_rebuilds() {
        // Push enough events to force several grow rebuilds, interleaved
        // with exact ties, then drain: order must be (time, seq) exact.
        let mut q = EventQueue::new();
        let mut expect: Vec<usize> = Vec::new();
        let mut idx = 0usize;
        for group in 0..200 {
            let t = group as f64 * 0.37;
            for _ in 0..5 {
                q.push_at(t, Event::TraceArrival { index: idx });
                expect.push(idx);
                idx += 1;
            }
        }
        assert_eq!(q.len(), 1000);
        assert_eq!(q.peak_len(), 1000);
        let got: Vec<usize> = std::iter::from_fn(|| q.pop()).map(|(_, e)| match e {
            Event::TraceArrival { index } => index,
            _ => unreachable!(),
        })
        .collect();
        assert_eq!(got, expect);
        assert_eq!(q.popped(), 1000);
    }

    #[test]
    fn sparse_far_future_jump() {
        // A lone event far beyond one bucket rotation exercises the
        // direct-search path.
        let mut q = EventQueue::new();
        q.push_at(0.5, Event::SweepTick);
        q.pop();
        q.push_at(1.0e6, Event::TraceArrival { index: 7 });
        let (t, e) = q.pop().unwrap();
        assert_eq!(t, 1.0e6);
        assert_eq!(e, Event::TraceArrival { index: 7 });
        assert!(q.is_empty());
    }

    #[test]
    fn shrink_after_burst() {
        // Fill (grow), drain to near-empty (shrink), then keep operating.
        let mut q = EventQueue::new();
        for i in 0..5000 {
            q.push_at(i as f64 * 1e-3, Event::TraceArrival { index: i });
        }
        for _ in 0..4990 {
            q.pop();
        }
        assert_eq!(q.len(), 10);
        q.push_after(0.001, Event::SweepTick);
        let mut last = q.now();
        while let Some((t, _)) = q.pop() {
            assert!(t >= last);
            last = t;
        }
    }

    /// Randomized ops against a sorted-Vec model: every pop must return
    /// the minimum (time, seq) entry — FIFO ties, monotone clock.
    #[test]
    fn prop_calendar_matches_sorted_model() {
        check("calendar-vs-model", PropConfig { cases: 150, ..Default::default() }, |rng, size| {
            let mut q = EventQueue::new();
            let mut model: Vec<(u64, u64)> = Vec::new(); // (key, tag=seq)
            let mut tag = 0u64;
            for _ in 0..size * 6 {
                if rng.next_f64() < 0.6 || q.is_empty() {
                    let delay = match rng.index(4) {
                        0 => 0.0, // exact tie with the clock
                        1 => rng.next_f64() * 1e-3,
                        2 => rng.next_f64() * 10.0,
                        _ => rng.next_f64() * 1000.0,
                    };
                    let t = q.now() + delay;
                    q.push_at(t, Event::TraceArrival { index: tag as usize });
                    model.push((t.to_bits(), tag));
                    tag += 1;
                } else {
                    let (t, ev) = q.pop().unwrap();
                    let (mi, _) = model
                        .iter()
                        .enumerate()
                        .min_by_key(|&(_, &(k, s))| (k, s))
                        .expect("model empty but queue popped");
                    let (k, want) = model.swap_remove(mi);
                    prop_assert!(
                        t.to_bits() == k,
                        "popped time {} != model min {}",
                        t,
                        f64::from_bits(k)
                    );
                    let got = match ev {
                        Event::TraceArrival { index } => index as u64,
                        _ => unreachable!(),
                    };
                    prop_assert!(got == want, "popped tag {} != model {}", got, want);
                }
            }
            while let Some((_, ev)) = q.pop() {
                let (mi, _) = model
                    .iter()
                    .enumerate()
                    .min_by_key(|&(_, &(k, s))| (k, s))
                    .expect("model drained early");
                let (_, want) = model.swap_remove(mi);
                let got = match ev {
                    Event::TraceArrival { index } => index as u64,
                    _ => unreachable!(),
                };
                prop_assert!(got == want, "drain tag {} != model {}", got, want);
            }
            prop_assert!(model.is_empty(), "{} entries left in model", model.len());
            Ok(())
        });
    }

    /// The calendar queue and the reference heap pop identical sequences
    /// under identical randomized schedules.
    #[cfg(feature = "ref-heap")]
    #[test]
    fn prop_calendar_equals_reference_heap() {
        check("calendar-vs-heap", PropConfig { cases: 120, ..Default::default() }, |rng, size| {
            // Pre-draw the op script so both queues see the same schedule.
            #[derive(Clone, Copy)]
            enum Op {
                Push(f64, usize),
                Pop,
            }
            let mut ops = Vec::new();
            let mut pending = 0usize;
            let mut tag = 0usize;
            for _ in 0..size * 6 {
                if rng.next_f64() < 0.55 || pending == 0 {
                    let delay = match rng.index(3) {
                        0 => 0.0,
                        1 => rng.next_f64() * 0.01,
                        _ => rng.next_f64() * 50.0,
                    };
                    ops.push(Op::Push(delay, tag));
                    tag += 1;
                    pending += 1;
                } else {
                    ops.push(Op::Pop);
                    pending -= 1;
                }
            }
            let mut cal = EventQueue::new();
            let mut heap = EventQueue::reference();
            for &op in &ops {
                match op {
                    Op::Push(delay, tag) => {
                        cal.push_after(delay, Event::TraceArrival { index: tag });
                        heap.push_after(delay, Event::TraceArrival { index: tag });
                    }
                    Op::Pop => {
                        let a = cal.pop();
                        let b = heap.pop();
                        prop_assert!(a == b, "pop diverged: {:?} vs {:?}", a, b);
                    }
                }
                prop_assert!(
                    cal.now() == heap.now() && cal.len() == heap.len(),
                    "state diverged: now {}/{} len {}/{}",
                    cal.now(),
                    heap.now(),
                    cal.len(),
                    heap.len()
                );
            }
            loop {
                let a = cal.pop();
                let b = heap.pop();
                prop_assert!(a == b, "drain diverged: {:?} vs {:?}", a, b);
                if a.is_none() {
                    break;
                }
            }
            Ok(())
        });
    }

    #[test]
    fn peek_and_pop_before_respect_bounds() {
        let mut q = EventQueue::new();
        q.push_at(2.0, Event::TraceArrival { index: 0 });
        q.push_at(5.0, Event::TraceArrival { index: 1 });
        assert_eq!(q.peek_time(), Some(2.0));
        assert_eq!(q.len(), 2, "peek must not remove");
        // Head at the limit: strictly-before rule refuses it.
        assert_eq!(q.pop_before(2.0), None);
        assert_eq!(q.pop_before(2.5), Some((2.0, Event::TraceArrival { index: 0 })));
        assert_eq!(q.now(), 2.0);
        assert_eq!(q.pop_before(4.0), None, "next head is at 5.0");
        // advance_to moves the clock into the gap; pushes at the boundary
        // stay legal and the head is untouched.
        q.advance_to(4.0);
        assert_eq!(q.now(), 4.0);
        q.push_at(4.0, Event::SweepTick);
        assert_eq!(q.pop_before(6.0), Some((4.0, Event::SweepTick)));
        assert_eq!(q.pop_before(6.0), Some((5.0, Event::TraceArrival { index: 1 })));
        assert!(q.is_empty());
        assert_eq!(q.peek_time(), None);
        assert_eq!(q.pop_before(100.0), None);
        assert_eq!(q.popped(), 3, "refused pops must not count");
    }

    #[test]
    fn pop_if_matches_head_only() {
        let mut q = EventQueue::new();
        q.push_at(1.0, Event::TraceArrival { index: 0 });
        q.push_at(1.0, Event::SweepTick);
        // Predicate rejects the head (index 0): nothing pops, even though
        // the second entry would match.
        assert_eq!(q.pop_if(|_, e| matches!(e, Event::SweepTick)), None);
        assert_eq!(q.len(), 2);
        assert_eq!(q.pop_if(|_, e| matches!(e, Event::TraceArrival { .. })).map(|(_, e)| e),
            Some(Event::TraceArrival { index: 0 }));
        assert_eq!(q.pop_if(|_, e| matches!(e, Event::SweepTick)).map(|(t, _)| t), Some(1.0));
    }

    /// `pop_before` over rising limits drains the identical (time, seq)
    /// sequence as plain `pop` — the sharded engine's epoch-stepping rule
    /// is a pure re-chunking of the serial order.
    #[cfg(feature = "ref-heap")]
    #[test]
    fn prop_pop_before_equals_pop_sequence() {
        check("pop-before-vs-pop", PropConfig { cases: 80, ..Default::default() }, |rng, size| {
            let mut plain = EventQueue::new();
            let mut epoch = EventQueue::reference();
            for i in 0..size * 4 {
                let t = rng.next_f64() * 40.0;
                plain.push_at(t, Event::TraceArrival { index: i });
                epoch.push_at(t, Event::TraceArrival { index: i });
            }
            let dt = 0.5 + rng.next_f64();
            let mut k = 1u32;
            loop {
                let limit = dt * k as f64;
                while let Some(got) = epoch.pop_before(limit) {
                    let want = plain.pop();
                    prop_assert!(Some(got) == want, "diverged: {:?} vs {:?}", got, want);
                }
                if epoch.is_empty() {
                    break;
                }
                k += 1;
            }
            prop_assert!(plain.is_empty(), "plain queue has leftovers");
            Ok(())
        });
    }

    /// Rejects a worst case: all events at one timestamp still drain FIFO.
    #[test]
    fn massive_tie_block() {
        let mut q = EventQueue::new();
        for i in 0..2000 {
            q.push_at(42.0, Event::TraceArrival { index: i });
        }
        for i in 0..2000 {
            let (t, e) = q.pop().unwrap();
            assert_eq!(t, 42.0);
            assert_eq!(e, Event::TraceArrival { index: i });
        }
    }
}
