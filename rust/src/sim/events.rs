//! Discrete-event queue: a binary heap over (time, seq) with deterministic
//! FIFO tie-breaking — two events at the same timestamp fire in insertion
//! order, which makes whole simulations bit-reproducible under a seed.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use crate::platform::SandboxId;
use crate::platform::WorkerId;

/// Simulation events.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Event {
    /// A virtual user issues the invocation at `step` of its script.
    Arrival { vu: usize, step: usize },
    /// An execution finishes on a worker.
    Completion { worker: WorkerId, sandbox: SandboxId, request: u64 },
    /// Keep-alive countdown for an idle sandbox elapsed (used by the
    /// precise per-sandbox expiry mode; the engine defaults to SweepTick).
    KeepAlive { worker: WorkerId, sandbox: SandboxId, epoch: u64 },
    /// Periodic keep-alive sweep across all workers (O(1) events/s).
    SweepTick,
    /// An open-loop trace arrival (trace replay mode).
    TraceArrival { index: usize },
    /// Auto-scaling: one worker joins (up) or drains out of the cluster.
    Scale { up: bool },
    /// Recurring autoscale control tick: the engine snapshots the cluster
    /// and asks the configured [`crate::autoscale::AutoscalePolicy`].
    AutoscaleTick,
    /// Pre-warming policy tick (1 Hz when cluster.prewarm is on).
    PreWarmTick,
    /// A speculative sandbox finished initializing.
    PreWarmDone { worker: WorkerId, sandbox: SandboxId },
}

#[derive(Clone, Copy, Debug)]
struct HeapEntry {
    time: f64,
    seq: u64,
    event: Event,
}

impl PartialEq for HeapEntry {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl Eq for HeapEntry {}

impl Ord for HeapEntry {
    fn cmp(&self, other: &Self) -> Ordering {
        // Reverse for a min-heap on (time, seq). Times are finite by
        // construction (asserted on push).
        other
            .time
            .partial_cmp(&self.time)
            .unwrap()
            .then_with(|| other.seq.cmp(&self.seq))
    }
}
impl PartialOrd for HeapEntry {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// Min-heap event queue with a virtual clock.
#[derive(Debug, Default)]
pub struct EventQueue {
    heap: BinaryHeap<HeapEntry>,
    seq: u64,
    now: f64,
}

impl EventQueue {
    pub fn new() -> Self {
        Default::default()
    }

    pub fn now(&self) -> f64 {
        self.now
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Schedule `event` at absolute time `t` (must be >= now and finite).
    pub fn push_at(&mut self, t: f64, event: Event) {
        assert!(t.is_finite(), "non-finite event time");
        debug_assert!(t >= self.now, "scheduling into the past: {t} < {}", self.now);
        self.heap.push(HeapEntry { time: t, seq: self.seq, event });
        self.seq += 1;
    }

    /// Schedule `event` after a delay from the current clock.
    pub fn push_after(&mut self, delay: f64, event: Event) {
        self.push_at(self.now + delay.max(0.0), event);
    }

    /// Pop the earliest event, advancing the clock to its timestamp.
    pub fn pop(&mut self) -> Option<(f64, Event)> {
        let e = self.heap.pop()?;
        debug_assert!(e.time >= self.now);
        self.now = e.time;
        Some((e.time, e.event))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_ordering() {
        let mut q = EventQueue::new();
        q.push_at(3.0, Event::Arrival { vu: 3, step: 0 });
        q.push_at(1.0, Event::Arrival { vu: 1, step: 0 });
        q.push_at(2.0, Event::Arrival { vu: 2, step: 0 });
        let order: Vec<usize> = std::iter::from_fn(|| q.pop()).map(|(_, e)| match e {
            Event::Arrival { vu, .. } => vu,
            _ => unreachable!(),
        })
        .collect();
        assert_eq!(order, vec![1, 2, 3]);
    }

    #[test]
    fn fifo_tie_breaking() {
        let mut q = EventQueue::new();
        for vu in 0..10 {
            q.push_at(5.0, Event::Arrival { vu, step: 0 });
        }
        let order: Vec<usize> = std::iter::from_fn(|| q.pop()).map(|(_, e)| match e {
            Event::Arrival { vu, .. } => vu,
            _ => unreachable!(),
        })
        .collect();
        assert_eq!(order, (0..10).collect::<Vec<_>>(), "same-time events must be FIFO");
    }

    #[test]
    fn clock_advances_monotonically() {
        let mut q = EventQueue::new();
        q.push_at(1.0, Event::TraceArrival { index: 0 });
        q.pop();
        assert_eq!(q.now(), 1.0);
        q.push_after(0.5, Event::TraceArrival { index: 1 });
        let (t, _) = q.pop().unwrap();
        assert!((t - 1.5).abs() < 1e-12);
    }

    #[test]
    #[should_panic(expected = "non-finite")]
    fn rejects_nan_time() {
        let mut q = EventQueue::new();
        q.push_at(f64::NAN, Event::TraceArrival { index: 0 });
    }
}
