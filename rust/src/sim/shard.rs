//! Sharded parallel event core: the worker set partitioned across OS
//! threads behind an **event-time barrier**.
//!
//! ## Model
//!
//! `cfg.sim.shards = N` splits the cluster into N contiguous worker
//! slices and the workload into N VU slices (VU `v` → shard `v mod N`;
//! open-loop trace arrival `i` → shard `i mod N`). Each shard runs its own
//! serial [`Simulation`] — its own calendar-queue [`super::EventQueue`],
//! its own `Cluster` slice, scheduler instance(s), load views and split
//! RNG streams — on its own thread. Under push dispatch workloads are
//! *partition-closed*: every request routes to a worker of the shard that
//! issued it, which is exactly the paper's synchronization-free
//! distributed-scheduler deployment (§I; the engine's
//! `scheduler.instances` ablation, now with real parallelism).
//!
//! ## Cross-shard task stealing (pull dispatch)
//!
//! `dispatch.mode = "pull"` lifts the partition-closed restriction for
//! *parked* requests: at each epoch barrier the coordinator reads every
//! shard's pending-queue digest and orders backlogged donors — visited in
//! shard order — to hand up to `dispatch.steal_batch` parked requests to
//! the least-loaded pending-free shard ([`ShardMsg::Handoff`]). The donor
//! extracts its payload in **deficit-round-robin order over its function
//! queues** (`dispatch.fair`, the default — a hot function cannot
//! monopolize every donation; `dispatch.fair = false` restores the PR 4
//! oldest-first order). Payloads move through a `handoff[to][from]`
//! buffer behind one extra transfer barrier and are ingested in (donor
//! shard, donor drain) order, so the migration is deterministic under
//! (seed, shards). The determinism rule: **steal in shard order, at
//! epoch boundaries only** — mid-epoch requests never cross shards, and
//! each donor's DRR cursor state is shard-local (DESIGN.md §8). Bound
//! (and running) requests never migrate; for a stolen closed-loop
//! request the VU's continuation migrates with it.
//!
//! ## The event-time barrier
//!
//! Virtual time is chopped into epochs of `barrier_dt` seconds (the
//! autoscale control interval when a tick-driven policy is configured,
//! else `cfg.sim.barrier_s`). Within an epoch every shard drains its own
//! events with `t < epoch_end` — no cross-thread communication at all —
//! then the shards rendezvous twice per epoch:
//!
//! 1. each shard publishes a report (`ShardReport`): drained flag, active
//!    worker count, running/queued totals, per-function warm supply, an
//!    O(1) [`LoadSummary`] of its worker loads, and its local pre-warm
//!    deficits;
//! 2. *(barrier)* one thread becomes the coordinator: it merges the
//!    reports in shard order (deterministic regardless of which thread
//!    leads), runs the global control decisions — the autoscale policy
//!    tick over the merged observation, scheduled scale events due this
//!    epoch, and global pre-warm placement — and writes per-shard
//!    [`ShardMsg`] mailboxes;
//! 3. *(barrier)* each shard applies its mailbox at the epoch boundary
//!    (the clock advances to the barrier time first, so control actions
//!    are timestamped like the serial engine's control ticks) and starts
//!    the next epoch.
//!
//! The run ends when every shard is drained, the epoch has passed
//! `duration_s`, and the coordinator issued no messages.
//!
//! ## Cross-shard selection: power-of-d over shard summaries
//!
//! Global decisions that the serial engine answers with "the least-loaded
//! worker" (pre-warm placement) would need a cross-shard argmin — Θ(tie
//! set) by the exact-semantics argument of DESIGN.md §5. The coordinator
//! instead samples **d = 2 shards** per placement from the merged
//! [`LoadSummary`] table and routes to the less-loaded sample (mean load,
//! then `min_load` as the tie key): O(d) per decision, never O(workers),
//! and the chosen shard places locally with its own O(tie set) min-load
//! index. This is the power-of-d-choices trade (Mitzenmacher): a bounded
//! approximation of the argmin in exchange for constant cost.
//!
//! ## Determinism
//!
//! For a fixed (seed, shard count) the run is bit-reproducible regardless
//! of thread scheduling: shards only interact at barriers, reports are
//! merged in shard order, the coordinator's RNG is its own split stream,
//! and every mailbox is a pure function of the epoch's reports. `--shards
//! 1` never enters this module — [`super::run_once`] routes it to the
//! serial engine, so the single-shard path stays bit-identical to the
//! PR 2 engine (enforced by `tests/determinism.rs`). For shard counts
//! ≥ 2 with no coordinator traffic (static cluster, no pre-warm) the run
//! equals the *merge of N independent serial runs* of the partitions —
//! also enforced by `tests/determinism.rs` against the `ref-heap`
//! reference engine. Semantics that differ from the serial engine, by
//! design: control actions quantize to epoch boundaries, the global
//! worker floor is one *per shard*, and pre-warm placement is sampled
//! rather than exact (DESIGN.md §6).
//!
//! The `predictive` autoscale policy needs the per-arrival forecast feed,
//! which would require streaming every arrival to the coordinator;
//! rejected at validation for `shards > 1`.

use std::collections::BTreeMap;
use std::sync::{Barrier, Mutex};
use std::time::Instant;

use super::engine::{Simulation, StolenTask};
use crate::autoscale::{AutoscaleObs, AutoscalePolicy};
use crate::config::{parse_crash_list, Config};
use crate::metrics::RunMetrics;
use crate::scheduler::{make_scheduler, Scheduler};
use crate::util::loadidx::LoadSummary;
use crate::util::rng::Pcg64;
use crate::workload::loadgen::{OpenLoopTrace, Workload};
use crate::workload::spec::FunctionRegistry;

/// A control message delivered to one shard at an epoch barrier.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ShardMsg {
    /// Scale this shard's active worker slice to `target` (its share of a
    /// global autoscale decision).
    ScaleTo {
        /// Desired active workers in this shard after the barrier.
        target: usize,
    },
    /// Speculatively initialize `n` sandboxes for function `f` on this
    /// shard (global pre-warm placement routed here by power-of-d
    /// sampling over the shard load summaries).
    SpawnPrewarm {
        /// Function type to pre-warm.
        f: usize,
        /// Sandboxes to initialize.
        n: usize,
    },
    /// Cross-shard task stealing (pull dispatch): this shard — the donor
    /// — moves up to `n` of its parked requests to shard `to`, extracted
    /// in deficit-round-robin order over its function queues
    /// (`dispatch.fair`; arrival order otherwise). The donor deposits
    /// payloads in the coordinator's handoff buffer at the epoch
    /// boundary; the recipient ingests them after the transfer barrier,
    /// in (donor shard, donor drain) order. This is what lifts the
    /// partition-closed restriction — the documented determinism rule is
    /// *steal in shard order, at epoch boundaries only* (DESIGN.md §8).
    Handoff {
        /// Receiving shard.
        to: usize,
        /// Most parked requests to move.
        n: usize,
    },
}

/// What one shard publishes at each barrier: the whole cross-thread
/// surface of an epoch. Everything here is O(functions) or O(1) — the
/// barrier never ships per-worker or per-request state.
#[derive(Clone, Debug, Default)]
struct ShardReport {
    /// The shard's event queue is empty.
    drained: bool,
    /// Active workers in the shard.
    active: usize,
    /// Failure digest: active workers not currently crash-marked
    /// (`faults` section; equals `active` with fault injection off). The
    /// steal rule never routes work toward a shard with `live == 0`, so
    /// cross-shard handoffs cannot bind to an all-dead partition.
    live: usize,
    /// Executions running across the shard's active workers.
    running: usize,
    /// Requests queued at the shard's active workers.
    queued: usize,
    /// O(1) digest of the shard's worker loads.
    load: LoadSummary,
    /// Requests parked in the shard's pending queue (pull dispatch; the
    /// steal rule's input — always 0 in push mode).
    pending: usize,
    /// Per-function warm supply (idle + initializing).
    warm: Vec<usize>,
    /// Per-function pre-warm deficits from the shard-local rate EWMAs.
    deficits: Vec<(usize, usize)>,
}

/// Coordinator state: owned by whichever thread wins the first barrier
/// each epoch, mutated only between the two barriers (so a plain mutex
/// with zero contention).
struct Coord {
    /// Tick-driven global autoscale policy (`reactive`); `none` ⇒ None.
    policy: Option<Box<dyn AutoscalePolicy>>,
    /// Scheduled-policy scale events not yet applied, ascending time.
    pending_events: Vec<(f64, bool)>,
    /// Next `pending_events` entry to apply.
    next_event: usize,
    /// Coordinator RNG: its own stream, used only for power-of-d shard
    /// sampling (shard-local streams are untouched).
    rng: Pcg64,
    /// Global pre-warm heuristic on (`cluster.prewarm`).
    prewarm_global: bool,
    /// Cross-shard steal cap per donor per epoch (`dispatch.steal_batch`;
    /// 0 — always in push mode — disables stealing).
    steal_batch: usize,
    /// Core-granular mode (`sim.cores_per_worker > 1`): the steal rule
    /// reads the slot digest in the barrier load summaries — a recipient
    /// must advertise free slots, and a handoff never exceeds them. Off
    /// (the default) leaves the worker-granular rule byte-identical.
    slot_mode: bool,
    duration_s: f64,
    concurrency: usize,
    shards: usize,
    mean_exec_s: Vec<f64>,
    warm_scratch: Vec<usize>,
    reports: Vec<ShardReport>,
    mailboxes: Vec<Vec<ShardMsg>>,
    /// Handoff payload buffers: `handoff[to][from]`, written by donors in
    /// the mailbox phase, drained by recipients after the transfer
    /// barrier. Indexed by both shards so ingest order is (donor shard,
    /// arrival) regardless of thread timing.
    handoff: Vec<Vec<Vec<StolenTask>>>,
    /// A handoff was ordered this epoch: every shard takes the transfer
    /// barrier (all read this flag after the coordination barrier, so
    /// they agree).
    stole: bool,
    done: bool,
}

impl Coord {
    /// Sample two shards uniformly and keep the less-loaded one (mean
    /// load, then `min_load`) — O(d=2) cross-shard selection.
    fn sample_shard(&mut self) -> usize {
        let a = self.rng.index(self.shards);
        let b = self.rng.index(self.shards);
        if self.reports[b].load.less_loaded_than(&self.reports[a].load) {
            b
        } else {
            a
        }
    }

    /// One barrier: merge the reports, run the global control decisions,
    /// fill the mailboxes, and decide termination. Pure function of
    /// (reports, coordinator state) — independent of which thread leads.
    fn coordinate(&mut self, limit: f64) {
        let mut active = 0usize;
        let mut running = 0usize;
        let mut queued = 0usize;
        let mut all_drained = true;
        self.warm_scratch.fill(0);
        for r in &self.reports {
            active += r.active;
            running += r.running;
            // Parked requests are queued demand the policy must see
            // (autoscale-aware admission; always 0 in push mode).
            queued += r.queued + r.pending;
            all_drained &= r.drained;
            for (acc, w) in self.warm_scratch.iter_mut().zip(&r.warm) {
                *acc += *w;
            }
        }

        let mut sent = false;
        self.stole = false;
        if limit < self.duration_s {
            // 1) Global worker target: scheduled events due this epoch,
            //    then the tick-driven policy over the merged observation.
            let mut target: Option<usize> = None;
            let mut tgt = active;
            while self.next_event < self.pending_events.len()
                && self.pending_events[self.next_event].0 <= limit
            {
                let (_, up) = self.pending_events[self.next_event];
                self.next_event += 1;
                if up {
                    tgt += 1;
                } else if tgt > self.shards {
                    tgt -= 1; // never below one worker per shard
                }
                target = Some(tgt);
            }
            let decision = match self.policy.as_mut() {
                Some(p) if p.tick_driven() => {
                    let obs = AutoscaleObs {
                        now: limit,
                        active_workers: active,
                        concurrency: self.concurrency,
                        total_running: running,
                        total_queued: queued,
                        warm_supply: &self.warm_scratch,
                        mean_exec_s: &self.mean_exec_s,
                    };
                    Some(p.tick(&obs))
                }
                _ => None,
            };
            if let Some(d) = decision {
                if let Some(t) = d.target_workers {
                    target = Some(t);
                }
                // Policy-requested pools (none for reactive today) place
                // exactly like the heuristic's: power-of-d over shards.
                for (f, count) in d.prewarm {
                    for _ in 0..count {
                        let s = self.sample_shard();
                        self.mailboxes[s].push(ShardMsg::SpawnPrewarm { f, n: 1 });
                        sent = true;
                    }
                }
            }
            if let Some(t) = target {
                let t = t.max(self.shards); // one worker per shard, minimum
                if t != active {
                    for s in 0..self.shards {
                        let share = shard_workers(t, s, self.shards);
                        if share != self.reports[s].active {
                            self.mailboxes[s].push(ShardMsg::ScaleTo { target: share });
                            sent = true;
                        }
                    }
                }
            }

            // 2) Global pre-warm placement: sum the shard-local deficits
            //    per function (BTreeMap: deterministic order), cap at the
            //    serial heuristic's 2/function/tick, place each sandbox on
            //    a power-of-d sampled shard.
            if self.prewarm_global {
                let mut need: BTreeMap<usize, usize> = BTreeMap::new();
                for r in &self.reports {
                    for &(f, d) in &r.deficits {
                        *need.entry(f).or_insert(0) += d;
                    }
                }
                for (f, d) in need {
                    for _ in 0..d.min(2) {
                        let s = self.sample_shard();
                        self.mailboxes[s].push(ShardMsg::SpawnPrewarm { f, n: 1 });
                        sent = true;
                    }
                }
            }
        }

        // 3) Cross-shard stealing (pull dispatch): each donor with a
        //    backlog, visited in shard order, hands up to `steal_batch`
        //    of its oldest parked requests to the least-loaded shard with
        //    an empty pending queue — and only if that shard is actually
        //    less loaded. Pure function of the epoch's reports, so the
        //    decision is identical regardless of which thread leads.
        if self.steal_batch > 0 {
            for donor in 0..self.shards {
                if self.reports[donor].pending == 0 {
                    continue;
                }
                let mut best: Option<usize> = None;
                for r in 0..self.shards {
                    // Failure digest: a shard whose active slice is
                    // entirely crash-marked can run nothing — stealing
                    // toward it would park the payload behind dead
                    // workers until the retry budget burns out.
                    if r == donor || self.reports[r].pending > 0 || self.reports[r].live == 0 {
                        continue;
                    }
                    // Slot digest: a recipient with no free core slot
                    // cannot start anything — handing work over would
                    // only park it behind saturated workers.
                    if self.slot_mode && self.reports[r].load.free_slots == 0 {
                        continue;
                    }
                    best = match best {
                        Some(b) if !self.reports[r].load.less_loaded_than(&self.reports[b].load) => {
                            Some(b)
                        }
                        _ => Some(r),
                    };
                }
                let Some(to) = best else { continue };
                // Never move work to a busier shard — unless the donor
                // has zero live workers, in which case its backlog can
                // only make progress by escaping (crash recovery).
                if self.reports[donor].live > 0
                    && !self.reports[to].load.less_loaded_than(&self.reports[donor].load)
                {
                    continue;
                }
                let mut n = self.reports[donor].pending.min(self.steal_batch);
                if self.slot_mode {
                    // Never hand over more than the recipient can start.
                    n = n.min(self.reports[to].load.free_slots as usize);
                }
                if n == 0 {
                    continue;
                }
                self.mailboxes[donor].push(ShardMsg::Handoff { to, n });
                sent = true;
                self.stole = true;
            }
        }

        self.done = all_drained && !sent && limit >= self.duration_s;
    }
}

/// Number of workers shard `s` of `n` owns out of `total`: contiguous
/// blocks differing by at most one, the first `total mod n` shards taking
/// the extra worker. Also the split rule for global worker targets.
pub fn shard_workers(total: usize, s: usize, n: usize) -> usize {
    total / n + usize::from(s < total % n)
}

/// The per-shard `Config`: the shard's worker slice, local control
/// disabled (the coordinator owns autoscale and pre-warm placement), and
/// `shards` reset to 1. VU slicing is applied separately via
/// [`Simulation::with_vu_slice`].
///
/// An explicit `faults.crashes` schedule addresses *global* worker ids;
/// since the partition is contiguous slices, entries are remapped to the
/// shard-local id space here and out-of-slice entries dropped, so
/// `"10:3"` kills the same physical worker at any shard count. Rate-based
/// faults (`crash_rate`, `straggler_frac`, `init_fail_prob`) need no
/// remapping: each shard draws them from per-worker streams salted with
/// its own shard seed.
pub fn partition_config(cfg: &Config, s: usize, n: usize) -> Config {
    let mut c = cfg.clone();
    c.cluster.workers = shard_workers(cfg.cluster.workers, s, n);
    c.sim.shards = 1;
    c.cluster.prewarm = false;
    c.autoscale.policy = "none".into();
    if c.faults.enabled && !c.faults.crashes.is_empty() {
        let base: usize = (0..s).map(|i| shard_workers(cfg.cluster.workers, i, n)).sum();
        let local = c.cluster.workers;
        // The list was validated by Config::validate before any shard
        // config is derived, so a parse error here is unreachable.
        let kept: Vec<String> = parse_crash_list(&c.faults.crashes)
            .unwrap_or_default()
            .into_iter()
            .filter(|&(_, w)| (base..base + local).contains(&w))
            .map(|(t, w)| format!("{t}:{}", w - base))
            .collect();
        c.faults.crashes = kept.join(";");
    }
    c
}

/// The per-shard RNG seed. Shard 0 keeps the run seed — with one shard
/// the serial engine consumes the identical streams — and later shards
/// derive disjoint streams via a golden-ratio step.
pub fn shard_seed(seed: u64, s: usize) -> u64 {
    seed ^ (s as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)
}

/// Shared entry-point setup (the sharded twin of `engine::build_parts`):
/// validated registry plus the scripted workload. `vus` overrides the
/// configured VU count (open-loop mode only needs a placeholder set).
fn build_registry_workload(
    cfg: &Config,
    seed: u64,
    vus: Option<usize>,
) -> Result<(FunctionRegistry, Workload), String> {
    let registry = FunctionRegistry::functionbench(cfg.workload.copies);
    if registry.len() != cfg.num_functions() {
        return Err(format!(
            "registry size {} != configured {}",
            registry.len(),
            cfg.num_functions()
        ));
    }
    let mut wcfg = cfg.workload.clone();
    if let Some(v) = vus {
        wcfg.vus = v;
    }
    let workload = Workload::generate(&wcfg, registry.len(), seed);
    Ok((registry, workload))
}

/// Run one (config, seed) closed-loop experiment on `cfg.sim.shards`
/// threads. Prefer [`super::run_once`], which routes here for
/// `shards > 1` and to the serial engine otherwise.
pub fn run_sharded(cfg: &Config, seed: u64) -> Result<RunMetrics, String> {
    let (registry, workload) = build_registry_workload(cfg, seed, None)?;
    run_sharded_with(cfg, &registry, &workload, None, seed)
}

/// Sharded open-loop trace replay: arrival `i` is issued by shard
/// `i mod shards`. Prefer [`super::run_trace`], which routes here.
pub fn run_sharded_trace(
    cfg: &Config,
    trace: &OpenLoopTrace,
    seed: u64,
) -> Result<RunMetrics, String> {
    // The VU workload is unused in open-loop mode; minimal script set.
    let (registry, workload) = build_registry_workload(cfg, seed, Some(1))?;
    run_sharded_with(cfg, &registry, &workload, Some(trace), seed)
}

/// The sharded driver over pre-built workload parts (the perf bench times
/// this directly so workload generation stays outside the measurement).
/// `trace` switches to open-loop replay.
pub fn run_sharded_with(
    cfg: &Config,
    registry: &FunctionRegistry,
    workload: &Workload,
    trace: Option<&OpenLoopTrace>,
    seed: u64,
) -> Result<RunMetrics, String> {
    let n = cfg.sim.shards;
    if n < 2 {
        return Err("run_sharded_with needs sim.shards >= 2 (1 is the serial engine)".into());
    }
    if cfg.cluster.workers < n {
        return Err(format!(
            "sim.shards = {n} exceeds cluster.workers = {}",
            cfg.cluster.workers
        ));
    }
    if cfg.autoscale.policy == "predictive" {
        return Err("autoscale.policy = predictive is not supported with sim.shards > 1 \
                    (needs the per-arrival forecast feed; see DESIGN.md §6)"
            .into());
    }

    // Per-shard configs and scheduler instances (fallible work happens
    // before any thread spawns, so the barrier protocol can't deadlock on
    // a construction error).
    let shard_cfgs: Vec<Config> = (0..n).map(|s| partition_config(cfg, s, n)).collect();
    let mut shard_scheds: Vec<Vec<Box<dyn Scheduler>>> = Vec::with_capacity(n);
    for sc in &shard_cfgs {
        let mut v = Vec::new();
        for _ in 0..cfg.scheduler.instances.max(1) {
            v.push(make_scheduler(&cfg.scheduler, sc.cluster.workers)?);
        }
        shard_scheds.push(v);
    }

    // Global control: the coordinator owns the policy (ticked over merged
    // observations) and the scheduled event list (epoch-quantized).
    let policy = crate::autoscale::make_policy(&cfg.autoscale)?;
    let mut pending_events = policy.scheduled_events();
    pending_events.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
    let tick_driven = policy.tick_driven();
    let barrier_dt =
        if tick_driven { cfg.autoscale.interval_s } else { cfg.sim.barrier_s };
    debug_assert!(barrier_dt > 0.0, "validated by Config::validate");
    // The serial open-loop engine never pre-warms (`prepare_open` installs
    // no PreWarmTick), so the coordinator must not either — otherwise
    // shard-count comparisons on trace benches would be confounded.
    let prewarm_global = cfg.cluster.prewarm && trace.is_none();
    let coord = Mutex::new(Coord {
        policy: if tick_driven { Some(policy) } else { None },
        pending_events,
        next_event: 0,
        rng: Pcg64::new(seed ^ 0x5AAD_C0DE),
        prewarm_global,
        steal_batch: if cfg.pull_dispatch() { cfg.dispatch.steal_batch } else { 0 },
        slot_mode: cfg.sim.cores_per_worker > 1,
        duration_s: cfg.workload.duration_s,
        concurrency: cfg.cluster.concurrency,
        shards: n,
        mean_exec_s: (0..registry.len()).map(|f| registry.app(f).warm_ms / 1000.0).collect(),
        warm_scratch: vec![0; registry.len()],
        reports: vec![ShardReport::default(); n],
        mailboxes: vec![Vec::new(); n],
        handoff: vec![vec![Vec::new(); n]; n],
        stole: false,
        done: false,
    });
    let barrier = Barrier::new(n);
    let results: Mutex<Vec<Option<RunMetrics>>> = Mutex::new((0..n).map(|_| None).collect());

    std::thread::scope(|scope| {
        for (s, scheds) in shard_scheds.into_iter().enumerate() {
            let shard_cfg = &shard_cfgs[s];
            let (coord, barrier, results) = (&coord, &barrier, &results);
            scope.spawn(move || {
                // A panicking shard would leave its siblings blocked in
                // barrier.wait() forever (std Barrier has no poisoning),
                // turning an invariant violation into a silent hang. Catch
                // the panic, surface it, and abort the process instead.
                let run = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    shard_main(shard_cfg, registry, workload, trace, scheds, seed, s, n,
                        barrier_dt, prewarm_global, coord, barrier)
                }));
                match run {
                    Ok(m) => results.lock().unwrap()[s] = Some(m),
                    Err(payload) => {
                        let msg = payload
                            .downcast_ref::<&str>()
                            .map(|m| m.to_string())
                            .or_else(|| payload.downcast_ref::<String>().cloned())
                            .unwrap_or_else(|| "non-string panic payload".into());
                        eprintln!(
                            "shard {s} panicked ({msg}); aborting — barrier peers \
                             cannot make progress"
                        );
                        std::process::abort();
                    }
                }
            });
        }
    });

    // Merge per-shard metrics in shard order (worker ids are the shard
    // slices concatenated — the same global ids the partition defines).
    let mut merged: Option<RunMetrics> = None;
    for slot in results.into_inner().unwrap() {
        let m = slot.expect("shard thread exited without producing metrics");
        match &mut merged {
            None => merged = Some(m),
            Some(acc) => acc.merge(&m),
        }
    }
    Ok(merged.expect("at least two shards ran"))
}

/// One shard's whole life: build the per-shard simulation, run the epoch
/// loop against the barrier protocol, finalize. Runs on its own thread.
#[allow(clippy::too_many_arguments)]
fn shard_main(
    shard_cfg: &Config,
    registry: &FunctionRegistry,
    workload: &Workload,
    trace: Option<&OpenLoopTrace>,
    scheds: Vec<Box<dyn Scheduler>>,
    seed: u64,
    s: usize,
    n: usize,
    barrier_dt: f64,
    prewarm_global: bool,
    coord: &Mutex<Coord>,
    barrier: &Barrier,
) -> RunMetrics {
    let mut sim =
        Simulation::with_schedulers(shard_cfg, registry, workload, scheds, shard_seed(seed, s))
            .with_vu_slice(s, n);
    if prewarm_global {
        sim = sim.with_rate_tracking();
    }
    match trace {
        Some(tr) => sim.prepare_open(tr),
        None => sim.prepare_closed(),
    }
    // Phase profiling (`telemetry.phase_profile`): wall-clock timers
    // around the barrier rendezvous and the handoff transfer, write-only
    // into the metrics — a profiled run is bit-identical to an unprofiled
    // one. `step_until` meters its own pop/decide/autoscale time; the
    // sections below are exactly the time a shard spends *not* draining
    // its own events.
    let profiled = sim.phases_enabled();
    let mut epoch = 0u64;
    loop {
        epoch += 1;
        let limit = epoch as f64 * barrier_dt;
        let drained = sim.step_until(limit);
        // detlint:allow(R2) -- barrier-phase profiler wall-clock; write-only telemetry (DESIGN.md §12)
        let bar0 = profiled.then(Instant::now);
        // Phase 1: publish this shard's report.
        {
            let mut c = coord.lock().unwrap();
            let r = &mut c.reports[s];
            r.drained = drained;
            r.active = sim.active_workers();
            r.live = sim.live_workers();
            let (running, queued) = sim.cluster_running_queued();
            r.running = running;
            r.queued = queued;
            r.load = sim.cluster_load_summary();
            r.pending = sim.pending_len();
            r.warm.resize(registry.len(), 0);
            r.warm.fill(0);
            sim.cluster_warm_supply_into(&mut r.warm);
            if prewarm_global {
                sim.prewarm_deficits_into(&mut r.deficits);
            } else {
                r.deficits.clear();
            }
        }
        // Phase 2: one thread coordinates between the barriers.
        if barrier.wait().is_leader() {
            coord.lock().unwrap().coordinate(limit);
        }
        barrier.wait();
        // Phase 3: apply this shard's mailbox at the epoch boundary, then
        // check termination.
        let (msgs, done, stole) = {
            let mut c = coord.lock().unwrap();
            (std::mem::take(&mut c.mailboxes[s]), c.done, c.stole)
        };
        if let Some(t0) = bar0 {
            let dt = t0.elapsed().as_secs_f64();
            let p = sim.phases_mut();
            p.barrier_s += dt;
            p.wall_s += dt;
        }
        if !msgs.is_empty() {
            sim.advance_clock_to(limit);
            for m in msgs {
                // detlint:allow(R2) -- mailbox-phase profiler wall-clock; write-only telemetry (DESIGN.md §12)
                let t0 = profiled.then(Instant::now);
                let is_handoff = matches!(m, ShardMsg::Handoff { .. });
                match m {
                    ShardMsg::ScaleTo { target } => sim.apply_scale_target(target),
                    ShardMsg::SpawnPrewarm { f, n } => sim.apply_prewarm(f, n),
                    ShardMsg::Handoff { to, n } => {
                        // Donor side: deposit payloads for the recipient.
                        let tasks = sim.extract_stolen(n);
                        if !tasks.is_empty() {
                            coord.lock().unwrap().handoff[to][s] = tasks;
                        }
                    }
                }
                if let Some(t0) = t0 {
                    let dt = t0.elapsed().as_secs_f64();
                    let p = sim.phases_mut();
                    if is_handoff {
                        p.handoff_s += dt;
                    } else {
                        p.autoscale_s += dt;
                    }
                    p.wall_s += dt;
                }
            }
        }
        if stole {
            // detlint:allow(R2) -- handoff-phase profiler wall-clock; write-only telemetry (DESIGN.md §12)
            let t0 = profiled.then(Instant::now);
            // Transfer barrier: every donor has deposited its payloads.
            // All shards agree on `stole` (read between the same pair of
            // barriers), so the rendezvous count always matches.
            barrier.wait();
            let incoming: Vec<Vec<StolenTask>> = {
                let mut c = coord.lock().unwrap();
                c.handoff[s].iter_mut().map(std::mem::take).collect()
            };
            if incoming.iter().any(|v| !v.is_empty()) {
                sim.advance_clock_to(limit);
                for from in incoming {
                    for task in from {
                        sim.ingest_stolen(task);
                    }
                }
            }
            if let Some(t0) = t0 {
                let dt = t0.elapsed().as_secs_f64();
                let p = sim.phases_mut();
                p.handoff_s += dt;
                p.wall_s += dt;
            }
        }
        if done {
            break;
        }
    }
    sim.finish()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shard_worker_split_covers_total() {
        for total in [5usize, 8, 100, 101, 103] {
            for n in [2usize, 3, 4, 7] {
                let parts: Vec<usize> = (0..n).map(|s| shard_workers(total, s, n)).collect();
                assert_eq!(parts.iter().sum::<usize>(), total, "{total}/{n}: {parts:?}");
                let (mn, mx) =
                    (parts.iter().min().unwrap(), parts.iter().max().unwrap());
                assert!(mx - mn <= 1, "uneven split {parts:?}");
            }
        }
    }

    #[test]
    fn shard_seed_zero_is_run_seed() {
        assert_eq!(shard_seed(42, 0), 42);
        assert_ne!(shard_seed(42, 1), 42);
        assert_ne!(shard_seed(42, 1), shard_seed(42, 2));
    }

    #[test]
    fn partition_config_slices_and_disarms_local_control() {
        let mut cfg = Config::default();
        cfg.cluster.workers = 5;
        cfg.cluster.prewarm = true;
        cfg.sim.shards = 2;
        let p0 = partition_config(&cfg, 0, 2);
        let p1 = partition_config(&cfg, 1, 2);
        assert_eq!(p0.cluster.workers, 3);
        assert_eq!(p1.cluster.workers, 2);
        for p in [&p0, &p1] {
            assert_eq!(p.sim.shards, 1);
            assert!(!p.cluster.prewarm, "local pre-warm must be coordinator-owned");
            assert_eq!(p.autoscale.policy, "none");
            assert_eq!(p.workload, cfg.workload, "workload section must stay global");
        }
    }

    #[test]
    fn partition_config_remaps_explicit_crashes() {
        let mut cfg = Config::default();
        cfg.cluster.workers = 5; // slices: {0,1,2} and {3,4}
        cfg.sim.shards = 2;
        cfg.faults.enabled = true;
        cfg.faults.crashes = "10:1;40:3;50:4".into();
        let p0 = partition_config(&cfg, 0, 2);
        let p1 = partition_config(&cfg, 1, 2);
        assert_eq!(p0.faults.crashes, "10:1", "global id 1 is local 1 of shard 0");
        assert_eq!(p1.faults.crashes, "40:0;50:1", "global ids 3,4 are local 0,1 of shard 1");
        assert!(p0.faults.enabled && p1.faults.enabled, "faults section must stay armed");
    }

    #[test]
    fn rejects_bad_shard_setups() {
        let registry = FunctionRegistry::functionbench(5);
        let mut cfg = Config::default();
        cfg.workload.vus = 2;
        cfg.workload.duration_s = 1.0;
        let workload = Workload::generate(&cfg.workload, registry.len(), 1);
        // shards = 1 is the serial engine's job.
        cfg.sim.shards = 1;
        assert!(run_sharded_with(&cfg, &registry, &workload, None, 1).is_err());
        // More shards than workers cannot partition.
        cfg.sim.shards = 9;
        cfg.cluster.workers = 5;
        assert!(run_sharded_with(&cfg, &registry, &workload, None, 1).is_err());
    }
}
