//! The discrete-event simulation engine: wires workload -> scheduler(s) ->
//! cluster and produces [`RunMetrics`].
//!
//! This reproduces the paper's cluster experiments (§V) without the AWS
//! testbed: the same closed-loop VU workload, the same scheduler contract,
//! the same sandbox lifecycle, with service times calibrated from Table I.
//! Everything is deterministic under (config, seed): scripts, service-time
//! streams and scheduler tie-breaking derive from split PRNG streams.
//!
//! ## Dispatch protocol
//!
//! Requests route through [`crate::scheduler::Scheduler::decide`]:
//! `dispatch.mode = "push"` (default) takes the adapter path — an
//! immediate `Assign` with the identical RNG stream, bit-identical to the
//! pre-protocol engine — while `"pull"` makes the paper's pull loop
//! first-class: requests with a warm prospect park in the router-owned
//! [`crate::dispatch::PendingQueue`], idle workers claim them via `on_worker_idle`, a
//! `PullDeadline` event force-places stragglers, and
//! `autoscale.min_workers = 0` lets the cluster park entirely with a
//! queue-triggered `Wake` event (DESIGN.md §8).
//!
//! The pull router is a **per-function fair dispatcher**:
//! - admission is bounded *per function* (`dispatch.queue_cap` default +
//!   `dispatch.queue_caps` overrides), so one hot function's overflow
//!   rejects only itself (rejects are metered per function, never
//!   silently dropped);
//! - backlog drains (wake flushes, cross-shard steal donation, and
//!   idle-capacity claims of prospect-less requests) pop in
//!   deficit-round-robin order over the function queues
//!   (`dispatch.weights`; `dispatch.fair = false` restores the PR 4
//!   arrival-order FIFO as the ablation baseline);
//! - wait deadlines are cost-aware per function
//!   (`dispatch.adaptive_wait`): `min(max_wait_s, ewma cold penalty)`,
//!   where the EWMA tracks the observed cold−warm start delta;
//! - a scale-to-zero `Wake` restores `⌈backlog / concurrency⌉` workers
//!   at once before flushing, so bursts into an empty cluster do not
//!   serialize behind a single woken worker.
//!
//! Beyond the paper's base protocol the engine supports three extensions
//! used by the ablation benches:
//! - **auto-scaling** (the [`crate::autoscale`] subsystem): a recurring
//!   control tick evaluates the configured policy, which adds/drains
//!   workers and plans per-function pre-warm pools; schedulers are
//!   notified via `on_worker_added`/`on_worker_removed` (§II-C's
//!   redistribution story). Externally scripted scale times are the
//!   `scheduled` policy's event list;
//! - **multiple scheduler instances** (`scheduler.instances`): VUs are
//!   sharded across independent, synchronization-free schedulers, each
//!   with its own local load view (§I's distributed-scheduling claim);
//! - **open-loop trace replay** (`run_open_loop`): arrivals from a
//!   synthetic Azure-like trace instead of closed-loop VUs (burst
//!   response, Fig 6 tie-in).
//!
//! ## Hot-path architecture (the event-core overhaul)
//!
//! The engine is built for 10k–100k-worker simulations:
//! - events live in a calendar queue ([`EventQueue`], amortized O(1)
//!   push/pop) instead of a binary heap;
//! - the control ticks (`on_autoscale_tick`, `on_prewarm_tick`) read the
//!   cluster's incrementally maintained aggregates (O(functions)) instead
//!   of scanning O(workers × functions) state;
//! - `spawn_prewarm` and the schedulers' least-loaded decisions use
//!   incremental min-load indices (O(tie set)) instead of O(workers)
//!   scans.
//!
//! Each replacement is *bit-identical* to the scan it replaces. With the
//! `ref-heap` feature (default) the seed paths are kept alive behind
//! [`Simulation::with_reference_core`], and `tests/determinism.rs` asserts
//! run-for-run equivalence across schedulers, modes, autoscale policies
//! and seeds; `benches/sim_engine_perf.rs` measures the before/after.
//!
//! ## Sharding hooks
//!
//! The parallel driver ([`crate::sim::shard`]) runs one `Simulation` per
//! OS thread over a worker slice and a VU slice
//! ([`Simulation::with_vu_slice`]), stepping each through epoch-bounded
//! event processing (`step_until`) between event-time barriers. `run()`
//! is exactly `prepare + drain-everything + finalize`, so the serial path
//! (`--shards 1`) is byte-for-byte the seed behavior — the stepping API
//! only re-chunks the identical pop sequence.
//!
//! ## Batch-coalesced completions
//!
//! When several completions land on the same worker at the same timestamp
//! *adjacently* in `(time, seq)` order, the dispatcher folds them into one
//! [`Cluster::complete_batch`] call: the worker-side transitions run in
//! the same order, but the aggregate snapshot/journal/load-index
//! bookkeeping is paid once per batch instead of once per event. Only
//! adjacent events are merged, so scheduler callbacks, RNG draws, metric
//! pushes and event seq numbers are identical to one-at-a-time dispatch
//! (DESIGN.md §6; equivalence property-tested in `tests/determinism.rs`).

use super::events::{Event, EventQueue};
use crate::autoscale::{AutoscaleObs, AutoscalePolicy};
use crate::config::Config;
use crate::dispatch::PendingQueue;
use crate::faults::{fault_coin, retry_backoff, FaultPlan};
use crate::metrics::RunMetrics;
use crate::platform::{AssignOutcome, BatchCompletion, Cluster, SandboxId, StartInfo, WorkerId};
use crate::scheduler::{Decision, DispatchCtx, Pull, SchedCtx, SchedCtxBuilder, Scheduler, SlotCtx};
use crate::util::loadidx::{LoadSummary, MinLoadIndex};
use crate::util::rng::Pcg64;
use crate::workload::loadgen::{OpenLoopTrace, Workload};
use crate::workload::spec::FunctionRegistry;
use std::collections::VecDeque;
use std::time::Instant;

/// The engine's one `SchedCtx` construction path (a free function so the
/// split borrows of `Simulation` fields stay legal at every call site):
/// active-prefix loads, the min-load index (the reference engine opts
/// out — linear scans are its semantics baseline), the scheduler RNG
/// stream, and the fault avoid mask. Callers chain `.dispatch()` /
/// `.slots()` onto the returned builder for the pull/slot signals.
fn sched_ctx<'a>(
    loads: &'a MinLoadIndex,
    reference: bool,
    active: usize,
    rng: &'a mut Pcg64,
    faults: Option<&'a FaultRuntime>,
) -> SchedCtxBuilder<'a> {
    SchedCtx::builder(&loads.loads()[..active], rng)
        .min_index(if reference { None } else { Some(loads) })
        .avoid(faults.map(|fr| fr.dead.as_slice()))
}

/// Per-request bookkeeping.
#[derive(Clone, Copy, Debug)]
struct RequestMeta {
    /// Closed loop: issuing VU; open loop: usize::MAX.
    vu: usize,
    step: usize,
    function: usize,
    /// Bound worker; `usize::MAX` while parked in the pending queue.
    worker: WorkerId,
    /// Scheduler instance that routed this request.
    sched: usize,
    arrival: f64,
}

/// A parked request handed off across shards at an epoch barrier — the
/// `ShardMsg::Handoff` payload. Carries everything the receiving shard
/// needs to re-issue the request locally; for closed-loop requests the
/// VU's continuation migrates with it (its next arrival issues from the
/// receiving shard).
#[derive(Clone, Copy, Debug)]
pub(crate) struct StolenTask {
    /// Requested function type.
    pub(crate) function: usize,
    /// Original arrival time (latency and queue-wait keep accruing).
    pub(crate) arrival: f64,
    /// Issuing VU (`usize::MAX` for open-loop trace arrivals).
    pub(crate) vu: usize,
    /// Script step (closed loop) or trace index (open loop).
    pub(crate) step: usize,
    /// Retry attempts already consumed on the donating shard — the retry
    /// budget travels with the request (0 when faults are off).
    pub(crate) retries: u32,
}

/// Mutable fault-injection state for one engine (or one shard). Present
/// only when `[faults].enabled`; `None` keeps every fault check
/// short-circuited so a fault-free run is byte-identical to the
/// pre-fault engine (no extra events, RNG draws, or metric pushes).
struct FaultRuntime {
    /// The run seed — fault-salted pure-hash draws key off it
    /// ([`crate::faults::fault_coin`] / [`crate::faults::retry_backoff`]).
    seed: u64,
    /// Crash-marked workers. Dead workers stay in the active prefix (so
    /// worker ids never renumber); the router re-routes around them.
    dead: Vec<bool>,
    /// Per-worker service-time multiplier (1.0 = healthy; a straggler
    /// episode raises it for new starts until the episode ends).
    slow: Vec<f64>,
    /// Crash timestamp per worker, for the recovery-latency metric.
    crashed_at: Vec<f64>,
    /// Executions in flight per worker as `(request, sandbox)`, so a
    /// crash can harvest and re-enqueue its victims in O(running).
    running_on: Vec<Vec<(u64, SandboxId)>>,
    /// Sandbox-id watermark recorded at each worker's last crash: a
    /// completion whose sandbox id is below the floor refers to state the
    /// crash destroyed and is dropped (ids are never reused).
    crash_floor: Vec<SandboxId>,
    /// Retry attempts consumed per request (lazily grown with `requests`).
    attempts: Vec<u32>,
    /// Request reached a terminal state (completed / failed / donated to
    /// another shard): duplicate completions from hedges and stray
    /// retry/hedge events become no-ops — every arrival resolves once.
    resolved: Vec<bool>,
    /// A hedge duplicate was already issued for this request (at most one).
    hedged: Vec<bool>,
    /// The current execution's cold init failed (fault coin): its
    /// completion evicts the broken sandbox and retries instead of
    /// resolving the request.
    init_failed: Vec<bool>,
    /// Per-function EWMA of the sampled (pre-straggler) execution time —
    /// the runtime estimate behind the hedge deadline.
    runtime_ewma: Vec<f64>,
    /// Warm state harvested from crashed workers: `(function, expiry)`.
    /// Consumed by retried requests at re-bind while the original
    /// keep-alive window still allows — the warm-state handoff.
    warm_bank: Vec<(usize, f64)>,
    /// Requests donated to another shard (conservation accounting:
    /// `requests.len() == completed + failed + donated` per shard).
    donated: u64,
}

impl FaultRuntime {
    fn new(seed: u64, workers: usize, functions: usize) -> Self {
        Self {
            seed,
            dead: vec![false; workers],
            slow: vec![1.0; workers],
            crashed_at: vec![0.0; workers],
            running_on: vec![Vec::new(); workers],
            crash_floor: vec![0; workers],
            attempts: Vec::new(),
            resolved: Vec::new(),
            hedged: Vec::new(),
            init_failed: Vec::new(),
            runtime_ewma: vec![0.0; functions],
            warm_bank: Vec::new(),
            donated: 0,
        }
    }

    /// Grow the per-worker tables to cover `w` (scale-up adds workers).
    fn ensure_worker(&mut self, w: WorkerId) {
        if w >= self.dead.len() {
            self.dead.resize(w + 1, false);
            self.slow.resize(w + 1, 1.0);
            self.crashed_at.resize(w + 1, 0.0);
            self.running_on.resize(w + 1, Vec::new());
            self.crash_floor.resize(w + 1, 0);
        }
    }

    /// Grow the per-request tables to cover `rid`.
    fn ensure_request(&mut self, rid: u64) {
        let n = rid as usize + 1;
        if n > self.attempts.len() {
            self.attempts.resize(n, 0);
            self.resolved.resize(n, false);
            self.hedged.resize(n, false);
            self.init_failed.resize(n, false);
        }
    }

    fn is_dead(&self, w: WorkerId) -> bool {
        self.dead.get(w).copied().unwrap_or(false)
    }

    fn is_resolved(&self, rid: u64) -> bool {
        self.resolved.get(rid as usize).copied().unwrap_or(false)
    }

    /// Least-loaded live worker in the active prefix — the re-route
    /// target when a selection landed on a crashed worker. O(active),
    /// paid only on the (rare) re-route path.
    fn best_live(&self, loads: &[u32], active: usize) -> Option<WorkerId> {
        let mut best: Option<WorkerId> = None;
        for w in 0..active {
            if self.is_dead(w) {
                continue;
            }
            if best.map_or(true, |b| loads[w] < loads[b]) {
                best = Some(w);
            }
        }
        best
    }
}

/// One simulation run: scheduler instance(s) against the workload.
pub struct Simulation<'a> {
    cfg: &'a Config,
    registry: &'a FunctionRegistry,
    workload: &'a Workload,
    /// Scheduler instances; VU v is served by instance v % len.
    schedulers: Vec<Box<dyn Scheduler>>,
    cluster: Cluster,
    queue: EventQueue,
    /// Per-instance router-side active connections (local load views —
    /// instances do not synchronize, per the paper's distributed design).
    /// Each view is a min-load index: the counts vector plus the bucket
    /// structure behind the O(tie set) least-loaded queries.
    loads: Vec<MinLoadIndex>,
    sched_rng: Pcg64,
    service_rng: Pcg64,
    /// (time, up) auto-scaling events; up=false drains the highest worker.
    scale_events: Vec<(f64, bool)>,
    /// Closed-loop autoscale policy (None = static cluster). Scheduled
    /// events and the recurring control tick both come from here.
    autoscaler: Option<Box<dyn AutoscalePolicy>>,
    /// Control-tick period (config `autoscale.interval_s`).
    tick_dt: f64,
    /// Per-function mean warm execution time (autoscale observation).
    mean_exec_s: Vec<f64>,
    requests: Vec<RequestMeta>,
    /// EWMA arrival rate per function (req/s), for the pre-warm policy.
    arrival_rate: Vec<f64>,
    last_arrival: Vec<f64>,
    /// Cold-start flag per request, resolved when its execution starts.
    /// Grows in lockstep with `requests` (pushed at issue time).
    cold_flags: Vec<bool>,
    /// Worker-queue delay per request (same lockstep).
    queue_delays: Vec<f64>,
    /// Scratch for the per-tick warm-supply observation (O(functions)).
    warm_scratch: Vec<usize>,
    /// Reference mode: seed event core + seed O(workers) scan paths, for
    /// the equivalence suite and before/after benchmarks.
    reference: bool,
    /// VU-slice restriction (sharded runs): this instance issues arrivals
    /// only for VUs (closed loop) / trace indices (open loop) with
    /// `i % vu_stride == vu_offset`. `(0, 1)` = the whole workload.
    vu_offset: usize,
    vu_stride: usize,
    /// Open-loop arrivals table, installed by `prepare_open`.
    open_arrivals: Option<Vec<(f64, usize)>>,
    /// Track per-function arrival rates even when `cluster.prewarm` is off
    /// (sharded runs: the coordinator pre-warms globally from shard-local
    /// rate estimates).
    track_rates: bool,
    /// Scratch for same-tick completion coalescing: (sandbox, request).
    batch_buf: Vec<(SandboxId, u64)>,
    /// Scratch sandbox-id list handed to `Cluster::complete_batch`.
    batch_ids: Vec<SandboxId>,
    /// Pull dispatch protocol active (`dispatch.mode = "pull"`). Push
    /// mode leaves every field below untouched and is bit-identical to
    /// the pre-protocol engine.
    pull: bool,
    /// Router-owned pending queue behind `Decision::Enqueue` (DRR state
    /// seeded from `dispatch.weights`).
    pending: PendingQueue,
    /// Fair (DRR) backlog draining on (`dispatch.fair`); false restores
    /// the PR 4 global arrival-order FIFO for flushes/steals/claims.
    fair: bool,
    /// Cost-aware deadlines on (`dispatch.adaptive_wait`).
    adaptive_wait: bool,
    /// Per-function admission caps on the pending queue
    /// (`dispatch.queue_cap` default + `dispatch.queue_caps` overrides;
    /// 0 = unbounded).
    cap_f: Vec<usize>,
    /// EWMA of the observed per-function cold-start penalty (the init
    /// sample added to cold executions), seconds; 0 = no observation yet.
    /// Sizes the adaptive pull deadline `min(max_wait_s, ewma)`.
    cold_penalty_ewma: Vec<f64>,
    /// Executions of each function currently running (the warm-prospect
    /// signal handed to `decide` via `DispatchCtx`). Pull mode only.
    inflight_f: Vec<usize>,
    /// A scale-to-zero wake event is already scheduled.
    wake_armed: bool,
    /// Scale-down floor: 0 only for scale-to-zero configs
    /// (`autoscale.min_workers = 0` under pull dispatch), else 1.
    min_active: usize,
    /// The run seed (fault plans and per-request fault hashes key off it;
    /// the RNG streams above were already split from a salted copy).
    run_seed: u64,
    /// Fault-injection runtime (`[faults].enabled`); `None` short-circuits
    /// every fault check — byte-identical to the pre-fault engine.
    faults: Option<FaultRuntime>,
    /// Core-granular scheduling active (`sim.cores_per_worker > 1`,
    /// DESIGN.md §11). Off (the default) leaves every slot field below
    /// untouched — byte-identical to the slot-agnostic engine.
    slot_mode: bool,
    /// Push-mode bounded re-route window (`dispatch.rebind_window_s`);
    /// 0 disables the rebind hook entirely.
    rebind_window_s: f64,
    /// Requests queued behind a busy worker that may still re-route:
    /// `(request, bound worker, window expiry)` in queueing order.
    /// Expired and stale entries are dropped lazily.
    rebind_q: VecDeque<(u64, WorkerId, f64)>,
    /// Scratch for the per-decide slot view (free slots per worker).
    slot_free_scratch: Vec<u32>,
    /// Scratch for the per-decide slot view (lowest free warm-affine
    /// slot per worker, -1 = none).
    slot_warm_scratch: Vec<i32>,
    metrics: RunMetrics,
}

impl<'a> Simulation<'a> {
    /// A single-scheduler simulation over the configured cluster/workload.
    pub fn new(
        cfg: &'a Config,
        registry: &'a FunctionRegistry,
        workload: &'a Workload,
        scheduler: Box<dyn Scheduler>,
        seed: u64,
    ) -> Self {
        Self::with_schedulers(cfg, registry, workload, vec![scheduler], seed)
    }

    /// A simulation with several independent scheduler instances (VU `v`
    /// is served by instance `v % instances` — the distributed-scheduling
    /// ablation).
    pub fn with_schedulers(
        cfg: &'a Config,
        registry: &'a FunctionRegistry,
        workload: &'a Workload,
        schedulers: Vec<Box<dyn Scheduler>>,
        seed: u64,
    ) -> Self {
        assert!(!schedulers.is_empty());
        let mut root = Pcg64::new(seed ^ 0x51D0_C0DE);
        let sched_rng = root.split();
        let service_rng = root.split();
        let name = schedulers[0].name().to_string();
        let n = schedulers.len();
        // Pre-size per-request tables to the scripted upper bound:
        // avoids realloc + page-fault churn in the hot loop (§Perf).
        let cap = workload.total_steps().min(4_000_000);
        Self {
            cfg,
            registry,
            workload,
            schedulers,
            cluster: Cluster::new_with_cores(&cfg.cluster, cfg.sim.cores_per_worker),
            queue: EventQueue::new(),
            loads: (0..n).map(|_| MinLoadIndex::new(cfg.cluster.workers)).collect(),
            sched_rng,
            service_rng,
            scale_events: Vec::new(),
            autoscaler: None,
            tick_dt: cfg.autoscale.interval_s,
            mean_exec_s: (0..registry.len()).map(|f| registry.app(f).warm_ms / 1000.0).collect(),
            requests: Vec::with_capacity(cap),
            arrival_rate: vec![0.0; registry.len()],
            last_arrival: vec![-1.0; registry.len()],
            cold_flags: Vec::with_capacity(cap),
            queue_delays: Vec::with_capacity(cap),
            warm_scratch: vec![0; registry.len()],
            reference: false,
            vu_offset: 0,
            vu_stride: 1,
            open_arrivals: None,
            track_rates: false,
            batch_buf: Vec::new(),
            batch_ids: Vec::new(),
            pull: cfg.pull_dispatch(),
            pending: PendingQueue::with_layout(
                registry.len(),
                &cfg.dispatch.weights_sparse(),
            ),
            fair: cfg.dispatch.fair,
            adaptive_wait: cfg.dispatch.adaptive_wait,
            cap_f: cfg.dispatch.caps_dense(registry.len()),
            cold_penalty_ewma: vec![0.0; registry.len()],
            inflight_f: vec![0; registry.len()],
            wake_armed: false,
            min_active: if cfg.pull_dispatch() && cfg.autoscale.min_workers == 0 { 0 } else { 1 },
            run_seed: seed,
            faults: if cfg.faults.enabled {
                Some(FaultRuntime::new(seed, cfg.cluster.workers, registry.len()))
            } else {
                None
            },
            slot_mode: cfg.sim.cores_per_worker > 1,
            rebind_window_s: cfg.dispatch.rebind_window_s,
            rebind_q: VecDeque::new(),
            slot_free_scratch: Vec::new(),
            slot_warm_scratch: Vec::new(),
            metrics: {
                let mut m = RunMetrics::with_telemetry(
                    &name,
                    cfg.cluster.workers,
                    cfg.workload.vus,
                    cfg.workload.duration_s,
                    &cfg.telemetry,
                );
                m.faults_enabled = cfg.faults.enabled;
                m.slots_enabled =
                    cfg.sim.cores_per_worker > 1 || cfg.dispatch.rebind_window_s > 0.0;
                m
            },
        }
    }

    /// Schedule auto-scaling events: one worker joins at each time.
    pub fn with_scale_times(mut self, times: &[f64]) -> Self {
        self.scale_events = times.iter().map(|&t| (t, true)).collect();
        self
    }

    /// Schedule mixed scale events: (time, up). Scale-down is LIFO — the
    /// highest-id worker drains.
    pub fn with_scale_events(mut self, events: &[(f64, bool)]) -> Self {
        self.scale_events = events.to_vec();
        self
    }

    /// Install an autoscale policy (closed-loop scaling + pre-warming).
    pub fn with_autoscaler(mut self, policy: Box<dyn AutoscalePolicy>) -> Self {
        self.autoscaler = Some(policy);
        self
    }

    /// Install the autoscale policy the config's `[autoscale]` section
    /// asks for (the `none` policy is inert, so this is always safe).
    pub fn with_config_autoscaler(mut self) -> Result<Self, String> {
        self.autoscaler = Some(crate::autoscale::make_policy(&self.cfg.autoscale)?);
        Ok(self)
    }

    /// Run on the seed implementation: `BinaryHeap` event core plus the
    /// original O(workers)/O(workers × functions) scan paths. Exists to
    /// prove the optimized engine bit-identical (`tests/determinism.rs`)
    /// and to measure the before/after (`benches/sim_engine_perf.rs`).
    #[cfg(feature = "ref-heap")]
    pub fn with_reference_core(mut self) -> Self {
        self.reference = true;
        self.queue = EventQueue::reference();
        self
    }

    /// Restrict this instance to the VU slice `offset, offset + stride, …`
    /// — the sharded engine's workload partition (the worker slice comes
    /// from `cfg.cluster.workers`; VU ids stay global). In open-loop mode
    /// the same rule partitions trace arrival indices. `(0, 1)` is the
    /// default whole-workload behavior, with an identical event stream to
    /// an unsliced run.
    pub fn with_vu_slice(mut self, offset: usize, stride: usize) -> Self {
        assert!(stride >= 1 && offset < stride, "bad VU slice {offset}/{stride}");
        self.vu_offset = offset;
        self.vu_stride = stride;
        // Sampled trace spans carry the shard index so a merged trace
        // stays attributable (serial runs keep shard 0).
        self.metrics.trace.set_shard(offset);
        self
    }

    /// Mutable access to the phase profile, for the sharded driver's
    /// barrier/handoff timers (no-op accumulators unless
    /// `telemetry.phase_profile` is on).
    pub(crate) fn phases_mut(&mut self) -> &mut crate::metrics::PhaseProfile {
        &mut self.metrics.phases
    }

    /// Whether phase profiling is enabled for this run.
    pub(crate) fn phases_enabled(&self) -> bool {
        self.metrics.phases.enabled
    }

    /// Track per-function arrival rates even without the local pre-warm
    /// heuristic — the sharded coordinator aggregates shard-local rates at
    /// barriers to drive globally placed pre-warming.
    pub(crate) fn with_rate_tracking(mut self) -> Self {
        self.track_rates = true;
        self
    }

    /// Pre-schedule the autoscaler's exact-time events and, for
    /// tick-driven policies, the first control tick.
    fn install_autoscaler_events(&mut self) {
        let Some(p) = &self.autoscaler else { return };
        for (t, up) in p.scheduled_events() {
            self.queue.push_at(t, Event::Scale { up });
        }
        if p.tick_driven() && self.tick_dt < self.cfg.workload.duration_s {
            self.queue.push_at(self.tick_dt, Event::AutoscaleTick);
        }
    }

    /// Copy prewarm speculation counters into the metrics and close the
    /// worker-seconds integral once the event loop has drained.
    fn finalize_metrics(&mut self) {
        debug_assert!(
            self.pending.is_empty(),
            "{} requests still parked at run end (leaked from the pull protocol)",
            self.pending.len()
        );
        // The router's own telemetry counters and the metrics layer must
        // agree — they observe the same pushes from opposite sides.
        debug_assert_eq!(
            self.metrics.enqueued,
            self.pending.pushed(),
            "pending-queue push telemetry drifted from RunMetrics.enqueued"
        );
        debug_assert_eq!(
            self.metrics.peak_pending,
            self.pending.peak_len(),
            "pending-queue peak telemetry drifted from RunMetrics.peak_pending"
        );
        let end = self.queue.now().max(self.cfg.workload.duration_s);
        self.metrics.finalize_scaling(end);
        let totals = self.cluster.totals();
        self.metrics.prewarm_spawned = totals.prewarm_spawned;
        self.metrics.prewarm_hits = totals.prewarm_hits;
        self.metrics.events_processed = self.queue.popped();
        self.metrics.peak_event_queue = self.queue.peak_len();
        // Conservation accounting: every arrival (admitted request or
        // issue-time rejection) ends exactly once. Donations to other
        // shards are balanced globally by the receiver's `stolen` count,
        // so the merged identity is
        // `arrivals == completed + rejected + failed + stolen`.
        self.metrics.arrivals = self.requests.len() as u64 + self.metrics.rejected;
        if let Some(fr) = self.faults.as_ref() {
            debug_assert_eq!(
                self.metrics.completed + self.metrics.failed + fr.donated,
                self.requests.len() as u64,
                "fault conservation violated: an admitted request leaked \
                 without resolving as completed, failed, or donated"
            );
        }
    }

    /// Seed the initial event set for a closed-loop run. The push order is
    /// part of the determinism contract (event `seq` numbers break ties),
    /// so it must not change across refactors.
    pub(crate) fn prepare_closed(&mut self) {
        self.metrics.record_scale(0.0, self.cluster.active_workers());
        self.install_autoscaler_events();
        for &(t, up) in &self.scale_events.clone() {
            self.queue.push_at(t, Event::Scale { up });
        }
        for (vu, script) in self.workload.vus.iter().enumerate() {
            if vu % self.vu_stride == self.vu_offset {
                self.queue.push_at(script.start_delay_s, Event::Arrival { vu, step: 0 });
            }
        }
        if self.cfg.cluster.prewarm {
            self.queue.push_at(1.0, Event::PreWarmTick);
        }
        self.queue.push_at(self.sweep_dt(), Event::SweepTick);
        self.install_fault_plan();
    }

    /// Append the fault plan's events (crashes, recoveries, straggler
    /// episodes) to the initial event set. A disabled `[faults]` section
    /// pushes nothing, so fault-free runs keep the exact pre-fault event
    /// stream; when enabled, the plan is appended *after* every other
    /// initial push so fault-free seq numbers are undisturbed.
    fn install_fault_plan(&mut self) {
        if self.faults.is_none() {
            return;
        }
        let plan = FaultPlan::generate(
            &self.cfg.faults,
            self.cluster.len(),
            self.cfg.workload.duration_s,
            self.run_seed,
        );
        for &(t, w) in &plan.crashes {
            self.queue.push_at(t, Event::WorkerFail { worker: w });
        }
        for &(t, w) in &plan.recoveries {
            self.queue.push_at(t, Event::WorkerRecover { worker: w });
        }
        for &(t, w, m) in &plan.stragglers {
            self.queue.push_at(t, Event::StragglerSet { worker: w, mult: m });
        }
    }

    /// Run the closed-loop VU workload to completion.
    pub fn run(mut self) -> RunMetrics {
        self.prepare_closed();
        self.event_loop();
        self.finalize_metrics();
        self.metrics
    }

    /// Keep-alive sweep interval: fine-grained for short TTLs, 1 Hz cap.
    fn sweep_dt(&self) -> f64 {
        (self.cfg.cluster.keep_alive_s / 2.0).clamp(0.05, 1.0)
    }

    /// Seed the initial event set for an open-loop trace replay (same
    /// push-order contract as [`Simulation::prepare_closed`]) and install
    /// the arrivals table the dispatcher resolves trace indices against.
    pub(crate) fn prepare_open(&mut self, trace: &OpenLoopTrace) {
        self.metrics.record_scale(0.0, self.cluster.active_workers());
        self.install_autoscaler_events();
        for &(t, up) in &self.scale_events.clone() {
            self.queue.push_at(t, Event::Scale { up });
        }
        for (index, &(t, _)) in trace.arrivals.iter().enumerate() {
            if t >= self.cfg.workload.duration_s {
                break;
            }
            if index % self.vu_stride == self.vu_offset {
                self.queue.push_at(t, Event::TraceArrival { index });
            }
        }
        self.queue.push_at(self.sweep_dt(), Event::SweepTick);
        self.install_fault_plan();
        // Steal the arrivals for dispatch (cheap copy of (f64, usize)).
        self.open_arrivals = Some(trace.arrivals.clone());
    }

    /// Run an open-loop trace: arrivals at fixed timestamps, ignoring
    /// completions (burst-response experiments).
    pub fn run_open_loop(mut self, trace: &OpenLoopTrace) -> RunMetrics {
        self.prepare_open(trace);
        self.event_loop();
        self.finalize_metrics();
        self.metrics
    }

    fn event_loop(&mut self) {
        if self.metrics.phases.enabled {
            // detlint:allow(R2) -- phase profiler wall-clock; write-only telemetry (DESIGN.md §12)
            let loop0 = Instant::now();
            loop {
                // detlint:allow(R2) -- phase profiler wall-clock; write-only telemetry (DESIGN.md §12)
                let t0 = Instant::now();
                let popped = self.queue.pop();
                self.metrics.phases.pop_s += t0.elapsed().as_secs_f64();
                let Some((t, ev)) = popped else { break };
                self.dispatch_timed(ev, t);
            }
            self.metrics.phases.wall_s += loop0.elapsed().as_secs_f64();
        } else {
            while let Some((t, ev)) = self.queue.pop() {
                self.dispatch(ev, t);
            }
        }
    }

    /// Dispatch one event under the phase profiler: autoscale ticks are
    /// metered separately from ordinary decide/handler work. Wall-clock
    /// only — timers never touch simulation state, so a profiled run is
    /// bit-identical to an unprofiled one.
    fn dispatch_timed(&mut self, ev: Event, t: f64) {
        let autoscale = matches!(ev, Event::AutoscaleTick);
        // detlint:allow(R2) -- phase profiler wall-clock; write-only telemetry (DESIGN.md §12)
        let t0 = Instant::now();
        self.dispatch(ev, t);
        let dt = t0.elapsed().as_secs_f64();
        if autoscale {
            self.metrics.phases.autoscale_s += dt;
        } else {
            self.metrics.phases.decide_s += dt;
        }
    }

    // ---- sharded-driver stepping API (crate::sim::shard) -----------------

    /// Process every pending event strictly before `limit` (one barrier
    /// epoch); returns true when the queue is fully drained. Over rising
    /// limits this pops the exact sequence `run()`'s drain would — the
    /// barrier only re-chunks it.
    pub(crate) fn step_until(&mut self, limit: f64) -> bool {
        if self.metrics.phases.enabled {
            // detlint:allow(R2) -- phase profiler wall-clock; write-only telemetry (DESIGN.md §12)
            let loop0 = Instant::now();
            loop {
                // detlint:allow(R2) -- phase profiler wall-clock; write-only telemetry (DESIGN.md §12)
                let t0 = Instant::now();
                let popped = self.queue.pop_before(limit);
                self.metrics.phases.pop_s += t0.elapsed().as_secs_f64();
                let Some((t, ev)) = popped else { break };
                self.dispatch_timed(ev, t);
            }
            self.metrics.phases.wall_s += loop0.elapsed().as_secs_f64();
        } else {
            while let Some((t, ev)) = self.queue.pop_before(limit) {
                self.dispatch(ev, t);
            }
        }
        self.queue.is_empty()
    }

    /// Advance the virtual clock to the barrier epoch `t` so coordinator
    /// actions (scale, pre-warm) are timestamped at the boundary.
    pub(crate) fn advance_clock_to(&mut self, t: f64) {
        self.queue.advance_to(t);
    }

    /// Finalize and return the metrics (the per-shard tail of a run).
    pub(crate) fn finish(mut self) -> RunMetrics {
        self.finalize_metrics();
        self.metrics
    }

    /// Workers currently eligible for selection in this shard.
    pub(crate) fn active_workers(&self) -> usize {
        self.cluster.active_workers()
    }

    /// Active workers not currently crash-marked — the failure digest a
    /// shard publishes at each epoch barrier so cross-shard stealing
    /// never routes work toward a dead partition. Equals
    /// [`Self::active_workers`] when fault injection is disabled.
    pub(crate) fn live_workers(&self) -> usize {
        let active = self.cluster.active_workers();
        match self.faults.as_ref() {
            Some(fr) => (0..active).filter(|&w| !fr.is_dead(w)).count(),
            None => active,
        }
    }

    /// (running, queued) totals over this shard's active workers.
    pub(crate) fn cluster_running_queued(&self) -> (usize, usize) {
        (self.cluster.total_running(), self.cluster.total_queued())
    }

    /// Fill `out[f]` with this shard's warm supply per function.
    pub(crate) fn cluster_warm_supply_into(&self, out: &mut [usize]) {
        self.cluster.warm_supply_into(out);
    }

    /// O(1) digest of this shard's worker loads (barrier payload).
    pub(crate) fn cluster_load_summary(&self) -> LoadSummary {
        self.cluster.load_summary()
    }

    /// The pre-warm heuristic's capped deficit for one function given the
    /// current warm `supply`: expected concurrent demand (EWMA arrival
    /// rate × mean warm service time) minus supply, at most 2 per tick.
    /// Single source of truth shared by the serial `on_prewarm_tick` and
    /// the shard report (`prewarm_deficits_into`), so the sharded
    /// coordinator can never drift from the serial formula.
    fn prewarm_deficit(&self, f: usize, supply: usize) -> usize {
        let rate = self.arrival_rate[f];
        if rate <= 0.0 {
            return 0;
        }
        let mean_exec = self.registry.app(f).warm_ms / 1000.0;
        let demand = (rate * mean_exec).ceil() as usize;
        demand.saturating_sub(supply).min(2) // <= 2/tick/function
    }

    /// Per-function pre-warm deficits under the 1 Hz heuristic: the
    /// shard-local [`Simulation::prewarm_deficit`] against the local warm
    /// supply. The coordinator sums these across shards and places the
    /// global total.
    pub(crate) fn prewarm_deficits_into(&self, out: &mut Vec<(usize, usize)>) {
        out.clear();
        for f in 0..self.registry.len() {
            let deficit = self.prewarm_deficit(f, self.cluster.warm_nonbusy(f));
            if deficit > 0 {
                out.push((f, deficit));
            }
        }
    }

    /// Scale this shard's active worker slice to `target` (the shard's
    /// share of a global autoscale decision), one worker at a time exactly
    /// like the serial `on_autoscale_tick` application loop.
    pub(crate) fn apply_scale_target(&mut self, target: usize) {
        while self.cluster.active_workers() < target {
            self.on_scale(true);
        }
        while self.cluster.active_workers() > target {
            let before = self.cluster.active_workers();
            self.on_scale(false);
            if self.cluster.active_workers() == before {
                break; // the shard's last worker never drains
            }
        }
    }

    /// Speculatively initialize `n` sandboxes for `f` at the current clock
    /// (a coordinator `SpawnPrewarm` message; placement is shard-local via
    /// the min-load index).
    pub(crate) fn apply_prewarm(&mut self, f: usize, n: usize) {
        let t = self.queue.now();
        self.spawn_prewarm(f, n, t);
    }

    /// Parked requests in this shard's pending queue (the barrier
    /// digest the coordinator's steal rule reads).
    pub(crate) fn pending_len(&self) -> usize {
        self.pending.len()
    }

    /// Extract up to `k` parked requests for a cross-shard handoff
    /// (`ShardMsg::Handoff`), in deficit-round-robin order over the
    /// function queues (`dispatch.fair`, the default) so a hot function
    /// cannot monopolize every donation — arrival order with
    /// `dispatch.fair = false`. The local bookkeeping forgets them: their
    /// deadline events become no-ops and the receiving shard re-issues
    /// them under its own request ids.
    pub(crate) fn extract_stolen(&mut self, k: usize) -> Vec<StolenTask> {
        let mut out = Vec::with_capacity(k);
        for _ in 0..k {
            let Some((rid, f)) = self.pop_next_pending() else { break };
            let mut retries = 0;
            if let Some(fr) = self.faults.as_mut() {
                fr.ensure_request(rid);
                let i = rid as usize;
                if fr.hedged[i] || fr.resolved[i] {
                    // A hedge duplicate (its original execution stays
                    // here) or an already-terminal request must not
                    // migrate: re-park it and stop donating this round.
                    self.pending.push(rid, f);
                    self.metrics.record_enqueue(self.pending.len());
                    break;
                }
                fr.resolved[i] = true; // terminal on this shard: donated
                fr.donated += 1;
                retries = fr.attempts[i];
            }
            let meta = self.requests[rid as usize];
            debug_assert_eq!(meta.function, f);
            out.push(StolenTask {
                function: f,
                arrival: meta.arrival,
                vu: meta.vu,
                step: meta.step,
                retries,
            });
        }
        out
    }

    /// Ingest a stolen task at the epoch boundary (the clock is already
    /// advanced to the barrier): allocate a local request id and place it
    /// immediately through the scheduler's synchronous path — a warm pull
    /// from `PQ_f` when this shard advertises one, fallback placement
    /// otherwise. For closed-loop requests the VU's continuation migrates
    /// here: its next arrival issues from this shard.
    pub(crate) fn ingest_stolen(&mut self, task: StolenTask) {
        let t = self.queue.now();
        let rid = self.requests.len() as u64;
        let si = if task.vu == usize::MAX {
            task.step % self.schedulers.len()
        } else {
            task.vu % self.schedulers.len()
        };
        self.requests.push(RequestMeta {
            vu: task.vu,
            step: task.step,
            function: task.function,
            worker: usize::MAX,
            sched: si,
            arrival: task.arrival,
        });
        self.cold_flags.push(false);
        self.queue_delays.push(0.0);
        if let Some(fr) = self.faults.as_mut() {
            // The retry budget travels with the request across shards.
            fr.ensure_request(rid);
            fr.attempts[rid as usize] = task.retries;
        }
        self.metrics.stolen += 1;
        let active = self.cluster.active_workers();
        debug_assert!(active > 0, "stolen task handed to an empty shard");
        let w = {
            let mut ctx = sched_ctx(
                &self.loads[si],
                self.reference,
                active,
                &mut self.sched_rng,
                self.faults.as_ref(),
            )
            .build();
            self.schedulers[si].select(task.function, &mut ctx)
        };
        self.bind_pending(rid, w, t, "steal");
    }

    fn dispatch(&mut self, ev: Event, t: f64) {
        match ev {
            Event::Arrival { vu, step } => self.on_arrival(vu, step, t),
            Event::Completion { worker, sandbox, request } => {
                // With faults on, completions bypass coalescing (a batch
                // could straddle a crash's stale entries) and drop events
                // whose sandbox a crash destroyed. Faults off keeps the
                // coalesced fast path untouched.
                if let Some(fr) = self.faults.as_ref() {
                    let floor = fr.crash_floor.get(worker).copied().unwrap_or(0);
                    if sandbox >= floor {
                        self.on_completion(worker, sandbox, request, t);
                    }
                } else {
                    self.on_completion_coalesced(worker, sandbox, request, t)
                }
            }
            Event::SweepTick => self.on_sweep(t),
            Event::Scale { up } => {
                // Pull dispatch: a scripted scale event that restores the
                // first worker after scale-to-zero must flush the parked
                // backlog (the wake path does the same after batching).
                let was_empty = self.cluster.active_workers() == 0;
                self.on_scale(up);
                if self.pull && was_empty && self.cluster.active_workers() > 0 {
                    self.flush_pending();
                }
            }
            Event::KeepAlive { worker, sandbox, epoch } => {
                // Precise per-sandbox expiry (unused by the default sweep
                // mode, kept for API completeness).
                if let Some(f) = self.cluster.expire_keepalive(worker, sandbox, epoch) {
                    self.notify_evict(worker, f);
                }
            }
            Event::AutoscaleTick => self.on_autoscale_tick(t),
            Event::PreWarmTick => self.on_prewarm_tick(t),
            Event::PreWarmDone { worker, sandbox } => self.on_prewarm_done(worker, sandbox, t),
            Event::TraceArrival { index } => {
                let f = self.open_arrivals.as_ref().expect("open-loop arrivals not installed")
                    [index]
                    .1;
                self.issue(usize::MAX, index, f, t);
            }
            Event::PullDeadline { request } => self.on_pull_deadline(request, t),
            Event::Wake => self.on_wake(),
            Event::WorkerFail { worker } => self.on_worker_fail(worker, t),
            Event::WorkerRecover { worker } => self.on_worker_recover(worker, t),
            Event::StragglerSet { worker, mult } => self.on_straggler_set(worker, mult),
            Event::RetryEnqueue { request } => self.on_retry_enqueue(request, t),
            Event::HedgeCheck { request } => self.on_hedge_check(request, t),
        }
    }

    /// Dispatch a completion, folding the maximal run of *immediately
    /// following* same-timestamp completions on the same worker into one
    /// batched cluster update (see "Batch-coalesced completions" in the
    /// module docs). Only adjacent `(time, seq)` events merge, so every
    /// observable ordering is identical to one-at-a-time dispatch; the
    /// saving is one aggregate sync per batch instead of per event.
    fn on_completion_coalesced(&mut self, w: WorkerId, sandbox: SandboxId, request: u64, t: f64) {
        // Fast path: the head of the queue is not a same-tick completion
        // on this worker (ties need identical f64 completion times, so
        // batches only form under quantized service times / extreme
        // rates).
        let first_more = self.queue.pop_if(|t2, ev| {
            t2 == t && matches!(ev, Event::Completion { worker, .. } if *worker == w)
        });
        let Some((_, more)) = first_more else {
            self.on_completion(w, sandbox, request, t);
            return;
        };
        let mut batch = std::mem::take(&mut self.batch_buf);
        batch.clear();
        batch.push((sandbox, request));
        let Event::Completion { sandbox: sb2, request: rid2, .. } = more else { unreachable!() };
        batch.push((sb2, rid2));
        while let Some((_, ev)) = self.queue.pop_if(|t2, ev| {
            t2 == t && matches!(ev, Event::Completion { worker, .. } if *worker == w)
        }) {
            let Event::Completion { sandbox, request, .. } = ev else { unreachable!() };
            batch.push((sandbox, request));
        }
        let mut ids = std::mem::take(&mut self.batch_ids);
        ids.clear();
        ids.extend(batch.iter().map(|&(sb, _)| sb));
        let outcomes = self.cluster.complete_batch(w, &ids, self.cfg.cluster.elastic, t);
        for (&(_, rid), outcome) in batch.iter().zip(outcomes) {
            self.post_completion(w, rid, outcome, t);
        }
        self.batch_ids = ids;
        self.batch_buf = batch;
    }

    /// Periodic keep-alive sweep across all workers. In pull mode the
    /// sweep doubles as the pending-depth sampler (1 Hz timeline).
    fn on_sweep(&mut self, t: f64) {
        let cutoff = t - self.cfg.cluster.keep_alive_s;
        for w in 0..self.cluster.len() {
            let evicted = self.cluster.sweep_keepalive(w, cutoff);
            for f in evicted {
                self.notify_evict(w, f);
            }
        }
        if self.pull {
            self.metrics.record_pending_depth(t, self.pending.len());
        }
        if self.slot_mode {
            // Slot-occupancy timeline (1 Hz with the sweep): busy slots =
            // active capacity minus the cluster's free-slot aggregate.
            let cap = self.cluster.active_workers() * self.cluster.cores();
            let busy = cap.saturating_sub(self.cluster.total_free_slots());
            self.metrics.record_slot_depth(t, busy);
        }
        let next = t + self.sweep_dt();
        // Stop sweeping once no more work can arrive and drain completes.
        if next < self.cfg.workload.duration_s + self.cfg.cluster.keep_alive_s {
            self.queue.push_at(next, Event::SweepTick);
        }
    }

    /// Keep the cluster's and every instance load view's active set in
    /// lockstep (they must agree for the index-backed paths to be exact).
    fn set_active(&mut self, n: usize) {
        self.cluster.set_active(n);
        for view in &mut self.loads {
            view.set_active(n);
        }
    }

    /// A worker joins or drains out of the cluster (auto-scaling).
    fn on_scale(&mut self, up: bool) {
        let active = self.cluster.active_workers();
        crate::log_debug!(
            "sim",
            "scale {} at t={:.1}s (active {})",
            if up { "up" } else { "down" },
            self.queue.now(),
            active
        );
        if up {
            if active < self.cluster.len() {
                // Re-activate a previously drained worker slot. (A 0 -> k
                // transition's backlog flush is the *caller's* job — wake
                // batching must restore every worker before flushing.)
                let id = active;
                self.set_active(active + 1);
                for s in &mut self.schedulers {
                    s.on_worker_added(id);
                }
                self.metrics.record_scale(self.queue.now(), self.cluster.active_workers());
                return;
            }
            let id =
                self.cluster.push_worker(self.cfg.cluster.mem_mb, self.cfg.cluster.concurrency);
            for view in &mut self.loads {
                view.add_worker();
            }
            self.set_active(active + 1);
            self.metrics.imbalance.add_worker();
            for s in &mut self.schedulers {
                s.on_worker_added(id);
            }
        } else {
            if active <= self.min_active {
                // Never drain below the floor: the last worker in push
                // mode, nothing at all for scale-to-zero configs.
                return;
            }
            let id = active - 1;
            self.set_active(id);
            for s in &mut self.schedulers {
                s.on_worker_removed(id);
            }
            // Reclaim the drained worker's idle sandboxes immediately.
            let evicted = self.cluster.drain_idle(id);
            for f in evicted {
                self.notify_evict(id, f);
            }
        }
        self.metrics.record_scale(self.queue.now(), self.cluster.active_workers());
    }

    /// Autoscale control tick: snapshot the active cluster, ask the policy,
    /// apply its worker target and pre-warm plan. Everything here is
    /// deterministic under (config, seed): the observation derives from
    /// simulator state and the only randomness (pre-warm init sampling)
    /// comes from the dedicated service-time stream.
    ///
    /// The observation is read from the cluster's incremental aggregates
    /// (O(functions)); reference mode recomputes it with the seed's
    /// O(workers × functions) scan, and the two are bit-identical.
    fn on_autoscale_tick(&mut self, t: f64) {
        let decision = {
            let Some(policy) = self.autoscaler.as_mut() else { return };
            let active = self.cluster.active_workers();
            let (total_running, total_queued) = if self.reference {
                self.warm_scratch.fill(0);
                let mut running = 0usize;
                let mut queued = 0usize;
                for w in 0..active {
                    let wk = self.cluster.worker(w);
                    wk.warm_counts_into(&mut self.warm_scratch);
                    running += wk.running();
                    queued += wk.queue_len();
                }
                (running, queued)
            } else {
                self.cluster.warm_supply_into(&mut self.warm_scratch);
                (self.cluster.total_running(), self.cluster.total_queued())
            };
            // Autoscale-aware admission: the router's parked backlog is
            // queued demand the policy must see (always empty in push
            // mode, so the observation is unchanged there).
            let total_queued = total_queued + self.pending.len();
            let obs = AutoscaleObs {
                now: t,
                active_workers: active,
                concurrency: self.cfg.cluster.concurrency,
                total_running,
                total_queued,
                warm_supply: &self.warm_scratch,
                mean_exec_s: &self.mean_exec_s,
            };
            policy.tick(&obs)
        };

        if let Some(target) = decision.target_workers {
            crate::log_debug!(
                "autoscale",
                "t={t:.1}s target {} (active {})",
                target,
                self.cluster.active_workers()
            );
            let was_empty = self.cluster.active_workers() == 0;
            while self.cluster.active_workers() < target {
                self.on_scale(true);
            }
            while self.cluster.active_workers() > target {
                let before = self.cluster.active_workers();
                self.on_scale(false);
                if self.cluster.active_workers() == before {
                    break; // the last worker never drains
                }
            }
            // The policy restored capacity after scale-to-zero: flush the
            // parked backlog over the *full* restored set.
            if self.pull && was_empty && self.cluster.active_workers() > 0 {
                self.flush_pending();
            }
        }
        for (f, n) in decision.prewarm {
            self.spawn_prewarm(f, n, t);
        }

        let next = t + self.tick_dt;
        if next < self.cfg.workload.duration_s {
            self.queue.push_at(next, Event::AutoscaleTick);
        }
    }

    /// Speculatively initialize up to `n` sandboxes for `f` on the
    /// least-loaded active workers with free memory (never evicts).
    /// Placement comes from the cluster's min-load index (O(tie set));
    /// reference mode keeps the seed's O(workers) scan — identical picks.
    fn spawn_prewarm(&mut self, f: usize, n: usize, t: f64) {
        let mem = self.registry.mem_mb(f);
        for _ in 0..n {
            let target = if self.reference {
                (0..self.cluster.active_workers())
                    .filter(|&w| self.cluster.worker(w).mem_free_mb() >= mem)
                    .min_by_key(|&w| self.cluster.worker(w).load())
            } else {
                self.cluster.least_loaded_fitting(mem)
            };
            let Some(w) = target else { return };
            if self.faults.as_ref().map_or(false, |fr| fr.is_dead(w)) {
                continue; // never pre-warm a crashed worker
            }
            if let Some(sb) = self.cluster.prewarm(w, f, mem, t) {
                let init = self.registry.sample_init_s(f, &mut self.service_rng);
                self.queue.push_at(t + init, Event::PreWarmDone { worker: w, sandbox: sb });
            }
        }
    }

    /// Broadcast an eviction notification. With one instance this is the
    /// paper's exact mechanism; with several it is conservative (an entry
    /// is dropped from every instance that advertises the worker, never
    /// leaving a stale entry behind).
    fn notify_evict(&mut self, w: WorkerId, f: usize) {
        for s in &mut self.schedulers {
            s.on_evict(w, f);
        }
    }

    fn on_arrival(&mut self, vu: usize, step: usize, t: f64) {
        // The run stops issuing at duration_s; in-flight requests drain.
        if t >= self.cfg.workload.duration_s {
            return;
        }
        let script = &self.workload.vus[vu];
        let Some(s) = script.steps.get(step) else {
            return; // script exhausted (bounded generation)
        };
        let f = s.function;
        self.issue(vu, step, f, t);
    }

    /// Update the per-function EWMA arrival-rate estimate.
    fn track_arrival(&mut self, f: usize, t: f64) {
        const ALPHA: f64 = 0.2;
        let last = self.last_arrival[f];
        if last >= 0.0 && t > last {
            let inst = 1.0 / (t - last);
            self.arrival_rate[f] = ALPHA * inst + (1.0 - ALPHA) * self.arrival_rate[f];
        }
        self.last_arrival[f] = t;
    }

    /// Pre-warm policy (1 Hz): for each function, estimate the expected
    /// concurrent demand (rate x mean warm service time) and speculatively
    /// initialize sandboxes to cover any deficit vs. the warm supply, on
    /// the least-loaded workers with free memory. Cf. Kim & Roh [24].
    /// The supply term reads the cluster's per-function warm aggregate
    /// (O(1) per function); reference mode keeps the seed's O(workers)
    /// recount per function.
    fn on_prewarm_tick(&mut self, t: f64) {
        for f in 0..self.registry.len() {
            if self.arrival_rate[f] <= 0.0 {
                continue; // skip the supply read entirely (hot at scale)
            }
            let supply: usize = if self.reference {
                (0..self.cluster.active_workers())
                    .map(|w| {
                        let wk = self.cluster.worker(w);
                        wk.idle_count(f) + wk.initializing_count(f)
                    })
                    .sum()
            } else {
                self.cluster.warm_nonbusy(f)
            };
            let deficit = self.prewarm_deficit(f, supply);
            self.spawn_prewarm(f, deficit, t);
        }
        if t + 1.0 < self.cfg.workload.duration_s {
            self.queue.push_at(t + 1.0, Event::PreWarmTick);
        }
    }

    /// A speculative sandbox finished initializing: it becomes idle, is
    /// advertised to a scheduler instance, and starts its keep-alive.
    fn on_prewarm_done(&mut self, w: WorkerId, sandbox: u64, t: f64) {
        if let Some((f, epoch)) = self.cluster.finish_prewarm(w, sandbox, t) {
            let active = self.cluster.active_workers();
            if w < active {
                let si = f % self.schedulers.len();
                // Pull dispatch: a freshly warmed instance claims a
                // parked request before it is advertised; the freed
                // capacity then serves prospect-less backlog fairly.
                self.worker_idle(w, f, si, t);
                // Keep-alive expiry handled by the periodic SweepTick.
                let _ = (sandbox, epoch);
            }
        }
    }

    /// Route one request (closed- or open-loop) through the dispatch
    /// protocol. Push mode always assigns synchronously via the adapter
    /// (bit-identical to the pre-protocol engine); pull mode may park the
    /// request in the pending queue or refuse it at the admission bound.
    fn issue(&mut self, vu: usize, step: usize, f: usize, t: f64) {
        let rid = self.requests.len() as u64;
        self.metrics.trace.record(rid, f, "arrival", t, t, None, "");
        if self.cfg.cluster.prewarm || self.track_rates {
            self.track_arrival(f, t);
        }
        if let Some(p) = self.autoscaler.as_mut() {
            p.on_arrival(f, t);
        }
        let si =
            if vu == usize::MAX { step % self.schedulers.len() } else { vu % self.schedulers.len() };
        let active = self.cluster.active_workers();

        // Scale-to-zero: an arrival against an empty cluster parks and
        // triggers a wake event (pull dispatch only — the config
        // validator guarantees `min_active == 0` implies pull mode).
        if self.pull && active == 0 {
            if !self.admit(f) {
                self.metrics.trace.record(rid, f, "decide", t, t, None, "reject");
                self.on_reject(vu, step, f, t);
                return;
            }
            self.metrics.trace.record(rid, f, "decide", t, t, None, "enqueue");
            self.park(rid, vu, step, f, si, t);
            if !self.wake_armed {
                self.wake_armed = true;
                self.queue.push_at(t, Event::Wake);
            }
            return;
        }

        // --- the dispatch decision (Algorithm 1 entry point) ---
        // Slot mode: expose the slot-granular load view (free-slot count
        // and lowest free warm-affine slot per worker). The view iterates
        // worker ids ascending — the determinism rule of DESIGN.md §11 —
        // and is rebuilt per decision from the cluster's incremental
        // aggregates (O(active)).
        let mut slot_free = std::mem::take(&mut self.slot_free_scratch);
        let mut slot_warm = std::mem::take(&mut self.slot_warm_scratch);
        if self.slot_mode {
            slot_free.clear();
            slot_warm.clear();
            for w in 0..active {
                slot_free.push(self.cluster.worker_free_slots(w) as u32);
                slot_warm.push(match self.cluster.warm_free_slot(w, f) {
                    Some(s) => s as i32,
                    None => -1,
                });
            }
        }
        let decision = {
            let dispatch = if self.pull {
                Some(DispatchCtx {
                    inflight_f: self.inflight_f[f],
                    pending_f: self.pending.len_fn(f),
                })
            } else {
                None
            };
            let slots = if self.slot_mode {
                Some(SlotCtx { free: &slot_free, warm_free: &slot_warm })
            } else {
                None
            };
            let mut ctx = sched_ctx(
                &self.loads[si],
                self.reference,
                active,
                &mut self.sched_rng,
                self.faults.as_ref(),
            )
            .dispatch(dispatch)
            .slots(slots)
            .build();
            self.schedulers[si].decide(f, &mut ctx)
        };
        self.slot_free_scratch = slot_free;
        self.slot_warm_scratch = slot_warm;
        match decision {
            Decision::Assign(_) | Decision::AssignSlot(_, _) => {
                let (w, preferred_slot) = match decision {
                    Decision::Assign(w) => (w, None),
                    Decision::AssignSlot(w, s) => (w, Some(s)),
                    _ => unreachable!(),
                };
                debug_assert!(w < active, "scheduler picked drained worker {w}");
                if self.faults.as_ref().map_or(false, |fr| fr.is_dead(w)) {
                    // The pick landed on a crashed worker the scheduler
                    // didn't (or couldn't) avoid.
                    self.metrics.trace.record(rid, f, "decide", t, t, Some(w), "dead-assign");
                    self.requests.push(RequestMeta {
                        vu,
                        step,
                        function: f,
                        worker: usize::MAX,
                        sched: si,
                        arrival: t,
                    });
                    self.cold_flags.push(false);
                    self.queue_delays.push(0.0);
                    if self.pull {
                        // The pull router observes liveness: re-route.
                        self.bind_pending(rid, w, t, "reroute");
                    } else {
                        // Push mode cannot — the bind bounces off the dead
                        // node and burns a retry (the ablation's contrast).
                        self.fault_retry(rid, t);
                    }
                    return;
                }
                // Core-granular late binding (pull + slot mode): an
                // assignment that would queue behind a fully busy worker
                // parks centrally instead — the request binds to whichever
                // *slot* frees first (a pull, an idle claim, or the wait
                // deadline), not to a worker picked now. Admission is
                // still per-function; past the cap the request falls
                // through to the worker queue like the slot-agnostic path.
                if self.pull
                    && self.slot_mode
                    && self.cluster.worker_free_slots(w) == 0
                    && self.admit(f)
                {
                    self.metrics.trace.record(rid, f, "decide", t, t, Some(w), "late-bind");
                    self.park(rid, vu, step, f, si, t);
                    return;
                }
                self.metrics.trace.record(rid, f, "decide", t, t, Some(w), "assign");
                self.loads[si].inc(w);
                self.metrics.record_assignment(w, t);
                self.requests.push(RequestMeta {
                    vu,
                    step,
                    function: f,
                    worker: w,
                    sched: si,
                    arrival: t,
                });
                // Per-request tables grow in lockstep with `requests` so
                // handle_start never resizes on the hot path.
                self.cold_flags.push(false);
                self.queue_delays.push(0.0);
                self.start_on(w, rid, f, t, preferred_slot);
            }
            Decision::Enqueue => {
                if self.admit(f) {
                    self.metrics.trace.record(rid, f, "decide", t, t, None, "enqueue");
                    self.park(rid, vu, step, f, si, t);
                } else {
                    self.metrics.trace.record(rid, f, "decide", t, t, None, "reject");
                    self.on_reject(vu, step, f, t);
                }
            }
            Decision::Reject(_) => {
                self.metrics.trace.record(rid, f, "decide", t, t, None, "reject");
                self.on_reject(vu, step, f, t);
            }
        }
    }

    /// Start (elastic) or queue (hard-admission) request `rid` on its
    /// bound worker — the tail every assignment path shares.
    /// `preferred_slot` is the scheduler's core pin (slot mode only;
    /// best-effort — the worker falls back to its own deterministic pick
    /// when the pinned slot is busy).
    fn start_on(&mut self, w: WorkerId, rid: u64, f: usize, t: f64, preferred_slot: Option<u32>) {
        let mem = self.registry.mem_mb(f);
        if self.cfg.cluster.elastic {
            let info = self.cluster.assign_elastic(w, rid, f, mem, t);
            self.handle_start(w, info, t);
        } else {
            match self.cluster.assign_slot(w, rid, f, mem, t, preferred_slot) {
                AssignOutcome::Started(info) => self.handle_start(w, info, t),
                AssignOutcome::Queued => {
                    // Push-mode bounded rebind (DESIGN.md §11): remember
                    // the queued request so a slot freeing elsewhere
                    // within the window can claim it.
                    if self.rebind_window_s > 0.0 {
                        self.rebind_q.push_back((rid, w, t + self.rebind_window_s));
                    }
                }
            }
        }
    }

    /// Admission control: room in function `f`'s pending queue for one
    /// more parked request? The cap is **per function**
    /// (`dispatch.queue_cap` default, `dispatch.queue_caps` overrides;
    /// 0 = unbounded), so a hot function overflowing its line cannot
    /// crowd any other function out of admission.
    fn admit(&self, f: usize) -> bool {
        let cap = self.cap_f[f];
        cap == 0 || self.pending.len_fn(f) < cap
    }

    /// The wait deadline for a request of `f`: `dispatch.max_wait_s`
    /// capped by the observed per-function cold-start penalty EWMA when
    /// `dispatch.adaptive_wait` is on — waiting only pays while the
    /// expected queue wait is below the cold start it might avoid, so
    /// the deadline self-tunes per function instead of using one global
    /// knob (DESIGN.md §8).
    /// The adaptive deadline is floored by `dispatch.min_wait_s` so a
    /// near-zero cold-penalty EWMA (tiny init times) cannot collapse the
    /// wait to 0 and turn every park into an immediate force-place.
    fn pull_wait_s(&self, f: usize) -> f64 {
        let base = self.cfg.dispatch.max_wait_s;
        if !self.adaptive_wait {
            return base;
        }
        let penalty = self.cold_penalty_ewma[f];
        if penalty > 0.0 {
            base.min(penalty).max(self.cfg.dispatch.min_wait_s)
        } else {
            base
        }
    }

    /// Park request `rid` in the pending queue with a wait deadline.
    fn park(&mut self, rid: u64, vu: usize, step: usize, f: usize, si: usize, t: f64) {
        debug_assert!(self.pull);
        self.requests.push(RequestMeta {
            vu,
            step,
            function: f,
            worker: usize::MAX,
            sched: si,
            arrival: t,
        });
        self.cold_flags.push(false);
        self.queue_delays.push(0.0);
        self.pending.push(rid, f);
        debug_assert!(
            self.cap_f[f] == 0 || self.pending.len_fn(f) <= self.cap_f[f],
            "function {f} parked past its cap"
        );
        self.metrics.record_enqueue(self.pending.len());
        self.queue.push_at(t + self.pull_wait_s(f), Event::PullDeadline { request: rid });
    }

    /// Record a refused request ([`Decision::Reject`] or a full pending
    /// queue) and keep the closed loop alive: the VU observes the
    /// rejection immediately and thinks before its next step. Rejected
    /// requests never enter the latency samples.
    fn on_reject(&mut self, vu: usize, step: usize, f: usize, t: f64) {
        self.metrics.record_reject(f);
        if vu != usize::MAX {
            let think = self.workload.vus[vu].steps[step].think_s;
            let next_t = t + think;
            if next_t < self.cfg.workload.duration_s {
                self.queue.push_at(next_t, Event::Arrival { vu, step: step + 1 });
            }
        }
    }

    /// Bind a parked request to worker `w` at time `t` (a pull, a
    /// deadline flush, a wake flush or a cross-shard steal). Never binds
    /// to a drained worker — the pull protocol's safety invariant,
    /// enforced unconditionally (property-tested in tests/dispatch.rs).
    /// `kind` labels the bind path for the lifecycle trace
    /// (`pull`/`idle`/`deadline`/`flush`/`steal`).
    fn bind_pending(&mut self, rid: u64, w: WorkerId, t: f64, kind: &'static str) {
        let mut w = w;
        if let Some(fr) = self.faults.as_ref() {
            if fr.is_resolved(rid) {
                // A hedge duplicate whose sibling already resolved the
                // request (or a donated/failed request): nothing to run.
                return;
            }
            let active = self.cluster.active_workers();
            if w >= active || fr.is_dead(w) {
                // The selection landed on a crashed (or stale) worker: the
                // router observes liveness and re-routes to the
                // least-loaded live worker. With no live capacity at all
                // the request burns a retry instead of re-arming forever
                // (the budget bounds the run).
                let si = self.requests[rid as usize].sched;
                match fr.best_live(self.loads[si].loads(), active) {
                    Some(b) => {
                        w = b;
                        self.metrics.re_routed += 1;
                    }
                    None => {
                        self.fault_retry(rid, t);
                        return;
                    }
                }
            }
        }
        assert!(
            w < self.cluster.active_workers(),
            "pull dispatch bound request {rid} to drained worker {w}"
        );
        let meta = &mut self.requests[rid as usize];
        debug_assert!(
            self.faults.is_some() || meta.worker == usize::MAX,
            "request {rid} bound twice"
        );
        meta.worker = w;
        let (si, f, arrival) = (meta.sched, meta.function, meta.arrival);
        self.loads[si].inc(w);
        self.metrics.record_assignment(w, t);
        self.metrics.record_pending_wait(f, t - arrival);
        self.metrics.trace.record(rid, f, "pending", arrival, t, None, "");
        self.metrics.trace.record(rid, f, "bind", t, t, Some(w), kind);
        if self.faults.is_some() {
            self.try_migrate_warm(rid, w, f, t);
        }
        // Late binding's slot choice: the worker's own deterministic
        // warm-affine pick at the moment the request lands (no pin).
        self.start_on(w, rid, f, t, None);
    }

    /// Warm-state handoff: a *retried* request of `f` landing on `w`
    /// consumes one unexpired entry from the crash warm bank — the
    /// sandbox state a crashed worker held for `f` migrates with the
    /// re-routed request (modeled as an instant pre-warm, so the assign
    /// below wins a warm start). No-op when `w` is already warm for `f`,
    /// when the bank holds no live entry, or when memory is tight.
    fn try_migrate_warm(&mut self, rid: u64, w: WorkerId, f: usize, t: f64) {
        {
            let fr = self.faults.as_mut().unwrap();
            fr.warm_bank.retain(|&(_, exp)| exp > t);
            if fr.attempts.get(rid as usize).copied().unwrap_or(0) == 0 {
                return;
            }
            if self.cluster.worker(w).idle_count(f) > 0 {
                return;
            }
            let Some(pos) = fr.warm_bank.iter().position(|&(g, _)| g == f) else {
                return;
            };
            fr.warm_bank.swap_remove(pos);
        }
        let mem = self.registry.mem_mb(f);
        if let Some(sb) = self.cluster.prewarm(w, f, mem, t) {
            if self.cluster.finish_prewarm(w, sb, t).is_some() {
                self.metrics.migrated += 1;
                self.metrics.trace.record(rid, f, "migrate", t, t, Some(w), "warm-state");
            }
        }
    }

    /// Force-place one parked request of `f` through the scheduler's
    /// synchronous path (warm if `PQ_f` gained an entry in the meantime,
    /// fallback placement otherwise) — the shared tail of the deadline
    /// drain below. `kind` labels the trigger for the lifecycle trace.
    fn force_place_fn(&mut self, rid: u64, f: usize, t: f64, kind: &'static str) {
        let active = self.cluster.active_workers();
        let si = self.requests[rid as usize].sched;
        let w = {
            let mut ctx = sched_ctx(
                &self.loads[si],
                self.reference,
                active,
                &mut self.sched_rng,
                self.faults.as_ref(),
            )
            .build();
            self.schedulers[si].select(f, &mut ctx)
        };
        self.bind_pending(rid, w, t, kind);
    }

    /// A parked request's wait deadline expired: force-place function
    /// `f`'s queue **oldest-first up to and including** the expired
    /// request. Usually that is exactly the expired request; when
    /// adaptive deadlines shrink mid-run, a later park can expire first,
    /// and draining oldest-first preserves within-function FIFO (no
    /// request overtakes an older sibling). Against an empty cluster the
    /// deadline re-arms — the wake event flushes the queue as soon as
    /// capacity returns.
    fn on_pull_deadline(&mut self, rid: u64, t: f64) {
        if !self.pending.is_waiting(rid) {
            return; // already pulled, flushed, or stolen
        }
        let meta = self.requests[rid as usize];
        let active = self.cluster.active_workers();
        if active == 0 {
            // The cluster drained to zero while this request was parked
            // (possible under the scheduled policy): make sure a wake is
            // coming, then re-arm — the wake's flush will claim the
            // request and turn this deadline into a no-op.
            if !self.wake_armed {
                self.wake_armed = true;
                self.queue.push_at(t, Event::Wake);
            }
            self.queue
                .push_at(t + self.pull_wait_s(meta.function), Event::PullDeadline {
                    request: rid,
                });
            return;
        }
        loop {
            let Some(head) = self.pending.pop_fn(meta.function) else { break };
            self.force_place_fn(head, meta.function, t, "deadline");
            if head == rid {
                break;
            }
        }
    }

    /// Scale-to-zero wake: restore `⌈backlog / concurrency⌉` workers in
    /// one step, then flush the backlog over the whole restored set — a
    /// burst into an empty cluster no longer serializes behind a single
    /// woken worker. Bounded by `autoscale.max_workers` when a
    /// tick-driven policy manages capacity (it will right-size later);
    /// without one, only previously-provisioned slots are restored —
    /// the wake must never *grow* a cluster nothing will ever shrink.
    /// No-op when the autoscaler already restored capacity.
    fn on_wake(&mut self) {
        self.wake_armed = false;
        if self.cluster.active_workers() > 0 {
            return;
        }
        let conc = self.cfg.cluster.concurrency.max(1);
        let backlog = self.pending.len().max(1);
        let managed =
            self.autoscaler.as_ref().map(|p| p.tick_driven()).unwrap_or(false);
        let bound = if managed {
            self.cfg.autoscale.max_workers.max(1)
        } else {
            self.cluster.len().max(1)
        };
        let target = ((backlog + conc - 1) / conc).clamp(1, bound);
        while self.cluster.active_workers() < target {
            let before = self.cluster.active_workers();
            self.on_scale(true);
            if self.cluster.active_workers() == before {
                break;
            }
        }
        self.flush_pending();
    }

    // ---- fault injection & recovery ([`crate::faults`]) ------------------

    /// Continue a closed-loop VU after its request terminated without a
    /// normal completion (budget-exhausted failure): think, then next
    /// step — the same continuation a rejection takes.
    fn vu_next(&mut self, vu: usize, step: usize, t: f64) {
        if vu == usize::MAX {
            return;
        }
        let think = self.workload.vus[vu].steps[step].think_s;
        let next_t = t + think;
        if next_t < self.cfg.workload.duration_s {
            self.queue.push_at(next_t, Event::Arrival { vu, step: step + 1 });
        }
    }

    /// Send request `rid` around the retry loop: consume one attempt and
    /// schedule a deterministically jittered `RetryEnqueue` — or, budget
    /// exhausted, meter it as `failed` (never silently dropped) and let
    /// the issuing VU continue.
    fn fault_retry(&mut self, rid: u64, t: f64) {
        let max_retries = self.cfg.faults.max_retries;
        let backoff = self.cfg.faults.retry_backoff_s;
        let (seed, att) = {
            let fr = self.faults.as_mut().unwrap();
            fr.ensure_request(rid);
            if fr.resolved[rid as usize] {
                return;
            }
            (fr.seed, fr.attempts[rid as usize])
        };
        let i = rid as usize;
        if att >= max_retries {
            self.faults.as_mut().unwrap().resolved[i] = true;
            self.metrics.failed += 1;
            let meta = self.requests[i];
            self.requests[i].worker = usize::MAX;
            self.metrics.trace.record(rid, meta.function, "failed", t, t, None, "budget");
            self.vu_next(meta.vu, meta.step, t);
            return;
        }
        self.faults.as_mut().unwrap().attempts[i] = att + 1;
        self.metrics.retried += 1;
        self.requests[i].worker = usize::MAX;
        let delay = retry_backoff(backoff, seed, rid, att + 1);
        self.queue.push_at(t + delay, Event::RetryEnqueue { request: rid });
    }

    /// A crash destroyed `rid`'s execution or queue slot. If a hedge
    /// duplicate is already parked in the pending queue, that copy *is*
    /// the retry; otherwise go around the retry loop.
    fn fault_requeue(&mut self, rid: u64, t: f64) {
        if self.pull && self.pending.is_waiting(rid) {
            return;
        }
        self.fault_retry(rid, t);
    }

    /// `WorkerFail`: destroy the worker's entire state (sandboxes, queue,
    /// load), bank its warm inventory for migration, and re-enqueue every
    /// in-flight and queued request under the bounded retry budget. The
    /// dead worker stays in the active prefix — worker ids never renumber
    /// — and the router re-routes around it until `WorkerRecover`.
    fn on_worker_fail(&mut self, w: WorkerId, t: f64) {
        if self.faults.is_none() || w >= self.cluster.len() {
            return;
        }
        {
            let fr = self.faults.as_mut().unwrap();
            fr.ensure_worker(w);
            if fr.dead[w] {
                return;
            }
            fr.dead[w] = true;
            fr.crashed_at[w] = t;
        }
        self.metrics.worker_crashes += 1;
        crate::log_debug!("faults", "worker {w} crashed at t={t:.2}s");
        let (queued, warm) = self.cluster.crash(w);
        let watermark = self.cluster.worker(w).sandbox_watermark();
        let inflight = {
            let fr = self.faults.as_mut().unwrap();
            fr.crash_floor[w] = watermark;
            std::mem::take(&mut fr.running_on[w])
        };
        // Bank the warm inventory for handoff while keep-alive allows,
        // and tell the schedulers those advertisements are gone.
        let ka = self.cfg.cluster.keep_alive_s;
        for &(f, idle_since) in &warm {
            let expires = idle_since + ka;
            if expires > t {
                self.faults.as_mut().unwrap().warm_bank.push((f, expires));
            }
            self.notify_evict(w, f);
        }
        // In-flight executions: their completions are now stale (below
        // the crash floor); undo the per-execution bookkeeping and retry.
        for (rid, _sb) in inflight {
            let meta = self.requests[rid as usize];
            self.loads[meta.sched].dec(w);
            if self.pull {
                debug_assert!(self.inflight_f[meta.function] > 0);
                self.inflight_f[meta.function] -= 1;
            }
            self.metrics.trace.record(rid, meta.function, "crash", t, t, Some(w), "inflight");
            self.fault_requeue(rid, t);
        }
        // Worker-queue requests never started; rebind them too.
        for q in queued {
            let rid = q.request_id;
            let meta = self.requests[rid as usize];
            self.loads[meta.sched].dec(w);
            self.metrics.trace.record(rid, meta.function, "crash", t, t, Some(w), "queued");
            self.fault_requeue(rid, t);
        }
    }

    /// `WorkerRecover`: the worker rejoins, cold. Pull mode immediately
    /// lets the restored capacity claim prospect-less backlog (up to its
    /// concurrency), exactly like any other idle-capacity return.
    fn on_worker_recover(&mut self, w: WorkerId, t: f64) {
        {
            let Some(fr) = self.faults.as_mut() else { return };
            if w >= fr.dead.len() || !fr.dead[w] {
                return;
            }
            fr.dead[w] = false;
            let down_ms = (t - fr.crashed_at[w]) * 1000.0;
            self.metrics.worker_recoveries += 1;
            self.metrics.recovery_latency_ms.push(down_ms);
        }
        crate::log_debug!("faults", "worker {w} recovered at t={t:.2}s");
        if self.pull && w < self.cluster.active_workers() {
            let conc = self.cfg.cluster.concurrency.max(1);
            for _ in 0..conc {
                if self.pending.is_empty() || !self.claim_stale_pending(w, t) {
                    break;
                }
            }
        }
    }

    /// `StragglerSet`: set the worker's service-time multiplier. New
    /// starts only — in-flight executions keep their sampled times.
    fn on_straggler_set(&mut self, w: WorkerId, mult: f64) {
        if let Some(fr) = self.faults.as_mut() {
            fr.ensure_worker(w);
            fr.slow[w] = mult.max(1.0);
        }
    }

    /// `RetryEnqueue`: the backoff elapsed — re-enter dispatch. Pull mode
    /// re-parks the request in the pending queue (admission was already
    /// paid at arrival, so retries never re-face the cap); push mode
    /// re-runs the synchronous decision, and a pick that lands on a
    /// crashed worker burns another retry — the push protocol cannot
    /// observe liveness, which is the fault ablation's central contrast.
    fn on_retry_enqueue(&mut self, rid: u64, t: f64) {
        if self.faults.is_none() || self.faults.as_ref().unwrap().is_resolved(rid) {
            return;
        }
        if self.pull && self.pending.is_waiting(rid) {
            return;
        }
        let meta = self.requests[rid as usize];
        let f = meta.function;
        if self.pull {
            self.pending.push(rid, f);
            self.metrics.record_enqueue(self.pending.len());
            self.metrics.trace.record(rid, f, "retry", t, t, None, "park");
            self.queue.push_at(t + self.pull_wait_s(f), Event::PullDeadline { request: rid });
            if self.cluster.active_workers() == 0 && !self.wake_armed {
                self.wake_armed = true;
                self.queue.push_at(t, Event::Wake);
            }
            return;
        }
        let active = self.cluster.active_workers();
        if active == 0 {
            self.fault_retry(rid, t);
            return;
        }
        let si = meta.sched;
        let w = {
            let mut ctx = sched_ctx(
                &self.loads[si],
                self.reference,
                active,
                &mut self.sched_rng,
                self.faults.as_ref(),
            )
            .build();
            self.schedulers[si].select(f, &mut ctx)
        };
        if w >= active || self.faults.as_ref().unwrap().is_dead(w) {
            self.metrics.trace.record(rid, f, "retry", t, t, Some(w), "dead-bind");
            self.fault_retry(rid, t);
            return;
        }
        self.requests[rid as usize].worker = w;
        self.loads[si].inc(w);
        self.metrics.record_assignment(w, t);
        self.metrics.trace.record(rid, f, "bind", t, t, Some(w), "retry");
        self.start_on(w, rid, f, t, None);
    }

    /// `HedgeCheck`: the request has been running on a straggler past
    /// `hedge_factor x` its function's runtime EWMA. Issue one duplicate
    /// into the pull path; whichever execution completes first resolves
    /// the request (the loser only cleans up worker-side).
    fn on_hedge_check(&mut self, rid: u64, t: f64) {
        let meta = self.requests[rid as usize];
        {
            let Some(fr) = self.faults.as_mut() else { return };
            let i = rid as usize;
            if i >= fr.resolved.len() || fr.resolved[i] || fr.hedged[i] {
                return;
            }
            // Only hedge an execution still held by a live straggler; a
            // crash-retried or re-parked request is already in recovery.
            if meta.worker == usize::MAX
                || fr.is_dead(meta.worker)
                || fr.slow.get(meta.worker).copied().unwrap_or(1.0) <= 1.0
                || fr.running_on
                    .get(meta.worker)
                    .map_or(true, |v| v.iter().all(|&(r, _)| r != rid))
            {
                return;
            }
            fr.hedged[i] = true;
        }
        self.metrics.hedged += 1;
        self.metrics.trace.record(rid, meta.function, "hedge", t, t, Some(meta.worker), "");
        self.pending.push(rid, meta.function);
        self.metrics.record_enqueue(self.pending.len());
        self.queue
            .push_at(t + self.pull_wait_s(meta.function), Event::PullDeadline { request: rid });
    }

    /// Force-place every parked request — the cluster just regained
    /// capacity after scale-to-zero, and the backlog must not wait out
    /// its deadlines against a live worker. Drains in deficit-round-robin
    /// order over the function queues (`dispatch.fair`, the default;
    /// DESIGN.md §8), arrival order otherwise.
    fn flush_pending(&mut self) {
        let t = self.queue.now();
        while let Some((rid, f)) = self.pop_next_pending() {
            debug_assert!(
                self.cluster.active_workers() > 0,
                "flush_pending on an empty cluster"
            );
            self.force_place_fn(rid, f, t, "flush");
        }
    }

    /// Claim the next parked request in the configured drain order
    /// (DRR when `dispatch.fair`, global arrival order otherwise).
    fn pop_next_pending(&mut self) -> Option<(u64, usize)> {
        if self.fair {
            self.pending.pop_fair()
        } else {
            self.pending.pop_arrival()
        }
    }

    /// Idle-capacity fairness claim: worker `w` has no warm work of its
    /// own to pull, so it serves the backlog's next request **among
    /// functions with no execution in flight** — their warm prospect is
    /// gone, so waiting longer cannot pay, and draining them in DRR
    /// order keeps a hot function from monopolizing reclaimed capacity.
    /// Functions with in-flight work stay parked (a warm pull is still
    /// coming). Returns true when a request was bound.
    fn claim_stale_pending(&mut self, w: WorkerId, t: f64) -> bool {
        let fair = self.fair;
        let (pending, inflight_f) = (&mut self.pending, &self.inflight_f);
        let eligible = |g: usize| inflight_f.get(g).copied().unwrap_or(0) == 0;
        let got =
            if fair { pending.pop_fair_where(eligible) } else { pending.pop_arrival_where(eligible) };
        match got {
            Some((rid, _f)) => {
                self.bind_pending(rid, w, t, "idle");
                true
            }
            None => false,
        }
    }

    /// The first-class pull loop: worker `w` idles holding a warm
    /// instance of `f`; ask the scheduler which pending queue it claims
    /// from and bind the oldest waiting request. Returns true when a
    /// request was bound (the instance is busy again and must not be
    /// advertised through `on_complete`).
    fn try_pull(&mut self, w: WorkerId, f: usize, si: usize, t: f64) -> bool {
        debug_assert!(self.pull);
        if self.pending.is_empty() {
            return false;
        }
        let active = self.cluster.active_workers();
        let pull = {
            let mut ctx = sched_ctx(
                &self.loads[si],
                self.reference,
                active,
                &mut self.sched_rng,
                self.faults.as_ref(),
            )
            .dispatch(Some(DispatchCtx {
                inflight_f: self.inflight_f[f],
                pending_f: self.pending.len_fn(f),
            }))
            .build();
            self.schedulers[si].on_worker_idle(w, f, &mut ctx)
        };
        let Pull::Function(pf) = pull else { return false };
        let Some(rid) = self.pending.pop_fn(pf) else { return false };
        self.bind_pending(rid, w, t, "pull");
        true
    }

    /// Everything that happens when worker `w` becomes idle holding a
    /// warm instance of `f`: (1) a warm pull from the scheduler's named
    /// queue; failing that, (2) the idle instance is advertised through
    /// `on_complete`, and (3) the idle *capacity* claims a parked request
    /// whose warm prospect died (`claim_stale_pending`) — the
    /// advertisement survives, so a later pull of `f` can still win a
    /// warm start on `w`.
    fn worker_idle(&mut self, w: WorkerId, f: usize, si: usize, t: f64) {
        if self.pull && self.try_pull(w, f, si, t) {
            return;
        }
        let active = self.cluster.active_workers();
        {
            let mut ctx = sched_ctx(
                &self.loads[si],
                self.reference,
                active,
                &mut self.sched_rng,
                self.faults.as_ref(),
            )
            .build();
            self.schedulers[si].on_complete(w, f, &mut ctx);
        }
        if self.pull && !self.pending.is_empty() {
            self.claim_stale_pending(w, t);
        }
    }

    /// An execution actually starts on `w`: sample its service time,
    /// schedule completion, and deliver eviction notifications.
    fn handle_start(&mut self, w: WorkerId, info: StartInfo, t: f64) {
        for &f in &info.evicted {
            self.notify_evict(w, f);
        }
        let meta = self.requests[info.request_id as usize];
        // Head-of-line-blocking breakdown: arrival→start wait, split by
        // runtime class (short functions are the ones a long execution
        // blocks). Recorded for every start; *reported* only when the
        // slots summary block is enabled, so default summaries are
        // untouched.
        let warm_ms = self.registry.app(meta.function).warm_ms;
        self.metrics
            .record_hol_wait(crate::dispatch::is_short_class(warm_ms), t - meta.arrival);
        if self.pull {
            // Warm-prospect signal for `decide`: executions of f running.
            self.inflight_f[meta.function] += 1;
        }
        let mut dur = self.registry.sample_exec_s(meta.function, &mut self.service_rng);
        let mut init_s = 0.0;
        if info.cold {
            let init = self.registry.sample_init_s(meta.function, &mut self.service_rng);
            init_s = init;
            if self.pull {
                // Observed cold−warm start delta: feeds the adaptive
                // per-function wait deadline (DESIGN.md §8). The sample
                // order is untouched, so push mode stays bit-identical.
                const WAIT_ALPHA: f64 = 0.2;
                let prev = self.cold_penalty_ewma[meta.function];
                self.cold_penalty_ewma[meta.function] = if prev > 0.0 {
                    WAIT_ALPHA * init + (1.0 - WAIT_ALPHA) * prev
                } else {
                    init
                };
            }
            dur += init;
        }
        if self.cfg.cluster.elastic {
            // vCPU time-sharing: executions beyond the core count slow all
            // of this worker's work down proportionally. Applying the
            // multiplier at start time (rather than re-scaling in flight)
            // keeps the DES single-pass; the approximation error is small
            // at the paper's load levels and identical across schedulers.
            let running = self.cluster.worker(w).running() as f64;
            let cores = self.cfg.cluster.concurrency as f64;
            let congestion = (running / cores).max(1.0);
            dur *= congestion;
        }
        if self.faults.is_some() {
            dur = self.fault_start(info.request_id, w, info.sandbox, meta.function, info.cold, init_s, dur, t);
        }
        // Cold/warm and queue delay resolved at start time, kept per rid.
        self.cold_flags[info.request_id as usize] = info.cold;
        self.queue_delays[info.request_id as usize] = info.queue_delay_s;
        if self.metrics.trace.sampled(info.request_id) {
            // Split the execution span at the (unscaled) init boundary;
            // congestion stretch lands in the service portion.
            if info.cold {
                self.metrics.trace.record(
                    info.request_id,
                    meta.function,
                    "cold_init",
                    t,
                    t + init_s,
                    Some(w),
                    "",
                );
            }
            self.metrics.trace.record(
                info.request_id,
                meta.function,
                "service",
                t + init_s.min(dur),
                t + dur,
                Some(w),
                if info.cold { "cold" } else { "warm" },
            );
        }
        self.queue.push_at(
            t + dur,
            Event::Completion { worker: w, sandbox: info.sandbox, request: info.request_id },
        );
    }

    /// Fault hooks at execution start, returning the (possibly adjusted)
    /// duration. All randomness is pure-hash (`fault_coin`), so the
    /// engine's RNG streams — and with them every fault-free draw — stay
    /// untouched:
    /// - a cold start's init may fail (`faults.init_fail_prob`): the
    ///   execution burns only the init time and its completion retries
    ///   the request instead of resolving it;
    /// - a straggler episode stretches the service time by the worker's
    ///   current multiplier, and (pull mode) arms a `HedgeCheck` at
    ///   `hedge_factor x` the function's runtime EWMA so requests held by
    ///   stragglers get hedged to the pull path;
    /// - the `(request, sandbox)` pair is journaled per worker so a crash
    ///   can harvest its in-flight victims.
    #[allow(clippy::too_many_arguments)]
    fn fault_start(
        &mut self,
        rid: u64,
        w: WorkerId,
        sb: SandboxId,
        f: usize,
        cold: bool,
        init_s: f64,
        mut dur: f64,
        t: f64,
    ) -> f64 {
        let init_fail_prob = self.cfg.faults.init_fail_prob;
        let hedge_factor = self.cfg.faults.hedge_factor;
        let pull = self.pull;
        let fr = self.faults.as_mut().unwrap();
        fr.ensure_request(rid);
        fr.ensure_worker(w);
        let i = rid as usize;
        let failed_init = cold
            && init_fail_prob > 0.0
            && fault_coin(fr.seed, rid, fr.attempts[i]) < init_fail_prob;
        if failed_init {
            fr.init_failed[i] = true;
            dur = init_s;
        } else {
            // Nominal-runtime EWMA (hedge deadline input), updated from
            // the sampled duration before any straggler stretch.
            const ALPHA: f64 = 0.2;
            let prev = fr.runtime_ewma[f];
            fr.runtime_ewma[f] = if prev > 0.0 { ALPHA * dur + (1.0 - ALPHA) * prev } else { dur };
        }
        let slow = fr.slow[w];
        if slow > 1.0 {
            dur *= slow;
            if pull && hedge_factor > 0.0 && !failed_init && !fr.hedged[i] {
                let deadline = hedge_factor * fr.runtime_ewma[f].max(1e-3);
                self.queue.push_at(t + deadline, Event::HedgeCheck { request: rid });
            }
        }
        fr.running_on[w].push((rid, sb));
        dur
    }

    fn on_completion(&mut self, w: WorkerId, sandbox: SandboxId, rid: u64, t: f64) {
        // Faults: this execution is no longer crash-harvestable.
        if let Some(fr) = self.faults.as_mut() {
            if let Some(v) = fr.running_on.get_mut(w) {
                if let Some(p) = v.iter().position(|&(r, s)| r == rid && s == sandbox) {
                    v.swap_remove(p);
                }
            }
        }
        // Worker-side: sandbox idles; (queue mode) a queued request may
        // start; (elastic mode) the idle pool is trimmed to capacity.
        let outcome = if self.cfg.cluster.elastic {
            let (expiry, evicted) = self.cluster.complete_elastic(w, sandbox, t);
            BatchCompletion { expiry, started: None, evicted }
        } else {
            let (expiry, started) = self.cluster.complete(w, sandbox, t);
            BatchCompletion { expiry, started, evicted: Vec::new() }
        };
        self.post_completion(w, rid, outcome, t);
    }

    /// Push-mode bounded rebind (`dispatch.rebind_window_s`): worker `w`
    /// just freed capacity with no local queued work to absorb it. Scan
    /// the rebind queue (oldest first) for a request still waiting in
    /// another worker's admission queue whose window is open, pull it
    /// back out, and start it here — push mode's bounded approximation
    /// of pull's late binding. At most one request re-routes per freed
    /// slot; expired and stale entries are dropped as they are passed.
    fn try_rebind(&mut self, w: WorkerId, t: f64) {
        if self.faults.as_ref().map_or(false, |fr| fr.is_dead(w)) {
            return;
        }
        let mut i = 0;
        while i < self.rebind_q.len() {
            let (rid, v, expiry) = self.rebind_q[i];
            if expiry < t {
                let _ = self.rebind_q.remove(i);
                continue;
            }
            if v == w {
                // Rebinding to the worker it already queues on is a no-op.
                i += 1;
                continue;
            }
            let Some(q) = self.cluster.remove_queued(v, rid) else {
                // Stale: already started, crash-harvested, or rebound.
                let _ = self.rebind_q.remove(i);
                continue;
            };
            let _ = self.rebind_q.remove(i);
            let meta = self.requests[rid as usize];
            self.loads[meta.sched].dec(v);
            self.loads[meta.sched].inc(w);
            self.requests[rid as usize].worker = w;
            self.metrics.rebound += 1;
            self.metrics.record_assignment(w, t);
            self.metrics.trace.record(rid, meta.function, "rebind", t, t, Some(w), "requeue");
            let mem = self.registry.mem_mb(meta.function);
            match self.cluster.assign_slot(w, rid, meta.function, mem, t, None) {
                AssignOutcome::Started(mut info) => {
                    // The wait accrued on the donor's queue counts.
                    info.queue_delay_s = t - q.queued_at;
                    self.handle_start(w, info, t);
                }
                AssignOutcome::Queued => {
                    // The freed capacity was taken concurrently (cannot
                    // happen on this single-threaded path, but stay safe):
                    // keep the original window on the new queue.
                    self.rebind_q.push_back((rid, w, expiry));
                }
            }
            return;
        }
    }

    /// Everything after the worker-side completion transition: load-view
    /// decrement, eviction notifications, the pull advertisement, the
    /// queued start, response metrics, and the VU's next arrival. Shared
    /// verbatim between one-at-a-time and batch-coalesced dispatch so the
    /// two paths cannot drift.
    fn post_completion(&mut self, w: WorkerId, rid: u64, outcome: BatchCompletion, t: f64) {
        let meta = self.requests[rid as usize];
        // Under faults a hedge duplicate can complete on a worker other
        // than the latest-bound one; the per-execution bookkeeping below
        // still balances (inc at bind, dec here, per execution).
        debug_assert!(self.faults.is_some() || meta.worker == w);
        self.loads[meta.sched].dec(w);
        if self.pull {
            debug_assert!(self.inflight_f[meta.function] > 0);
            self.inflight_f[meta.function] -= 1;
        }
        for f in outcome.evicted {
            self.notify_evict(w, f);
        }

        // Pull mechanism: the worker enqueues in PQ_f only if its instance
        // is actually idle after completion (if it was immediately reused
        // or reclaimed, there is nothing to advertise). The advertisement
        // goes to the scheduler instance that served the request — the
        // distributed-JIQ reporting rule [21]. Under pull dispatch the
        // idle worker first gets to *claim a parked request*
        // ([`crate::scheduler::Scheduler::on_worker_idle`]); only when
        // nothing is waiting does it advertise.
        let init_failed_now = self
            .faults
            .as_ref()
            .map_or(false, |fr| fr.init_failed.get(rid as usize).copied().unwrap_or(false));
        if let Some((sb, epoch)) = outcome.expiry {
            let active = self.cluster.active_workers();
            if init_failed_now {
                // The sandbox's init failed: never advertise it warm —
                // reclaim it immediately.
                if let Some(f) = self.cluster.expire_keepalive(w, sb, epoch) {
                    self.notify_evict(w, f);
                }
            } else if w < active {
                let si = meta.sched;
                self.worker_idle(w, meta.function, si, t);
                // Keep-alive expiry handled by the periodic SweepTick.
            } else if let Some(f) = self.cluster.expire_keepalive(w, sb, epoch) {
                // Drained worker: reclaim the sandbox instead of
                // advertising it.
                self.notify_evict(w, f);
            }
        }

        if let Some(info) = outcome.started {
            self.handle_start(w, info, t);
        } else if self.rebind_window_s > 0.0 && w < self.cluster.active_workers() {
            // The completion freed capacity and no locally queued request
            // took it: re-offer the slot to a request queued behind a
            // *busy* worker whose rebind window is still open.
            self.try_rebind(w, t);
        }

        if init_failed_now {
            // The execution only burned its (failed) init: the request is
            // not done — meter the failure and send it around the retry
            // loop instead of resolving it.
            let fr = self.faults.as_mut().unwrap();
            fr.init_failed[rid as usize] = false;
            self.metrics.init_failures += 1;
            self.metrics.trace.record(rid, meta.function, "init_fail", t, t, Some(w), "");
            self.requests[rid as usize].worker = usize::MAX;
            self.fault_retry(rid, t);
            return;
        }
        if let Some(fr) = self.faults.as_mut() {
            fr.ensure_request(rid);
            let i = rid as usize;
            if fr.resolved[i] {
                // A hedge duplicate lost the race: the request already
                // resolved; only the worker-side cleanup above applies.
                return;
            }
            fr.resolved[i] = true;
        }

        // Metrics: response latency for the completed request.
        let cold = self.cold_flags[rid as usize];
        let qd = self.queue_delays[rid as usize];
        self.metrics.record_response(t - meta.arrival, cold, qd, t);
        self.metrics.trace.record(
            rid,
            meta.function,
            "complete",
            t,
            t,
            Some(w),
            if cold { "cold" } else { "warm" },
        );

        // Closed loop: the VU thinks, then issues its next step.
        if meta.vu != usize::MAX {
            let script = &self.workload.vus[meta.vu];
            let think = script.steps[meta.step].think_s;
            let next_t = t + think;
            if next_t < self.cfg.workload.duration_s {
                self.queue.push_at(next_t, Event::Arrival { vu: meta.vu, step: meta.step + 1 });
            }
        }
    }
}

/// Build the scheduler instances a config asks for.
fn build_schedulers(cfg: &Config) -> Result<Vec<Box<dyn Scheduler>>, String> {
    (0..cfg.scheduler.instances.max(1))
        .map(|_| crate::scheduler::make_scheduler(&cfg.scheduler, cfg.cluster.workers))
        .collect()
}

/// Shared entry-point setup: registry (validated against the config),
/// scripted workload, scheduler instances. `vus` overrides the configured
/// VU count (open-loop mode only needs a placeholder script set).
fn build_parts(
    cfg: &Config,
    seed: u64,
    vus: Option<usize>,
) -> Result<(FunctionRegistry, Workload, Vec<Box<dyn Scheduler>>), String> {
    let registry = FunctionRegistry::functionbench(cfg.workload.copies);
    if registry.len() != cfg.num_functions() {
        return Err(format!(
            "registry size {} != configured {}",
            registry.len(),
            cfg.num_functions()
        ));
    }
    let mut wcfg = cfg.workload.clone();
    if let Some(v) = vus {
        wcfg.vus = v;
    }
    let workload = Workload::generate(&wcfg, registry.len(), seed);
    let schedulers = build_schedulers(cfg)?;
    Ok((registry, workload, schedulers))
}

/// Run one (config, seed) closed-loop experiment. This is the single
/// policy-driven entry point: auto-scaling comes from `cfg.autoscale`
/// (`none`, `scheduled`, `reactive`, or `predictive`), and `cfg.sim.shards`
/// selects the engine — 1 (default) is the serial engine, bit-identical to
/// the seed path; ≥ 2 partitions workers and VUs across OS threads behind
/// an event-time barrier ([`crate::sim::shard`]).
pub fn run_once(cfg: &Config, seed: u64) -> Result<RunMetrics, String> {
    if cfg.sim.shards > 1 {
        return super::shard::run_sharded(cfg, seed);
    }
    let (registry, workload, schedulers) = build_parts(cfg, seed, None)?;
    let sim = Simulation::with_schedulers(cfg, &registry, &workload, schedulers, seed)
        .with_config_autoscaler()?;
    Ok(sim.run())
}

/// `run_once` on the seed event core + seed scan paths (the equivalence
/// suite's "before"; see [`Simulation::with_reference_core`]).
#[cfg(feature = "ref-heap")]
pub fn run_once_reference(cfg: &Config, seed: u64) -> Result<RunMetrics, String> {
    let (registry, workload, schedulers) = build_parts(cfg, seed, None)?;
    let sim = Simulation::with_schedulers(cfg, &registry, &workload, schedulers, seed)
        .with_config_autoscaler()?
        .with_reference_core();
    Ok(sim.run())
}

/// Replay an open-loop (time, function) trace through the cluster, with
/// auto-scaling from `cfg.autoscale` (the bursty-trace autoscale bench).
/// `cfg.sim.shards ≥ 2` partitions trace arrivals round-robin across the
/// sharded engine's threads.
pub fn run_trace(cfg: &Config, trace: &OpenLoopTrace, seed: u64) -> Result<RunMetrics, String> {
    if cfg.sim.shards > 1 {
        return super::shard::run_sharded_trace(cfg, trace, seed);
    }
    // The VU workload is unused in open-loop mode, but the constructor
    // wants one; generate a minimal script set.
    let (registry, workload, schedulers) = build_parts(cfg, seed, Some(1))?;
    let sim = Simulation::with_schedulers(cfg, &registry, &workload, schedulers, seed)
        .with_config_autoscaler()?;
    Ok(sim.run_open_loop(trace))
}

/// `run_trace` on the reference core (see [`Simulation::with_reference_core`]).
#[cfg(feature = "ref-heap")]
pub fn run_trace_reference(
    cfg: &Config,
    trace: &OpenLoopTrace,
    seed: u64,
) -> Result<RunMetrics, String> {
    let (registry, workload, schedulers) = build_parts(cfg, seed, Some(1))?;
    let sim = Simulation::with_schedulers(cfg, &registry, &workload, schedulers, seed)
        .with_config_autoscaler()?
        .with_reference_core();
    Ok(sim.run_open_loop(trace))
}
