//! The discrete-event simulation engine: wires workload -> scheduler(s) ->
//! cluster and produces [`RunMetrics`].
//!
//! This reproduces the paper's cluster experiments (§V) without the AWS
//! testbed: the same closed-loop VU workload, the same scheduler contract,
//! the same sandbox lifecycle, with service times calibrated from Table I.
//! Everything is deterministic under (config, seed): scripts, service-time
//! streams and scheduler tie-breaking derive from split PRNG streams.
//!
//! Beyond the paper's base protocol the engine supports three extensions
//! used by the ablation benches:
//! - **auto-scaling** (the [`crate::autoscale`] subsystem): a recurring
//!   control tick evaluates the configured policy, which adds/drains
//!   workers and plans per-function pre-warm pools; schedulers are
//!   notified via `on_worker_added`/`on_worker_removed` (§II-C's
//!   redistribution story). Externally scripted scale times are the
//!   `scheduled` policy's event list;
//! - **multiple scheduler instances** (`scheduler.instances`): VUs are
//!   sharded across independent, synchronization-free schedulers, each
//!   with its own local load view (§I's distributed-scheduling claim);
//! - **open-loop trace replay** (`run_open_loop`): arrivals from a
//!   synthetic Azure-like trace instead of closed-loop VUs (burst
//!   response, Fig 6 tie-in).

use super::events::{Event, EventQueue};
use crate::autoscale::{AutoscaleObs, AutoscalePolicy, Scheduled};
use crate::config::Config;
use crate::metrics::RunMetrics;
use crate::platform::{AssignOutcome, Cluster, StartInfo, Worker, WorkerId};
use crate::scheduler::{SchedCtx, Scheduler};
use crate::util::rng::Pcg64;
use crate::workload::loadgen::{OpenLoopTrace, Workload};
use crate::workload::spec::FunctionRegistry;

/// Per-request bookkeeping.
#[derive(Clone, Copy, Debug)]
struct RequestMeta {
    /// Closed loop: issuing VU; open loop: usize::MAX.
    vu: usize,
    step: usize,
    function: usize,
    worker: WorkerId,
    /// Scheduler instance that routed this request.
    sched: usize,
    arrival: f64,
}

/// One simulation run: scheduler instance(s) against the workload.
pub struct Simulation<'a> {
    cfg: &'a Config,
    registry: &'a FunctionRegistry,
    workload: &'a Workload,
    /// Scheduler instances; VU v is served by instance v % len.
    schedulers: Vec<Box<dyn Scheduler>>,
    cluster: Cluster,
    queue: EventQueue,
    /// Per-instance router-side active connections (local load views —
    /// instances do not synchronize, per the paper's distributed design).
    loads: Vec<Vec<u32>>,
    sched_rng: Pcg64,
    service_rng: Pcg64,
    /// (time, up) auto-scaling events; up=false drains the highest worker.
    scale_events: Vec<(f64, bool)>,
    /// Closed-loop autoscale policy (None = static cluster). Scheduled
    /// events and the recurring control tick both come from here.
    autoscaler: Option<Box<dyn AutoscalePolicy>>,
    /// Control-tick period (config `autoscale.interval_s`).
    tick_dt: f64,
    /// Per-function mean warm execution time (autoscale observation).
    mean_exec_s: Vec<f64>,
    /// Workers currently eligible for selection (scale-down shrinks this;
    /// drained workers still exist in the cluster to finish in-flight work).
    active_workers: usize,
    requests: Vec<RequestMeta>,
    /// EWMA arrival rate per function (req/s), for the pre-warm policy.
    arrival_rate: Vec<f64>,
    last_arrival: Vec<f64>,
    /// Cold-start flag per request, resolved when its execution starts.
    cold_flags: Vec<bool>,
    /// Worker-queue delay per request.
    queue_delays: Vec<f64>,
    metrics: RunMetrics,
}

impl<'a> Simulation<'a> {
    pub fn new(
        cfg: &'a Config,
        registry: &'a FunctionRegistry,
        workload: &'a Workload,
        scheduler: Box<dyn Scheduler>,
        seed: u64,
    ) -> Self {
        Self::with_schedulers(cfg, registry, workload, vec![scheduler], seed)
    }

    pub fn with_schedulers(
        cfg: &'a Config,
        registry: &'a FunctionRegistry,
        workload: &'a Workload,
        schedulers: Vec<Box<dyn Scheduler>>,
        seed: u64,
    ) -> Self {
        assert!(!schedulers.is_empty());
        let mut root = Pcg64::new(seed ^ 0x51D0_C0DE);
        let sched_rng = root.split();
        let service_rng = root.split();
        let name = schedulers[0].name().to_string();
        let n = schedulers.len();
        Self {
            cfg,
            registry,
            workload,
            schedulers,
            cluster: Cluster::new(&cfg.cluster),
            queue: EventQueue::new(),
            loads: vec![vec![0; cfg.cluster.workers]; n],
            sched_rng,
            service_rng,
            scale_events: Vec::new(),
            autoscaler: None,
            tick_dt: cfg.autoscale.interval_s,
            mean_exec_s: (0..registry.len()).map(|f| registry.app(f).warm_ms / 1000.0).collect(),
            active_workers: cfg.cluster.workers,
            // Pre-size per-request tables to the scripted upper bound:
            // avoids realloc + page-fault churn in the hot loop (§Perf).
            requests: Vec::with_capacity(workload.total_steps().min(4_000_000)),
            arrival_rate: vec![0.0; registry.len()],
            last_arrival: vec![-1.0; registry.len()],
            cold_flags: Vec::new(),
            queue_delays: Vec::new(),
            metrics: RunMetrics::new(
                &name,
                cfg.cluster.workers,
                cfg.workload.vus,
                cfg.workload.duration_s,
            ),
        }
    }

    /// Schedule auto-scaling events: one worker joins at each time.
    pub fn with_scale_times(mut self, times: &[f64]) -> Self {
        self.scale_events = times.iter().map(|&t| (t, true)).collect();
        self
    }

    /// Schedule mixed scale events: (time, up). Scale-down is LIFO — the
    /// highest-id worker drains.
    pub fn with_scale_events(mut self, events: &[(f64, bool)]) -> Self {
        self.scale_events = events.to_vec();
        self
    }

    /// Install an autoscale policy (closed-loop scaling + pre-warming).
    pub fn with_autoscaler(mut self, policy: Box<dyn AutoscalePolicy>) -> Self {
        self.autoscaler = Some(policy);
        self
    }

    /// Install the autoscale policy the config's `[autoscale]` section
    /// asks for (the `none` policy is inert, so this is always safe).
    pub fn with_config_autoscaler(mut self) -> Result<Self, String> {
        self.autoscaler = Some(crate::autoscale::make_policy(&self.cfg.autoscale)?);
        Ok(self)
    }

    /// Pre-schedule the autoscaler's exact-time events and, for
    /// tick-driven policies, the first control tick.
    fn install_autoscaler_events(&mut self) {
        let Some(p) = &self.autoscaler else { return };
        for (t, up) in p.scheduled_events() {
            self.queue.push_at(t, Event::Scale { up });
        }
        if p.tick_driven() && self.tick_dt < self.cfg.workload.duration_s {
            self.queue.push_at(self.tick_dt, Event::AutoscaleTick);
        }
    }

    /// Copy prewarm speculation counters into the metrics and close the
    /// worker-seconds integral once the event loop has drained.
    fn finalize_metrics(&mut self) {
        let end = self.queue.now().max(self.cfg.workload.duration_s);
        self.metrics.finalize_scaling(end);
        let totals = self.cluster.totals();
        self.metrics.prewarm_spawned = totals.prewarm_spawned;
        self.metrics.prewarm_hits = totals.prewarm_hits;
    }

    /// Run the closed-loop VU workload to completion.
    pub fn run(mut self) -> RunMetrics {
        self.metrics.record_scale(0.0, self.active_workers);
        self.install_autoscaler_events();
        for &(t, up) in &self.scale_events.clone() {
            self.queue.push_at(t, Event::Scale { up });
        }
        for (vu, script) in self.workload.vus.iter().enumerate() {
            self.queue.push_at(script.start_delay_s, Event::Arrival { vu, step: 0 });
        }
        if self.cfg.cluster.prewarm {
            self.queue.push_at(1.0, Event::PreWarmTick);
        }
        self.queue.push_at(self.sweep_dt(), Event::SweepTick);
        self.event_loop();
        self.finalize_metrics();
        self.metrics
    }

    /// Keep-alive sweep interval: fine-grained for short TTLs, 1 Hz cap.
    fn sweep_dt(&self) -> f64 {
        (self.cfg.cluster.keep_alive_s / 2.0).clamp(0.05, 1.0)
    }

    /// Run an open-loop trace: arrivals at fixed timestamps, ignoring
    /// completions (burst-response experiments).
    pub fn run_open_loop(mut self, trace: &OpenLoopTrace) -> RunMetrics {
        self.metrics.record_scale(0.0, self.active_workers);
        self.install_autoscaler_events();
        for &(t, up) in &self.scale_events.clone() {
            self.queue.push_at(t, Event::Scale { up });
        }
        for (index, &(t, _)) in trace.arrivals.iter().enumerate() {
            if t >= self.cfg.workload.duration_s {
                break;
            }
            self.queue.push_at(t, Event::TraceArrival { index });
        }
        self.queue.push_at(self.sweep_dt(), Event::SweepTick);
        // Steal the arrivals for dispatch (cheap copy of (f64, usize)).
        let arrivals = trace.arrivals.clone();
        while let Some((t, ev)) = self.queue.pop() {
            match ev {
                Event::TraceArrival { index } => {
                    let (_, f) = arrivals[index];
                    self.issue(usize::MAX, index, f, t);
                }
                other => self.dispatch(other, t),
            }
        }
        self.finalize_metrics();
        self.metrics
    }

    fn event_loop(&mut self) {
        while let Some((t, ev)) = self.queue.pop() {
            self.dispatch(ev, t);
        }
    }

    fn dispatch(&mut self, ev: Event, t: f64) {
        match ev {
            Event::Arrival { vu, step } => self.on_arrival(vu, step, t),
            Event::Completion { worker, sandbox, request } => {
                self.on_completion(worker, sandbox, request, t)
            }
            Event::SweepTick => self.on_sweep(t),
            Event::KeepAlive { worker, sandbox, epoch } => {
                // Precise per-sandbox expiry (unused by the default sweep
                // mode, kept for API completeness).
                if let Some(f) =
                    self.cluster.worker_mut(worker).expire_keepalive(sandbox, epoch)
                {
                    self.notify_evict(worker, f);
                }
            }
            Event::Scale { up } => self.on_scale(up),
            Event::AutoscaleTick => self.on_autoscale_tick(t),
            Event::PreWarmTick => self.on_prewarm_tick(t),
            Event::PreWarmDone { worker, sandbox } => self.on_prewarm_done(worker, sandbox, t),
            Event::TraceArrival { .. } => unreachable!("only in run_open_loop"),
        }
    }

    /// Periodic keep-alive sweep across all workers.
    fn on_sweep(&mut self, t: f64) {
        let cutoff = t - self.cfg.cluster.keep_alive_s;
        for w in 0..self.cluster.len() {
            let evicted = self.cluster.worker_mut(w).sweep_keepalive(cutoff);
            for f in evicted {
                self.notify_evict(w, f);
            }
        }
        let next = t + self.sweep_dt();
        // Stop sweeping once no more work can arrive and drain completes.
        if next < self.cfg.workload.duration_s + self.cfg.cluster.keep_alive_s {
            self.queue.push_at(next, Event::SweepTick);
        }
    }

    /// A worker joins or drains out of the cluster (auto-scaling).
    fn on_scale(&mut self, up: bool) {
        crate::log_debug!(
            "sim",
            "scale {} at t={:.1}s (active {})",
            if up { "up" } else { "down" },
            self.queue.now(),
            self.active_workers
        );
        if up {
            if self.active_workers < self.cluster.len() {
                // Re-activate a previously drained worker slot.
                let id = self.active_workers;
                self.active_workers += 1;
                for s in &mut self.schedulers {
                    s.on_worker_added(id);
                }
                self.metrics.record_scale(self.queue.now(), self.active_workers);
                return;
            }
            let id = self.cluster.len();
            self.cluster
                .workers
                .push(Worker::new(id, self.cfg.cluster.mem_mb, self.cfg.cluster.concurrency));
            for loads in &mut self.loads {
                loads.push(0);
            }
            self.active_workers += 1;
            self.metrics.imbalance.add_worker();
            for s in &mut self.schedulers {
                s.on_worker_added(id);
            }
        } else {
            if self.active_workers <= 1 {
                return; // never drain the last worker
            }
            self.active_workers -= 1;
            let id = self.active_workers;
            for s in &mut self.schedulers {
                s.on_worker_removed(id);
            }
            // Reclaim the drained worker's idle sandboxes immediately.
            let evicted = self.cluster.worker_mut(id).drain_idle();
            for f in evicted {
                self.notify_evict(id, f);
            }
        }
        self.metrics.record_scale(self.queue.now(), self.active_workers);
    }

    /// Autoscale control tick: snapshot the active cluster, ask the policy,
    /// apply its worker target and pre-warm plan. Everything here is
    /// deterministic under (config, seed): the observation derives from
    /// simulator state and the only randomness (pre-warm init sampling)
    /// comes from the dedicated service-time stream.
    fn on_autoscale_tick(&mut self, t: f64) {
        let decision = {
            let Some(policy) = self.autoscaler.as_mut() else { return };
            let mut warm_supply = vec![0usize; self.registry.len()];
            let mut total_running = 0usize;
            let mut total_queued = 0usize;
            for w in 0..self.active_workers {
                let wk = self.cluster.worker(w);
                wk.warm_counts_into(&mut warm_supply);
                total_running += wk.running();
                total_queued += wk.queue_len();
            }
            let obs = AutoscaleObs {
                now: t,
                active_workers: self.active_workers,
                concurrency: self.cfg.cluster.concurrency,
                total_running,
                total_queued,
                warm_supply: &warm_supply,
                mean_exec_s: &self.mean_exec_s,
            };
            policy.tick(&obs)
        };

        if let Some(target) = decision.target_workers {
            crate::log_debug!(
                "autoscale",
                "t={t:.1}s target {} (active {})",
                target,
                self.active_workers
            );
            while self.active_workers < target {
                self.on_scale(true);
            }
            while self.active_workers > target {
                let before = self.active_workers;
                self.on_scale(false);
                if self.active_workers == before {
                    break; // the last worker never drains
                }
            }
        }
        for (f, n) in decision.prewarm {
            self.spawn_prewarm(f, n, t);
        }

        let next = t + self.tick_dt;
        if next < self.cfg.workload.duration_s {
            self.queue.push_at(next, Event::AutoscaleTick);
        }
    }

    /// Speculatively initialize up to `n` sandboxes for `f` on the
    /// least-loaded active workers with free memory (never evicts).
    fn spawn_prewarm(&mut self, f: usize, n: usize, t: f64) {
        let mem = self.registry.mem_mb(f);
        for _ in 0..n {
            // Least-loaded active worker that can fit without eviction.
            let target = (0..self.active_workers)
                .filter(|&w| self.cluster.worker(w).mem_free_mb() >= mem)
                .min_by_key(|&w| self.cluster.worker(w).load());
            let Some(w) = target else { return };
            if let Some(sb) = self.cluster.worker_mut(w).prewarm(f, mem, t) {
                let init = self.registry.sample_init_s(f, &mut self.service_rng);
                self.queue.push_at(t + init, Event::PreWarmDone { worker: w, sandbox: sb });
            }
        }
    }

    /// Broadcast an eviction notification. With one instance this is the
    /// paper's exact mechanism; with several it is conservative (an entry
    /// is dropped from every instance that advertises the worker, never
    /// leaving a stale entry behind).
    fn notify_evict(&mut self, w: WorkerId, f: usize) {
        for s in &mut self.schedulers {
            s.on_evict(w, f);
        }
    }

    fn on_arrival(&mut self, vu: usize, step: usize, t: f64) {
        // The run stops issuing at duration_s; in-flight requests drain.
        if t >= self.cfg.workload.duration_s {
            return;
        }
        let script = &self.workload.vus[vu];
        let Some(s) = script.steps.get(step) else {
            return; // script exhausted (bounded generation)
        };
        let f = s.function;
        self.issue(vu, step, f, t);
    }

    /// Update the per-function EWMA arrival-rate estimate.
    fn track_arrival(&mut self, f: usize, t: f64) {
        const ALPHA: f64 = 0.2;
        let last = self.last_arrival[f];
        if last >= 0.0 && t > last {
            let inst = 1.0 / (t - last);
            self.arrival_rate[f] = ALPHA * inst + (1.0 - ALPHA) * self.arrival_rate[f];
        }
        self.last_arrival[f] = t;
    }

    /// Pre-warm policy (1 Hz): for each function, estimate the expected
    /// concurrent demand (rate x mean warm service time) and speculatively
    /// initialize sandboxes to cover any deficit vs. the warm supply, on
    /// the least-loaded workers with free memory. Cf. Kim & Roh [24].
    fn on_prewarm_tick(&mut self, t: f64) {
        for f in 0..self.registry.len() {
            let rate = self.arrival_rate[f];
            if rate <= 0.0 {
                continue;
            }
            let mean_exec = self.registry.app(f).warm_ms / 1000.0;
            let demand = (rate * mean_exec).ceil() as usize;
            let supply: usize = (0..self.active_workers)
                .map(|w| {
                    let wk = self.cluster.worker(w);
                    wk.idle_count(f) + wk.initializing_count(f)
                })
                .sum();
            let deficit = demand.saturating_sub(supply).min(2); // <= 2/tick/function
            self.spawn_prewarm(f, deficit, t);
        }
        if t + 1.0 < self.cfg.workload.duration_s {
            self.queue.push_at(t + 1.0, Event::PreWarmTick);
        }
    }

    /// A speculative sandbox finished initializing: it becomes idle, is
    /// advertised to a scheduler instance, and starts its keep-alive.
    fn on_prewarm_done(&mut self, w: WorkerId, sandbox: u64, t: f64) {
        if let Some((f, epoch)) = self.cluster.worker_mut(w).finish_prewarm(sandbox, t) {
            if w < self.active_workers {
                let si = f % self.schedulers.len();
                let mut ctx = SchedCtx {
                    loads: &self.loads[si][..self.active_workers],
                    rng: &mut self.sched_rng,
                };
                self.schedulers[si].on_complete(w, f, &mut ctx);
                // Keep-alive expiry handled by the periodic SweepTick.
                let _ = (sandbox, epoch);
            }
        }
    }

    /// Route and start/queue one request (closed- or open-loop).
    fn issue(&mut self, vu: usize, step: usize, f: usize, t: f64) {
        let rid = self.requests.len() as u64;
        if self.cfg.cluster.prewarm {
            self.track_arrival(f, t);
        }
        if let Some(p) = self.autoscaler.as_mut() {
            p.on_arrival(f, t);
        }
        let si = if vu == usize::MAX { step % self.schedulers.len() } else { vu % self.schedulers.len() };

        // --- the scheduling decision (Algorithm 1 entry point) ---
        let w = {
            let mut ctx = SchedCtx {
                loads: &self.loads[si][..self.active_workers],
                rng: &mut self.sched_rng,
            };
            self.schedulers[si].select(f, &mut ctx)
        };
        debug_assert!(w < self.active_workers, "scheduler picked drained worker {w}");
        self.loads[si][w] += 1;
        self.metrics.record_assignment(w, t);
        self.requests.push(RequestMeta { vu, step, function: f, worker: w, sched: si, arrival: t });

        let mem = self.registry.mem_mb(f);
        if self.cfg.cluster.elastic {
            let info = self.cluster.worker_mut(w).assign_elastic(rid, f, mem, t);
            self.handle_start(w, info, t);
        } else {
            match self.cluster.worker_mut(w).assign(rid, f, mem, t) {
                AssignOutcome::Started(info) => self.handle_start(w, info, t),
                AssignOutcome::Queued => {}
            }
        }
    }

    /// An execution actually starts on `w`: sample its service time,
    /// schedule completion, and deliver eviction notifications.
    fn handle_start(&mut self, w: WorkerId, info: StartInfo, t: f64) {
        for f in info.evicted.clone() {
            self.notify_evict(w, f);
        }
        let meta = self.requests[info.request_id as usize];
        let mut dur = self.registry.sample_exec_s(meta.function, &mut self.service_rng);
        if info.cold {
            dur += self.registry.sample_init_s(meta.function, &mut self.service_rng);
        }
        if self.cfg.cluster.elastic {
            // vCPU time-sharing: executions beyond the core count slow all
            // of this worker's work down proportionally. Applying the
            // multiplier at start time (rather than re-scaling in flight)
            // keeps the DES single-pass; the approximation error is small
            // at the paper's load levels and identical across schedulers.
            let running = self.cluster.worker(w).running() as f64;
            let cores = self.cfg.cluster.concurrency as f64;
            let congestion = (running / cores).max(1.0);
            dur *= congestion;
        }
        // Cold/warm and queue delay resolved at start time, kept per rid.
        self.cold_flags.resize(self.requests.len(), false);
        self.cold_flags[info.request_id as usize] = info.cold;
        self.queue_delays.resize(self.requests.len(), 0.0);
        self.queue_delays[info.request_id as usize] = info.queue_delay_s;
        self.queue.push_at(
            t + dur,
            Event::Completion { worker: w, sandbox: info.sandbox, request: info.request_id },
        );
    }

    fn on_completion(&mut self, w: WorkerId, sandbox: u64, rid: u64, t: f64) {
        let meta = self.requests[rid as usize];
        debug_assert_eq!(meta.worker, w);
        self.loads[meta.sched][w] -= 1;

        // Worker-side: sandbox idles; (queue mode) a queued request may
        // start; (elastic mode) the idle pool is trimmed to capacity.
        let (expiry, started, evicted) = if self.cfg.cluster.elastic {
            let (expiry, evicted) = self.cluster.worker_mut(w).complete_elastic(sandbox, t);
            (expiry, None, evicted)
        } else {
            let (expiry, started) = self.cluster.worker_mut(w).complete(sandbox, t);
            (expiry, started, Vec::new())
        };
        for f in evicted {
            self.notify_evict(w, f);
        }

        // Pull mechanism: the worker enqueues in PQ_f only if its instance
        // is actually idle after completion (if it was immediately reused
        // or reclaimed, there is nothing to advertise). The advertisement
        // goes to the scheduler instance that served the request — the
        // distributed-JIQ reporting rule [21].
        if let Some((sb, epoch)) = expiry {
            if w < self.active_workers {
                let si = meta.sched;
                let mut ctx = SchedCtx {
                    loads: &self.loads[si][..self.active_workers],
                    rng: &mut self.sched_rng,
                };
                self.schedulers[si].on_complete(w, meta.function, &mut ctx);
                // Keep-alive expiry handled by the periodic SweepTick.
            } else {
                // Drained worker: reclaim the sandbox instead of
                // advertising it.
                if let Some(f) = self.cluster.worker_mut(w).expire_keepalive(sb, epoch) {
                    self.notify_evict(w, f);
                }
            }
        }

        if let Some(info) = started {
            self.handle_start(w, info, t);
        }

        // Metrics: response latency for the completed request.
        let cold = self.cold_flags[rid as usize];
        let qd = self.queue_delays[rid as usize];
        self.metrics.record_response(t - meta.arrival, cold, qd, t);

        // Closed loop: the VU thinks, then issues its next step.
        if meta.vu != usize::MAX {
            let script = &self.workload.vus[meta.vu];
            let think = script.steps[meta.step].think_s;
            let next_t = t + think;
            if next_t < self.cfg.workload.duration_s {
                self.queue.push_at(next_t, Event::Arrival { vu: meta.vu, step: meta.step + 1 });
            }
        }
    }
}

/// Build the scheduler instances a config asks for.
fn build_schedulers(cfg: &Config) -> Result<Vec<Box<dyn Scheduler>>, String> {
    (0..cfg.scheduler.instances.max(1))
        .map(|_| crate::scheduler::make_scheduler(&cfg.scheduler, cfg.cluster.workers))
        .collect()
}

/// Shared entry-point setup: registry (validated against the config),
/// scripted workload, scheduler instances. `vus` overrides the configured
/// VU count (open-loop mode only needs a placeholder script set).
fn build_parts(
    cfg: &Config,
    seed: u64,
    vus: Option<usize>,
) -> Result<(FunctionRegistry, Workload, Vec<Box<dyn Scheduler>>), String> {
    let registry = FunctionRegistry::functionbench(cfg.workload.copies);
    if registry.len() != cfg.num_functions() {
        return Err(format!(
            "registry size {} != configured {}",
            registry.len(),
            cfg.num_functions()
        ));
    }
    let mut wcfg = cfg.workload.clone();
    if let Some(v) = vus {
        wcfg.vus = v;
    }
    let workload = Workload::generate(&wcfg, registry.len(), seed);
    let schedulers = build_schedulers(cfg)?;
    Ok((registry, workload, schedulers))
}

/// Run one (config, seed) closed-loop experiment. This is the single
/// policy-driven entry point: auto-scaling comes from `cfg.autoscale`
/// (`none`, `scheduled`, `reactive`, or `predictive`).
pub fn run_once(cfg: &Config, seed: u64) -> Result<RunMetrics, String> {
    let (registry, workload, schedulers) = build_parts(cfg, seed, None)?;
    let sim = Simulation::with_schedulers(cfg, &registry, &workload, schedulers, seed)
        .with_config_autoscaler()?;
    Ok(sim.run())
}

/// Deprecated shim over the `scheduled` autoscale policy: mixed scale
/// events (time, up); up=false drains the highest-id worker (LIFO).
/// Prefer `cfg.autoscale.policy = "scheduled"` + `cfg.autoscale.events`.
pub fn run_scale_events(
    cfg: &Config,
    seed: u64,
    events: &[(f64, bool)],
) -> Result<RunMetrics, String> {
    let (registry, workload, schedulers) = build_parts(cfg, seed, None)?;
    let sim = Simulation::with_schedulers(cfg, &registry, &workload, schedulers, seed)
        .with_autoscaler(Box::new(Scheduled::new(events.to_vec())));
    Ok(sim.run())
}

/// Deprecated shim over the `scheduled` autoscale policy: one worker joins
/// at each of `scale_times`. Prefer `cfg.autoscale`.
pub fn run_scaled(cfg: &Config, seed: u64, scale_times: &[f64]) -> Result<RunMetrics, String> {
    let events: Vec<(f64, bool)> = scale_times.iter().map(|&t| (t, true)).collect();
    let (registry, workload, schedulers) = build_parts(cfg, seed, None)?;
    let sim = Simulation::with_schedulers(cfg, &registry, &workload, schedulers, seed)
        .with_autoscaler(Box::new(Scheduled::new(events)));
    Ok(sim.run())
}

/// Replay an open-loop (time, function) trace through the cluster, with
/// auto-scaling from `cfg.autoscale` (the bursty-trace autoscale bench).
pub fn run_trace(cfg: &Config, trace: &OpenLoopTrace, seed: u64) -> Result<RunMetrics, String> {
    // The VU workload is unused in open-loop mode, but the constructor
    // wants one; generate a minimal script set.
    let (registry, workload, schedulers) = build_parts(cfg, seed, Some(1))?;
    let sim = Simulation::with_schedulers(cfg, &registry, &workload, schedulers, seed)
        .with_config_autoscaler()?;
    Ok(sim.run_open_loop(trace))
}
