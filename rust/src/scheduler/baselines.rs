//! Baseline schedulers: least-connections, random, naive hash-mod, JSQ and
//! power-of-d-choices.
//!
//! Least-connections and random are two of the paper's three baselines
//! (§V, from the olscheduler suite [19]). Hash-mod is the naive hashing
//! scheme §II-C warns about (modulo redistributions under auto-scaling).
//! JSQ and power-of-d are the classic queueing-theory push-based algorithms
//! (§VI) included for ablation benches.

use super::{SchedCtx, Scheduler, WorkerId};
use crate::util::hashing;
use crate::workload::spec::FunctionId;

/// Least-connections: route to the worker with the fewest active
/// connections; uniform random among ties (olscheduler's "least-loaded").
#[derive(Clone, Debug, Default)]
pub struct LeastConnections {
    /// 0 = exact uniform-among-ties; d ≥ 1 = power-of-d sampled variant
    /// (`scheduler.tie_sample_d`, see [`super::sampled_least_loaded`]).
    sample_d: usize,
}

impl LeastConnections {
    /// Exact least-connections (the paper's baseline).
    pub fn new() -> Self {
        Self::default()
    }

    /// Switch to the power-of-d sampled tie-break when `d >= 1` (0 keeps
    /// the exact rule). O(d) per decision instead of Θ(tie set).
    pub fn with_tie_sample(mut self, d: usize) -> Self {
        self.sample_d = d;
        self
    }
}

impl Scheduler for LeastConnections {
    fn name(&self) -> &'static str {
        "least-connections"
    }

    fn select(&mut self, _f: FunctionId, ctx: &mut SchedCtx) -> WorkerId {
        if self.sample_d > 0 {
            return super::sampled_least_loaded(ctx.loads, ctx.rng, self.sample_d);
        }
        // O(tie set) via the router's min-load index when attached,
        // identical linear scan otherwise.
        ctx.least_loaded_random_tie()
    }
}

/// Random: uniform selection, oblivious to load and locality.
#[derive(Clone, Debug)]
pub struct RandomSched {
    workers: usize,
}

impl RandomSched {
    /// Uniform-random routing over `workers` workers.
    pub fn new(workers: usize) -> Self {
        assert!(workers > 0);
        Self { workers }
    }
}

impl Scheduler for RandomSched {
    fn name(&self) -> &'static str {
        "random"
    }

    fn select(&mut self, _f: FunctionId, ctx: &mut SchedCtx) -> WorkerId {
        ctx.rng.index(self.workers)
    }

    fn on_worker_added(&mut self, w: WorkerId) {
        self.workers = self.workers.max(w + 1);
    }

    fn on_worker_removed(&mut self, w: WorkerId) {
        self.workers = self.workers.min(w).max(1);
    }
}

/// Naive hash partitioning: `hash(f) mod m`. Maximum locality while the
/// worker set is static, but §II-C's auto-scaling redistribution problem
/// (quantified in the ring tests) and zero load awareness.
#[derive(Clone, Debug)]
pub struct HashMod {
    workers: usize,
}

impl HashMod {
    /// `hash(f) mod workers` routing.
    pub fn new(workers: usize) -> Self {
        assert!(workers > 0);
        Self { workers }
    }
}

impl Scheduler for HashMod {
    fn name(&self) -> &'static str {
        "hash-mod"
    }

    fn select(&mut self, f: FunctionId, _ctx: &mut SchedCtx) -> WorkerId {
        (hashing::mix64(f as u64) % self.workers as u64) as usize
    }

    fn on_worker_added(&mut self, w: WorkerId) {
        // The naive-modulo weakness (§II-C): changing the modulus
        // redistributes most keys. Nothing else to update.
        self.workers = self.workers.max(w + 1);
    }

    fn on_worker_removed(&mut self, w: WorkerId) {
        self.workers = self.workers.min(w).max(1);
    }
}

/// Join-Shortest-Queue with deterministic lowest-id tie-breaking (the
/// classical JSQ statement [30]; differs from least-connections only in
/// tie handling, which the ablation bench quantifies).
#[derive(Clone, Debug, Default)]
pub struct Jsq;

impl Jsq {
    /// Classical JSQ (lowest id among minima).
    pub fn new() -> Self {
        Self
    }
}

impl Scheduler for Jsq {
    fn name(&self) -> &'static str {
        "jsq"
    }

    fn select(&mut self, _f: FunctionId, ctx: &mut SchedCtx) -> WorkerId {
        ctx.least_loaded_lowest_id()
    }
}

/// Power-of-d-choices [17]: sample d distinct workers uniformly, route to
/// the least loaded of the sample.
#[derive(Clone, Debug)]
pub struct PowerOfD {
    workers: usize,
    d: usize,
}

impl PowerOfD {
    /// Power-of-d-choices over `workers` workers (d distinct samples).
    pub fn new(workers: usize, d: usize) -> Self {
        assert!(workers > 0 && d > 0);
        Self { workers, d: d.min(workers) }
    }
}

impl Scheduler for PowerOfD {
    fn name(&self) -> &'static str {
        "power-of-d"
    }

    fn select(&mut self, _f: FunctionId, ctx: &mut SchedCtx) -> WorkerId {
        // Sample d distinct indices via partial Fisher-Yates over a small
        // stack buffer (workers is small; avoid allocation for <= 64).
        debug_assert!(self.workers == ctx.loads.len());
        let mut best: Option<WorkerId> = None;
        if self.workers <= 64 {
            let mut idx: [usize; 64] = [0; 64];
            for (i, slot) in idx.iter_mut().enumerate().take(self.workers) {
                *slot = i;
            }
            for i in 0..self.d {
                let j = i + ctx.rng.index(self.workers - i);
                idx.swap(i, j);
                let w = idx[i];
                if best.map(|b| ctx.loads[w] < ctx.loads[b]).unwrap_or(true) {
                    best = Some(w);
                }
            }
        } else {
            let mut idx: Vec<usize> = (0..self.workers).collect();
            for i in 0..self.d {
                let j = i + ctx.rng.index(self.workers - i);
                idx.swap(i, j);
                let w = idx[i];
                if best.map(|b| ctx.loads[w] < ctx.loads[b]).unwrap_or(true) {
                    best = Some(w);
                }
            }
        }
        best.unwrap()
    }

    fn on_worker_added(&mut self, w: WorkerId) {
        self.workers = self.workers.max(w + 1);
        self.d = self.d.min(self.workers);
    }

    fn on_worker_removed(&mut self, w: WorkerId) {
        self.workers = self.workers.min(w).max(1);
        self.d = self.d.min(self.workers);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn least_connections_picks_min() {
        let mut s = LeastConnections::new();
        let mut rng = Pcg64::new(1);
        let loads = [3u32, 0, 2];
        let mut ctx = SchedCtx::new(&loads, &mut rng);
        assert_eq!(s.select(0, &mut ctx), 1);
    }

    #[test]
    fn random_is_roughly_uniform_and_locality_free() {
        let mut s = RandomSched::new(4);
        let mut rng = Pcg64::new(2);
        let loads = [100u32, 0, 0, 0]; // load-oblivious: still picks 0 sometimes
        let mut counts = [0usize; 4];
        for _ in 0..40_000 {
            let mut ctx = SchedCtx::new(&loads, &mut rng);
            counts[s.select(7, &mut ctx)] += 1;
        }
        for &c in &counts {
            assert!((c as f64 / 40_000.0 - 0.25).abs() < 0.02, "{counts:?}");
        }
    }

    #[test]
    fn hash_mod_is_deterministic_per_function() {
        let mut s = HashMod::new(5);
        let mut rng = Pcg64::new(3);
        let loads = [0u32; 5];
        for f in 0..40 {
            let mut ctx = SchedCtx::new(&loads, &mut rng);
            let w1 = s.select(f, &mut ctx);
            let mut ctx = SchedCtx::new(&loads, &mut rng);
            let w2 = s.select(f, &mut ctx);
            assert_eq!(w1, w2, "hashing must be stable");
        }
    }

    #[test]
    fn hash_mod_spreads_functions() {
        let mut s = HashMod::new(5);
        let mut rng = Pcg64::new(4);
        let loads = [0u32; 5];
        let mut hit = [false; 5];
        for f in 0..200 {
            let mut ctx = SchedCtx::new(&loads, &mut rng);
            hit[s.select(f, &mut ctx)] = true;
        }
        assert!(hit.iter().all(|&h| h), "200 functions must cover 5 workers");
    }

    #[test]
    fn jsq_deterministic_tiebreak() {
        let mut s = Jsq::new();
        let mut rng = Pcg64::new(5);
        let loads = [2u32, 1, 1, 5];
        let mut ctx = SchedCtx::new(&loads, &mut rng);
        assert_eq!(s.select(0, &mut ctx), 1, "lowest id among ties");
    }

    #[test]
    fn power_of_d_beats_random_on_imbalance() {
        // Classic result: d=2 picks the lower-loaded of two samples, so on
        // a skewed load vector it must select the overloaded worker less
        // often than random does.
        let mut pod = PowerOfD::new(4, 2);
        let mut rng = Pcg64::new(6);
        let loads = [100u32, 0, 0, 0];
        let mut overloaded_hits = 0usize;
        let n = 20_000;
        for _ in 0..n {
            let mut ctx = SchedCtx::new(&loads, &mut rng);
            if pod.select(0, &mut ctx) == 0 {
                overloaded_hits += 1;
            }
        }
        // P(pick worker 0) = P(both samples are 0) = C(1,2)... with d=2
        // distinct samples it's P(0 in sample) * P(0 wins) = 0 since any
        // other sample has load 0 < 100. Actually 0 can only win if both
        // samples are 0, impossible with distinct sampling => ~0 hits.
        assert_eq!(overloaded_hits, 0, "d=2 must never pick the clearly overloaded worker");
    }

    #[test]
    fn power_of_d_equals_workers_is_jsq() {
        let mut pod = PowerOfD::new(4, 4);
        let mut rng = Pcg64::new(7);
        let loads = [3u32, 1, 2, 4];
        let mut ctx = SchedCtx::new(&loads, &mut rng);
        assert_eq!(pod.select(0, &mut ctx), 1);
    }

    /// Baselines never override `decide`, so they inherit the slot-aware
    /// push adapter: under a core-granular router the pick is upgraded to
    /// `AssignSlot` when (and only when) the chosen worker has a free
    /// warm-affine core — no per-baseline slot logic required.
    #[test]
    fn baselines_inherit_slot_upgrade_through_default_decide() {
        use crate::scheduler::{Decision, SlotCtx};
        let mut s = Jsq::new();
        let mut rng = Pcg64::new(8);
        let loads = [2u32, 1, 1, 5]; // JSQ picks worker 1 (lowest id tie)
        let free = [1u32, 2, 2, 0];
        let warm_free = [-1i32, 3, -1, -1];
        let d = {
            let mut ctx = SchedCtx::new(&loads, &mut rng)
                .with_slots(SlotCtx { free: &free, warm_free: &warm_free });
            s.decide(0, &mut ctx)
        };
        assert_eq!(d, Decision::AssignSlot(1, 3));
        let warm_free = [-1i32; 4];
        let d = {
            let mut ctx = SchedCtx::new(&loads, &mut rng)
                .with_slots(SlotCtx { free: &free, warm_free: &warm_free });
            s.decide(0, &mut ctx)
        };
        assert_eq!(d, Decision::Assign(1), "no warm core anywhere: plain Assign");
    }
}
