//! Scheduling algorithms: Hiku pull-based scheduling (the paper's
//! contribution, Algorithm 1) and every baseline the paper evaluates
//! against (§V: least-connections, random, CH-BL) plus the related
//! algorithms discussed in §II/§VI (plain consistent hashing, naive
//! hash-mod, RJ-CH, JSQ, power-of-d-choices) for ablations.
//!
//! ## Contract
//!
//! The router (sim or real-time server) owns the *load view*: it increments
//! `loads[w]` when a request is routed to `w` and decrements it when the
//! response returns — this is the paper's "number of active connections".
//! Schedulers are notified of lifecycle events:
//!
//! - [`Scheduler::decide`] — the dispatch-protocol entry point: answer a
//!   request with a [`Decision`] — assign a worker now, park the request
//!   in the router's pending queue, or refuse it. The default
//!   implementation is the *push adapter*: it assigns synchronously via
//!   [`Scheduler::select`], so every legacy algorithm participates in the
//!   protocol with bit-identical behavior (DESIGN.md §8).
//! - [`Scheduler::select`] — choose a worker for a request (the decision
//!   whose overhead §V-B reports: 0.0023 ms for random .. 0.0149 ms for
//!   pull-based on the paper's testbed). Under the dispatch protocol this
//!   doubles as the *forced placement* rule the router uses when a parked
//!   request's wait deadline expires.
//! - [`Scheduler::on_worker_idle`] — pull hook: a worker just became idle
//!   holding a warm instance of `f`; the scheduler names the pending
//!   queue it should claim from (the paper's pull loop made first-class).
//! - [`Scheduler::on_complete`] — a worker finished executing `f` and now
//!   holds an idle instance (Hiku enqueues the worker in `PQ_f`).
//! - [`Scheduler::on_evict`] — a worker evicted an idle instance of `f`
//!   (Hiku's sandbox-destruction notification, §IV-A).

pub mod baselines;
pub mod hiku;
pub mod ring;

use crate::config::SchedulerConfig;
use crate::util::loadidx::MinLoadIndex;
use crate::util::rng::Pcg64;
use crate::workload::spec::FunctionId;

pub use baselines::{HashMod, Jsq, LeastConnections, PowerOfD, RandomSched};
pub use hiku::Hiku;
pub use ring::{ChBl, Consistent, RjCh};

/// Dense worker index (see [`crate::platform::worker::WorkerId`]).
pub type WorkerId = usize;

/// A dispatch decision — the answer to [`Scheduler::decide`]. Replaces
/// the implicit `select -> WorkerId` contract: task assignment is no
/// longer forced to happen at request arrival (late binding, DESIGN.md §8).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Decision {
    /// Bind the request to this worker immediately (push semantics).
    Assign(WorkerId),
    /// Bind the request to a specific core slot of this worker
    /// (core-granular scheduling, DESIGN.md §11). The slot preference is
    /// best-effort: if it is busy by the time the request lands, the
    /// worker falls back to its own deterministic pick. Routers that do
    /// not track slots (`cores_per_worker = 1`, the real-time server)
    /// treat this exactly like [`Decision::Assign`].
    AssignSlot(WorkerId, u32),
    /// Park the request in the router's pending queue: an idle worker
    /// will pull it ([`Scheduler::on_worker_idle`]) or the router's wait
    /// deadline will force-place it via [`Scheduler::select`].
    Enqueue,
    /// Refuse the request (admission control). The router records it in
    /// the reject metrics; the client moves on.
    Reject(RejectReason),
}

/// Why a request was refused ([`Decision::Reject`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RejectReason {
    /// The router's pending queue is at `dispatch.queue_cap`.
    QueueFull,
}

/// What an idle worker claims from the router's pending queues — the
/// answer to [`Scheduler::on_worker_idle`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Pull {
    /// Claim the oldest pending request of this function type (a warm
    /// start on the idle instance).
    Function(FunctionId),
    /// Claim nothing; the idle instance is advertised through
    /// [`Scheduler::on_complete`] instead.
    Skip,
}

/// Router-side dispatch state handed to [`Scheduler::decide`] when the
/// pull protocol is active (`dispatch.mode = "pull"`). `None` in the
/// [`SchedCtx`] means push semantics: `decide` must assign synchronously.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct DispatchCtx {
    /// Executions of the requested function currently running across the
    /// active workers — when > 0 a warm instance will free up soon, so
    /// parking the request has a prospect of a warm start.
    pub inflight_f: usize,
    /// Requests already waiting in the router's pending queue for the
    /// requested function. The built-in Hiku ignores it (it parks purely
    /// on `inflight_f`); it is provided so custom `decide` /
    /// `on_worker_idle` implementations can bound their own waiting
    /// lines without a side channel to the router.
    pub pending_f: usize,
}

/// Slot-level load view handed to [`Scheduler::decide`] when the router
/// runs core-granular (`sim.cores_per_worker > 1`, DESIGN.md §11). Both
/// slices are indexed like [`SchedCtx::loads`] (active workers only) and
/// are computed by the router *for the function being decided*, so
/// schedulers stay function-agnostic.
#[derive(Clone, Copy, Debug, Default)]
pub struct SlotCtx<'a> {
    /// Free core slots per active worker.
    pub free: &'a [u32],
    /// Per worker: the lowest-index free slot whose last occupant was the
    /// requested function (warm affinity), or -1 when no such slot is
    /// free. A scheduler that lands on worker `w` with `warm_free[w] >= 0`
    /// should return [`Decision::AssignSlot`] to pin the warm core.
    pub warm_free: &'a [i32],
}

/// Router-maintained state handed to every scheduler call.
pub struct SchedCtx<'a> {
    /// Active connections per worker (outstanding routed requests).
    pub loads: &'a [u32],
    /// Incremental min-load index over the *same* active worker set as
    /// `loads` (the router maintains both). `None` for callers without
    /// one — the selection helpers below then fall back to a linear scan
    /// with bit-identical semantics, so schedulers behave the same either
    /// way; the index only changes the cost.
    pub min_index: Option<&'a MinLoadIndex>,
    /// Scheduler-owned RNG stream (tie-breaking, random selection).
    pub rng: &'a mut Pcg64,
    /// Pull-dispatch context; `None` (push mode) makes [`Scheduler::decide`]
    /// behave exactly like [`Scheduler::select`].
    pub dispatch: Option<DispatchCtx>,
    /// Workers the router wants selections to avoid: crash-marked (fault
    /// injection, DESIGN.md §10) or drain-marked (autoscale scale-down in
    /// progress), indexed like `loads`. `None` means every active worker
    /// is eligible. This is advisory steering for force-places and stale
    /// idle-claims — the router re-routes any selection that lands on an
    /// avoided worker, so schedulers that ignore it stay correct (and
    /// keep their RNG streams unchanged).
    pub avoid: Option<&'a [bool]>,
    /// Slot-level load view (`None` unless the router runs core-granular).
    /// Schedulers that ignore it stay correct: an [`Decision::Assign`] on
    /// a slot-tracking router lets the worker pick the slot itself under
    /// the same deterministic rule.
    pub slots: Option<SlotCtx<'a>>,
}

/// Builder for [`SchedCtx`]: the one construction path shared by the
/// sim engine, the sharded engine and the real-time server router.
///
/// `SchedCtx` accreted optional router signals across releases
/// (`min_index`, `dispatch`, `avoid`, `slots`) and every construction
/// site spelled the full struct literal — so each new signal touched
/// all of them. The builder takes each optional signal as an `Option`
/// (routers usually hold one conditionally), so adding a future signal
/// means one new method here, defaulted everywhere else.
///
/// ```
/// # use hiku::scheduler::SchedCtx;
/// # use hiku::util::rng::Pcg64;
/// let loads = [0u32, 2, 1];
/// let mut rng = Pcg64::new(7);
/// let ctx = SchedCtx::builder(&loads, &mut rng).avoid(None).build();
/// assert!(ctx.min_index.is_none() && ctx.dispatch.is_none());
/// ```
pub struct SchedCtxBuilder<'a> {
    loads: &'a [u32],
    min_index: Option<&'a MinLoadIndex>,
    rng: &'a mut Pcg64,
    dispatch: Option<DispatchCtx>,
    avoid: Option<&'a [bool]>,
    slots: Option<SlotCtx<'a>>,
}

impl<'a> SchedCtxBuilder<'a> {
    /// Attach the router's incremental min-load index (`None` keeps the
    /// linear-scan fallback — bit-identical semantics, different cost).
    pub fn min_index(mut self, idx: Option<&'a MinLoadIndex>) -> Self {
        self.min_index = idx;
        self
    }

    /// Attach pull-dispatch context (`None` means push semantics).
    pub fn dispatch(mut self, d: Option<DispatchCtx>) -> Self {
        self.dispatch = d;
        self
    }

    /// Attach the router's avoid mask (dead ∪ draining workers).
    pub fn avoid(mut self, mask: Option<&'a [bool]>) -> Self {
        self.avoid = mask;
        self
    }

    /// Attach the slot-level load view (core-granular routers).
    pub fn slots(mut self, s: Option<SlotCtx<'a>>) -> Self {
        self.slots = s;
        self
    }

    /// Finish: every unset signal stays `None`.
    pub fn build(self) -> SchedCtx<'a> {
        SchedCtx {
            loads: self.loads,
            min_index: self.min_index,
            rng: self.rng,
            dispatch: self.dispatch,
            avoid: self.avoid,
            slots: self.slots,
        }
    }
}

impl<'a> SchedCtx<'a> {
    /// Context without an index (tests, the real-time server).
    pub fn new(loads: &'a [u32], rng: &'a mut Pcg64) -> Self {
        Self { loads, min_index: None, rng, dispatch: None, avoid: None, slots: None }
    }

    /// Start a [`SchedCtxBuilder`] over the mandatory state (the active
    /// load slice and the scheduler RNG stream).
    pub fn builder(loads: &'a [u32], rng: &'a mut Pcg64) -> SchedCtxBuilder<'a> {
        SchedCtxBuilder { loads, min_index: None, rng, dispatch: None, avoid: None, slots: None }
    }

    /// Attach pull-dispatch context (router pending-queue state).
    pub fn with_dispatch(mut self, d: DispatchCtx) -> Self {
        self.dispatch = Some(d);
        self
    }

    /// Attach the router's avoid set (dead ∪ draining workers).
    pub fn with_avoid(mut self, avoid: &'a [bool]) -> Self {
        self.avoid = Some(avoid);
        self
    }

    /// Attach the slot-level load view (core-granular routers).
    pub fn with_slots(mut self, slots: SlotCtx<'a>) -> Self {
        self.slots = Some(slots);
        self
    }

    /// Upgrade an `Assign`-style pick to [`Decision::AssignSlot`] when the
    /// slot view says worker `w` has a free warm-affine core for the
    /// decided function. The shared post-selection rule, so every
    /// scheduler pins warm cores identically.
    pub fn slotted(&self, w: WorkerId) -> Decision {
        if let Some(s) = self.slots {
            if let Some(&wf) = s.warm_free.get(w) {
                if wf >= 0 {
                    return Decision::AssignSlot(w, wf as u32);
                }
            }
        }
        Decision::Assign(w)
    }

    /// Whether worker `w` is eligible (not crash- or drain-marked).
    #[inline]
    pub fn allowed(&self, w: WorkerId) -> bool {
        match self.avoid {
            Some(mask) => !mask.get(w).copied().unwrap_or(false),
            None => true,
        }
    }

    /// Least-loaded worker, uniform random among ties — Algorithm 1's
    /// fallback rule and the whole of least-connections. With an index the
    /// reservoir runs over just the tie set (in ascending worker order, so
    /// the RNG stream and the winner match the linear scan exactly).
    ///
    /// When the router attached an [`SchedCtx::avoid`] mask, the rule is
    /// computed over eligible workers only (a crashed worker sits at load
    /// 0 and would otherwise soak up every fallback force-place). The
    /// masked scan draws the identical RNG sequence as the plain rule
    /// whenever the mask excludes nobody, and falls back to the
    /// unfiltered rule when it excludes everybody — the router re-routes
    /// or retries such doomed picks.
    pub fn least_loaded_random_tie(&mut self) -> WorkerId {
        if let Some(mask) = self.avoid {
            if let Some(w) = least_loaded_random_tie_avoiding(self.loads, mask, self.rng) {
                return w;
            }
        }
        match self.min_index {
            Some(idx) => {
                debug_assert_eq!(idx.active(), self.loads.len());
                idx.least_loaded_random_tie(self.rng)
            }
            None => least_loaded_random_tie(self.loads, self.rng),
        }
    }

    /// Least-loaded worker, lowest id among ties (classical JSQ).
    pub fn least_loaded_lowest_id(&self) -> WorkerId {
        match self.min_index {
            Some(idx) => {
                debug_assert_eq!(idx.active(), self.loads.len());
                idx.least_loaded_lowest_id()
            }
            None => {
                debug_assert!(!self.loads.is_empty());
                let mut best = 0usize;
                for (w, &l) in self.loads.iter().enumerate() {
                    if l < self.loads[best] {
                        best = w;
                    }
                }
                best
            }
        }
    }

    /// Total outstanding load across the active workers (CH-BL/RJ-CH's
    /// bounded-load capacity input). O(1) with an index.
    pub fn total_load(&self) -> u64 {
        match self.min_index {
            Some(idx) => {
                debug_assert_eq!(idx.active(), self.loads.len());
                idx.total_active_load()
            }
            None => self.loads.iter().map(|&l| l as u64).sum(),
        }
    }
}

/// A scheduling algorithm. Object-safe so the runtime can swap algorithms
/// from config (`scheduler.name`).
pub trait Scheduler: Send {
    /// Stable algorithm name (the config `scheduler.name` vocabulary).
    fn name(&self) -> &'static str;

    /// Route a request for function type `f` to a worker.
    fn select(&mut self, f: FunctionId, ctx: &mut SchedCtx) -> WorkerId;

    /// Dispatch-protocol entry point: assign, park, or refuse the request.
    ///
    /// The default is the **push adapter**: assign synchronously via
    /// [`Scheduler::select`], consuming the identical RNG stream — so
    /// every algorithm participates in the Decision protocol and
    /// `dispatch.mode = "push"` is bit-identical to the pre-protocol
    /// engine (enforced by `tests/determinism.rs`). Schedulers that
    /// understand late binding (Hiku) override this to return
    /// [`Decision::Enqueue`] when waiting briefly is likely to yield a
    /// warm start.
    ///
    /// When the router attaches a slot view ([`SchedCtx::slots`], only at
    /// `cores_per_worker > 1`), the adapter upgrades the pick to
    /// [`Decision::AssignSlot`] via [`SchedCtx::slotted`] — with the view
    /// absent it returns plain `Assign`, byte-identical to before.
    fn decide(&mut self, f: FunctionId, ctx: &mut SchedCtx) -> Decision {
        let w = self.select(f, ctx);
        ctx.slotted(w)
    }

    /// Pull hook: worker `w` just became idle holding a warm instance of
    /// `f`. The return value names the pending queue the router should
    /// let it claim from; [`Pull::Skip`] declines and the instance is
    /// advertised through [`Scheduler::on_complete`] instead. Only called
    /// under `dispatch.mode = "pull"`. The default claims the worker's
    /// own last function — a guaranteed warm start.
    fn on_worker_idle(&mut self, _w: WorkerId, f: FunctionId, _ctx: &mut SchedCtx) -> Pull {
        Pull::Function(f)
    }

    /// Worker `w` finished an execution of `f` (its sandbox is now idle).
    fn on_complete(&mut self, _w: WorkerId, _f: FunctionId, _ctx: &mut SchedCtx) {}

    /// Worker `w` evicted an idle instance of `f`.
    fn on_evict(&mut self, _w: WorkerId, _f: FunctionId) {}

    /// Auto-scaling: worker `w` (== previous worker count) joined the
    /// cluster. §II-C's motivation for consistent hashing is exactly this
    /// event — how many function->worker assignments get redistributed.
    fn on_worker_added(&mut self, _w: WorkerId) {}

    /// Auto-scaling: worker `w` (the highest id — scaling is LIFO) is
    /// draining out of the cluster and must no longer be selected.
    fn on_worker_removed(&mut self, _w: WorkerId) {}

    /// Diagnostic: total idle-queue entries (Hiku) or 0.
    fn idle_entries(&self) -> usize {
        0
    }
}

/// Power-of-d-style sampled approximation of [`least_loaded_random_tie`]:
/// draw `d` workers uniformly *with replacement* and keep the least
/// loaded, first-drawn among equals. O(d) time, zero allocation, exactly
/// `d` RNG draws — the `scheduler.tie_sample_d` variant that makes
/// least-connections viable at 100k workers, where the exact rule's
/// one-draw-per-tied-worker reservoir is Θ(tie set) by construction
/// (DESIGN.md §6). Not stream-compatible with the exact rule: enabling it
/// changes every subsequent tie-break draw.
pub fn sampled_least_loaded(loads: &[u32], rng: &mut Pcg64, d: usize) -> WorkerId {
    debug_assert!(!loads.is_empty() && d >= 1);
    let mut best = rng.index(loads.len());
    for _ in 1..d {
        let w = rng.index(loads.len());
        if loads[w] < loads[best] {
            best = w;
        }
    }
    best
}

/// Avoid-aware variant of [`least_loaded_random_tie`]: least-loaded among
/// workers the mask permits, uniform among ties. Returns `None` when the
/// mask excludes every worker (the caller falls back to the unfiltered
/// rule). Draws the identical RNG sequence as the plain rule when the
/// mask excludes nobody.
pub fn least_loaded_random_tie_avoiding(
    loads: &[u32],
    mask: &[bool],
    rng: &mut Pcg64,
) -> Option<WorkerId> {
    let blocked = |w: usize| mask.get(w).copied().unwrap_or(false);
    let mut min = u32::MAX;
    for (w, &l) in loads.iter().enumerate() {
        if !blocked(w) && l < min {
            min = l;
        }
    }
    if min == u32::MAX {
        return None;
    }
    let mut chosen = 0usize;
    let mut seen = 0u64;
    for (w, &l) in loads.iter().enumerate() {
        if l == min && !blocked(w) {
            seen += 1;
            if rng.next_bounded(seen) == 0 {
                chosen = w;
            }
        }
    }
    Some(chosen)
}

/// Least-loaded worker with uniform random tie-breaking — the fallback rule
/// of Algorithm 1 (lines 8-11) and the whole of least-connections.
pub fn least_loaded_random_tie(loads: &[u32], rng: &mut Pcg64) -> WorkerId {
    debug_assert!(!loads.is_empty());
    let min = *loads.iter().min().unwrap();
    // Reservoir-sample uniformly among ties in one pass.
    let mut chosen = 0usize;
    let mut seen = 0u64;
    for (w, &l) in loads.iter().enumerate() {
        if l == min {
            seen += 1;
            if rng.next_bounded(seen) == 0 {
                chosen = w;
            }
        }
    }
    chosen
}

/// Construct a scheduler by config name. `hiku+<name>` builds Hiku with a
/// custom fallback (§IV-B ablation), e.g. `hiku+random`, `hiku+ch-bl`.
pub fn make_scheduler(cfg: &SchedulerConfig, workers: usize) -> Result<Box<dyn Scheduler>, String> {
    if let Some(fb_name) = cfg.name.strip_prefix("hiku+") {
        let fb_cfg = SchedulerConfig { name: fb_name.to_string(), ..cfg.clone() };
        if fb_name.starts_with("hiku") {
            return Err("hiku fallback cannot itself be hiku".into());
        }
        let fallback = make_scheduler(&fb_cfg, workers)?;
        return Ok(Box::new(Hiku::with_fallback(workers, fallback)));
    }
    let s: Box<dyn Scheduler> = match cfg.name.as_str() {
        "hiku" | "pull-based" | "pull" => {
            Box::new(Hiku::new(workers).with_tie_sample(cfg.tie_sample_d))
        }
        "least-connections" | "lc" => {
            Box::new(LeastConnections::new().with_tie_sample(cfg.tie_sample_d))
        }
        "random" => Box::new(RandomSched::new(workers)),
        "hash-mod" => Box::new(HashMod::new(workers)),
        "consistent" | "ch" => Box::new(Consistent::new(workers, cfg.vnodes)),
        "ch-bl" => Box::new(ChBl::new(workers, cfg.vnodes, cfg.ch_bl_c)),
        "rj-ch" => Box::new(RjCh::new(workers, cfg.vnodes, cfg.ch_bl_c)),
        "jsq" => Box::new(Jsq::new()),
        "power-of-d" | "pod" => Box::new(PowerOfD::new(workers, cfg.power_d)),
        other => return Err(format!("unknown scheduler '{other}'")),
    };
    Ok(s)
}

/// The paper's evaluated schedulers (its contribution + three baselines).
pub const PAPER_SCHEDULERS: [&str; 4] = ["hiku", "ch-bl", "random", "least-connections"];
/// Every scheduler the crate implements (paper set + §II/§VI ablations).
pub const ALL_SCHEDULERS: [&str; 9] = [
    "hiku",
    "least-connections",
    "random",
    "hash-mod",
    "consistent",
    "ch-bl",
    "rj-ch",
    "jsq",
    "power-of-d",
];
/// Composite (`hiku+<fallback>`) registry names covered by the ablation
/// configs — regression-guarded alongside [`ALL_SCHEDULERS`] in the
/// registry and determinism tests.
pub const COMPOSITE_SCHEDULERS: [&str; 2] = ["hiku+random", "hiku+ch-bl"];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn avoid_mask_steers_fallback_rule() {
        // Worker 0 is dead at load 0 — without the mask it wins every
        // time; with the mask the rule must pick among the living.
        let loads = [0u32, 2, 1, 1];
        let mut rng = Pcg64::new(7);
        let mask = [true, false, false, false];
        for _ in 0..32 {
            let w = least_loaded_random_tie_avoiding(&loads, &mask, &mut rng).unwrap();
            assert!(w == 2 || w == 3, "picked avoided or overloaded worker {w}");
        }
        // All-blocked mask: None, so callers can fall back.
        assert_eq!(
            least_loaded_random_tie_avoiding(&loads, &[true; 4], &mut rng),
            None
        );
        // Empty mask draws the identical stream as the plain rule.
        let mut a = Pcg64::new(9);
        let mut b = Pcg64::new(9);
        for _ in 0..16 {
            assert_eq!(
                least_loaded_random_tie_avoiding(&loads, &[false; 4], &mut a),
                Some(least_loaded_random_tie(&loads, &mut b))
            );
        }
    }

    #[test]
    fn registry_constructs_all() {
        for name in ALL_SCHEDULERS.iter().chain(COMPOSITE_SCHEDULERS.iter()) {
            let cfg = SchedulerConfig { name: (*name).into(), ..Default::default() };
            let s = make_scheduler(&cfg, 5).unwrap();
            assert!(!s.name().is_empty());
        }
        let bad = SchedulerConfig { name: "bogus".into(), ..Default::default() };
        assert!(make_scheduler(&bad, 5).is_err());
        // Composite fallbacks must not recurse.
        let rec = SchedulerConfig { name: "hiku+hiku".into(), ..Default::default() };
        assert!(make_scheduler(&rec, 5).is_err());
    }

    /// The default `decide` is the push adapter: for every registry entry
    /// it must return `Assign` with the exact worker `select` would pick,
    /// consuming the identical RNG stream.
    #[test]
    fn decide_default_is_push_adapter() {
        for name in ALL_SCHEDULERS.iter().chain(COMPOSITE_SCHEDULERS.iter()) {
            let cfg = SchedulerConfig { name: (*name).into(), ..Default::default() };
            let mut a = make_scheduler(&cfg, 6).unwrap();
            let mut b = make_scheduler(&cfg, 6).unwrap();
            let mut rng_a = Pcg64::new(17);
            let mut rng_b = Pcg64::new(17);
            let loads = [2u32, 0, 1, 0, 3, 1];
            for f in 0..30 {
                let d = {
                    let mut ctx = SchedCtx::new(&loads, &mut rng_a);
                    a.decide(f, &mut ctx)
                };
                let w = {
                    let mut ctx = SchedCtx::new(&loads, &mut rng_b);
                    b.select(f, &mut ctx)
                };
                assert_eq!(d, Decision::Assign(w), "{name}: decide != push adapter");
            }
            assert_eq!(rng_a.next_u64(), rng_b.next_u64(), "{name}: RNG streams diverged");
        }
    }

    /// With a slot view attached, the default adapter upgrades its pick to
    /// `AssignSlot` exactly when the selected worker has a free warm-affine
    /// core — and the selection itself (worker + RNG stream) is unchanged.
    #[test]
    fn decide_upgrades_to_assign_slot_with_slot_view() {
        let loads = [2u32, 0, 1, 0, 3, 1];
        let free = [1u32, 2, 0, 2, 1, 1];
        for name in ALL_SCHEDULERS {
            let cfg = SchedulerConfig { name: name.into(), ..Default::default() };
            let mut a = make_scheduler(&cfg, 6).unwrap();
            let mut b = make_scheduler(&cfg, 6).unwrap();
            let mut rng_a = Pcg64::new(23);
            let mut rng_b = Pcg64::new(23);
            for f in 0..30 {
                let w = {
                    let mut ctx = SchedCtx::new(&loads, &mut rng_b);
                    b.select(f, &mut ctx)
                };
                // Warm view: every worker has slot 1 warm-affine and free.
                let warm_free = [1i32; 6];
                let d = {
                    let mut ctx = SchedCtx::new(&loads, &mut rng_a)
                        .with_slots(SlotCtx { free: &free, warm_free: &warm_free });
                    a.decide(f, &mut ctx)
                };
                assert_eq!(d, Decision::AssignSlot(w, 1), "{name}: warm core not pinned");
            }
            assert_eq!(rng_a.next_u64(), rng_b.next_u64(), "{name}: RNG streams diverged");
            // No warm-affine slot anywhere: plain Assign.
            let warm_free = [-1i32; 6];
            let d = {
                let mut ctx = SchedCtx::new(&loads, &mut rng_a)
                    .with_slots(SlotCtx { free: &free, warm_free: &warm_free });
                a.decide(0, &mut ctx)
            };
            assert!(matches!(d, Decision::Assign(_)), "{name}: expected plain Assign");
        }
    }

    #[test]
    fn least_loaded_picks_min() {
        let mut rng = Pcg64::new(1);
        let loads = [3u32, 1, 2, 1, 5];
        for _ in 0..100 {
            let w = least_loaded_random_tie(&loads, &mut rng);
            assert!(w == 1 || w == 3);
        }
    }

    #[test]
    fn least_loaded_tie_break_uniform() {
        let mut rng = Pcg64::new(2);
        let loads = [1u32, 1, 1, 1];
        let mut counts = [0usize; 4];
        let n = 40_000;
        for _ in 0..n {
            counts[least_loaded_random_tie(&loads, &mut rng)] += 1;
        }
        for &c in &counts {
            assert!((c as f64 / n as f64 - 0.25).abs() < 0.02, "{counts:?}");
        }
    }

    /// An indexed context and a plain-slice context must produce identical
    /// selections AND consume identical RNG streams, for every helper the
    /// schedulers route through.
    #[test]
    fn indexed_ctx_matches_scan_ctx() {
        let mut idx = MinLoadIndex::new(6);
        let loads = [2u32, 0, 1, 0, 3, 0];
        for (w, &l) in loads.iter().enumerate() {
            for _ in 0..l {
                idx.inc(w);
            }
        }
        let mut rng_a = Pcg64::new(11);
        let mut rng_b = Pcg64::new(11);
        for _ in 0..200 {
            let mut with_idx = SchedCtx {
                loads: &loads,
                min_index: Some(&idx),
                rng: &mut rng_a,
                dispatch: None,
                avoid: None,
                slots: None,
            };
            let a = with_idx.least_loaded_random_tie();
            let ta = with_idx.total_load();
            let ja = with_idx.least_loaded_lowest_id();
            let mut plain = SchedCtx::new(&loads, &mut rng_b);
            let b = plain.least_loaded_random_tie();
            let tb = plain.total_load();
            let jb = plain.least_loaded_lowest_id();
            assert_eq!(a, b, "tie-break diverged");
            assert_eq!(ta, tb, "total diverged");
            assert_eq!(ja, jb, "jsq rule diverged");
        }
        assert_eq!(rng_a.next_u64(), rng_b.next_u64(), "RNG streams diverged");
    }

    #[test]
    fn sampled_tie_break_is_bounded_and_load_aware() {
        let mut rng = Pcg64::new(9);
        // d = 1 is plain uniform random (no load awareness by design).
        let loads = [100u32, 0, 0, 0];
        let mut picked0 = false;
        for _ in 0..200 {
            let w = sampled_least_loaded(&loads, &mut rng, 1);
            assert!(w < 4);
            picked0 |= w == 0;
        }
        assert!(picked0, "d=1 must sometimes pick the loaded worker");
        // d = 4 with replacement: picking worker 0 needs all 4 draws to
        // land on it — p = (1/4)^4; over 2000 trials a handful at most.
        let mut hits = 0;
        for _ in 0..2000 {
            if sampled_least_loaded(&loads, &mut rng, 4) == 0 {
                hits += 1;
            }
        }
        assert!(hits < 40, "overloaded worker picked {hits}/2000 times");
    }

    #[test]
    fn tie_sample_config_reaches_lc_and_hiku_fallback() {
        // With a huge d the sample almost surely covers the single idle
        // worker, so the sampled variant still finds it.
        let cfg = SchedulerConfig {
            name: "least-connections".into(),
            tie_sample_d: 64,
            ..Default::default()
        };
        let mut s = make_scheduler(&cfg, 8).unwrap();
        let mut rng = Pcg64::new(10);
        let mut loads = [5u32; 8];
        loads[3] = 0;
        // A 64-draw sample misses the idle worker with p = (7/8)^64 ≈
        // 3e-4, so near-all selections must land on it.
        let mut hits = 0;
        for _ in 0..50 {
            let mut ctx = SchedCtx::new(&loads, &mut rng);
            if s.select(0, &mut ctx) == 3 {
                hits += 1;
            }
        }
        assert!(hits >= 45, "sampled LC found the idle worker only {hits}/50 times");
        // Hiku with an empty PQ_f takes the sampled fallback path.
        let cfg = SchedulerConfig { name: "hiku".into(), tie_sample_d: 64, ..Default::default() };
        let mut h = make_scheduler(&cfg, 8).unwrap();
        let mut hits = 0;
        for _ in 0..50 {
            let mut ctx = SchedCtx::new(&loads, &mut rng);
            if h.select(0, &mut ctx) == 3 {
                hits += 1;
            }
        }
        assert!(hits >= 45, "sampled hiku fallback found the idle worker only {hits}/50 times");
    }

    #[test]
    fn all_schedulers_select_in_range() {
        let mut rng = Pcg64::new(3);
        for name in ALL_SCHEDULERS {
            let cfg = SchedulerConfig { name: name.into(), ..Default::default() };
            let mut s = make_scheduler(&cfg, 7).unwrap();
            let loads = vec![0u32; 7];
            for f in 0..40 {
                let mut ctx = SchedCtx::new(&loads, &mut rng);
                let w = s.select(f, &mut ctx);
                assert!(w < 7, "{name} selected out-of-range worker {w}");
            }
        }
    }
}
