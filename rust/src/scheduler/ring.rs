//! Consistent-hashing schedulers: the hash ring (§II-C, Fig 3), plain
//! consistent hashing, consistent hashing with bounded loads (CH-BL [26],
//! the paper's strongest baseline with c = 1.25), and random jumps for
//! consistent hashing (RJ-CH [27], the cascaded-overflow fix).

use super::{SchedCtx, Scheduler, WorkerId};
use crate::util::hashing;
use crate::util::rng::Pcg64;
use crate::workload::spec::FunctionId;

/// The hash ring: each worker owns `vnodes` points on a u64 ring; a key is
/// served by the first worker point clockwise from the key's hash.
#[derive(Clone, Debug)]
pub struct HashRing {
    /// (point, worker) sorted by point.
    points: Vec<(u64, WorkerId)>,
    workers: usize,
    /// Per-worker visit stamps for `lookup_where` (replaces the seed's
    /// per-call `vec![false; workers]` allocation — at 10k+ workers that
    /// alloc+memset dominated every CH-BL decision).
    seen_stamp: Vec<u32>,
    stamp: u32,
}

impl HashRing {
    /// A ring over `workers` workers with `vnodes` points each.
    pub fn new(workers: usize, vnodes: usize) -> Self {
        assert!(workers > 0 && vnodes > 0);
        // Bulk build: generate every point, sort once. The seed sorted
        // after each worker (O(workers² · vnodes · log) at construction —
        // prohibitive at 10k+ workers); the final sorted vector is
        // identical since sorting is order-insensitive.
        let mut points = Vec::with_capacity(workers * vnodes);
        for w in 0..workers {
            Self::worker_points(w, vnodes, &mut points);
        }
        points.sort_unstable();
        Self { points, workers, seen_stamp: vec![0; workers], stamp: 0 }
    }

    fn worker_points(w: WorkerId, vnodes: usize, out: &mut Vec<(u64, WorkerId)>) {
        let base = hashing::mix64(0x57_u64.wrapping_mul(w as u64 + 1));
        for v in 0..vnodes {
            out.push((hashing::combine(base, v as u64), w));
        }
    }

    /// Add a worker's virtual nodes (auto-scaling up).
    pub fn add_worker(&mut self, w: WorkerId, vnodes: usize) {
        Self::worker_points(w, vnodes, &mut self.points);
        self.points.sort_unstable();
        self.workers = self.workers.max(w + 1);
    }

    /// Remove a worker's virtual nodes (auto-scaling down).
    pub fn remove_worker(&mut self, w: WorkerId) {
        self.points.retain(|&(_, pw)| pw != w);
    }

    /// True when the ring holds no points (no workers).
    pub fn is_empty(&self) -> bool {
        self.points.is_empty()
    }

    /// Index of the first ring point clockwise from `hash`.
    fn start_index(&self, hash: u64) -> usize {
        match self.points.binary_search_by(|&(p, _)| p.cmp(&hash)) {
            Ok(i) => i,
            Err(i) => i % self.points.len(),
        }
    }

    /// The worker owning `key` (plain consistent hashing).
    pub fn lookup(&self, key: u64) -> WorkerId {
        self.points[self.start_index(key)].1
    }

    /// Walk clockwise from `key`, returning the first worker accepted by
    /// `ok`. Falls back to the primary owner if nobody accepts (all
    /// overloaded — bounded-load threshold guarantees this cannot happen
    /// when capacity is computed from the live total, but keep it total).
    pub fn lookup_where<F: FnMut(WorkerId) -> bool>(&mut self, key: u64, mut ok: F) -> WorkerId {
        let start = self.start_index(key);
        let n = self.points.len();
        if self.seen_stamp.len() < self.workers {
            self.seen_stamp.resize(self.workers, 0);
        }
        self.stamp = self.stamp.wrapping_add(1);
        if self.stamp == 0 {
            // Stamp wrapped: reset the scratch once per ~4 billion calls.
            self.seen_stamp.fill(0);
            self.stamp = 1;
        }
        let mut seen = 0usize;
        let mut i = start;
        loop {
            let w = self.points[i].1;
            if self.seen_stamp[w] != self.stamp {
                if ok(w) {
                    return w;
                }
                self.seen_stamp[w] = self.stamp;
                seen += 1;
                if seen == self.workers {
                    return self.points[start].1;
                }
            }
            i = (i + 1) % n;
        }
    }

    /// Distinct workers in clockwise order from `key` (for tests).
    pub fn walk(&mut self, key: u64) -> Vec<WorkerId> {
        let mut order = Vec::new();
        self.lookup_where(key, |w| {
            order.push(w);
            false
        });
        order
    }
}

/// Key for a function type: a stable hash of its id. Real deployments hash
/// the function *name*; ids are bijective with names in the registry, and
/// mix64 gives the same uniformity.
#[inline]
pub fn function_key(f: FunctionId) -> u64 {
    hashing::mix64(0x9E37_0000_0000_0000 ^ f as u64)
}

/// CH-BL capacity: ceil(c * (inflight + 1) / workers) — each worker may
/// hold at most a factor c above the average load, counting the request
/// being placed ([26]'s bounded-load invariant).
#[inline]
pub fn chbl_capacity(c: f64, total_inflight: u64, workers: usize) -> u32 {
    let avg = (total_inflight + 1) as f64 / workers as f64;
    (c * avg).ceil() as u32
}

/// Plain consistent hashing (the common FaaS scheduler, §II-C).
#[derive(Clone, Debug)]
pub struct Consistent {
    ring: HashRing,
    vnodes: usize,
}

impl Consistent {
    /// Plain consistent hashing over `workers` workers.
    pub fn new(workers: usize, vnodes: usize) -> Self {
        Self { ring: HashRing::new(workers, vnodes), vnodes }
    }
}

impl Scheduler for Consistent {
    fn name(&self) -> &'static str {
        "consistent"
    }

    fn select(&mut self, f: FunctionId, _ctx: &mut SchedCtx) -> WorkerId {
        self.ring.lookup(function_key(f))
    }

    fn on_worker_added(&mut self, w: WorkerId) {
        self.ring.add_worker(w, self.vnodes);
    }

    fn on_worker_removed(&mut self, w: WorkerId) {
        self.ring.remove_worker(w);
    }
}

/// Consistent hashing with bounded loads (CH-BL [26]); threshold c = 1.25
/// per the paper. Overloaded workers overflow to the next clockwise
/// non-overloaded worker — which §II-C notes can cascade under load.
#[derive(Clone, Debug)]
pub struct ChBl {
    ring: HashRing,
    c: f64,
    workers: usize,
    vnodes: usize,
    /// Overflow decisions taken (diagnostics for the cascade ablation).
    pub overflows: u64,
}

impl ChBl {
    /// CH-BL with load threshold `c` (the paper uses 1.25).
    pub fn new(workers: usize, vnodes: usize, c: f64) -> Self {
        assert!(c >= 1.0);
        Self { ring: HashRing::new(workers, vnodes), c, workers, vnodes, overflows: 0 }
    }
}

impl Scheduler for ChBl {
    fn name(&self) -> &'static str {
        "ch-bl"
    }

    fn select(&mut self, f: FunctionId, ctx: &mut SchedCtx) -> WorkerId {
        // O(1) total via the router's index (falls back to a slice sum).
        let total = ctx.total_load();
        let cap = chbl_capacity(self.c, total, self.workers);
        let primary = self.ring.lookup(function_key(f));
        let w = self.ring.lookup_where(function_key(f), |w| ctx.loads[w] < cap);
        if w != primary {
            self.overflows += 1;
        }
        w
    }

    fn on_worker_added(&mut self, w: WorkerId) {
        self.ring.add_worker(w, self.vnodes);
        self.workers = self.workers.max(w + 1);
    }

    fn on_worker_removed(&mut self, w: WorkerId) {
        self.ring.remove_worker(w);
        self.workers = self.workers.min(w).max(1);
    }
}

/// Random jumps for consistent hashing (RJ-CH [27]): like CH-BL, but when
/// the primary worker is overloaded, jump to a uniformly random
/// non-overloaded worker instead of walking clockwise — avoiding cascaded
/// overflows at the cost of locality.
#[derive(Clone, Debug)]
pub struct RjCh {
    ring: HashRing,
    c: f64,
    workers: usize,
    vnodes: usize,
    /// Random jumps taken (diagnostics for the cascade ablation).
    pub jumps: u64,
}

impl RjCh {
    /// RJ-CH with load threshold `c`.
    pub fn new(workers: usize, vnodes: usize, c: f64) -> Self {
        assert!(c >= 1.0);
        Self { ring: HashRing::new(workers, vnodes), c, workers, vnodes, jumps: 0 }
    }

    fn random_underloaded(&self, cap: u32, loads: &[u32], rng: &mut Pcg64) -> Option<WorkerId> {
        // Reservoir-sample uniformly among non-overloaded workers.
        let mut chosen = None;
        let mut seen = 0u64;
        for (w, &l) in loads.iter().enumerate() {
            if l < cap {
                seen += 1;
                if rng.next_bounded(seen) == 0 {
                    chosen = Some(w);
                }
            }
        }
        chosen
    }
}

impl Scheduler for RjCh {
    fn name(&self) -> &'static str {
        "rj-ch"
    }

    fn select(&mut self, f: FunctionId, ctx: &mut SchedCtx) -> WorkerId {
        let total = ctx.total_load();
        let cap = chbl_capacity(self.c, total, self.workers);
        let primary = self.ring.lookup(function_key(f));
        if ctx.loads[primary] < cap {
            return primary;
        }
        self.jumps += 1;
        self.random_underloaded(cap, ctx.loads, ctx.rng).unwrap_or(primary)
    }

    fn on_worker_added(&mut self, w: WorkerId) {
        self.ring.add_worker(w, self.vnodes);
        self.workers = self.workers.max(w + 1);
    }

    fn on_worker_removed(&mut self, w: WorkerId) {
        self.ring.remove_worker(w);
        self.workers = self.workers.min(w).max(1);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::hashing;
    use crate::util::prop::{check, PropConfig};

    #[test]
    fn lookup_stable() {
        let ring = HashRing::new(5, 100);
        for f in 0..100 {
            assert_eq!(ring.lookup(function_key(f)), ring.lookup(function_key(f)));
        }
    }

    #[test]
    fn ring_balances_keys_roughly() {
        let ring = HashRing::new(5, 200);
        let mut counts = [0usize; 5];
        for f in 0..10_000 {
            counts[ring.lookup(function_key(f))] += 1;
        }
        for &c in &counts {
            // Within ±40% of perfect balance with 200 vnodes.
            assert!((1_200..=2_800).contains(&c), "key spread skewed: {counts:?}");
        }
    }

    #[test]
    fn removing_worker_only_remaps_its_keys() {
        // §II-C's minimal-redistribution property (Fig 3): keys not owned
        // by the removed worker keep their assignment.
        let ring_before = HashRing::new(6, 100);
        let mut ring_after = ring_before.clone();
        ring_after.remove_worker(3);
        let mut remapped = 0;
        for f in 0..5_000 {
            let before = ring_before.lookup(function_key(f));
            let after = ring_after.lookup(function_key(f));
            if before != 3 {
                assert_eq!(before, after, "key {f} moved although its worker stayed");
            } else {
                assert_ne!(after, 3);
                remapped += 1;
            }
        }
        // Roughly 1/6 of keys lived on worker 3.
        assert!((500..1200).contains(&remapped), "remapped {remapped}");
    }

    #[test]
    fn hash_mod_redistributes_many_more_keys_than_ring() {
        // The motivation for consistent hashing (§II-C): compare keys moved
        // when going from 6 to 5 workers.
        let moved_mod = (0..5_000u64)
            .filter(|&f| {
                (hashing::mix64(f) % 6) != (hashing::mix64(f) % 5)
            })
            .count();
        let ring_before = HashRing::new(6, 100);
        let mut ring_after = ring_before.clone();
        ring_after.remove_worker(5);
        let moved_ring = (0..5_000)
            .filter(|&f| ring_before.lookup(function_key(f)) != ring_after.lookup(function_key(f)))
            .count();
        assert!(
            moved_mod > 3 * moved_ring,
            "mod moved {moved_mod}, ring moved {moved_ring}"
        );
    }

    #[test]
    fn chbl_respects_capacity() {
        let mut s = ChBl::new(4, 100, 1.25);
        let mut rng = Pcg64::new(1);
        // Worker loads: primary owner of f=0 will be checked against cap.
        let loads = [10u32, 0, 0, 0];
        let total = 10u64;
        let cap = chbl_capacity(1.25, total, 4);
        assert_eq!(cap, 4); // ceil(1.25 * 11/4) = ceil(3.4375)
        let mut ctx = SchedCtx::new(&loads, &mut rng);
        let w = s.select(0, &mut ctx);
        assert_ne!(w, 0, "overloaded worker must be skipped (load 10 >= cap {cap})");
    }

    #[test]
    fn chbl_cascade_walks_clockwise() {
        let mut s = ChBl::new(4, 100, 1.25);
        let mut rng = Pcg64::new(2);
        let key = function_key(7);
        let order = s.ring.walk(key);
        // Overload the first two workers in clockwise order.
        let mut loads = [0u32; 4];
        loads[order[0]] = 100;
        loads[order[1]] = 100;
        let mut ctx = SchedCtx::new(&loads, &mut rng);
        let w = s.select(7, &mut ctx);
        assert_eq!(w, order[2], "must cascade to the next non-overloaded clockwise worker");
        assert_eq!(s.overflows, 1);
    }

    #[test]
    fn rjch_jumps_to_random_underloaded() {
        let mut s = RjCh::new(5, 100, 1.25);
        let mut rng = Pcg64::new(3);
        let key_owner = {
            let loads = [0u32; 5];
            let mut ctx = SchedCtx::new(&loads, &mut rng);
            s.select(11, &mut ctx)
        };
        // Overload the owner; the jump target must be uniform over others.
        let mut loads = [0u32; 5];
        loads[key_owner] = 100;
        let mut counts = [0usize; 5];
        for _ in 0..20_000 {
            let mut ctx = SchedCtx::new(&loads, &mut rng);
            counts[s.select(11, &mut ctx)] += 1;
        }
        assert_eq!(counts[key_owner], 0);
        for (w, &c) in counts.iter().enumerate() {
            if w != key_owner {
                assert!((c as f64 / 20_000.0 - 0.25).abs() < 0.03, "{counts:?}");
            }
        }
    }

    #[test]
    fn all_overloaded_falls_back_to_primary() {
        let mut s = ChBl::new(3, 50, 1.0);
        let mut rng = Pcg64::new(4);
        let loads = [50u32, 50, 50];
        let mut ctx = SchedCtx::new(&loads, &mut rng);
        let w = s.select(3, &mut ctx);
        assert!(w < 3);
    }

    /// Property: ring monotonicity — adding a worker only steals keys (no
    /// key moves between two pre-existing workers).
    #[test]
    fn prop_ring_monotone_under_growth() {
        check("ring-monotone", PropConfig { cases: 60, max_size: 12, ..Default::default() }, |rng, size| {
            let workers = 2 + size % 10;
            let vnodes = 20 + rng.index(80);
            let ring_before = HashRing::new(workers, vnodes);
            let mut ring_after = ring_before.clone();
            ring_after.add_worker(workers, vnodes);
            for f in 0..500 {
                let b = ring_before.lookup(function_key(f));
                let a = ring_after.lookup(function_key(f));
                prop_assert!(
                    a == b || a == workers,
                    "key {} moved {} -> {} (not to the new worker)",
                    f,
                    b,
                    a
                );
            }
            Ok(())
        });
    }

    /// Property: CH-BL never routes to a worker at/above capacity while any
    /// worker is below it.
    #[test]
    fn prop_chbl_bounded() {
        check("chbl-bounded", PropConfig { cases: 120, ..Default::default() }, |rng, size| {
            let workers = 2 + rng.index(8);
            let mut s = ChBl::new(workers, 64, 1.25);
            let loads: Vec<u32> =
                (0..workers).map(|_| rng.next_bounded(size as u64 + 1) as u32).collect();
            let total: u64 = loads.iter().map(|&l| l as u64).sum();
            let cap = chbl_capacity(1.25, total, workers);
            let any_under = loads.iter().any(|&l| l < cap);
            for f in 0..30 {
                let mut ctx = SchedCtx::new(&loads, rng);
                let w = s.select(f, &mut ctx);
                if any_under {
                    prop_assert!(
                        loads[w] < cap,
                        "routed to overloaded worker {} (load {}, cap {})",
                        w,
                        loads[w],
                        cap
                    );
                }
            }
            Ok(())
        });
    }
}
