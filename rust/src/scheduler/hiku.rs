//! Hiku: pull-based scheduling (Algorithm 1 of the paper).
//!
//! The core idea is to decouple worker selection from task assignment:
//! after finishing an execution of function `f`, a worker *enqueues itself*
//! in the idle queue `PQ_f` (the pull mechanism). A request for `f`
//! dequeues the least-loaded enqueued worker — a warm start with locality,
//! achieved without consistent hashing. If `PQ_f` is empty, the fallback
//! mechanism routes to the least-connections worker with random
//! tie-breaking. Sandbox destruction sends an eviction notification that
//! removes the first matching entry from `PQ_f`.
//!
//! ## Priority-queue representation
//!
//! `PQ_f` is "sorted by the number of active connections" (paper, Fig 8).
//! Since worker loads change continuously between enqueue and dequeue, a
//! heap keyed on enqueue-time loads would decay stale. We therefore store
//! `PQ_f` as a multiset of worker ids and resolve "least loaded" against
//! the *live* load vector at dequeue time — O(|PQ_f|) per dequeue with
//! |PQ_f| bounded by idle instances of `f` cluster-wide (a few dozen at
//! paper scale). This matches the algorithm's semantics exactly (the sort
//! key is the current load) while staying allocation-free on the hot path.
//!
//! ### Multiset invariant
//!
//! Because `PQ_f` is a multiset whose "least loaded" is resolved against
//! live loads at dequeue time, the *order* of entries inside the backing
//! `Vec` carries no meaning — only the multiset of worker ids does. All
//! mutations are therefore free to use `swap_remove` (O(1)) instead of
//! order-preserving `remove` (O(n) shift): eviction removes *a* matching
//! entry, and dequeue removes *a* minimum-load entry. The only observable
//! effect is which of several equally-loaded enqueued workers wins a tie,
//! which the algorithm leaves unspecified; under a fixed seed the choice
//! is still fully deterministic.

use super::{Decision, SchedCtx, Scheduler, WorkerId};
use crate::workload::spec::FunctionId;

/// The pull-based scheduler (Algorithm 1). See the module docs.
pub struct Hiku {
    /// PQ_f: one multiset of enqueued workers per function type. Indexed
    /// densely by FunctionId; grows on demand.
    idle_queues: Vec<Vec<WorkerId>>,
    workers: usize,
    /// Fallback used when PQ_f is empty. The paper (§IV-B): "The fallback
    /// mechanism can be changed to other scheduling algorithms". `None` =
    /// the paper's default (least connections, random tie-break).
    fallback: Option<Box<dyn Scheduler>>,
    /// 0 = the exact default fallback; d ≥ 1 = power-of-d sampled variant
    /// (`scheduler.tie_sample_d`). Ignored when a custom `fallback` is
    /// installed (the custom scheduler owns its own tie policy).
    sample_d: usize,
    // ---- diagnostics ----
    /// Requests served through the pull mechanism (PQ_f dequeues).
    pub pulls: u64,
    /// Requests served through the fallback mechanism.
    pub fallbacks: u64,
    /// `Enqueue` decisions returned (pull dispatch). Counts what the
    /// scheduler *asked for*: the router may still convert an enqueue
    /// into a reject at `dispatch.queue_cap`, so this can exceed the
    /// router's metered `RunMetrics::enqueued` by the reject count.
    pub enqueues: u64,
    /// Eviction notifications received.
    pub evict_notifications: u64,
}

impl Hiku {
    /// Hiku with the paper's default fallback (least connections).
    pub fn new(workers: usize) -> Self {
        Self {
            idle_queues: Vec::new(),
            workers,
            fallback: None,
            sample_d: 0,
            pulls: 0,
            fallbacks: 0,
            enqueues: 0,
            evict_notifications: 0,
        }
    }

    /// Hiku with a custom fallback scheduler (ablation §IV-B).
    pub fn with_fallback(workers: usize, fallback: Box<dyn Scheduler>) -> Self {
        let mut h = Self::new(workers);
        h.fallback = Some(fallback);
        h
    }

    /// Use the power-of-d sampled tie-break in the default fallback when
    /// `d >= 1` (0 keeps the exact uniform-among-ties rule).
    pub fn with_tie_sample(mut self, d: usize) -> Self {
        self.sample_d = d;
        self
    }

    fn queue_mut(&mut self, f: FunctionId) -> &mut Vec<WorkerId> {
        if f >= self.idle_queues.len() {
            self.idle_queues.resize_with(f + 1, Vec::new);
        }
        &mut self.idle_queues[f]
    }

    /// Current size of `PQ_f` (idle advertisements for `f`).
    pub fn queue_len(&self, f: FunctionId) -> usize {
        self.idle_queues.get(f).map(|q| q.len()).unwrap_or(0)
    }

    /// Dequeue the enqueued worker with the lowest current load.
    fn dequeue_least_loaded(&mut self, f: FunctionId, loads: &[u32]) -> Option<WorkerId> {
        let q = self.queue_mut(f);
        if q.is_empty() {
            return None;
        }
        let mut best = 0usize;
        for i in 1..q.len() {
            if loads[q[i]] < loads[q[best]] {
                best = i;
            }
        }
        Some(q.swap_remove(best))
    }

    /// The fallback mechanism (Algorithm 1, lines 7-11): least
    /// connections with random tie-breaking by default, a custom
    /// scheduler or the sampled variant per configuration (§IV-B).
    fn fallback_select(&mut self, f: FunctionId, ctx: &mut SchedCtx) -> WorkerId {
        match &mut self.fallback {
            Some(fb) => fb.select(f, ctx),
            None if self.sample_d > 0 => {
                super::sampled_least_loaded(ctx.loads, ctx.rng, self.sample_d)
            }
            None => ctx.least_loaded_random_tie(),
        }
    }
}

impl Scheduler for Hiku {
    fn name(&self) -> &'static str {
        "hiku"
    }

    fn select(&mut self, f: FunctionId, ctx: &mut SchedCtx) -> WorkerId {
        // Pull mechanism (Algorithm 1, lines 2-5).
        if let Some(w) = self.dequeue_least_loaded(f, ctx.loads) {
            self.pulls += 1;
            return w;
        }
        // Fallback mechanism (lines 7-11): least connections, random ties
        // by default; configurable per §IV-B. The ctx helper uses the
        // router's incremental min-load index when one is attached.
        self.fallbacks += 1;
        self.fallback_select(f, ctx)
    }

    /// The pull protocol: dequeue from `PQ_f` when a warm worker is
    /// advertised; otherwise park the request if an execution of `f` is
    /// in flight (a warm instance will free up soon — the late-binding
    /// window); otherwise fall back immediately, exactly like push mode.
    /// Without dispatch context this *is* the push adapter.
    /// Every assignment funnels through [`SchedCtx::slotted`], so under a
    /// core-granular router (slot view attached) a pick with a free
    /// warm-affine core is pinned via [`Decision::AssignSlot`]; without
    /// the view the behavior is byte-identical to the worker-granular
    /// protocol.
    fn decide(&mut self, f: FunctionId, ctx: &mut SchedCtx) -> Decision {
        let Some(d) = ctx.dispatch else {
            let w = self.select(f, ctx);
            return ctx.slotted(w);
        };
        if let Some(w) = self.dequeue_least_loaded(f, ctx.loads) {
            self.pulls += 1;
            return ctx.slotted(w);
        }
        if d.inflight_f > 0 {
            self.enqueues += 1;
            return Decision::Enqueue;
        }
        self.fallbacks += 1;
        let w = self.fallback_select(f, ctx);
        ctx.slotted(w)
    }

    fn on_complete(&mut self, w: WorkerId, f: FunctionId, _ctx: &mut SchedCtx) {
        // Pull mechanism (lines 14-15): the worker proactively signals
        // readiness for new tasks of its last executed function type.
        debug_assert!(w < self.workers);
        self.queue_mut(f).push(w);
    }

    fn on_evict(&mut self, w: WorkerId, f: FunctionId) {
        // Notification mechanism (lines 18-19): remove one occurrence.
        // swap_remove is O(1) and multiset-equivalent to the seed's O(n)
        // shifting remove — see "Multiset invariant" in the module docs.
        self.evict_notifications += 1;
        let q = self.queue_mut(f);
        if let Some(pos) = q.iter().position(|&x| x == w) {
            q.swap_remove(pos);
        }
    }

    fn on_worker_added(&mut self, w: WorkerId) {
        // Pull-based scheduling needs no remapping: the new worker starts
        // pulling as soon as it completes its first (fallback-routed)
        // execution. Propagate to the fallback if one is configured.
        self.workers = self.workers.max(w + 1);
        if let Some(fb) = &mut self.fallback {
            fb.on_worker_added(w);
        }
    }

    fn on_worker_removed(&mut self, w: WorkerId) {
        // Purge every advertisement from the drained worker.
        for q in &mut self.idle_queues {
            q.retain(|&x| x != w);
        }
        self.workers = self.workers.min(w);
        if let Some(fb) = &mut self.fallback {
            fb.on_worker_removed(w);
        }
    }

    fn idle_entries(&self) -> usize {
        self.idle_queues.iter().map(|q| q.len()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::prop::{check, PropConfig};
    use crate::util::rng::Pcg64;

    fn ctx<'a>(loads: &'a [u32], rng: &'a mut Pcg64) -> SchedCtx<'a> {
        SchedCtx::new(loads, rng)
    }

    #[test]
    fn pull_prefers_idle_worker() {
        let mut h = Hiku::new(4);
        let mut rng = Pcg64::new(1);
        let loads = [5u32, 5, 5, 5]; // worker 2 idle-enqueued despite high load
        h.on_complete(2, 7, &mut ctx(&loads, &mut rng));
        let w = h.select(7, &mut ctx(&loads, &mut rng));
        assert_eq!(w, 2, "must pull the enqueued worker");
        assert_eq!(h.pulls, 1);
        assert_eq!(h.fallbacks, 0);
    }

    #[test]
    fn dequeue_is_least_loaded_entry() {
        let mut h = Hiku::new(4);
        let mut rng = Pcg64::new(2);
        let loads = [9u32, 3, 7, 1];
        for w in [0, 1, 2] {
            h.on_complete(w, 0, &mut ctx(&loads, &mut rng));
        }
        // Worker 3 is least loaded overall but NOT enqueued; among the
        // enqueued {0,1,2} the least loaded is 1.
        assert_eq!(h.select(0, &mut ctx(&loads, &mut rng)), 1);
        // Next pull: among {0,2} -> 2.
        assert_eq!(h.select(0, &mut ctx(&loads, &mut rng)), 2);
    }

    #[test]
    fn fallback_when_queue_empty() {
        let mut h = Hiku::new(3);
        let mut rng = Pcg64::new(3);
        let loads = [4u32, 0, 2];
        let w = h.select(9, &mut ctx(&loads, &mut rng));
        assert_eq!(w, 1, "fallback must be least-connections");
        assert_eq!(h.fallbacks, 1);
    }

    #[test]
    fn queues_are_per_function() {
        let mut h = Hiku::new(4);
        let mut rng = Pcg64::new(4);
        let loads = [0u32, 9, 9, 9];
        h.on_complete(3, 5, &mut ctx(&loads, &mut rng));
        // Request for a DIFFERENT function must not consume f=5's entry.
        let w = h.select(6, &mut ctx(&loads, &mut rng));
        assert_eq!(w, 0, "different function must take the fallback path");
        assert_eq!(h.queue_len(5), 1);
        // And the entry is still there for f=5.
        assert_eq!(h.select(5, &mut ctx(&loads, &mut rng)), 3);
    }

    #[test]
    fn eviction_removes_one_occurrence_only() {
        let mut h = Hiku::new(4);
        let mut rng = Pcg64::new(5);
        let loads = [0u32; 4];
        h.on_complete(2, 1, &mut ctx(&loads, &mut rng));
        h.on_complete(2, 1, &mut ctx(&loads, &mut rng)); // two idle instances
        assert_eq!(h.queue_len(1), 2);
        h.on_evict(2, 1);
        assert_eq!(h.queue_len(1), 1, "exactly one occurrence is removed");
        h.on_evict(2, 1);
        assert_eq!(h.queue_len(1), 0);
        // Eviction of a non-enqueued worker is a no-op.
        h.on_evict(0, 1);
        assert_eq!(h.queue_len(1), 0);
    }

    #[test]
    fn multiset_semantics_multiple_workers() {
        let mut h = Hiku::new(3);
        let mut rng = Pcg64::new(6);
        let loads = [1u32, 2, 3];
        h.on_complete(0, 4, &mut ctx(&loads, &mut rng));
        h.on_complete(1, 4, &mut ctx(&loads, &mut rng));
        h.on_complete(2, 4, &mut ctx(&loads, &mut rng));
        assert_eq!(h.select(4, &mut ctx(&loads, &mut rng)), 0);
        assert_eq!(h.select(4, &mut ctx(&loads, &mut rng)), 1);
        assert_eq!(h.select(4, &mut ctx(&loads, &mut rng)), 2);
        assert_eq!(h.fallbacks, 0);
    }

    #[test]
    fn decide_pulls_parks_or_falls_back() {
        use crate::scheduler::DispatchCtx;
        let mut h = Hiku::new(3);
        let mut rng = Pcg64::new(8);
        let loads = [1u32, 0, 2];
        // Warm worker advertised: the pull wins regardless of inflight.
        h.on_complete(2, 4, &mut ctx(&loads, &mut rng));
        let d = {
            let mut c = ctx(&loads, &mut rng)
                .with_dispatch(DispatchCtx { inflight_f: 1, pending_f: 0 });
            h.decide(4, &mut c)
        };
        assert_eq!(d, Decision::Assign(2));
        assert_eq!(h.pulls, 1);
        // PQ_f empty + an execution of f in flight: park the request.
        let d = {
            let mut c = ctx(&loads, &mut rng)
                .with_dispatch(DispatchCtx { inflight_f: 1, pending_f: 0 });
            h.decide(4, &mut c)
        };
        assert_eq!(d, Decision::Enqueue);
        assert_eq!(h.enqueues, 1);
        // PQ_f empty + nothing in flight: immediate fallback, like push.
        let d = {
            let mut c = ctx(&loads, &mut rng).with_dispatch(DispatchCtx::default());
            h.decide(4, &mut c)
        };
        assert_eq!(d, Decision::Assign(1), "fallback must be least-connections");
        // No dispatch context at all: the push adapter.
        assert_eq!(h.decide(4, &mut ctx(&loads, &mut rng)), Decision::Assign(1));
    }

    /// With a slot view attached, both the pull path and the fallback pin
    /// a free warm-affine core via `AssignSlot`; `Enqueue` is unaffected.
    #[test]
    fn decide_pins_warm_core_with_slot_view() {
        use crate::scheduler::{DispatchCtx, SlotCtx};
        let mut h = Hiku::new(3);
        let mut rng = Pcg64::new(12);
        let loads = [1u32, 0, 2];
        let free = [2u32, 2, 2];
        // Pull path: worker 2 advertised with a warm core at slot 1.
        h.on_complete(2, 4, &mut ctx(&loads, &mut rng));
        let warm_free = [-1i32, -1, 1];
        let d = {
            let mut c = ctx(&loads, &mut rng)
                .with_dispatch(DispatchCtx { inflight_f: 1, pending_f: 0 })
                .with_slots(SlotCtx { free: &free, warm_free: &warm_free });
            h.decide(4, &mut c)
        };
        assert_eq!(d, Decision::AssignSlot(2, 1), "pulled worker's warm core pinned");
        // Parking is unchanged by the slot view.
        let d = {
            let mut c = ctx(&loads, &mut rng)
                .with_dispatch(DispatchCtx { inflight_f: 1, pending_f: 0 })
                .with_slots(SlotCtx { free: &free, warm_free: &warm_free });
            h.decide(4, &mut c)
        };
        assert_eq!(d, Decision::Enqueue);
        // Fallback lands on worker 1 (least loaded); no warm core there.
        let d = {
            let mut c = ctx(&loads, &mut rng)
                .with_dispatch(DispatchCtx::default())
                .with_slots(SlotCtx { free: &free, warm_free: &warm_free });
            h.decide(4, &mut c)
        };
        assert_eq!(d, Decision::Assign(1), "no warm core: plain Assign");
    }

    /// Property: a pull never returns a worker that is not enqueued, the
    /// queue shrinks by exactly one per pull, and enqueue/evict/pull
    /// sequences preserve multiset consistency.
    #[test]
    fn prop_queue_consistency() {
        check("hiku-queue-consistency", PropConfig { cases: 200, ..Default::default() }, |rng, size| {
            let workers = 2 + rng.index(6);
            let functions = 1 + rng.index(4);
            let mut h = Hiku::new(workers);
            // Shadow model: multiset per function.
            let mut shadow: Vec<Vec<WorkerId>> = vec![Vec::new(); functions];
            let loads: Vec<u32> = (0..workers).map(|_| rng.next_bounded(10) as u32).collect();
            for _ in 0..size * 4 {
                let f = rng.index(functions);
                match rng.index(3) {
                    0 => {
                        let w = rng.index(workers);
                        let mut c = SchedCtx::new(&loads, rng);
                        h.on_complete(w, f, &mut c);
                        shadow[f].push(w);
                    }
                    1 => {
                        let w = rng.index(workers);
                        h.on_evict(w, f);
                        if let Some(p) = shadow[f].iter().position(|&x| x == w) {
                            shadow[f].remove(p);
                        }
                    }
                    _ => {
                        let was_empty = shadow[f].is_empty();
                        let before = h.queue_len(f);
                        let mut c = SchedCtx::new(&loads, rng);
                        let w = h.select(f, &mut c);
                        prop_assert!(w < workers, "worker {} out of range", w);
                        if was_empty {
                            prop_assert!(
                                h.queue_len(f) == 0,
                                "fallback must not consume queue entries"
                            );
                            prop_assert!(
                                loads[w] == *loads.iter().min().unwrap(),
                                "fallback not least-loaded"
                            );
                        } else {
                            prop_assert!(
                                h.queue_len(f) == before - 1,
                                "pull must consume exactly one entry"
                            );
                            let p = shadow[f].iter().position(|&x| x == w);
                            prop_assert!(p.is_some(), "pulled worker {} not in shadow", w);
                            // Pulled worker must be least-loaded among enqueued.
                            let min_l = shadow[f].iter().map(|&x| loads[x]).min().unwrap();
                            prop_assert!(
                                loads[w] == min_l,
                                "pulled load {} != min enqueued {}",
                                loads[w],
                                min_l
                            );
                            shadow[f].remove(p.unwrap());
                        }
                    }
                }
                // Multiset sizes always agree.
                for (fi, s) in shadow.iter().enumerate() {
                    prop_assert!(
                        h.queue_len(fi) == s.len(),
                        "queue size mismatch f={}: {} vs {}",
                        fi,
                        h.queue_len(fi),
                        s.len()
                    );
                }
            }
            Ok(())
        });
    }
}
