//! PJRT execution engine: load HLO-text artifacts, compile them on the CPU
//! PJRT client, cache executables, run payloads.
//!
//! This is the real-compute backend of a worker: a *cold start* is an
//! actual XLA compilation (tens to hundreds of ms — the same asymmetry the
//! paper's Table I measures for container cold starts), a *warm start* hits
//! the executable cache and only pays execution. The cache is LRU-bounded
//! to model worker memory pressure; evictions surface to the caller so the
//! scheduler's notification mechanism works identically to the simulator.

use super::manifest::{Manifest, PayloadSpec};
use std::time::Instant;

/// One compiled payload held warm in the cache.
struct CacheEntry {
    name: String,
    exe: xla::PjRtLoadedExecutable,
    last_used: u64,
    pub executions: u64,
}

/// Execution result + timing.
#[derive(Clone, Debug, PartialEq)]
pub struct ExecResult {
    /// The payload's output digest (f32[2]).
    pub digest: [f32; 2],
    /// True when this execution compiled the payload (cold start).
    pub cold: bool,
    /// Total handling time (compile if cold + execute), seconds.
    pub total_s: f64,
    /// Compile time (0 for warm starts), seconds.
    pub compile_s: f64,
    /// Names evicted from the cache to admit this payload.
    pub evicted: Vec<String>,
}

/// A PJRT-backed worker engine with an LRU executable cache.
pub struct Engine {
    client: xla::PjRtClient,
    manifest: Manifest,
    cache: Vec<CacheEntry>,
    /// Maximum executables held warm (memory-pressure model).
    capacity: usize,
    tick: u64,
    /// Executions that required compilation (cold starts).
    pub total_cold: u64,
    /// Executions served from the executable cache (warm starts).
    pub total_warm: u64,
}

impl Engine {
    /// Create an engine over the artifact set. `capacity` bounds the
    /// executable cache (>= 1).
    pub fn new(manifest: Manifest, capacity: usize) -> Result<Engine, String> {
        // Silence TfrtCpuClient created/destroyed chatter on stderr.
        if std::env::var_os("TF_CPP_MIN_LOG_LEVEL").is_none() {
            std::env::set_var("TF_CPP_MIN_LOG_LEVEL", "1");
        }
        let client =
            xla::PjRtClient::cpu().map_err(|e| format!("PJRT CPU client: {e:?}"))?;
        Ok(Engine {
            client,
            manifest,
            cache: Vec::new(),
            capacity: capacity.max(1),
            tick: 0,
            total_cold: 0,
            total_warm: 0,
        })
    }

    /// Engine over `<dir>/manifest.json`'s artifact set.
    pub fn from_dir(dir: &str, capacity: usize) -> Result<Engine, String> {
        Ok(Self::new(Manifest::load(dir)?, capacity)?)
    }

    /// The artifact manifest this engine serves.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Whether `name` is currently held warm in the cache.
    pub fn cached(&self, name: &str) -> bool {
        self.cache.iter().any(|e| e.name == name)
    }

    /// Number of executables currently held warm.
    pub fn cache_len(&self) -> usize {
        self.cache.len()
    }

    fn compile(&self, spec: &PayloadSpec) -> Result<xla::PjRtLoadedExecutable, String> {
        let path = spec
            .path
            .to_str()
            .ok_or_else(|| format!("non-utf8 path {}", spec.path.display()))?;
        let proto = xla::HloModuleProto::from_text_file(path)
            .map_err(|e| format!("parse {}: {e:?}", spec.path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.client
            .compile(&comp)
            .map_err(|e| format!("compile {}: {e:?}", spec.name))
    }

    /// Execute `name` with `seed`. Compiles on first touch (cold start),
    /// possibly evicting LRU entries beyond capacity.
    pub fn execute(&mut self, name: &str, seed: u32) -> Result<ExecResult, String> {
        // detlint:allow(R2) -- real PJRT execution: measures actual wall-clock latency
        let t0 = Instant::now();
        self.tick += 1;
        let tick = self.tick;

        let mut evicted = Vec::new();
        let mut compile_s = 0.0;
        let mut cold = false;
        let idx = match self.cache.iter().position(|e| e.name == name) {
            Some(i) => {
                self.total_warm += 1;
                i
            }
            None => {
                cold = true;
                // Cold start: admit (evicting LRU first so peak memory
                // never exceeds capacity), then compile.
                let spec = self
                    .manifest
                    .get(name)
                    .ok_or_else(|| format!("unknown payload '{name}'"))?
                    .clone();
                while self.cache.len() >= self.capacity {
                    let lru = self
                        .cache
                        .iter()
                        .enumerate()
                        .min_by_key(|(_, e)| e.last_used)
                        .map(|(i, _)| i)
                        .unwrap();
                    evicted.push(self.cache.swap_remove(lru).name);
                }
                // detlint:allow(R2) -- real PJRT compile: measures actual wall-clock latency
                let tc = Instant::now();
                let exe = self.compile(&spec)?;
                compile_s = tc.elapsed().as_secs_f64();
                self.total_cold += 1;
                self.cache.push(CacheEntry {
                    name: name.to_string(),
                    exe,
                    last_used: tick,
                    executions: 0,
                });
                self.cache.len() - 1
            }
        };
        let entry = &mut self.cache[idx];
        entry.last_used = tick;
        entry.executions += 1;

        let input = xla::Literal::scalar(seed);
        let bufs = entry
            .exe
            .execute::<xla::Literal>(&[input])
            .map_err(|e| format!("execute {name}: {e:?}"))?;
        let lit = bufs[0][0]
            .to_literal_sync()
            .map_err(|e| format!("readback {name}: {e:?}"))?;
        // aot.py lowers with return_tuple=True: unwrap the 1-tuple.
        let out = lit.to_tuple1().map_err(|e| format!("untuple {name}: {e:?}"))?;
        let v = out.to_vec::<f32>().map_err(|e| format!("to_vec {name}: {e:?}"))?;
        if v.len() != 2 {
            return Err(format!("{name}: expected f32[2] digest, got len {}", v.len()));
        }
        Ok(ExecResult {
            digest: [v[0], v[1]],
            cold,
            total_s: t0.elapsed().as_secs_f64(),
            compile_s,
            evicted,
        })
    }

    /// Verify every payload against its manifest goldens. Returns the
    /// number of (payload, golden) pairs checked.
    pub fn verify_goldens(&mut self) -> Result<usize, String> {
        let checks: Vec<(String, u32, [f32; 2])> = self
            .manifest
            .payloads
            .iter()
            .flat_map(|p| p.goldens.iter().map(|g| (p.name.clone(), g.seed, g.digest)))
            .collect();
        let mut n = 0;
        for (name, seed, want) in checks {
            let got = self.execute(&name, seed)?.digest;
            for i in 0..2 {
                let (g, w) = (got[i], want[i]);
                let tol = 1e-4 * w.abs().max(1.0);
                if (g - w).abs() > tol {
                    return Err(format!(
                        "golden mismatch {name} seed {seed}: got {got:?}, want {want:?}"
                    ));
                }
            }
            n += 1;
        }
        Ok(n)
    }
}

#[cfg(test)]
mod tests {
    //! Engine tests require built artifacts; they skip (pass vacuously)
    //! when `make artifacts` has not run. The integration test suite in
    //! rust/tests/ runs them against the real artifact set.
    use super::*;

    fn engine(cap: usize) -> Option<Engine> {
        Manifest::load("artifacts").ok().map(|m| Engine::new(m, cap).unwrap())
    }

    #[test]
    fn cold_then_warm_and_digest_stable() {
        let Some(mut e) = engine(8) else { return };
        let r1 = e.execute("matmul", 42).unwrap();
        assert!(r1.cold && r1.compile_s > 0.0);
        let r2 = e.execute("matmul", 42).unwrap();
        assert!(!r2.cold && r2.compile_s == 0.0);
        assert_eq!(r1.digest, r2.digest, "execution must be deterministic");
        assert!(r1.total_s > r2.total_s, "cold must cost more than warm");
    }

    #[test]
    fn lru_eviction_at_capacity() {
        let Some(mut e) = engine(2) else { return };
        e.execute("matmul", 1).unwrap();
        e.execute("pyaes", 1).unwrap();
        let r = e.execute("dd", 1).unwrap(); // evicts matmul (LRU)
        assert_eq!(r.evicted, vec!["matmul".to_string()]);
        assert!(e.cached("pyaes") && e.cached("dd") && !e.cached("matmul"));
        assert_eq!(e.cache_len(), 2);
    }

    #[test]
    fn unknown_payload_errors() {
        let Some(mut e) = engine(2) else { return };
        assert!(e.execute("nope", 1).is_err());
    }
}
