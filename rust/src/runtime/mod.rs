//! PJRT runtime: load AOT-compiled HLO-text artifacts (emitted once at
//! build time by `python/compile/aot.py`) and execute them from the Rust
//! request path. Python is never on the hot path.
//!
//! Pattern adapted from /opt/xla-example/src/bin/load_hlo.rs:
//! `PjRtClient::cpu()` -> `HloModuleProto::from_text_file` ->
//! `XlaComputation::from_proto` -> `client.compile` -> `execute`.

pub mod engine;
pub mod manifest;

pub use engine::{Engine, ExecResult};
pub use manifest::{Golden, Manifest, PayloadSpec};

/// Returns the PJRT platform name of a freshly created CPU client (smoke).
pub fn platform_name() -> Result<String, String> {
    let client = xla::PjRtClient::cpu().map_err(|e| format!("{e:?}"))?;
    Ok(client.platform_name())
}
