//! AOT manifest: metadata for the artifacts emitted by `python/compile/aot.py`
//! (payload names, I/O specs, golden digests for numeric verification).

use crate::util::json::Json;
use std::path::{Path, PathBuf};

/// A golden check: digest of a payload's output for a known input seed.
#[derive(Clone, Debug, PartialEq)]
pub struct Golden {
    /// Input seed the digest was computed for.
    pub seed: u32,
    /// (sum, sum-of-squares)-style output digest from the AOT pipeline.
    pub digest: [f32; 2],
}

/// One AOT-compiled payload and its verification metadata.
#[derive(Clone, Debug, PartialEq)]
pub struct PayloadSpec {
    /// Payload (function) name.
    pub name: String,
    /// Absolute path to the HLO text artifact.
    pub path: PathBuf,
    /// Golden digests for numeric verification.
    pub goldens: Vec<Golden>,
    /// Size of the HLO artifact in bytes (compile-cost proxy).
    pub hlo_bytes: u64,
}

/// The artifact manifest emitted by `python/compile/aot.py`.
#[derive(Clone, Debug, PartialEq)]
pub struct Manifest {
    /// Every payload in the artifact set.
    pub payloads: Vec<PayloadSpec>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &str) -> Result<Manifest, String> {
        let dir = Path::new(dir);
        let mpath = dir.join("manifest.json");
        let text = std::fs::read_to_string(&mpath).map_err(|e| {
            format!(
                "cannot read {} ({e}); run `make artifacts` first",
                mpath.display()
            )
        })?;
        let j = Json::parse(&text).map_err(|e| format!("manifest parse: {e}"))?;
        Self::from_json(&j, dir)
    }

    /// Parse a manifest document; artifact paths resolve relative to `dir`.
    pub fn from_json(j: &Json, dir: &Path) -> Result<Manifest, String> {
        let fmt = j.get("format").and_then(|f| f.as_str()).unwrap_or("");
        if fmt != "hlo-text" {
            return Err(format!("unsupported artifact format '{fmt}' (want hlo-text)"));
        }
        let payloads = j
            .get("payloads")
            .and_then(|p| p.as_arr())
            .ok_or("manifest missing payloads[]")?;
        let mut out = Vec::new();
        for p in payloads {
            let name = p
                .get("name")
                .and_then(|v| v.as_str())
                .ok_or("payload missing name")?
                .to_string();
            let artifact = p
                .get("artifact")
                .and_then(|v| v.as_str())
                .ok_or("payload missing artifact")?;
            let mut goldens = Vec::new();
            if let Some(gs) = p.get("goldens").and_then(|g| g.as_arr()) {
                for g in gs {
                    let seed = g
                        .get("seed")
                        .and_then(|v| v.as_u64())
                        .ok_or("golden missing seed")? as u32;
                    let d = g
                        .get("digest")
                        .and_then(|v| v.as_arr())
                        .ok_or("golden missing digest")?;
                    if d.len() != 2 {
                        return Err("digest must have 2 entries".into());
                    }
                    let digest = [
                        d[0].as_f64().ok_or("bad digest[0]")? as f32,
                        d[1].as_f64().ok_or("bad digest[1]")? as f32,
                    ];
                    goldens.push(Golden { seed, digest });
                }
            }
            out.push(PayloadSpec {
                name,
                path: dir.join(artifact),
                goldens,
                hlo_bytes: p.get("hlo_bytes").and_then(|v| v.as_u64()).unwrap_or(0),
            });
        }
        if out.is_empty() {
            return Err("manifest has no payloads".into());
        }
        Ok(Manifest { payloads: out })
    }

    /// Look up a payload by name.
    pub fn get(&self, name: &str) -> Option<&PayloadSpec> {
        self.payloads.iter().find(|p| p.name == name)
    }

    /// All payload names, in manifest order.
    pub fn names(&self) -> Vec<&str> {
        self.payloads.iter().map(|p| p.name.as_str()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
        "format": "hlo-text",
        "payloads": [
            {"name": "matmul", "artifact": "matmul.hlo.txt",
             "input": {"dtype": "u32", "shape": []},
             "output": {"dtype": "f32", "shape": [2], "tuple": true},
             "goldens": [{"seed": 42, "digest": [0.25, 64.0]},
                          {"seed": 7, "digest": [0.5, 32.0]}],
             "hlo_bytes": 100}
        ]
    }"#;

    #[test]
    fn parses_sample() {
        let j = Json::parse(SAMPLE).unwrap();
        let m = Manifest::from_json(&j, Path::new("/tmp/a")).unwrap();
        assert_eq!(m.payloads.len(), 1);
        let p = m.get("matmul").unwrap();
        assert_eq!(p.path, PathBuf::from("/tmp/a/matmul.hlo.txt"));
        assert_eq!(p.goldens.len(), 2);
        assert_eq!(p.goldens[0].seed, 42);
        assert!(m.get("nope").is_none());
    }

    #[test]
    fn rejects_bad_format() {
        let j = Json::parse(r#"{"format": "proto", "payloads": []}"#).unwrap();
        assert!(Manifest::from_json(&j, Path::new("/tmp")).is_err());
    }

    #[test]
    fn real_manifest_if_built() {
        // Integration: parse the actual artifacts/ if `make artifacts` ran.
        if let Ok(m) = Manifest::load("artifacts") {
            assert_eq!(m.payloads.len(), 8);
            for p in &m.payloads {
                assert!(p.path.exists(), "{} missing", p.path.display());
                assert_eq!(p.goldens.len(), 2);
            }
        }
    }
}
